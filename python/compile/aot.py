"""AOT lowering: jax -> HLO *text* artifacts for the Rust PJRT runtime.

Emits HLO text (NOT ``.serialize()``): jax >= 0.5 serializes HloModuleProto
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the HLO text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
(idempotent; invoked by ``make artifacts``).
"""

import argparse
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact name filter"
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    count = 0
    for name, fn, example_args in model.artifact_specs():
        if only is not None and name not in only:
            continue
        path = os.path.join(args.out, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        count += 1
        print(f"  [{time.time() - t0:6.1f}s] {name}: {len(text)} chars")

    manifest = os.path.join(args.out, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(model.manifest_lines()) + "\n")
    print(f"wrote {count} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
