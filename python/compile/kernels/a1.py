"""L1 Pallas kernel for Algorithm A1: exact constrained episode counting.

Implements the paper's Algorithm 1 — non-overlapped occurrence counting of
a serial episode with full ``(t_low, t_high]`` inter-event constraints —
vectorized across a block of episodes. With a strict lower bound the most
recent timestamp no longer dominates (a too-recent entry fails ``> t_low``
where an older one passes), so each level keeps a bounded list of the K
most recent occurrence times. This mirrors the paper's GPU version, whose
lists are bounded by the 16 KB shared-memory budget (220 B per thread at
N=5); here the bound is the VMEM tile ``[B, N, K]``.

The list is stored most-recent-first; Algorithm 1 searches latest-first and
stops at the first entry satisfying the constraint, and since only the
*existence* of a satisfying entry matters (the current event time ``t`` is
what gets appended), the vectorized form reduces the search to a masked
``any`` over the K lanes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import NEG

# Events per loop iteration (see a2.py UNROLL — amortizes the XLA CPU
# while-loop's fixed per-iteration overhead).
UNROLL = 8


def _push_front(lst, t, mask):
    """Push scalar time ``t`` onto the front of ``[B, K]`` lists where
    ``mask`` (``[B]``) holds; the oldest entry falls off the end."""
    b = lst.shape[0]
    shifted = jnp.concatenate(
        [jnp.full((b, 1), t, jnp.int32), lst[:, :-1]], axis=1
    )
    return jnp.where(mask[:, None], shifted, lst)


def _a1_block_kernel(
    n_levels,
    types_ref,
    tlow_ref,
    thigh_ref,
    evt_ref,
    evtime_ref,
    s_ref,
    cnt_ref,
    s_out_ref,
    cnt_out_ref,
):
    """Count one episode block over one event chunk.

    Carried state ``s`` is ``[B, N, K]`` timestamps (NEG = empty slot) and
    ``cnt`` is ``[B]``.
    """
    types = types_ref[...]
    tlow = tlow_ref[...]
    thigh = thigh_ref[...]
    ev_t = evt_ref[...]
    ev_tm = evtime_ref[...]
    s0 = s_ref[...]
    c0 = cnt_ref[...]
    chunk = ev_t.shape[0]
    n = n_levels

    def one_event(s, cnt, e, t):
        done = jnp.zeros(s.shape[0], dtype=jnp.bool_)
        for i in range(n - 1, -1, -1):
            m = (types[:, i] == e) & ~done
            if i == 0:
                # First level: every matching event is recorded (Alg. 1
                # line 19); the K-bound keeps the most recent K.
                s = s.at[:, 0, :].set(_push_front(s[:, 0, :], t, m))
            else:
                d = t - s[:, i - 1, :]  # [B, K]
                okk = (d > tlow[:, i - 1, None]) & (d <= thigh[:, i - 1, None])
                found = m & okk.any(axis=1)
                if i == n - 1:
                    cnt = cnt + found.astype(jnp.int32)
                    s = jnp.where(found[:, None, None], NEG, s)
                    done = done | found
                else:
                    s = s.at[:, i, :].set(_push_front(s[:, i, :], t, found))
        return s, cnt

    def step(j, carry):
        s, cnt = carry
        base = j * UNROLL
        for u in range(UNROLL):
            s, cnt = one_event(s, cnt, ev_t[base + u], ev_tm[base + u])
        return s, cnt

    if chunk % UNROLL != 0:
        raise ValueError(f"chunk {chunk} not a multiple of UNROLL {UNROLL}")
    s, cnt = jax.lax.fori_loop(0, chunk // UNROLL, step, (s0, c0))
    s_out_ref[...] = s
    cnt_out_ref[...] = cnt


def a1_count(types, tlow, thigh, ev_type, ev_time, s_in, cnt_in, *, block=128):
    """Run the A1 kernel over a batch of episodes and one event chunk.

    Args:
      types: ``[M, N]`` int32 episode event types (pad lanes with EP_PAD).
      tlow / thigh: ``[M, N-1]`` int32 inter-event constraint bounds.
      ev_type / ev_time: ``[C]`` int32 event chunk (pad with EV_PAD).
      s_in: ``[M, N, K]`` int32 carried lists (init: NEG).
      cnt_in: ``[M]`` int32 carried counts (init: 0).
      block: episode lanes per grid program.

    Returns:
      ``(s_out, cnt_out)`` with the same shapes as ``(s_in, cnt_in)``.
    """
    m, n = types.shape
    k = s_in.shape[2]
    chunk = ev_type.shape[0]
    if m % block != 0:
        raise ValueError(f"episode batch {m} not a multiple of block {block}")
    kernel = functools.partial(_a1_block_kernel, n)
    return pl.pallas_call(
        kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block, n), lambda i: (i, 0)),
            pl.BlockSpec((block, n - 1), lambda i: (i, 0)),
            pl.BlockSpec((block, n - 1), lambda i: (i, 0)),
            pl.BlockSpec((chunk,), lambda i: (0,)),
            pl.BlockSpec((chunk,), lambda i: (0,)),
            pl.BlockSpec((block, n, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block, n, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n, k), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=True,
    )(types, tlow, thigh, ev_type, ev_time, s_in, cnt_in)
