"""L1 Pallas kernel for Algorithm A2: less-constrained episode counting.

A2 (paper Algorithm 3 / Observation 5.1) counts non-overlapped occurrences
of a serial episode when the *lower* bounds of the inter-event constraints
are relaxed to 0. With only upper bounds, each level's occurrence list
collapses to a single timestamp (the most recent one dominates), so the
per-episode state is ``[N]`` int32 instead of ``[N, K]`` — this is the
"cheap first pass" of the paper's two-pass elimination approach.

Hardware adaptation (GTX280 -> TPU-style Pallas): a CUDA thread holding one
episode's automaton in registers/shared memory becomes one *lane* of a
``[B]``-wide episode block held in VMEM. The event chunk is scanned with an
in-kernel ``fori_loop``; each step performs masked compare/select rows
across all lanes, which is how SIMT branch divergence is rephrased for a
vector unit. State (``s`` and counts) is threaded in/out of the kernel so
the Rust runtime can stream arbitrarily long event sequences through a
fixed-shape executable chunk by chunk.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import NEG

# Events processed per loop iteration. The XLA CPU while-loop carries a
# fixed per-iteration overhead that dwarfs the per-event vector work at
# B=128 lanes; unrolling 8 events per iteration amortizes it ~8x (see
# EXPERIMENTS.md §Perf L1). The chunk length must be a multiple of this.
UNROLL = 8


def _a2_block_kernel(
    n_levels,
    types_ref,
    thigh_ref,
    evt_ref,
    evtime_ref,
    s_ref,
    cnt_ref,
    s_out_ref,
    cnt_out_ref,
):
    """Count one episode block over one event chunk.

    Block shapes: types ``[B, N]``, thigh ``[B, N-1]``, events ``[C]``
    (whole chunk, shared by every grid program), carried state ``s`` is
    ``[B, N]`` timestamps and ``cnt`` is ``[B]``.
    """
    types = types_ref[...]
    thigh = thigh_ref[...]
    ev_t = evt_ref[...]
    ev_tm = evtime_ref[...]
    s0 = s_ref[...]
    c0 = cnt_ref[...]
    chunk = ev_t.shape[0]
    n = n_levels

    def one_event(s, cnt, e, t):
        # `done` lanes completed an occurrence with this event: the serial
        # algorithm consumes the event entirely (Alg. 1 line 13 breaks to
        # the next event), so lower levels must not also use it.
        done = jnp.zeros(s.shape[0], dtype=jnp.bool_)
        # Walk levels from last to first so an event cannot serve two
        # adjacent levels of the same episode at one timestamp.
        for i in range(n - 1, -1, -1):
            m = (types[:, i] == e) & ~done
            if i == 0:
                # First level accepts unconditionally (Alg. 3 line 14).
                s = s.at[:, 0].set(jnp.where(m, t, s[:, 0]))
            else:
                d = t - s[:, i - 1]
                # [0, t_high] — the paper's Algorithm 3 (line 8) checks only
                # the upper bound. Allowing d == 0 (simultaneous events) is
                # what makes the single-timestamp state sound (Observation
                # 5.1 keeps only the *latest* entry, which can tie with t)
                # and keeps Theorem 5.1's count(a') >= count(a) true on
                # streams with tied timestamps. The NEG empty sentinel fails
                # the upper bound (its delta exceeds any t_high).
                ok = m & (d >= 0) & (d <= thigh[:, i - 1])
                if i == n - 1:
                    cnt = cnt + ok.astype(jnp.int32)
                    # Non-overlapped count: full state reset on completion.
                    s = jnp.where(ok[:, None], NEG, s)
                    done = done | ok
                else:
                    s = s.at[:, i].set(jnp.where(ok, t, s[:, i]))
        return s, cnt

    def step(j, carry):
        s, cnt = carry
        base = j * UNROLL
        for u in range(UNROLL):
            s, cnt = one_event(s, cnt, ev_t[base + u], ev_tm[base + u])
        return s, cnt

    if chunk % UNROLL != 0:
        raise ValueError(f"chunk {chunk} not a multiple of UNROLL {UNROLL}")
    s, cnt = jax.lax.fori_loop(0, chunk // UNROLL, step, (s0, c0))
    s_out_ref[...] = s
    cnt_out_ref[...] = cnt


def a2_count(types, thigh, ev_type, ev_time, s_in, cnt_in, *, block=128):
    """Run the A2 kernel over a batch of episodes and one event chunk.

    Args:
      types: ``[M, N]`` int32 episode event types (pad lanes with EP_PAD).
      thigh: ``[M, N-1]`` int32 upper inter-event bounds.
      ev_type / ev_time: ``[C]`` int32 event chunk (pad with EV_PAD).
      s_in: ``[M, N]`` int32 carried automaton state (init: NEG).
      cnt_in: ``[M]`` int32 carried counts (init: 0).
      block: episode lanes per grid program (VMEM tile height).

    Returns:
      ``(s_out, cnt_out)`` with the same shapes as ``(s_in, cnt_in)``.
    """
    m, n = types.shape
    chunk = ev_type.shape[0]
    if m % block != 0:
        raise ValueError(f"episode batch {m} not a multiple of block {block}")
    kernel = functools.partial(_a2_block_kernel, n)
    return pl.pallas_call(
        kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block, n), lambda i: (i, 0)),
            pl.BlockSpec((block, n - 1), lambda i: (i, 0)),
            pl.BlockSpec((chunk,), lambda i: (0,)),
            pl.BlockSpec((chunk,), lambda i: (0,)),
            pl.BlockSpec((block, n), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block, n), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=True,
    )(types, thigh, ev_type, ev_time, s_in, cnt_in)
