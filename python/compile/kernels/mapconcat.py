"""L1 Pallas kernel for the Map step of MapConcatenate (paper §5.2.2).

When the number of candidate episodes is too small to fill the machine
with per-lane episodes (the PTPE regime), the paper parallelizes *within*
one episode: the event stream is split into P segments and each segment is
counted locally, with N state machines per segment — one per way an
occurrence can straddle the boundary (machine k starts at
``tau_p - sum_{i<=k} t_high_i``, Fig. 4/5). Each machine emits a tuple
``(a, count, b)``:

- ``count`` — occurrences completing in ``(tau_p, tau_{p+1}]``,
- ``a``     — end time of the machine's first completion in
              ``(tau_p, tau_p + sum t_high)``, else the sentinel ``tau_p``,
- ``b``     — end time of the one *crossing* occurrence the machine chases
              past the segment end (completing before
              ``tau_{p+1} + sum t_high``, not counted), else the sentinel
              ``tau_{p+1}``.

The Concatenate step (owned by the Rust coordinator, ``coordinator/
mapconcat.rs``) chains tuples of adjacent segments by matching
``b_s^k == a_t^l``; sentinels are constructed so that "no crossing
occurrence" chains with "first completion unaffected by the boundary".

Grid is ``(episodes, segments)``; each program runs its segment's N
machines as an ``[N_machines]``-wide vector automaton (each machine itself
holding ``[N, K]`` bounded lists, as in A1). The program scans from the
previous segment's first event (machines start before ``tau_p``) until
``tau_{p+1} + sum t_high`` — the Map step reads adjacent segments, which is
exactly why the paper distinguishes MapConcatenate from MapReduce.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import NEG

# Events per loop iteration (see a2.py UNROLL). Sub-events past the scan
# window are masked off rather than branched over.
UNROLL = 8


def _mapcat_kernel(
    n_levels,
    k_slots,
    types_ref,
    tlow_ref,
    thigh_ref,
    evt_ref,
    evtime_ref,
    taus_ref,
    seglo_ref,
    a_ref,
    cnt_ref,
    b_ref,
):
    n = n_levels
    k = k_slots
    p = pl.program_id(1)
    types = types_ref[0, :]  # [N]
    tlow = tlow_ref[0, :]  # [N-1]
    thigh = thigh_ref[0, :]
    ev = evt_ref[...]
    tm = evtime_ref[...]
    taus = taus_ref[...]
    chunk = ev.shape[0]

    tau_p = taus[p]
    tau_p1 = taus[p + 1]
    sumh = jnp.sum(thigh)
    # Machine k starts observing at tau_p - sum_{i=1..k} t_high_i (Fig. 4).
    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(thigh)])
    start = tau_p - cum[:n]  # [N] machine start times
    stop = tau_p1 + sumh
    lo = seglo_ref[p]

    init = (
        lo,
        jnp.full((n, n, k), NEG, jnp.int32),  # s[machine, level, slot]
        jnp.zeros((n,), jnp.int32),  # count
        jnp.full((n,), tau_p, jnp.int32),  # a (sentinel tau_p)
        jnp.full((n,), tau_p1, jnp.int32),  # b (sentinel tau_{p+1})
        jnp.zeros((n,), jnp.bool_),  # frozen: b recorded
        jnp.zeros((n,), jnp.bool_),  # a_window_closed
    )

    def cond(carry):
        idx = carry[0]
        t = tm[jnp.minimum(idx, chunk - 1)]
        # Inclusive: a crossing occurrence can complete at exactly
        # tau_{p+1} + sum t_high (its first event exactly on the boundary).
        # The paper's strict "<" (step 4) drops it and desynchronizes the
        # b == a chain; see DESIGN.md §6 (MapConcatenate fidelity).
        return (idx < chunk) & (t <= stop)

    def one_event(state, e, t, valid):
        s, cnt, a, b, frozen, a_closed = state
        active = valid & (t > start) & ~frozen  # [N] machines
        done = jnp.zeros((n,), jnp.bool_)
        for i in range(n - 1, -1, -1):
            m = active & ~done & (types[i] == e)
            if i == 0:
                shifted = jnp.concatenate(
                    [jnp.full((n, 1), t, jnp.int32), s[:, 0, :-1]], axis=1
                )
                s = s.at[:, 0, :].set(
                    jnp.where(m[:, None], shifted, s[:, 0, :])
                )
            else:
                d = t - s[:, i - 1, :]  # [N, K]
                okk = (d > tlow[i - 1]) & (d <= thigh[i - 1])
                found = m & okk.any(axis=1)
                if i == n - 1:
                    # Completion at time t for machines in `found`.
                    in_count = found & (t > tau_p) & (t <= tau_p1)
                    cnt = cnt + in_count.astype(jnp.int32)
                    # inclusive window, mirroring the crossing (`b`) window
                    set_a = in_count & ~a_closed & (t <= tau_p + sumh)
                    a = jnp.where(set_a, t, a)
                    # Only the *first* completion can define `a`; a first
                    # completion beyond the straddle window leaves the
                    # sentinel in place.
                    a_closed = a_closed | in_count
                    cross = found & (t > tau_p1)
                    b = jnp.where(cross, t, b)
                    frozen = frozen | cross
                    s = jnp.where(found[:, None, None], NEG, s)
                    done = done | found
                else:
                    shifted = jnp.concatenate(
                        [jnp.full((n, 1), t, jnp.int32), s[:, i, :-1]],
                        axis=1,
                    )
                    s = s.at[:, i, :].set(
                        jnp.where(found[:, None], shifted, s[:, i, :])
                    )
        return (s, cnt, a, b, frozen, a_closed)

    def body(carry):
        idx, s, cnt, a, b, frozen, a_closed = carry
        state = (s, cnt, a, b, frozen, a_closed)
        for u in range(UNROLL):
            j = idx + u
            jc = jnp.minimum(j, chunk - 1)
            e = ev[jc]
            t = tm[jc]
            # sub-events past the chunk or scan window are masked, not
            # branched (SIMT style)
            valid = (j < chunk) & (t <= stop)
            state = one_event(state, e, t, valid)
        s, cnt, a, b, frozen, a_closed = state
        return (idx + UNROLL, s, cnt, a, b, frozen, a_closed)

    _, _, cnt, a, b, _, _ = jax.lax.while_loop(cond, body, init)
    a_ref[0, 0, :] = a
    cnt_ref[0, 0, :] = cnt
    b_ref[0, 0, :] = b


def mapcat_map(types, tlow, thigh, ev_type, ev_time, taus, seg_lo, *, k_slots=8):
    """Run the Map step for a batch of episodes over one event chunk.

    Args:
      types: ``[E, N]`` int32 episode event types.
      tlow / thigh: ``[E, N-1]`` int32 constraint bounds.
      ev_type / ev_time: ``[C]`` int32 events, time-sorted (pad EV_PAD with
        time = last real time so padded events sit past every window).
      taus: ``[P+1]`` int32 segment boundary times; counting window of
        segment p is ``(taus[p], taus[p+1]]``; ``taus[0]`` must precede the
        first event, ``taus[P]`` must be >= the last event time.
      seg_lo: ``[P]`` int32 scan-start event index per segment (the first
        event of segment p-1; 0 for p = 0) — machines start before
        ``tau_p`` and need the previous segment's tail.
      k_slots: bounded list length per level (as in A1).

    Returns:
      ``(a, cnt, b)`` each ``[E, P, N]`` int32 — per episode, segment, and
      boundary-machine.
    """
    e_count, n = types.shape
    p_count = taus.shape[0] - 1
    chunk = ev_type.shape[0]
    kernel = functools.partial(_mapcat_kernel, n, k_slots)
    return pl.pallas_call(
        kernel,
        grid=(e_count, p_count),
        in_specs=[
            pl.BlockSpec((1, n), lambda e, p: (e, 0)),
            pl.BlockSpec((1, n - 1), lambda e, p: (e, 0)),
            pl.BlockSpec((1, n - 1), lambda e, p: (e, 0)),
            pl.BlockSpec((chunk,), lambda e, p: (0,)),
            pl.BlockSpec((chunk,), lambda e, p: (0,)),
            pl.BlockSpec((p_count + 1,), lambda e, p: (0,)),
            pl.BlockSpec((p_count,), lambda e, p: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n), lambda e, p: (e, p, 0)),
            pl.BlockSpec((1, 1, n), lambda e, p: (e, p, 0)),
            pl.BlockSpec((1, 1, n), lambda e, p: (e, p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e_count, p_count, n), jnp.int32),
            jax.ShapeDtypeStruct((e_count, p_count, n), jnp.int32),
            jax.ShapeDtypeStruct((e_count, p_count, n), jnp.int32),
        ],
        interpret=True,
    )(types, tlow, thigh, ev_type, ev_time, taus, seg_lo)
