"""Pure-Python correctness oracles for the L1 kernels.

These implement the paper's serial algorithms directly from the pseudocode
(Algorithm 1 and Algorithm 3) with no vectorization tricks, and are the
ground truth every kernel is tested against. They are also mirrored by the
Rust reference implementations in ``rust/src/mining/`` — the same fixture
vectors are asserted on both sides (see ``python/tests/test_fixtures.py``
and ``rust/tests/cross_fixtures.rs``).
"""


def count_serial(types, tlow, thigh, ev, tm):
    """Paper Algorithm 1: exact non-overlapped count, unbounded lists.

    ``types`` is the episode's event-type tuple; ``tlow``/``thigh`` are the
    N-1 inter-event constraint bounds ``(t_low, t_high]``; ``ev``/``tm``
    the time-sorted event stream.
    """
    n = len(types)
    if n == 1:
        return sum(1 for e in ev if e == types[0])
    count = 0
    s = [[] for _ in range(n)]
    for e, t in zip(ev, tm):
        completed = False
        for i in range(n - 1, -1, -1):
            if e != types[i]:
                continue
            if i == 0:
                s[0].append(t)
            else:
                # Search latest-first; stop at the first satisfying entry.
                for tp in reversed(s[i - 1]):
                    d = t - tp
                    if tlow[i - 1] < d <= thigh[i - 1]:
                        if i == n - 1:
                            count += 1
                            s = [[] for _ in range(n)]
                            completed = True
                        else:
                            s[i].append(t)
                        break
            if completed:
                break
    return count


def count_serial_bounded(types, tlow, thigh, ev, tm, k):
    """Algorithm 1 with lists bounded to the K most recent entries.

    This matches the GPU/Pallas A1 kernel bit-for-bit (the kernel's
    fixed-size ``[N, K]`` state is exactly "keep the K most recent").
    """
    n = len(types)
    if n == 1:
        return sum(1 for e in ev if e == types[0])
    count = 0
    s = [[] for _ in range(n)]
    for e, t in zip(ev, tm):
        completed = False
        for i in range(n - 1, -1, -1):
            if e != types[i]:
                continue
            if i == 0:
                s[0].append(t)
                if len(s[0]) > k:
                    s[0].pop(0)
            else:
                for tp in reversed(s[i - 1]):
                    d = t - tp
                    if tlow[i - 1] < d <= thigh[i - 1]:
                        if i == n - 1:
                            count += 1
                            s = [[] for _ in range(n)]
                            completed = True
                        else:
                            s[i].append(t)
                            if len(s[i]) > k:
                                s[i].pop(0)
                        break
            if completed:
                break
    return count


def count_a2_serial(types, thigh, ev, tm):
    """Paper Algorithm 3: relaxed counting, single timestamp per level."""
    n = len(types)
    if n == 1:
        return sum(1 for e in ev if e == types[0])
    count = 0
    s = [None] * n
    for e, t in zip(ev, tm):
        completed = False
        for i in range(n - 1, -1, -1):
            if e != types[i]:
                continue
            if i == 0:
                s[0] = t
            else:
                tp = s[i - 1]
                # [0, t_high]: Algorithm 3 checks only the upper bound; see
                # the A2 kernel for why d == 0 must be admitted.
                if tp is not None and 0 <= t - tp <= thigh[i - 1]:
                    if i == n - 1:
                        count += 1
                        s = [None] * n
                        completed = True
                    else:
                        s[i] = t
            if completed:
                break
    return count


def mapcat_map_serial(types, tlow, thigh, ev, tm, taus, k):
    """Reference Map step: per segment p, run the N boundary machines and
    emit ``(a, count, b)`` tuples. Mirrors the kernel semantics exactly
    (bounded-K lists, sentinels a=tau_p / b=tau_{p+1})."""
    n = len(types)
    p_count = len(taus) - 1
    sumh = sum(thigh)
    out = []
    for p in range(p_count):
        tau_p, tau_p1 = taus[p], taus[p + 1]
        stop = tau_p1 + sumh
        tuples = []
        for mk in range(n):
            start = tau_p - sum(thigh[:mk])
            s = [[] for _ in range(n)]
            cnt = 0
            a, b = tau_p, tau_p1
            a_closed = False
            frozen = False
            for e, t in zip(ev, tm):
                # inclusive stop: crossing completions at exactly
                # tau_{p+1} + sum(thigh) must be observed (see kernel docs)
                if t > stop or frozen:
                    break
                if t <= start:
                    continue
                completed = False
                for i in range(n - 1, -1, -1):
                    if e != types[i]:
                        continue
                    if i == 0:
                        s[0].append(t)
                        if len(s[0]) > k:
                            s[0].pop(0)
                    else:
                        for tp in reversed(s[i - 1]):
                            d = t - tp
                            if tlow[i - 1] < d <= thigh[i - 1]:
                                if i == n - 1:
                                    completed = True
                                else:
                                    s[i].append(t)
                                    if len(s[i]) > k:
                                        s[i].pop(0)
                                break
                    if completed:
                        break
                if completed:
                    s = [[] for _ in range(n)]
                    if tau_p < t <= tau_p1:
                        cnt += 1
                        if not a_closed and t <= tau_p + sumh:
                            a = t
                        a_closed = True
                    elif t > tau_p1:
                        b = t
                        frozen = True
            tuples.append((a, cnt, b))
        out.append(tuples)
    return out


def concatenate_fold(tuples):
    """Concatenate step as a left fold: start from segment 0's machine 0
    (the true stream-start automaton) and chain ``b == a`` matches.

    Returns ``(total_count, misses)`` where ``misses`` counts segments with
    no matching machine (falls back to machine 0 — measured, see
    DESIGN.md §6 MapConcatenate fidelity)."""
    total = tuples[0][0][1]
    cur_b = tuples[0][0][2]
    misses = 0
    for p in range(1, len(tuples)):
        for a, cnt, b in tuples[p]:
            if a == cur_b:
                total += cnt
                cur_b = b
                break
        else:
            misses += 1
            a, cnt, b = tuples[p][0]
            total += cnt
            cur_b = b
    return total, misses


def concatenate_tree(tuples):
    """Concatenate step as the paper's log-tree merge (§5.2.2 step 2-3):
    adjacent segment pairs are merged level by level; a left tuple
    ``(a, c, b)`` joins the right tuple ``(a', c', b')`` with ``a' == b``.

    Left tuples with no right match keep their count and take the right
    side's machine-0 continuation (the same fallback the fold uses).
    Returns ``(total_count, misses)``.
    """
    level = [list(seg) for seg in tuples]
    misses = 0
    while len(level) > 1:
        nxt = []
        for j in range(0, len(level) - 1, 2):
            left, right = level[j], level[j + 1]
            merged = []
            for a, c, b in left:
                hit = None
                for a2, c2, b2 in right:
                    if a2 == b:
                        hit = (a, c + c2, b2)
                        break
                if hit is None:
                    misses += 1
                    a2, c2, b2 = right[0]
                    hit = (a, c + c2, b2)
                merged.append(hit)
            nxt.append(merged)
        if len(level) % 2 == 1:
            nxt.append(level[-1])
        level = nxt
    return level[0][0][1], misses
