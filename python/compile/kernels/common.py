"""Shared constants and conventions for the L1 Pallas counting kernels.

All kernel state is int32. Times are integer ticks (the datasets use 1 tick
= 1 ms). Conventions:

- ``NEG`` is the "empty slot / invalid timestamp" sentinel. It is chosen so
  that ``t - NEG`` never overflows int32 for any valid event time
  (``0 <= t < 2**30``) and always fails the ``<= t_high`` constraint check,
  so empty list slots need no separate validity mask.
- ``EV_PAD`` pads event chunks out to the static chunk length. It never
  equals a real event type (real types are ``>= 0``).
- ``EP_PAD`` pads episode batches out to the static batch size. It is
  distinct from ``EV_PAD`` so a padded episode can never match a padded
  event.
"""

NEG = -(1 << 30)
EV_PAD = -1
EP_PAD = -2
