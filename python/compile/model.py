"""L2: the batched episode-counting compute graphs, built on the L1 kernels.

The "model" of this paper is not a neural network but the counting
computation itself: a batch of serial-episode automata advanced over an
event chunk. This module fixes the production shapes (the artifact matrix
of DESIGN.md §7), provides jit-able entry points with example arguments for
AOT lowering, and is the single source of truth for the constants the Rust
runtime needs (mirrored into ``artifacts/manifest.txt`` by ``aot.py``).

Python only ever runs at build time (``make artifacts``); the Rust
coordinator streams arbitrary-length event sequences through these
fixed-shape executables by carrying the automaton state across chunks.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import a1, a2, mapconcat
from .kernels.common import NEG, EV_PAD, EP_PAD

# --- Production shape configuration (mirrored in artifacts/manifest.txt) ---

# PTPE-style counting artifacts (A1 exact / A2 relaxed):
M_EPISODES = 512   # episode lanes per executable call (pad with EP_PAD)
C_CHUNK = 8192     # events per chunk (pad with EV_PAD)
EP_BLOCK = 128     # episode lanes per Pallas grid program (VMEM tile)
K_SLOTS = 8        # bounded occurrence-list length per level (A1)

# MapConcatenate artifacts:
MC_EPISODES = 64   # episodes per Map call
MC_SEGMENTS = 64   # stream segments P
MC_CHUNK = 65536   # events per Map call (whole partition in one chunk)

N_MIN, N_MAX = 2, 8  # episode sizes with dedicated artifacts (N=1 is Rust)


def a2_fn(n):
    """A2 relaxed-counting graph for episode size ``n``.

    Signature: (types[M,n], thigh[M,n-1], ev_type[C], ev_time[C],
    s[M,n], cnt[M]) -> (s'[M,n], cnt'[M]).
    """

    def fn(types, thigh, ev_type, ev_time, s_in, cnt_in):
        return a2.a2_count(
            types, thigh, ev_type, ev_time, s_in, cnt_in, block=EP_BLOCK
        )

    return fn


def a1_fn(n):
    """A1 exact-counting graph for episode size ``n``.

    Signature: (types[M,n], tlow[M,n-1], thigh[M,n-1], ev_type[C],
    ev_time[C], s[M,n,K], cnt[M]) -> (s'[M,n,K], cnt'[M]).
    """

    def fn(types, tlow, thigh, ev_type, ev_time, s_in, cnt_in):
        return a1.a1_count(
            types, tlow, thigh, ev_type, ev_time, s_in, cnt_in, block=EP_BLOCK
        )

    return fn


def mapcat_fn(n):
    """MapConcatenate Map-step graph for episode size ``n``.

    Signature: (types[E,n], tlow[E,n-1], thigh[E,n-1], ev_type[C],
    ev_time[C], taus[P+1], seg_lo[P]) -> (a[E,P,n], cnt[E,P,n], b[E,P,n]).
    """

    def fn(types, tlow, thigh, ev_type, ev_time, taus, seg_lo):
        return mapconcat.mapcat_map(
            types, tlow, thigh, ev_type, ev_time, taus, seg_lo, k_slots=K_SLOTS
        )

    return fn


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def artifact_specs():
    """Yield (name, fn, example_args) for every artifact to AOT-compile."""
    for n in range(N_MIN, N_MAX + 1):
        yield (
            f"a2_n{n}",
            a2_fn(n),
            (
                _i32((M_EPISODES, n)),
                _i32((M_EPISODES, n - 1)),
                _i32((C_CHUNK,)),
                _i32((C_CHUNK,)),
                _i32((M_EPISODES, n)),
                _i32((M_EPISODES,)),
            ),
        )
        yield (
            f"a1_n{n}",
            a1_fn(n),
            (
                _i32((M_EPISODES, n)),
                _i32((M_EPISODES, n - 1)),
                _i32((M_EPISODES, n - 1)),
                _i32((C_CHUNK,)),
                _i32((C_CHUNK,)),
                _i32((M_EPISODES, n, K_SLOTS)),
                _i32((M_EPISODES,)),
            ),
        )
        yield (
            f"mapcat_n{n}",
            mapcat_fn(n),
            (
                _i32((MC_EPISODES, n)),
                _i32((MC_EPISODES, n - 1)),
                _i32((MC_EPISODES, n - 1)),
                _i32((MC_CHUNK,)),
                _i32((MC_CHUNK,)),
                _i32((MC_SEGMENTS + 1,)),
                _i32((MC_SEGMENTS,)),
            ),
        )


def manifest_lines():
    """Constants the Rust runtime must agree on, as ``key=value`` lines
    (the offline crate set has no serde; a flat text manifest is parsed by
    ``rust/src/runtime/manifest.rs``)."""
    return [
        f"m_episodes={M_EPISODES}",
        f"c_chunk={C_CHUNK}",
        f"ep_block={EP_BLOCK}",
        f"k_slots={K_SLOTS}",
        f"mc_episodes={MC_EPISODES}",
        f"mc_segments={MC_SEGMENTS}",
        f"mc_chunk={MC_CHUNK}",
        f"n_min={N_MIN}",
        f"n_max={N_MAX}",
        f"neg_sentinel={NEG}",
        f"ev_pad={EV_PAD}",
        f"ep_pad={EP_PAD}",
    ]
