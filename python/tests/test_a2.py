"""A2 kernel vs serial oracle (Algorithm 3)."""

import numpy as np
import pytest

from util import (
    random_stream,
    random_episode,
    pad_events,
    pad_episodes,
    fresh_state_a2,
)
from compile.kernels import a2
from compile.kernels import ref

M, C, BLOCK = 8, 64, 4


def run_a2(types_l, thigh_l, ev, tm, n):
    types, _, thigh = pad_episodes(
        types_l, [np.zeros(n - 1, np.int32)] * len(types_l), thigh_l, M, n
    )
    pev, ptm = pad_events(ev, tm, C)
    s, cnt = fresh_state_a2(M, n)
    s_out, cnt_out = a2.a2_count(types, thigh, pev, ptm, s, cnt, block=BLOCK)
    return np.asarray(cnt_out), np.asarray(s_out)


def test_single_occurrence():
    # A -> B -> C with t_high (10, 15]; two clean occurrences.
    ev = np.array([0, 1, 2, 0, 1, 2], np.int32)
    tm = np.array([1, 8, 20, 30, 35, 45], np.int32)
    cnt, _ = run_a2([[0, 1, 2]], [[10, 15]], ev, tm, 3)
    assert cnt[0] == 2


def test_junk_events_interleaved():
    # Junk events (type 9) between episode events must not break it.
    ev = np.array([0, 9, 9, 1, 9, 2], np.int32)
    tm = np.array([1, 2, 3, 6, 7, 12], np.int32)
    cnt, _ = run_a2([[0, 1, 2]], [[10, 15]], ev, tm, 3)
    assert cnt[0] == 1


def test_upper_bound_violation():
    # Gap beyond t_high breaks the chain.
    ev = np.array([0, 1, 2], np.int32)
    tm = np.array([1, 20, 25], np.int32)
    cnt, _ = run_a2([[0, 1, 2]], [[10, 15]], ev, tm, 3)
    assert cnt[0] == 0


def test_simultaneous_events_chain_in_relaxed_a2():
    # A2's relaxation is effectively [0, t_high] (Algorithm 3 line 8 checks
    # only the upper bound): a gap of exactly 0 chains. This is required
    # for Theorem 5.1 (A2 dominates A1) on streams with tied timestamps;
    # A1 itself still rejects d == 0 via its strict (t_low, t_high].
    ev = np.array([0, 1], np.int32)
    tm = np.array([5, 5], np.int32)
    cnt, _ = run_a2([[0, 1]], [[10]], ev, tm, 2)
    assert cnt[0] == 1


def test_non_overlap_reset():
    # A A B B: only one non-overlapped occurrence of A->B is counted by the
    # left-most inner-most semantics (count resets consume state).
    ev = np.array([0, 0, 1, 1], np.int32)
    tm = np.array([1, 2, 4, 5], np.int32)
    cnt, _ = run_a2([[0, 1]], [[10]], ev, tm, 2)
    # First B at 4 completes with latest A (2); state reset; second B at 5
    # finds no A.
    assert cnt[0] == 1


def test_event_cannot_serve_two_levels():
    # Episode A -> A: one event must not chain with itself.
    ev = np.array([0, 0], np.int32)
    tm = np.array([1, 4], np.int32)
    cnt, _ = run_a2([[0, 0]], [[10]], ev, tm, 2)
    assert cnt[0] == 1


def test_duplicate_type_episode_repeated():
    ev = np.array([0, 0, 0, 0, 0], np.int32)
    tm = np.array([1, 3, 5, 7, 9], np.int32)
    cnt, _ = run_a2([[0, 0]], [[10]], ev, tm, 2)
    # occurrences: (1,3) count, reset; (5,7) count, reset; 9 dangling.
    assert cnt[0] == 2


@pytest.mark.parametrize("n", [2, 3, 4, 5])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_vs_serial(n, seed):
    rng = np.random.default_rng(seed * 100 + n)
    ev, tm = random_stream(rng, C - 8, 5)
    eps = [random_episode(rng, n, 5) for _ in range(M)]
    types_l = [e[0] for e in eps]
    thigh_l = [e[2] for e in eps]
    cnt, _ = run_a2(types_l, thigh_l, ev, tm, n)
    for j in range(M):
        expect = ref.count_a2_serial(types_l[j].tolist(), thigh_l[j].tolist(), ev, tm)
        assert cnt[j] == expect, f"episode {j}: {cnt[j]} != {expect}"


@pytest.mark.parametrize("split", [1, 17, 32, 63])
def test_chunk_carry_equivalence(split):
    """Streaming the events through two chunks with carried state must give
    the same counts as one pass — the contract the Rust runtime relies on."""
    rng = np.random.default_rng(42)
    n = 3
    ev, tm = random_stream(rng, C - 8, 4)
    eps = [random_episode(rng, n, 4) for _ in range(M)]
    types, _, thigh = pad_episodes(
        [e[0] for e in eps], [e[1] for e in eps], [e[2] for e in eps], M, n
    )

    pev, ptm = pad_events(ev, tm, C)
    s, cnt = fresh_state_a2(M, n)
    _, cnt_one = a2.a2_count(types, thigh, pev, ptm, s, cnt, block=BLOCK)

    pev1, ptm1 = pad_events(ev[:split], tm[:split], C)
    pev2, ptm2 = pad_events(ev[split:], tm[split:], C)
    s, cnt = fresh_state_a2(M, n)
    s1, c1 = a2.a2_count(types, thigh, pev1, ptm1, s, cnt, block=BLOCK)
    _, cnt_two = a2.a2_count(types, thigh, pev2, ptm2, s1, c1, block=BLOCK)

    np.testing.assert_array_equal(np.asarray(cnt_one), np.asarray(cnt_two))


def test_padded_lanes_stay_zero():
    ev = np.array([0, 1, 2], np.int32)
    tm = np.array([1, 2, 3], np.int32)
    cnt, _ = run_a2([[0, 1]], [[5]], ev, tm, 2)
    assert (cnt[1:] == 0).all()
