"""A1 kernel vs serial oracles (Algorithm 1, bounded and unbounded)."""

import numpy as np
import pytest

from util import (
    random_stream,
    random_episode,
    pad_events,
    pad_episodes,
    fresh_state_a1,
)
from compile.kernels import a1
from compile.kernels import ref

M, C, BLOCK, K = 8, 64, 4, 8


def run_a1(types_l, tlow_l, thigh_l, ev, tm, n, k=K):
    types, tlow, thigh = pad_episodes(types_l, tlow_l, thigh_l, M, n)
    pev, ptm = pad_events(ev, tm, C)
    s, cnt = fresh_state_a1(M, n, k)
    s_out, cnt_out = a1.a1_count(
        types, tlow, thigh, pev, ptm, s, cnt, block=BLOCK
    )
    return np.asarray(cnt_out), np.asarray(s_out)


def test_lower_bound_rejects_recent():
    # t_low = 2: B at distance 1 must not count, B at distance 5 must.
    ev = np.array([0, 1, 0, 1], np.int32)
    tm = np.array([0, 1, 10, 15], np.int32)
    cnt, _ = run_a1([[0, 1]], [[2]], [[10]], ev, tm, 2)
    assert cnt[0] == 1


def test_list_needed_with_lower_bound():
    # Events 0@0, 0@9, 1@10: the most recent A (9) fails t_low=2 but the
    # older A (0) satisfies (2, 10]. A single-timestamp state (A2-style)
    # would miss this; the K-list must catch it.
    ev = np.array([0, 0, 1], np.int32)
    tm = np.array([0, 9, 10], np.int32)
    cnt, _ = run_a1([[0, 1]], [[2]], [[10]], ev, tm, 2)
    assert cnt[0] == 1
    # And with K=1 the truncated list loses the older A:
    cnt1, _ = run_a1([[0, 1]], [[2]], [[10]], ev, tm, 2, k=1)
    assert cnt1[0] == 0


def test_paper_example_constraints():
    # A -(5,10]-> B -(10,15]-> C (paper Fig. 2 constraint set).
    ev = np.array([0, 1, 2, 0, 1, 2], np.int32)
    tm = np.array([1, 8, 20, 30, 32, 45], np.int32)
    # First triple: 8-1=7 in (5,10], 20-8=12 in (10,15] -> count.
    # Second: 32-30=2 fails (5,10] -> no count.
    cnt, _ = run_a1([[0, 1, 2]], [[5, 10]], [[10, 15]], ev, tm, 3)
    assert cnt[0] == 1


@pytest.mark.parametrize("n", [2, 3, 4, 5])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_vs_serial_bounded(n, seed):
    rng = np.random.default_rng(seed * 100 + n + 7)
    ev, tm = random_stream(rng, C - 8, 5)
    eps = [random_episode(rng, n, 5) for _ in range(M)]
    cnt, _ = run_a1(
        [e[0] for e in eps], [e[1] for e in eps], [e[2] for e in eps], ev, tm, n
    )
    for j in range(M):
        expect = ref.count_serial_bounded(
            eps[j][0].tolist(), eps[j][1].tolist(), eps[j][2].tolist(), ev, tm, K
        )
        assert cnt[j] == expect, f"episode {j}: {cnt[j]} != {expect}"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bounded_k8_matches_unbounded_on_neural_rates(seed):
    """At realistic event rates the K=8 bound never bites: bounded count ==
    unbounded Algorithm 1 (the Rust serial reference)."""
    rng = np.random.default_rng(seed)
    ev, tm = random_stream(rng, C - 8, 8, max_gap=6)
    for _ in range(8):
        types, tlow, thigh = random_episode(rng, 3, 8)
        b = ref.count_serial_bounded(
            types.tolist(), tlow.tolist(), thigh.tolist(), ev, tm, K
        )
        u = ref.count_serial(types.tolist(), tlow.tolist(), thigh.tolist(), ev, tm)
        assert b == u


@pytest.mark.parametrize("n", [2, 3, 4])
def test_theorem_5_1_upper_bound(n):
    """count(alpha') >= count(alpha): the relaxed A2 count dominates the
    exact A1 count (the soundness of two-pass elimination)."""
    rng = np.random.default_rng(n)
    for seed in range(6):
        ev, tm = random_stream(rng, C - 8, 4)
        types, tlow, thigh = random_episode(rng, n, 4)
        a1c = ref.count_serial(types.tolist(), tlow.tolist(), thigh.tolist(), ev, tm)
        a2c = ref.count_a2_serial(types.tolist(), thigh.tolist(), ev, tm)
        assert a2c >= a1c


@pytest.mark.parametrize("split", [1, 31, 63])
def test_chunk_carry_equivalence(split):
    rng = np.random.default_rng(43)
    n = 3
    ev, tm = random_stream(rng, C - 8, 4)
    eps = [random_episode(rng, n, 4) for _ in range(M)]
    types, tlow, thigh = pad_episodes(
        [e[0] for e in eps], [e[1] for e in eps], [e[2] for e in eps], M, n
    )

    pev, ptm = pad_events(ev, tm, C)
    s, cnt = fresh_state_a1(M, n, K)
    _, cnt_one = a1.a1_count(types, tlow, thigh, pev, ptm, s, cnt, block=BLOCK)

    pev1, ptm1 = pad_events(ev[:split], tm[:split], C)
    pev2, ptm2 = pad_events(ev[split:], tm[split:], C)
    s, cnt = fresh_state_a1(M, n, K)
    s1, c1 = a1.a1_count(types, tlow, thigh, pev1, ptm1, s, cnt, block=BLOCK)
    _, cnt_two = a1.a1_count(types, tlow, thigh, pev2, ptm2, s1, c1, block=BLOCK)

    np.testing.assert_array_equal(np.asarray(cnt_one), np.asarray(cnt_two))
