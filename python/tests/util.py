"""Shared helpers for the kernel test-suite."""

import numpy as np
import jax.numpy as jnp

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels.common import NEG, EV_PAD, EP_PAD  # noqa: E402


def random_stream(rng, n_events, n_types, max_gap=4):
    """Time-sorted random event stream with strictly positive total span.

    Gaps of 0 are included on purpose: simultaneous events exercise the
    strict lower bound of the ``(t_low, t_high]`` constraint.
    """
    ev = rng.integers(0, n_types, size=n_events).astype(np.int32)
    gaps = rng.integers(0, max_gap + 1, size=n_events)
    tm = np.cumsum(gaps).astype(np.int32)
    return ev, tm


def random_episode(rng, n, n_types, max_low=3, max_high=12):
    """Random episode of size n with random (t_low, t_high] constraints."""
    types = rng.integers(0, n_types, size=n).astype(np.int32)
    tlow = rng.integers(0, max_low + 1, size=n - 1).astype(np.int32)
    thigh = (tlow + 1 + rng.integers(0, max_high, size=n - 1)).astype(np.int32)
    return types, tlow, thigh


def planted_stream(rng, types, delays, n_reps, noise_types, noise_rate, gap):
    """Stream with ``n_reps`` planted occurrences of ``types`` separated by
    ``gap`` ticks, interleaved with uniform noise events."""
    ev, tm = [], []
    t = 1
    for _ in range(n_reps):
        for i, e in enumerate(types):
            ev.append(e)
            tm.append(t)
            if i < len(delays):
                t += delays[i]
        t += gap
    # noise
    n_noise = int(len(ev) * noise_rate)
    if n_noise and noise_types:
        nev = rng.choice(noise_types, size=n_noise)
        ntm = rng.integers(1, max(t, 2), size=n_noise)
        ev = np.concatenate([np.array(ev), nev])
        tm = np.concatenate([np.array(tm), ntm])
        order = np.argsort(tm, kind="stable")
        ev, tm = ev[order], tm[order]
    return np.asarray(ev, np.int32), np.asarray(tm, np.int32)


def pad_events(ev, tm, c):
    """Pad an event stream to chunk length ``c`` with EV_PAD events."""
    assert len(ev) <= c
    pe = np.full(c, EV_PAD, np.int32)
    pt = np.full(c, tm[-1] if len(tm) else 0, np.int32)
    pe[: len(ev)] = ev
    pt[: len(tm)] = tm
    return jnp.asarray(pe), jnp.asarray(pt)


def pad_episodes(types_list, tlow_list, thigh_list, m, n):
    """Pad an episode batch to ``m`` lanes with EP_PAD episodes."""
    types = np.full((m, n), EP_PAD, np.int32)
    tlow = np.zeros((m, n - 1), np.int32)
    thigh = np.zeros((m, n - 1), np.int32)
    for j, (ty, lo, hi) in enumerate(zip(types_list, tlow_list, thigh_list)):
        types[j] = ty
        tlow[j] = lo
        thigh[j] = hi
    return jnp.asarray(types), jnp.asarray(tlow), jnp.asarray(thigh)


def fresh_state_a2(m, n):
    return jnp.full((m, n), NEG, jnp.int32), jnp.zeros((m,), jnp.int32)


def fresh_state_a1(m, n, k):
    return jnp.full((m, n, k), NEG, jnp.int32), jnp.zeros((m,), jnp.int32)
