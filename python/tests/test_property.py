"""Hypothesis sweeps: kernel shapes/dtypes/data vs the ref.py oracles.

Shapes are drawn from a small fixed menu so XLA's compile cache is reused
across examples (fresh shapes would recompile the interpret-lowered kernel
on every example)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from util import pad_events, pad_episodes, fresh_state_a1, fresh_state_a2
from compile.kernels import a1, a2
from compile.kernels import ref

M, C, BLOCK, K = 8, 64, 4, 8


@st.composite
def stream_and_episodes(draw, n):
    n_events = draw(st.integers(min_value=0, max_value=C - 8))
    n_types = draw(st.sampled_from([2, 4, 6]))
    ev = draw(
        st.lists(
            st.integers(0, n_types - 1), min_size=n_events, max_size=n_events
        )
    )
    gaps = draw(st.lists(st.integers(0, 5), min_size=n_events, max_size=n_events))
    tm = np.cumsum(np.asarray(gaps, np.int64)).astype(np.int32)
    eps = []
    for _ in range(M):
        types = draw(
            st.lists(st.integers(0, n_types - 1), min_size=n, max_size=n)
        )
        tlow = draw(st.lists(st.integers(0, 3), min_size=n - 1, max_size=n - 1))
        thigh = [lo + draw(st.integers(1, 10)) for lo in tlow]
        eps.append(
            (
                np.asarray(types, np.int32),
                np.asarray(tlow, np.int32),
                np.asarray(thigh, np.int32),
            )
        )
    return np.asarray(ev, np.int32), tm, eps


@settings(max_examples=25, deadline=None)
@given(data=stream_and_episodes(n=3))
def test_a1_kernel_matches_oracle(data):
    ev, tm, eps = data
    n = 3
    types, tlow, thigh = pad_episodes(
        [e[0] for e in eps], [e[1] for e in eps], [e[2] for e in eps], M, n
    )
    pev, ptm = pad_events(ev, tm, C) if len(ev) else pad_events(
        np.asarray([0], np.int32), np.asarray([0], np.int32), C
    )
    if len(ev) == 0:
        ev = np.asarray([0], np.int32)
        tm = np.asarray([0], np.int32)
    s, cnt = fresh_state_a1(M, n, K)
    _, cnt_out = a1.a1_count(types, tlow, thigh, pev, ptm, s, cnt, block=BLOCK)
    cnt_out = np.asarray(cnt_out)
    for j, (ty, lo, hi) in enumerate(eps):
        expect = ref.count_serial_bounded(
            ty.tolist(), lo.tolist(), hi.tolist(), ev, tm, K
        )
        assert cnt_out[j] == expect


@settings(max_examples=25, deadline=None)
@given(data=stream_and_episodes(n=4))
def test_a2_kernel_matches_oracle_and_dominates_a1(data):
    ev, tm, eps = data
    n = 4
    types, _, thigh = pad_episodes(
        [e[0] for e in eps], [e[1] for e in eps], [e[2] for e in eps], M, n
    )
    if len(ev) == 0:
        ev = np.asarray([0], np.int32)
        tm = np.asarray([0], np.int32)
    pev, ptm = pad_events(ev, tm, C)
    s, cnt = fresh_state_a2(M, n)
    _, cnt_out = a2.a2_count(types, thigh, pev, ptm, s, cnt, block=BLOCK)
    cnt_out = np.asarray(cnt_out)
    for j, (ty, lo, hi) in enumerate(eps):
        expect = ref.count_a2_serial(ty.tolist(), hi.tolist(), ev, tm)
        assert cnt_out[j] == expect
        # Theorem 5.1: relaxed count is an upper bound on the exact count.
        exact = ref.count_serial(ty.tolist(), lo.tolist(), hi.tolist(), ev, tm)
        assert cnt_out[j] >= exact
