"""MapConcatenate Map kernel vs serial reference, and full
Map+Concatenate vs the single-machine serial count."""

import numpy as np
import jax.numpy as jnp
import pytest

from util import random_stream, random_episode, pad_events
from compile.kernels import mapconcat
from compile.kernels import ref
from compile.kernels.common import EV_PAD

K = 8


def make_segments(tm, p_count):
    """Even time segmentation: taus[0] < first event, taus[P] >= last."""
    t0, t1 = int(tm[0]) - 1, int(tm[-1])
    span = max(t1 - t0, p_count)
    taus = [t0 + (span * i) // p_count for i in range(p_count)] + [t1]
    return np.asarray(taus, np.int32)


def seg_lo_indices(tm, taus):
    """Scan-start index per segment: first event of the previous segment."""
    p_count = len(taus) - 1
    firsts = np.searchsorted(tm, taus[:-1], side="right")
    lo = np.zeros(p_count, np.int64)
    lo[1:] = firsts[:-1]
    return lo.astype(np.int32)


def run_map(types_l, tlow_l, thigh_l, ev, tm, taus, c=256):
    e_count = len(types_l)
    n = len(types_l[0])
    types = jnp.asarray(np.stack(types_l).astype(np.int32))
    tlow = jnp.asarray(np.stack(tlow_l).astype(np.int32).reshape(e_count, n - 1))
    thigh = jnp.asarray(np.stack(thigh_l).astype(np.int32).reshape(e_count, n - 1))
    pev, ptm = pad_events(ev, tm, c)
    lo = seg_lo_indices(tm, taus)
    a, cnt, b = mapconcat.mapcat_map(
        types, tlow, thigh, pev, ptm, jnp.asarray(taus), jnp.asarray(lo), k_slots=K
    )
    return np.asarray(a), np.asarray(cnt), np.asarray(b)


def tuples_from_arrays(a, cnt, b, e):
    p_count, n = a.shape[1], a.shape[2]
    return [
        [(int(a[e, p, k]), int(cnt[e, p, k]), int(b[e, p, k])) for k in range(n)]
        for p in range(p_count)
    ]


@pytest.mark.parametrize("n", [2, 3, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("p_count", [2, 4])
def test_map_kernel_matches_serial_map(n, seed, p_count):
    rng = np.random.default_rng(seed * 10 + n)
    ev, tm = random_stream(rng, 200, 5)
    taus = make_segments(tm, p_count)
    eps = [random_episode(rng, n, 5) for _ in range(4)]
    a, cnt, b = run_map(
        [e[0] for e in eps], [e[1] for e in eps], [e[2] for e in eps], ev, tm, taus
    )
    for j, (types, tlow, thigh) in enumerate(eps):
        expect = ref.mapcat_map_serial(
            types.tolist(), tlow.tolist(), thigh.tolist(), ev, tm, taus.tolist(), K
        )
        got = tuples_from_arrays(a, cnt, b, j)
        assert got == expect, f"episode {j}: {got} != {expect}"


@pytest.mark.parametrize("n", [2, 3])
@pytest.mark.parametrize("p_count", [2, 4, 8])
def test_concatenate_equals_serial_on_sparse(n, p_count):
    """On sparse streams (occurrences well inside segments) the Map +
    Concatenate total must equal the serial Algorithm 1 count exactly."""
    rng = np.random.default_rng(99 + n + p_count)
    # Sparse: large gaps relative to t_high so occurrences rarely straddle.
    ev, tm = random_stream(rng, 150, 4, max_gap=9)
    taus = make_segments(tm, p_count)
    eps = [random_episode(rng, n, 4, max_low=1, max_high=5) for _ in range(4)]
    a, cnt, b = run_map(
        [e[0] for e in eps], [e[1] for e in eps], [e[2] for e in eps], ev, tm, taus
    )
    for j, (types, tlow, thigh) in enumerate(eps):
        serial = ref.count_serial_bounded(
            types.tolist(), tlow.tolist(), thigh.tolist(), ev, tm, K
        )
        tuples = tuples_from_arrays(a, cnt, b, j)
        total, misses = ref.concatenate_fold(tuples)
        assert total == serial, f"episode {j}: {total} != {serial} (misses={misses})"


@pytest.mark.parametrize("seed", range(8))
def test_concatenate_dense_streams(seed):
    """Dense streams with straddling occurrences: measure that the
    boundary-machine construction reproduces the serial count."""
    rng = np.random.default_rng(seed)
    ev, tm = random_stream(rng, 200, 3, max_gap=3)
    taus = make_segments(tm, 4)
    types, tlow, thigh = random_episode(rng, 3, 3, max_low=2, max_high=8)
    a, cnt, b = run_map([types], [tlow], [thigh], ev, tm, taus)
    serial = ref.count_serial_bounded(
        types.tolist(), tlow.tolist(), thigh.tolist(), ev, tm, K
    )
    total, misses = ref.concatenate_fold(tuples_from_arrays(a, cnt, b, 0))
    assert total == serial, f"{total} != {serial} (misses={misses})"


def test_tree_equals_fold():
    rng = np.random.default_rng(7)
    ev, tm = random_stream(rng, 200, 4, max_gap=4)
    taus = make_segments(tm, 8)
    for _ in range(6):
        types, tlow, thigh = random_episode(rng, 3, 4)
        tuples = ref.mapcat_map_serial(
            types.tolist(), tlow.tolist(), thigh.tolist(), ev, tm, taus.tolist(), K
        )
        ft, fm = ref.concatenate_fold(tuples)
        tt, tmiss = ref.concatenate_tree(tuples)
        assert ft == tt


def test_single_segment_is_plain_count():
    rng = np.random.default_rng(3)
    ev, tm = random_stream(rng, 100, 4)
    taus = np.asarray([int(tm[0]) - 1, int(tm[-1])], np.int32)
    types, tlow, thigh = random_episode(rng, 3, 4)
    a, cnt, b = run_map([types], [tlow], [thigh], ev, tm, taus)
    serial = ref.count_serial_bounded(
        types.tolist(), tlow.tolist(), thigh.tolist(), ev, tm, K
    )
    # machine 0 of the single segment sees the whole stream
    assert int(cnt[0, 0, 0]) == serial
