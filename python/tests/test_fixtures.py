"""Cross-language fixtures: the same stream, episodes, and expected counts
are asserted here and in ``rust/tests/cross_fixtures.rs``. If either side
drifts from the paper's semantics, the two suites diverge and one fails.

The stream is 60 events over 6 types with tied timestamps included
(np.random.default_rng(2009); literals inlined so neither side needs the
other's RNG)."""

import numpy as np

from util import pad_events, pad_episodes, fresh_state_a1, fresh_state_a2
from compile.kernels import a1, a2, ref

EV = [5, 1, 2, 3, 4, 5, 0, 2, 0, 2, 0, 1, 4, 4, 3, 1, 1, 4, 4, 0, 5, 2, 0,
      1, 2, 3, 2, 4, 3, 5, 1, 4, 5, 0, 5, 1, 5, 3, 2, 2, 5, 2, 1, 3, 0, 2,
      4, 3, 4, 4, 3, 3, 5, 5, 4, 2, 1, 4, 3, 2]
TM = [2, 5, 5, 6, 9, 9, 9, 12, 13, 14, 17, 17, 20, 20, 21, 22, 22, 24, 27,
      28, 29, 31, 34, 35, 38, 41, 44, 45, 46, 48, 48, 48, 49, 49, 52, 53,
      56, 57, 59, 62, 64, 64, 64, 64, 64, 64, 65, 66, 66, 66, 66, 66, 69,
      69, 72, 75, 75, 77, 77, 77]

# (types, tlow, thigh, a1_count, a2_count) — a1 == bounded(K=8) on this data
CASES = [
    ([1, 1, 2], [0, 0], [10, 10], 2, 2),
    ([5, 0, 3, 2], [0, 0, 0], [12, 12, 12], 2, 3),
    ([4, 3], [0], [3], 3, 5),
    ([2, 0, 1], [1, 0], [9, 12], 4, 4),
]

M, C, BLOCK, K = 8, 64, 4, 8


def test_oracle_matches_fixture_counts():
    ev = np.asarray(EV, np.int32)
    tm = np.asarray(TM, np.int32)
    for types, tlow, thigh, a1_expect, a2_expect in CASES:
        assert ref.count_serial(types, tlow, thigh, ev, tm) == a1_expect
        assert ref.count_serial_bounded(types, tlow, thigh, ev, tm, K) == a1_expect
        assert ref.count_a2_serial(types, thigh, ev, tm) == a2_expect


def test_kernels_match_fixture_counts():
    ev = np.asarray(EV, np.int32)
    tm = np.asarray(TM, np.int32)
    pev, ptm = pad_events(ev, tm, C)
    for types, tlow, thigh, a1_expect, a2_expect in CASES:
        n = len(types)
        ty, lo, hi = pad_episodes(
            [np.asarray(types, np.int32)],
            [np.asarray(tlow, np.int32)],
            [np.asarray(thigh, np.int32)],
            M,
            n,
        )
        s, cnt = fresh_state_a1(M, n, K)
        _, c1 = a1.a1_count(ty, lo, hi, pev, ptm, s, cnt, block=BLOCK)
        assert int(np.asarray(c1)[0]) == a1_expect
        s, cnt = fresh_state_a2(M, n)
        _, c2 = a2.a2_count(ty, hi, pev, ptm, s, cnt, block=BLOCK)
        assert int(np.asarray(c2)[0]) == a2_expect
