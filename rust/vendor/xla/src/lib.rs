//! Offline stand-in for the `xla` (xla_extension / PJRT) bindings.
//!
//! The episodes-gpu runtime layer (`episodes_gpu::runtime`) is written
//! against this API: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `compile` → `execute`. In environments
//! without the PJRT shared library this stub keeps the crate building and
//! testable — client construction fails with a descriptive error, which the
//! library surfaces as `MineError::RuntimeUnavailable` and answers with its
//! CPU counting backends.
//!
//! To enable the real accelerator path, patch this crate with the actual
//! bindings in the workspace `Cargo.toml`:
//!
//! ```toml
//! [patch.crates-io]
//! # or a [patch."path"] entry pointing at the xla_extension-backed crate
//! ```
//!
//! Host-side `Literal` bookkeeping (construction, reshape, readback) is
//! implemented for real so unit tests of the batching layer can exercise
//! shape validation without a device.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' catch-all error.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "xla stub: PJRT bindings are not linked into this build \
                        (substitute the real `xla` crate via [patch] to enable \
                        the accelerator path)";

fn unavailable<T>() -> Result<T> {
    Err(Error::new(STUB_MSG))
}

/// PJRT client handle. Construction always fails in the stub.
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU PJRT client. Always unavailable in the stub build.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled, loaded executable. Unobtainable in the stub (the client
/// cannot be constructed), but the type and its `execute` signature keep
/// call sites compiling.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A device buffer returned by `execute`.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Element types readable out of a [`Literal`].
pub trait NativeElement: Sized + Copy {
    fn read_all(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeElement for i32 {
    fn read_all(lit: &Literal) -> Result<Vec<i32>> {
        Ok(lit.data.clone())
    }
}

/// Host-side literal: flat i32 storage plus a shape. Fully functional so
/// the batching layer's shape handling is testable without a device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Literal {
    data: Vec<i32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1(data: &[i32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.data.len() as i64 {
            return Err(Error::new(format!(
                "reshape to {dims:?} wants {want} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeElement>(&self) -> Result<Vec<T>> {
        T::read_all(self)
    }

    /// Destructure a tuple literal. Device tuples never exist in the stub
    /// (nothing executes), so this reports unavailability.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub client must not construct");
        assert!(err.to_string().contains("PJRT"));
    }

    #[test]
    fn literal_reshape_roundtrip() {
        let lit = Literal::vec1(&[1, 2, 3, 4, 5, 6]);
        let l2 = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(l2.shape(), &[2, 3]);
        assert_eq!(l2.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(lit.reshape(&[4, 2]).is_err());
    }
}
