//! Artifact manifest: the shape/constant contract between `aot.py` and
//! the Rust runtime, as flat `key=value` lines (no serde offline).

use std::collections::HashMap;
use std::path::Path;

use crate::error::MineError;

/// Constants baked into the AOT artifacts (see `python/compile/model.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// episode lanes per A1/A2 executable call
    pub m_episodes: usize,
    /// events per A1/A2 chunk
    pub c_chunk: usize,
    /// episode lanes per Pallas grid program
    pub ep_block: usize,
    /// bounded occurrence-list length (A1 / MapConcatenate)
    pub k_slots: usize,
    /// episodes per MapConcatenate Map call
    pub mc_episodes: usize,
    /// MapConcatenate segment count P
    pub mc_segments: usize,
    /// events per MapConcatenate chunk
    pub mc_chunk: usize,
    /// episode sizes with artifacts: n_min..=n_max
    pub n_min: usize,
    pub n_max: usize,
    /// empty-timestamp sentinel
    pub neg_sentinel: i32,
    /// event-chunk padding type
    pub ev_pad: i32,
    /// episode-batch padding type
    pub ep_pad: i32,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest, MineError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            MineError::runtime_unavailable(format!(
                "reading manifest {path:?}: {e} (run `make artifacts`)"
            ))
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest, MineError> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(MineError::runtime_unavailable(format!(
                    "malformed manifest line: {line:?}"
                )));
            };
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<i64, MineError> {
            kv.get(k)
                .ok_or_else(|| {
                    MineError::runtime_unavailable(format!("manifest missing key {k}"))
                })?
                .parse::<i64>()
                .map_err(|_| {
                    MineError::runtime_unavailable(format!("manifest key {k} not an integer"))
                })
        };
        Ok(Manifest {
            m_episodes: get("m_episodes")? as usize,
            c_chunk: get("c_chunk")? as usize,
            ep_block: get("ep_block")? as usize,
            k_slots: get("k_slots")? as usize,
            mc_episodes: get("mc_episodes")? as usize,
            mc_segments: get("mc_segments")? as usize,
            mc_chunk: get("mc_chunk")? as usize,
            n_min: get("n_min")? as usize,
            n_max: get("n_max")? as usize,
            neg_sentinel: get("neg_sentinel")? as i32,
            ev_pad: get("ev_pad")? as i32,
            ep_pad: get("ep_pad")? as i32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
m_episodes=512
c_chunk=8192
ep_block=128
k_slots=8
mc_episodes=64
mc_segments=64
mc_chunk=65536
n_min=2
n_max=8

# comment
neg_sentinel=-1073741824
ev_pad=-1
ep_pad=-2
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.m_episodes, 512);
        assert_eq!(m.neg_sentinel, -(1 << 30));
        assert_eq!(m.ep_pad, -2);
    }

    #[test]
    fn missing_key_rejected() {
        assert!(Manifest::parse("m_episodes=1").is_err());
    }

    #[test]
    fn malformed_line_rejected() {
        let bad = SAMPLE.replace("k_slots=8", "k_slots 8");
        assert!(Manifest::parse(&bad).is_err());
    }
}
