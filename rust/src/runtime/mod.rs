//! PJRT runtime: loads the AOT-compiled Pallas counting kernels
//! (`artifacts/*.hlo.txt`) and streams event data through them.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (jax ≥ 0.5 protos are rejected by xla_extension 0.5.1).
//!
//! Python never runs here: after `make artifacts` the binary is
//! self-contained.

pub mod manifest;
pub mod exec;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::error::MineError;

pub use manifest::Manifest;

/// A PJRT client plus the compiled-executable cache over the artifact
/// directory. One `Runtime` per process; executables compile lazily on
/// first use and are reused across mining levels.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// compile wall-time per artifact, for metrics
    compile_ns: RefCell<HashMap<String, u128>>,
}

impl Runtime {
    /// Open the artifact directory (default: `artifacts/` next to the
    /// workspace root, override with env `EPISODES_GPU_ARTIFACTS`).
    pub fn new(dir: &Path) -> Result<Runtime, MineError> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| {
            MineError::runtime_unavailable(format!("creating PJRT CPU client: {e}"))
        })?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_ns: RefCell::new(HashMap::new()),
        })
    }

    /// Artifact directory resolution used by binaries/examples/tests.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("EPISODES_GPU_ARTIFACTS") {
            return PathBuf::from(d);
        }
        // walk up from cwd looking for artifacts/manifest.txt
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.txt").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    pub fn open_default() -> Result<Runtime, MineError> {
        Self::new(&Self::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) the executable for `name`
    /// (e.g. `a1_n3`).
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>, MineError> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(MineError::runtime_unavailable(format!(
                "artifact {path:?} missing — run `make artifacts`"
            )));
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| MineError::accel(format!("parsing {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| MineError::accel(format!("compiling {name}: {e}")))?;
        let exe = Rc::new(exe);
        self.compile_ns
            .borrow_mut()
            .insert(name.to_string(), t0.elapsed().as_nanos());
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// (artifact, compile-time ns) pairs for everything compiled so far.
    pub fn compile_times(&self) -> Vec<(String, u128)> {
        let mut v: Vec<_> =
            self.compile_ns.borrow().iter().map(|(k, &t)| (k.clone(), t)).collect();
        v.sort();
        v
    }

    /// Does this runtime have an artifact for episode size n?
    pub fn supports_n(&self, n: usize) -> bool {
        (self.manifest.n_min..=self.manifest.n_max).contains(&n)
    }
}

/// Build an int32 literal of the given shape from a flat slice.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal, MineError> {
    let expected: i64 = dims.iter().product();
    if expected != data.len() as i64 {
        return Err(MineError::internal(format!(
            "shape {dims:?} wants {expected} elements, got {}",
            data.len()
        )));
    }
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(dims)?)
}

/// Extract a Vec<i32> from an int32 literal.
pub fn vec_i32(lit: &xla::Literal) -> Result<Vec<i32>, MineError> {
    Ok(lit.to_vec::<i32>()?)
}
