//! Batched execution of the counting artifacts: episode padding, event
//! chunking, and automaton-state carry across chunk boundaries.
//!
//! The artifacts have static shapes (M episodes × C events); this module
//! adapts arbitrary workloads to them: episode batches are padded with
//! `ep_pad` lanes (which can never match an event), event chunks are
//! padded with `ev_pad` events (which can never match an episode level),
//! and the `(s, cnt)` automaton state returned by chunk i is fed as input
//! to chunk i+1 — making the fixed-shape executable a streaming machine.

use super::{lit_i32, vec_i32, Runtime};
use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::{EventStream, Tick};

/// Counts for a uniform-size episode batch via the A1 (exact) artifacts.
pub fn count_a1(
    rt: &Runtime,
    episodes: &[Episode],
    stream: &EventStream,
) -> Result<Vec<u64>, MineError> {
    count_batched(rt, episodes, stream, Algo::A1)
}

/// Counts via the A2 (relaxed) artifacts. Episodes are interpreted as
/// their relaxed counterparts α′ (only `t_high` is sent to the kernel).
pub fn count_a2(
    rt: &Runtime,
    episodes: &[Episode],
    stream: &EventStream,
) -> Result<Vec<u64>, MineError> {
    count_batched(rt, episodes, stream, Algo::A2)
}

#[derive(Clone, Copy, PartialEq)]
enum Algo {
    A1,
    A2,
}

fn count_batched(
    rt: &Runtime,
    episodes: &[Episode],
    stream: &EventStream,
    algo: Algo,
) -> Result<Vec<u64>, MineError> {
    if episodes.is_empty() {
        return Ok(vec![]);
    }
    let n = episodes[0].n();
    if !episodes.iter().all(|e| e.n() == n) {
        return Err(MineError::internal("mixed episode sizes in batch"));
    }
    let name = match algo {
        Algo::A1 => format!("a1_n{n}"),
        Algo::A2 => format!("a2_n{n}"),
    };
    if !rt.supports_n(n) {
        return Err(MineError::UnsupportedEpisodeSize { backend: format!("pjrt:{name}"), n });
    }
    let mf = *rt.manifest();
    let (m, c, k) = (mf.m_episodes, mf.c_chunk, mf.k_slots);
    let exe = rt.executable(&name)?;

    let mut counts = Vec::with_capacity(episodes.len());
    for batch in episodes.chunks(m) {
        // --- episode tensors, padded to M lanes ---
        let mut types = vec![mf.ep_pad; m * n];
        let mut tlow = vec![0i32; m * (n - 1)];
        let mut thigh = vec![0i32; m * (n - 1)];
        for (j, ep) in batch.iter().enumerate() {
            types[j * n..(j + 1) * n].copy_from_slice(&ep.types);
            for (g, iv) in ep.intervals.iter().enumerate() {
                tlow[j * (n - 1) + g] = iv.t_low;
                thigh[j * (n - 1) + g] = iv.t_high;
            }
        }
        let types_l = lit_i32(&types, &[m as i64, n as i64])?;
        let tlow_l = lit_i32(&tlow, &[m as i64, (n - 1) as i64])?;
        let thigh_l = lit_i32(&thigh, &[m as i64, (n - 1) as i64])?;

        // --- carried automaton state ---
        let state_len = match algo {
            Algo::A1 => m * n * k,
            Algo::A2 => m * n,
        };
        let state_dims: Vec<i64> = match algo {
            Algo::A1 => vec![m as i64, n as i64, k as i64],
            Algo::A2 => vec![m as i64, n as i64],
        };
        let mut s_l = lit_i32(&vec![mf.neg_sentinel; state_len], &state_dims)?;
        let mut cnt_l = lit_i32(&vec![0i32; m], &[m as i64])?;

        // --- stream chunks ---
        let total = stream.len().max(1);
        let n_chunks = total.div_ceil(c);
        for ci in 0..n_chunks {
            let lo = ci * c;
            let hi = (lo + c).min(stream.len());
            let mut ev = vec![mf.ev_pad; c];
            let mut tm = vec![0i32; c];
            if hi > lo {
                ev[..hi - lo].copy_from_slice(&stream.types[lo..hi]);
                tm[..hi - lo].copy_from_slice(&stream.times[lo..hi]);
                let last = stream.times[hi - 1];
                tm[hi - lo..].fill(last);
            }
            let ev_l = lit_i32(&ev, &[c as i64])?;
            let tm_l = lit_i32(&tm, &[c as i64])?;

            let inputs: Vec<&xla::Literal> = match algo {
                Algo::A1 => vec![&types_l, &tlow_l, &thigh_l, &ev_l, &tm_l, &s_l, &cnt_l],
                Algo::A2 => vec![&types_l, &thigh_l, &ev_l, &tm_l, &s_l, &cnt_l],
            };
            let result = exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
            let mut parts = result.to_tuple()?;
            if parts.len() != 2 {
                return Err(MineError::accel(format!(
                    "expected (s, cnt) tuple from {name}, got {} parts",
                    parts.len()
                )));
            }
            cnt_l = parts.pop().unwrap();
            s_l = parts.pop().unwrap();
        }

        let cnt = vec_i32(&cnt_l)?;
        counts.extend(batch.iter().enumerate().map(|(j, _)| cnt[j] as u64));
    }
    Ok(counts)
}

/// MapConcatenate Map step on the accelerator: returns, per episode, per
/// segment, the N `(a, count, b)` boundary-machine tuples. The stream must
/// fit in one MapConcatenate chunk.
pub fn mapcat_map(
    rt: &Runtime,
    episodes: &[Episode],
    stream: &EventStream,
    taus: &[Tick],
) -> Result<Vec<Vec<Vec<(Tick, u64, Tick)>>>, MineError> {
    if episodes.is_empty() {
        return Ok(vec![]);
    }
    let n = episodes[0].n();
    if !episodes.iter().all(|e| e.n() == n) {
        return Err(MineError::internal("mixed episode sizes in batch"));
    }
    if n < 2 {
        return Err(MineError::internal("MapConcatenate needs n >= 2"));
    }
    if !rt.supports_n(n) {
        return Err(MineError::UnsupportedEpisodeSize {
            backend: format!("pjrt:mapcat_n{n}"),
            n,
        });
    }
    let mf = *rt.manifest();
    let (e_cap, p, c) = (mf.mc_episodes, mf.mc_segments, mf.mc_chunk);
    if taus.len() != p + 1 {
        return Err(MineError::internal(format!(
            "need exactly {} segment boundaries, got {}",
            p + 1,
            taus.len()
        )));
    }
    if stream.len() > c {
        return Err(MineError::internal(format!(
            "stream ({} events) exceeds MapConcatenate chunk {c}",
            stream.len()
        )));
    }
    let exe = rt.executable(&format!("mapcat_n{n}"))?;

    // events padded past every window: pad time = taus[P] + 1
    let mut ev = vec![mf.ev_pad; c];
    let mut tm = vec![taus[p] + 1; c];
    ev[..stream.len()].copy_from_slice(&stream.types);
    tm[..stream.len()].copy_from_slice(&stream.times);
    let ev_l = lit_i32(&ev, &[c as i64])?;
    let tm_l = lit_i32(&tm, &[c as i64])?;
    let taus_l = lit_i32(taus, &[(p + 1) as i64])?;
    // scan-start index per segment: first event of the previous segment
    let mut seg_lo = vec![0i32; p];
    for i in 1..p {
        seg_lo[i] = stream.first_after(taus[i - 1]) as i32;
    }
    let seglo_l = lit_i32(&seg_lo, &[p as i64])?;

    let mut out = Vec::with_capacity(episodes.len());
    for batch in episodes.chunks(e_cap) {
        let mut types = vec![mf.ep_pad; e_cap * n];
        let mut tlow = vec![0i32; e_cap * (n - 1)];
        let mut thigh = vec![0i32; e_cap * (n - 1)];
        for (j, ep) in batch.iter().enumerate() {
            types[j * n..(j + 1) * n].copy_from_slice(&ep.types);
            for (g, iv) in ep.intervals.iter().enumerate() {
                tlow[j * (n - 1) + g] = iv.t_low;
                thigh[j * (n - 1) + g] = iv.t_high;
            }
        }
        let types_l = lit_i32(&types, &[e_cap as i64, n as i64])?;
        let tlow_l = lit_i32(&tlow, &[e_cap as i64, (n - 1) as i64])?;
        let thigh_l = lit_i32(&thigh, &[e_cap as i64, (n - 1) as i64])?;

        let inputs = [&types_l, &tlow_l, &thigh_l, &ev_l, &tm_l, &taus_l, &seglo_l];
        let result = exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 3 {
            return Err(MineError::accel("expected (a, cnt, b) tuple from mapcat"));
        }
        let a = vec_i32(&parts[0])?;
        let cnt = vec_i32(&parts[1])?;
        let b = vec_i32(&parts[2])?;

        for (j, _) in batch.iter().enumerate() {
            let mut per_seg = Vec::with_capacity(p);
            for seg in 0..p {
                let base = (j * p + seg) * n;
                per_seg.push(
                    (0..n)
                        .map(|mk| (a[base + mk], cnt[base + mk] as u64, b[base + mk]))
                        .collect::<Vec<_>>(),
                );
            }
            out.push(per_seg);
        }
    }
    Ok(out)
}
