//! The paper's optimized multi-threaded CPU baseline (§6.4, Fig. 11).
//!
//! Each thread owns a disjoint subset of the candidate episodes and makes
//! exactly one pass over the event stream, updating all of its automata on
//! each event. The "acceleration structure" the paper mentions is the
//! per-event-type watcher index: event type -> [(episode, level), ...], so
//! an event only touches the automata that watch its type (at neural
//! alphabet sizes this cuts the inner loop by ~|alphabet|×).

use std::collections::HashMap;

use crate::episodes::Episode;
use crate::events::{EventStream, EventType, Tick};

/// Per-episode Algorithm-1 automaton state (unbounded lists).
struct A1State {
    lists: Vec<Vec<Tick>>,
}

/// Count all episodes with `n_threads` worker threads (the paper used 4 on
/// a quad-core). Returns counts in episode order.
pub fn count_all_parallel(
    episodes: &[Episode],
    stream: &EventStream,
    n_threads: usize,
) -> Vec<u64> {
    scatter_parallel(episodes, n_threads, |eps| count_subset(eps, stream))
}

/// The worker-split shell shared by the parallel counting paths: chunk the
/// episodes across `n_threads` scoped workers, run `per_chunk` on each
/// subset, and scatter results back into episode order.
pub fn scatter_parallel<F>(episodes: &[Episode], n_threads: usize, per_chunk: F) -> Vec<u64>
where
    F: Fn(&[Episode]) -> Vec<u64> + Sync,
{
    assert!(n_threads > 0);
    let mut counts = vec![0u64; episodes.len()];
    let chunk = episodes.len().div_ceil(n_threads);
    if chunk == 0 {
        return counts;
    }
    std::thread::scope(|scope| {
        let per_chunk = &per_chunk;
        let mut handles = vec![];
        for (ti, eps) in episodes.chunks(chunk).enumerate() {
            let handle = scope.spawn(move || (ti, per_chunk(eps)));
            handles.push(handle);
        }
        for h in handles {
            let (ti, sub) = h.join().expect("worker panicked");
            counts[ti * chunk..ti * chunk + sub.len()].copy_from_slice(&sub);
        }
    });
    counts
}

/// One pass over the stream counting a subset of episodes, with the
/// event-type watcher index.
fn count_subset(episodes: &[Episode], stream: &EventStream) -> Vec<u64> {
    let mut counts = vec![0u64; episodes.len()];
    // 1-node episodes are plain frequencies; handle inline.
    let mut states: Vec<A1State> = episodes
        .iter()
        .map(|e| A1State { lists: vec![vec![]; e.n()] })
        .collect();
    // watchers[e] = [(episode index, level)], levels descending per episode
    // so one event cannot serve two adjacent levels of the same episode.
    let mut watchers: HashMap<EventType, Vec<(u32, u32)>> = HashMap::new();
    for (j, ep) in episodes.iter().enumerate() {
        for (lvl, &ty) in ep.types.iter().enumerate().rev() {
            watchers.entry(ty).or_default().push((j as u32, lvl as u32));
        }
    }
    // group by episode preserving descending level order within a group
    for list in watchers.values_mut() {
        list.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
    }

    for (e, t) in stream.iter() {
        let Some(watch) = watchers.get(&e) else { continue };
        let mut idx = 0;
        while idx < watch.len() {
            let (j, _) = watch[idx];
            // process this episode's matching levels (desc) until
            // completion or exhaustion
            let ep = &episodes[j as usize];
            let n = ep.n();
            let st = &mut states[j as usize];
            let mut completed = false;
            while idx < watch.len() && watch[idx].0 == j {
                let lvl = watch[idx].1 as usize;
                idx += 1;
                if completed {
                    continue;
                }
                if n == 1 {
                    counts[j as usize] += 1;
                    completed = true;
                } else if lvl == 0 {
                    st.lists[0].push(t);
                } else {
                    let iv = &ep.intervals[lvl - 1];
                    if st.lists[lvl - 1].iter().rev().any(|&tp| iv.admits(t - tp)) {
                        if lvl == n - 1 {
                            counts[j as usize] += 1;
                            st.lists.iter_mut().for_each(Vec::clear);
                            completed = true;
                        } else {
                            st.lists[lvl].push(t);
                        }
                    }
                }
            }
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;
    use crate::mining::serial;
    use crate::util::rng::Rng;

    fn random_world(seed: u64, n_eps: usize) -> (Vec<Episode>, EventStream) {
        let mut rng = Rng::new(seed);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..500 {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 5), t));
        }
        let stream = EventStream::from_pairs(pairs, 6);
        let mut eps = vec![];
        for _ in 0..n_eps {
            let n = rng.range_i32(1, 4) as usize;
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 5)).collect();
            let ivs: Vec<Interval> = (0..n.saturating_sub(1))
                .map(|_| {
                    let lo = rng.range_i32(0, 2);
                    Interval::new(lo, lo + rng.range_i32(1, 8))
                })
                .collect();
            eps.push(Episode::new(types, ivs));
        }
        (eps, stream)
    }

    #[test]
    fn matches_serial_reference() {
        for seed in 0..5 {
            let (eps, stream) = random_world(seed, 23);
            let par = count_all_parallel(&eps, &stream, 4);
            let ser: Vec<u64> = eps.iter().map(|e| serial::count_a1(e, &stream)).collect();
            assert_eq!(par, ser, "seed {seed}");
        }
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let (eps, stream) = random_world(42, 17);
        let one = count_all_parallel(&eps, &stream, 1);
        let eight = count_all_parallel(&eps, &stream, 8);
        assert_eq!(one, eight);
    }

    #[test]
    fn empty_inputs() {
        let (_, stream) = random_world(1, 0);
        assert!(count_all_parallel(&[], &stream, 4).is_empty());
    }
}
