//! Window-based episode frequency (Mannila et al. [9]) — the *other*
//! algorithm class the paper positions state-machine counting against
//! (§3 Prior Work). Implemented as a comparison baseline: frequency is
//! the number of width-`w` sliding windows (one per tick) containing at
//! least one occurrence of the episode.
//!
//! The per-window definition here follows WINEPI for serial episodes
//! *without* inter-event constraints beyond the window width itself —
//! exactly the setting of [9] — so it is a semantic baseline, not a
//! drop-in replacement for Algorithm 1 (the paper's point: non-overlapped
//! state-machine counts are both cheaper and better suited to the
//! neuroscience interpretation).

use crate::episodes::Episode;
use crate::events::{EventStream, Tick};

/// Number of windows `(t, t + w]`, for t in [t_begin - w, t_end),
/// containing an occurrence of the serial episode (types only; the
/// window width is the only temporal constraint, per [9]).
///
/// Runs the standard WINEPI recognition trick in O(|stream| * N) per
/// episode: track, for each episode prefix, the latest window start time
/// at which the prefix completes; a window contains the episode iff the
/// full-prefix completion is fresh enough.
pub fn count_windows(ep: &Episode, stream: &EventStream, w: Tick) -> u64 {
    assert!(w > 0);
    if stream.is_empty() {
        return 0;
    }
    let w_begin = stream.t_begin() - w; // first window start
    // Find all minimal occurrences (O(|S| * N)), then count the union of
    // the window-start intervals each occurrence covers: a window (s, s+w]
    // contains occurrence [os, oe] iff oe - w <= s < os (s in ticks).
    let occs = minimal_occurrences(ep, stream, w);
    let mut intervals: Vec<(Tick, Tick)> = occs
        .into_iter()
        // (s, s+w] contains [os, oe] iff oe - w <= s <= os - 1
        .map(|(os, oe)| ((oe - w).max(w_begin), os - 1))
        .filter(|(lo, hi)| lo <= hi)
        .collect();
    intervals.sort_unstable();
    let mut total: u64 = 0;
    let mut cur: Option<(Tick, Tick)> = None;
    for (lo, hi) in intervals {
        match cur {
            None => cur = Some((lo, hi)),
            Some((clo, chi)) => {
                if lo <= chi + 1 {
                    cur = Some((clo, chi.max(hi)));
                } else {
                    total += (chi - clo + 1) as u64;
                    cur = Some((lo, hi));
                }
            }
        }
    }
    if let Some((clo, chi)) = cur {
        total += (chi - clo + 1) as u64;
    }
    total
}

/// All minimal occurrences (start, end) of the episode with span < w:
/// occurrences such that no other occurrence is strictly inside them.
pub fn minimal_occurrences(ep: &Episode, stream: &EventStream, w: Tick) -> Vec<(Tick, Tick)> {
    let n = ep.n();
    if n == 1 {
        return stream
            .iter()
            .filter(|&(e, _)| e == ep.types[0])
            .map(|(_, t)| (t, t))
            .collect();
    }
    const NONE: Tick = i32::MIN / 2;
    // latest_start[i]: latest start time of an occurrence of prefix 0..=i
    // ending at or before the current event
    let mut latest_start: Vec<Tick> = vec![NONE; n];
    let mut out = vec![];
    for (e, t) in stream.iter() {
        for i in (0..n).rev() {
            if ep.types[i] != e {
                continue;
            }
            if i == 0 {
                latest_start[0] = t;
            } else if latest_start[i - 1] != NONE && t - latest_start[i - 1] < w {
                latest_start[i] = latest_start[i - 1];
                if i == n - 1 {
                    let s = latest_start[n - 1];
                    // minimality: drop a previous occurrence that strictly
                    // contains this one
                    if let Some(&(ps, pe)) = out.last() {
                        if ps <= s && t <= pe {
                            out.pop();
                        }
                    }
                    if out.last().map(|&(ps, pe)| !(s <= ps && pe <= t)).unwrap_or(true) {
                        out.push((s, t));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Episode;

    fn stream(pairs: Vec<(i32, i32)>) -> EventStream {
        EventStream::from_pairs(pairs, 8)
    }

    fn ep(types: Vec<i32>) -> Episode {
        let n = types.len();
        Episode::new(
            types,
            vec![crate::episodes::Interval::new(0, 1_000_000); n - 1],
        )
    }

    #[test]
    fn single_occurrence_window_count() {
        // A@10, B@12; w=5: windows (s, s+5] containing both: s in [7..9]
        // -> 10-7=3 starts {7,8,9}
        let s = stream(vec![(0, 10), (1, 12)]);
        let c = count_windows(&ep(vec![0, 1]), &s, 5);
        assert_eq!(c, 3);
    }

    #[test]
    fn occurrence_wider_than_window_not_counted() {
        let s = stream(vec![(0, 10), (1, 30)]);
        assert_eq!(count_windows(&ep(vec![0, 1]), &s, 5), 0);
    }

    #[test]
    fn overlapping_occurrences_union_windows() {
        let s = stream(vec![(0, 10), (1, 12), (0, 13), (1, 15)]);
        let c = count_windows(&ep(vec![0, 1]), &s, 5);
        // occurrences (10,12) and (13,15): window starts [7,9] and [10,12]
        // union = {7..12} = 6
        assert_eq!(c, 6);
    }

    #[test]
    fn minimal_occurrences_drop_containing() {
        let s = stream(vec![(0, 1), (0, 5), (1, 7)]);
        let occs = minimal_occurrences(&ep(vec![0, 1]), &s, 20);
        assert_eq!(occs, vec![(5, 7)]); // (1,7) contains (5,7) -> dropped
    }

    #[test]
    fn window_frequency_monotone_in_w() {
        let s = stream(vec![(0, 5), (2, 7), (1, 9), (0, 20), (1, 26)]);
        let e = ep(vec![0, 1]);
        let mut prev = 0;
        for w in [2, 4, 6, 8, 12] {
            let c = count_windows(&e, &s, w);
            assert!(c >= prev, "w={w}: {c} < {prev}");
            prev = c;
        }
    }
}
