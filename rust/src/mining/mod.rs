//! CPU reference mining algorithms.
//!
//! These are (a) the ground truth the accelerated path is tested against,
//! (b) the paper's CPU baseline (§6.4) for the Fig. 11 comparison, and
//! (c) the instrumented telemetry source for the GTX280 profiler model
//! (Fig. 10).

pub mod serial;
pub mod cpu_parallel;
pub mod telemetry;
pub mod windows;

use crate::episodes::Episode;
use crate::events::EventStream;

/// Count non-overlapped occurrences for every episode (serial Algorithm 1,
/// unbounded lists — the exact reference).
pub fn count_all_serial(episodes: &[Episode], stream: &EventStream) -> Vec<u64> {
    episodes.iter().map(|e| serial::count_a1(e, stream)).collect()
}

/// Count under the relaxed constraints for every episode (Algorithm 3).
pub fn count_all_a2_serial(episodes: &[Episode], stream: &EventStream) -> Vec<u64> {
    episodes.iter().map(|e| serial::count_a2(e, stream)).collect()
}
