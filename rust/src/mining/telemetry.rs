//! Instrumented counting: the telemetry source for the GTX280 profiler
//! model (Fig. 10 reproduction).
//!
//! We cannot run the CUDA Visual Profiler on this substrate, so we count
//! the *algorithmic events* those hardware counters measure, simulating
//! SIMT execution over warps of 32 episode-lanes:
//!
//! - **divergent branches**: a data-dependent branch (type-match test,
//!   constraint-satisfaction test, completion test) whose outcome differs
//!   across the active lanes of a warp — on the GTX280 every such branch
//!   serializes both paths.
//! - **local loads/stores**: A1's per-level occurrence lists exceed the
//!   register budget (paper: 17 registers + 80 B local per A1 thread) and
//!   spill to local memory, so every list probe is a local load and every
//!   list update a local store. A2's single-timestamp state fits in
//!   registers (13 registers, no local memory), so its counters are zero
//!   by construction — matching the profiler numbers in Fig. 10(a).

use crate::episodes::Episode;
use crate::events::{EventStream, Tick};

pub const WARP: usize = 32;

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProfileCounters {
    pub branches: u64,
    pub divergent_branches: u64,
    pub local_loads: u64,
    pub local_stores: u64,
}

impl ProfileCounters {
    pub fn add(&mut self, o: &ProfileCounters) {
        self.branches += o.branches;
        self.divergent_branches += o.divergent_branches;
        self.local_loads += o.local_loads;
        self.local_stores += o.local_stores;
    }
}

/// Tally a warp-level branch: one branch instruction issued; divergent if
/// both outcomes are present among active lanes.
#[inline]
fn tally_branch(c: &mut ProfileCounters, taken: u32, active: u32) {
    debug_assert!(taken & !active == 0);
    c.branches += 1;
    if taken != 0 && taken != active {
        c.divergent_branches += 1;
    }
}

/// Profile Algorithm A1 (bounded lists, as on the GPU) over warps of 32
/// episodes. Returns aggregated counters; counting results are discarded
/// (use `serial::count_a1_bounded` for counts).
pub fn profile_a1(episodes: &[Episode], stream: &EventStream, k: usize) -> ProfileCounters {
    let mut total = ProfileCounters::default();
    for warp in episodes.chunks(WARP) {
        total.add(&profile_a1_warp(warp, stream, k));
    }
    total
}

fn profile_a1_warp(warp: &[Episode], stream: &EventStream, k: usize) -> ProfileCounters {
    let mut c = ProfileCounters::default();
    let lanes = warp.len();
    let all: u32 = if lanes == 32 { u32::MAX } else { (1u32 << lanes) - 1 };
    let max_n = warp.iter().map(|e| e.n()).max().unwrap_or(0);
    let mut states: Vec<Vec<Vec<Tick>>> = warp.iter().map(|e| vec![vec![]; e.n()]).collect();
    for (e, t) in stream.iter() {
        let mut done: u32 = 0;
        for i in (0..max_n).rev() {
            // SIMT: every lane evaluates the level-i type-match branch.
            let mut match_mask: u32 = 0;
            for (l, ep) in warp.iter().enumerate() {
                if i < ep.n() && ep.types[i] == e && done & (1 << l) == 0 {
                    match_mask |= 1 << l;
                }
            }
            tally_branch(&mut c, match_mask, all & !done);
            if match_mask == 0 {
                continue;
            }
            // Matching lanes probe their level i-1 list (local loads) and
            // branch on whether a satisfying entry exists.
            let mut sat_mask: u32 = 0;
            for l in 0..lanes {
                if match_mask & (1 << l) == 0 {
                    continue;
                }
                let ep = &warp[l];
                if i == 0 {
                    push_bounded(&mut states[l][0], t, k);
                    c.local_stores += 1;
                    continue;
                }
                let iv = &ep.intervals[i - 1];
                let mut found = false;
                for &tp in states[l][i - 1].iter().rev() {
                    c.local_loads += 1; // each probe reads a spilled slot
                    if iv.admits(t - tp) {
                        found = true;
                        break;
                    }
                }
                if found {
                    sat_mask |= 1 << l;
                }
            }
            if i == 0 {
                continue;
            }
            tally_branch(&mut c, sat_mask, match_mask);
            for l in 0..lanes {
                if sat_mask & (1 << l) == 0 {
                    continue;
                }
                let n = warp[l].n();
                if i == n - 1 {
                    // completion: clear all lists (stores) and consume event
                    let cleared: u64 = states[l].iter().map(|v| v.len() as u64).sum();
                    c.local_stores += cleared.max(1);
                    states[l].iter_mut().for_each(Vec::clear);
                    done |= 1 << l;
                } else {
                    push_bounded(&mut states[l][i], t, k);
                    c.local_stores += 1;
                }
            }
        }
    }
    c
}

/// Profile Algorithm A2 over warps of 32 episodes. A2's state is
/// register-resident, so local loads/stores stay zero; only branch
/// behavior is tallied.
pub fn profile_a2(episodes: &[Episode], stream: &EventStream) -> ProfileCounters {
    let mut total = ProfileCounters::default();
    for warp in episodes.chunks(WARP) {
        total.add(&profile_a2_warp(warp, stream));
    }
    total
}

fn profile_a2_warp(warp: &[Episode], stream: &EventStream) -> ProfileCounters {
    let mut c = ProfileCounters::default();
    let lanes = warp.len();
    let all: u32 = if lanes == 32 { u32::MAX } else { (1u32 << lanes) - 1 };
    let max_n = warp.iter().map(|e| e.n()).max().unwrap_or(0);
    let mut states: Vec<Vec<Option<Tick>>> = warp.iter().map(|e| vec![None; e.n()]).collect();
    for (e, t) in stream.iter() {
        let mut done: u32 = 0;
        for i in (0..max_n).rev() {
            let mut match_mask: u32 = 0;
            for (l, ep) in warp.iter().enumerate() {
                if i < ep.n() && ep.types[i] == e && done & (1 << l) == 0 {
                    match_mask |= 1 << l;
                }
            }
            tally_branch(&mut c, match_mask, all & !done);
            if match_mask == 0 {
                continue;
            }
            let mut sat_mask: u32 = 0;
            for l in 0..lanes {
                if match_mask & (1 << l) == 0 {
                    continue;
                }
                if i == 0 {
                    states[l][0] = Some(t);
                    continue;
                }
                let ep = &warp[l];
                if let Some(tp) = states[l][i - 1] {
                    let d = t - tp;
                    if 0 <= d && d <= ep.intervals[i - 1].t_high {
                        sat_mask |= 1 << l;
                    }
                }
            }
            if i == 0 {
                continue;
            }
            tally_branch(&mut c, sat_mask, match_mask);
            for l in 0..lanes {
                if sat_mask & (1 << l) == 0 {
                    continue;
                }
                let n = warp[l].n();
                if i == n - 1 {
                    states[l].iter_mut().for_each(|x| *x = None);
                    done |= 1 << l;
                } else {
                    states[l][i] = Some(t);
                }
            }
        }
    }
    c
}

#[inline]
fn push_bounded(list: &mut Vec<Tick>, t: Tick, k: usize) {
    list.push(t);
    if list.len() > k {
        list.remove(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;
    use crate::util::rng::Rng;

    fn world(seed: u64, n_eps: usize, n: usize) -> (Vec<Episode>, EventStream) {
        let mut rng = Rng::new(seed);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..800 {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 6), t));
        }
        let stream = EventStream::from_pairs(pairs, 7);
        let eps = (0..n_eps)
            .map(|_| {
                let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 6)).collect();
                let ivs = (0..n - 1)
                    .map(|_| {
                        let lo = rng.range_i32(0, 2);
                        Interval::new(lo, lo + rng.range_i32(3, 10))
                    })
                    .collect();
                Episode::new(types, ivs)
            })
            .collect();
        (eps, stream)
    }

    #[test]
    fn a2_has_no_local_memory_traffic() {
        let (eps, stream) = world(1, 64, 4);
        let c = profile_a2(&eps, &stream);
        assert_eq!(c.local_loads, 0);
        assert_eq!(c.local_stores, 0);
        assert!(c.branches > 0);
    }

    #[test]
    fn a1_has_local_memory_traffic() {
        let (eps, stream) = world(2, 64, 4);
        let c = profile_a1(&eps, &stream, 8);
        assert!(c.local_loads > 0);
        assert!(c.local_stores > 0);
    }

    #[test]
    fn a1_diverges_more_than_a2_fig10b() {
        // Fig. 10(b): A1's divergent-branch count exceeds A2's — the list
        // search introduces extra data-dependent branching.
        let (eps, stream) = world(3, 128, 5);
        let c1 = profile_a1(&eps, &stream, 8);
        let c2 = profile_a2(&eps, &stream);
        assert!(
            c1.divergent_branches + c1.local_loads > c2.divergent_branches,
            "a1 {c1:?} vs a2 {c2:?}"
        );
    }

    #[test]
    fn divergence_zero_for_identical_lanes() {
        // a warp of identical episodes never diverges
        let (mut eps, stream) = world(4, 1, 3);
        let proto = eps.pop().unwrap();
        let eps: Vec<Episode> = (0..32).map(|_| proto.clone()).collect();
        let c = profile_a1(&eps, &stream, 8);
        assert_eq!(c.divergent_branches, 0);
    }

    #[test]
    fn counters_scale_with_episode_count() {
        let (eps, stream) = world(5, 64, 3);
        let half = profile_a1(&eps[..32], &stream, 8);
        let full = profile_a1(&eps, &stream, 8);
        assert!(full.branches > half.branches);
    }
}
