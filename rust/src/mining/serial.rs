//! Serial counting algorithms, straight from the paper's pseudocode.
//!
//! `count_a1` is Algorithm 1 (exact, unbounded per-level occurrence
//! lists); `count_a1_bounded` bounds the lists to the K most recent
//! entries (bit-for-bit the semantics of the Pallas A1 kernel);
//! `count_a2` is Algorithm 3 (relaxed constraints, single timestamp per
//! level — Observation 5.1). These mirror `python/compile/kernels/ref.py`
//! exactly; the shared fixtures in `rust/tests/cross_fixtures.rs` pin both
//! sides together.

use std::collections::VecDeque;

use crate::episodes::Episode;
use crate::events::{EventStream, Tick};

/// Paper Algorithm 1: exact non-overlapped count with `(t_low, t_high]`
/// inter-event constraints, unbounded lists.
pub fn count_a1(ep: &Episode, stream: &EventStream) -> u64 {
    let n = ep.n();
    if n == 1 {
        return stream.types.iter().filter(|&&e| e == ep.types[0]).count() as u64;
    }
    let mut count = 0u64;
    let mut s: Vec<Vec<Tick>> = vec![vec![]; n];
    for (e, t) in stream.iter() {
        let mut completed = false;
        for i in (0..n).rev() {
            if e != ep.types[i] {
                continue;
            }
            if i == 0 {
                s[0].push(t);
            } else {
                let iv = &ep.intervals[i - 1];
                // latest-first search, stop at the first satisfying entry
                if s[i - 1].iter().rev().any(|&tp| iv.admits(t - tp)) {
                    if i == n - 1 {
                        count += 1;
                        s.iter_mut().for_each(Vec::clear);
                        completed = true;
                    } else {
                        s[i].push(t);
                    }
                }
            }
            if completed {
                break;
            }
        }
    }
    count
}

/// Algorithm 1 with per-level lists bounded to the K most recent entries —
/// the exact semantics of the GPU/Pallas A1 kernel. Requires `k >= 1`
/// (a zero-slot automaton is meaningless; debug builds assert);
/// `k == usize::MAX` never evicts, i.e. behaves as unbounded `count_a1`.
pub fn count_a1_bounded(ep: &Episode, stream: &EventStream, k: usize) -> u64 {
    debug_assert!(k >= 1, "bounded lists need at least one slot");
    let n = ep.n();
    if n == 1 {
        return stream.types.iter().filter(|&&e| e == ep.types[0]).count() as u64;
    }
    let mut count = 0u64;
    let mut s: Vec<VecDeque<Tick>> = vec![bounded_list(k); n];
    for (e, t) in stream.iter() {
        let mut completed = false;
        for i in (0..n).rev() {
            if e != ep.types[i] {
                continue;
            }
            if i == 0 {
                push_bounded(&mut s[0], t, k);
            } else {
                let iv = &ep.intervals[i - 1];
                if s[i - 1].iter().rev().any(|&tp| iv.admits(t - tp)) {
                    if i == n - 1 {
                        count += 1;
                        s.iter_mut().for_each(VecDeque::clear);
                        completed = true;
                    } else {
                        push_bounded(&mut s[i], t, k);
                    }
                }
            }
            if completed {
                break;
            }
        }
    }
    count
}

/// A fresh bounded occurrence list. Small K pre-allocates exactly;
/// unbounded (`usize::MAX`) grows on demand.
#[inline]
fn bounded_list(k: usize) -> VecDeque<Tick> {
    VecDeque::with_capacity(k.saturating_add(1).min(64))
}

/// Ring-buffer push: evicting the oldest entry is O(1), unlike the
/// `Vec::remove(0)` memmove this hot path used to pay on every bounded
/// push. `k == usize::MAX` never evicts.
#[inline]
fn push_bounded(list: &mut VecDeque<Tick>, t: Tick, k: usize) {
    if list.len() >= k {
        list.pop_front();
    }
    list.push_back(t);
}

/// Paper Algorithm 3: relaxed counting (upper bounds only), single
/// timestamp per level. The effective relaxation is `[0, t_high]` — see
/// the A2 kernel docs for why `d == 0` must be admitted.
pub fn count_a2(ep: &Episode, stream: &EventStream) -> u64 {
    let n = ep.n();
    if n == 1 {
        return stream.types.iter().filter(|&&e| e == ep.types[0]).count() as u64;
    }
    let mut count = 0u64;
    let mut s: Vec<Option<Tick>> = vec![None; n];
    for (e, t) in stream.iter() {
        let mut completed = false;
        for i in (0..n).rev() {
            if e != ep.types[i] {
                continue;
            }
            if i == 0 {
                s[0] = Some(t);
            } else if let Some(tp) = s[i - 1] {
                let d = t - tp;
                if 0 <= d && d <= ep.intervals[i - 1].t_high {
                    if i == n - 1 {
                        count += 1;
                        s.iter_mut().for_each(|x| *x = None);
                        completed = true;
                    } else {
                        s[i] = Some(t);
                    }
                }
            }
            if completed {
                break;
            }
        }
    }
    count
}

/// MapConcatenate boundary-machine Map step on the CPU (reference for the
/// Pallas kernel and the Concatenate input when running CPU-only).
/// Returns, per segment, the N `(a, count, b)` machine tuples.
pub fn mapcat_map(
    ep: &Episode,
    stream: &EventStream,
    taus: &[Tick],
    k: usize,
) -> Vec<Vec<(Tick, u64, Tick)>> {
    let n = ep.n();
    assert!(n >= 2);
    debug_assert!(k >= 1, "bounded lists need at least one slot");
    let sumh = ep.span_max();
    let p_count = taus.len() - 1;
    let mut out = Vec::with_capacity(p_count);
    for p in 0..p_count {
        let (tau_p, tau_p1) = (taus[p], taus[p + 1]);
        let stop = tau_p1 + sumh;
        let mut tuples = Vec::with_capacity(n);
        for mk in 0..n {
            let start: Tick = tau_p - ep.intervals[..mk].iter().map(|iv| iv.t_high).sum::<Tick>();
            let mut s: Vec<VecDeque<Tick>> = vec![bounded_list(k); n];
            let (mut cnt, mut a, mut b) = (0u64, tau_p, tau_p1);
            let (mut a_closed, mut frozen) = (false, false);
            for (e, t) in stream.iter() {
                // inclusive stop: a crossing occurrence can complete at
                // exactly tau_{p+1} + sum(t_high) (first event exactly on
                // the boundary). The paper's strict "<" (§5.2.2 step 4)
                // loses it and desynchronizes the b == a chain.
                if t > stop || frozen {
                    break;
                }
                if t <= start {
                    continue;
                }
                let mut completed = false;
                for i in (0..n).rev() {
                    if e != ep.types[i] {
                        continue;
                    }
                    if i == 0 {
                        push_bounded(&mut s[0], t, k);
                    } else {
                        let iv = &ep.intervals[i - 1];
                        if s[i - 1].iter().rev().any(|&tp| iv.admits(t - tp)) {
                            if i == n - 1 {
                                completed = true;
                            } else {
                                push_bounded(&mut s[i], t, k);
                            }
                        }
                    }
                    if completed {
                        break;
                    }
                }
                if completed {
                    s.iter_mut().for_each(VecDeque::clear);
                    if tau_p < t && t <= tau_p1 {
                        cnt += 1;
                        // inclusive window, mirroring the crossing window
                        if !a_closed && t <= tau_p + sumh {
                            a = t;
                        }
                        a_closed = true;
                    } else if t > tau_p1 {
                        b = t;
                        frozen = true;
                    }
                }
            }
            tuples.push((a, cnt, b));
        }
        out.push(tuples);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;
    use crate::util::rng::Rng;

    fn ep(types: Vec<i32>, lows: Vec<i32>, highs: Vec<i32>) -> Episode {
        let ivs = lows
            .into_iter()
            .zip(highs)
            .map(|(l, h)| Interval::new(l, h))
            .collect();
        Episode::new(types, ivs)
    }

    fn stream(pairs: Vec<(i32, i32)>) -> EventStream {
        EventStream::from_pairs(pairs, 10)
    }

    #[test]
    fn a1_basic_two_occurrences() {
        let s = stream(vec![(0, 1), (1, 8), (2, 20), (0, 30), (1, 35), (2, 45)]);
        let e = ep(vec![0, 1, 2], vec![0, 0], vec![10, 15]);
        assert_eq!(count_a1(&e, &s), 2);
    }

    #[test]
    fn a1_lower_bound_needs_older_entry() {
        // most recent A fails t_low, older A satisfies — the list matters
        let s = stream(vec![(0, 0), (0, 9), (1, 10)]);
        let e = ep(vec![0, 1], vec![2], vec![10]);
        assert_eq!(count_a1(&e, &s), 1);
        assert_eq!(count_a1_bounded(&e, &s, 8), 1);
        assert_eq!(count_a1_bounded(&e, &s, 1), 0); // K=1 truncates it away
    }

    #[test]
    fn a1_event_cannot_chain_itself() {
        let s = stream(vec![(0, 1), (0, 4)]);
        let e = ep(vec![0, 0], vec![0], vec![10]);
        assert_eq!(count_a1(&e, &s), 1);
    }

    #[test]
    fn a2_dominates_a1_with_ties() {
        // simultaneous events: A2 admits d == 0, A1 does not
        let s = stream(vec![(0, 5), (1, 5)]);
        let e = ep(vec![0, 1], vec![0], vec![10]);
        assert_eq!(count_a1(&e, &s), 0);
        assert_eq!(count_a2(&e, &s), 1);
    }

    #[test]
    fn theorem_5_1_on_random_streams() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n_ev = 200;
            let mut pairs = vec![];
            let mut t = 0;
            for _ in 0..n_ev {
                t += rng.range_i32(0, 4);
                pairs.push((rng.range_i32(0, 4), t));
            }
            let s = stream(pairs);
            let n = rng.range_i32(2, 4) as usize;
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 4)).collect();
            let lows: Vec<i32> = (0..n - 1).map(|_| rng.range_i32(0, 3)).collect();
            let highs: Vec<i32> = lows.iter().map(|&l| l + rng.range_i32(1, 9)).collect();
            let e = ep(types, lows, highs);
            assert!(count_a2(&e, &s) >= count_a1(&e, &s), "{}", e.display());
        }
    }

    #[test]
    fn n1_episode_is_frequency() {
        let s = stream(vec![(3, 1), (3, 2), (1, 3), (3, 9)]);
        assert_eq!(count_a1(&Episode::single(3), &s), 3);
        assert_eq!(count_a2(&Episode::single(3), &s), 3);
    }

    #[test]
    fn bounded_with_usize_max_equals_unbounded() {
        let mut rng = Rng::new(77);
        for _ in 0..20 {
            let mut pairs = vec![];
            let mut t = 0;
            for _ in 0..250 {
                t += rng.range_i32(0, 3);
                pairs.push((rng.range_i32(0, 4), t));
            }
            let s = stream(pairs);
            let n = rng.range_i32(2, 4) as usize;
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 4)).collect();
            let lows: Vec<i32> = (0..n - 1).map(|_| rng.range_i32(0, 3)).collect();
            let highs: Vec<i32> = lows.iter().map(|&l| l + rng.range_i32(1, 9)).collect();
            let e = ep(types, lows, highs);
            assert_eq!(count_a1_bounded(&e, &s, usize::MAX), count_a1(&e, &s));
        }
    }

    #[test]
    fn mapcat_single_segment_machine0_equals_serial() {
        let mut rng = Rng::new(5);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..300 {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 3), t));
        }
        let s = stream(pairs);
        let e = ep(vec![0, 1, 2], vec![1, 0], vec![8, 6]);
        let taus = vec![s.t_begin() - 1, s.t_end()];
        let tuples = mapcat_map(&e, &s, &taus, 8);
        assert_eq!(tuples[0][0].1, count_a1_bounded(&e, &s, 8));
    }
}
