//! The mining-phase profiler: where did this query's time go?
//!
//! A [`MineProfile`] is an optional attachment on
//! [`crate::coordinator::miner::MineResult`] recording, per level, the
//! generate / count / prune wall time of the block-streamed driver plus
//! the work volumes that explain them (candidate rows materialized,
//! blocks streamed), and the whole-query roll-ups the accelerator
//! crossover model needs (concatenate misses, shard Map calls, serial
//! recounts). It is off by default — `SessionBuilder::profile(true)` or
//! `--profile` turns it on — and it travels the cluster wire as an
//! optional field, so old peers interoperate unchanged.

use crate::util::json::Json;

/// Per-level phase breakdown of one generate-count-prune pass.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LevelProfile {
    pub level: usize,
    /// wall time in candidate generation (join + row materialization)
    pub generate_seconds: f64,
    /// wall time in the counting backend
    pub count_seconds: f64,
    /// wall time pruning + persisting survivors
    pub prune_seconds: f64,
    /// candidate rows materialized at this level
    pub candidates: u64,
    /// streamed candidate blocks (chunks handed to the backend)
    pub blocks: u64,
}

/// Whole-query phase profile. All counters are exact; times are wall
/// clock on the machine that ran the driver.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MineProfile {
    /// end-to-end driver wall time
    pub total_seconds: f64,
    pub levels: Vec<LevelProfile>,
    /// candidate rows materialized across all levels
    pub candidate_rows: u64,
    /// candidate blocks streamed across all levels
    pub blocks_streamed: u64,
    /// concatenate-fold misses (shard/halo chains that desynchronized)
    pub concat_misses: u64,
    /// sharded / scattered Map dispatches
    pub shard_map_calls: u64,
    /// serial recounts that restored exactness after a miss
    pub serial_recounts: u64,
    /// how the serving layer satisfied this request, when it knows
    /// ("cache" for a cache hit; `None` = mined fresh)
    pub cache_outcome: Option<String>,
}

impl MineProfile {
    pub fn to_json(&self) -> Json {
        let levels = self
            .levels
            .iter()
            .map(|l| {
                Json::Obj(vec![
                    ("level".into(), Json::Num(l.level as f64)),
                    ("generate_seconds".into(), Json::Num(l.generate_seconds)),
                    ("count_seconds".into(), Json::Num(l.count_seconds)),
                    ("prune_seconds".into(), Json::Num(l.prune_seconds)),
                    ("candidates".into(), Json::Num(l.candidates as f64)),
                    ("blocks".into(), Json::Num(l.blocks as f64)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("total_seconds".into(), Json::Num(self.total_seconds)),
            ("levels".into(), Json::Arr(levels)),
            ("candidate_rows".into(), Json::Num(self.candidate_rows as f64)),
            ("blocks_streamed".into(), Json::Num(self.blocks_streamed as f64)),
            ("concat_misses".into(), Json::Num(self.concat_misses as f64)),
            ("shard_map_calls".into(), Json::Num(self.shard_map_calls as f64)),
            ("serial_recounts".into(), Json::Num(self.serial_recounts as f64)),
        ];
        if let Some(outcome) = &self.cache_outcome {
            fields.push(("cache_outcome".into(), Json::Str(outcome.clone())));
        }
        Json::Obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<MineProfile, crate::error::MineError> {
        use crate::error::MineError;
        let num = |o: &Json, k: &str| {
            o.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| MineError::invalid(format!("mine profile missing number {k:?}")))
        };
        let count = |o: &Json, k: &str| {
            o.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| MineError::invalid(format!("mine profile missing count {k:?}")))
        };
        let levels = v
            .get("levels")
            .and_then(Json::as_arr)
            .ok_or_else(|| MineError::invalid("mine profile missing levels array"))?
            .iter()
            .map(|l| {
                Ok(LevelProfile {
                    level: count(l, "level")? as usize,
                    generate_seconds: num(l, "generate_seconds")?,
                    count_seconds: num(l, "count_seconds")?,
                    prune_seconds: num(l, "prune_seconds")?,
                    candidates: count(l, "candidates")?,
                    blocks: count(l, "blocks")?,
                })
            })
            .collect::<Result<Vec<_>, MineError>>()?;
        Ok(MineProfile {
            total_seconds: num(v, "total_seconds")?,
            levels,
            candidate_rows: count(v, "candidate_rows")?,
            blocks_streamed: count(v, "blocks_streamed")?,
            concat_misses: count(v, "concat_misses")?,
            shard_map_calls: count(v, "shard_map_calls")?,
            serial_recounts: count(v, "serial_recounts")?,
            cache_outcome: v.get("cache_outcome").and_then(Json::as_str).map(str::to_string),
        })
    }

    /// Human-readable phase table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "profile: total {:.3}ms, {} candidate rows in {} blocks, \
             {} map calls, {} concat misses, {} serial recounts{}\n",
            self.total_seconds * 1e3,
            self.candidate_rows,
            self.blocks_streamed,
            self.shard_map_calls,
            self.concat_misses,
            self.serial_recounts,
            match &self.cache_outcome {
                Some(o) => format!(" [{o}]"),
                None => String::new(),
            }
        );
        out.push_str("  level  generate_ms  count_ms  prune_ms  candidates  blocks\n");
        for l in &self.levels {
            out.push_str(&format!(
                "  {:<5}  {:>11.3}  {:>8.3}  {:>8.3}  {:>10}  {:>6}\n",
                l.level,
                l.generate_seconds * 1e3,
                l.count_seconds * 1e3,
                l.prune_seconds * 1e3,
                l.candidates,
                l.blocks
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MineProfile {
        MineProfile {
            total_seconds: 0.25,
            levels: vec![
                LevelProfile {
                    level: 1,
                    generate_seconds: 0.0,
                    count_seconds: 0.1,
                    prune_seconds: 0.001,
                    candidates: 26,
                    blocks: 1,
                },
                LevelProfile {
                    level: 2,
                    generate_seconds: 0.02,
                    count_seconds: 0.12,
                    prune_seconds: 0.002,
                    candidates: 130,
                    blocks: 3,
                },
            ],
            candidate_rows: 156,
            blocks_streamed: 4,
            concat_misses: 1,
            shard_map_calls: 8,
            serial_recounts: 1,
            cache_outcome: None,
        }
    }

    #[test]
    fn json_round_trips() {
        let p = sample();
        let back = MineProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);

        let mut with_outcome = p;
        with_outcome.cache_outcome = Some("cache".into());
        let back = MineProfile::from_json(&with_outcome.to_json()).unwrap();
        assert_eq!(back.cache_outcome.as_deref(), Some("cache"));
    }

    #[test]
    fn malformed_profiles_are_typed_errors() {
        assert!(MineProfile::from_json(&Json::Obj(vec![])).is_err());
        let mut j = sample().to_json();
        if let Json::Obj(fields) = &mut j {
            fields.retain(|(k, _)| k != "levels");
        }
        assert!(MineProfile::from_json(&j).is_err());
    }

    #[test]
    fn render_mentions_every_level() {
        let text = sample().render();
        assert!(text.contains("level"), "{text}");
        assert!(text.lines().count() >= 4, "{text}");
    }
}
