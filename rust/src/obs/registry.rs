//! One metrics registry for the whole process: named typed counters,
//! gauges, and windowed histograms, with a single snapshot API rendering
//! Prometheus-style text and JSON.
//!
//! Handles are live: [`Registry::counter`] returns (get-or-creating) a
//! cheap cloneable [`Counter`] whose atomic *is* the counter the
//! subsystem increments — there is no copy step between "the number the
//! hot path bumps" and "the number the snapshot reports". The serving
//! pool, the scatter coordinator, and `coordinator::Metrics` all publish
//! through one registry instead of owning disjoint mutexed fields; a
//! snapshot is one consistent walk over sorted names.
//!
//! Names are dotted lowercase (`serve.submitted`,
//! `cluster.node.0.calls`); the Prometheus render sanitizes them to
//! `_`-separated and emits histogram quantiles as a `summary` family.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Default histogram window (samples kept for percentile estimation).
pub const DEFAULT_HIST_WINDOW: usize = 4096;

/// A monotonically increasing counter. Clone freely — clones share the
/// same atomic.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable signed gauge (queue depths, in-flight counts).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistBuf {
    window: VecDeque<f64>,
    cap: usize,
    /// lifetime observation count (window-independent)
    total: u64,
}

/// A windowed histogram: keeps the most recent `cap` samples and
/// summarizes them via [`Summary`]. Non-finite observations are dropped
/// at the door — a NaN can never poison the percentiles.
#[derive(Clone)]
pub struct Histogram(Arc<Mutex<HistBuf>>);

impl Histogram {
    fn new(cap: usize) -> Histogram {
        Histogram(Arc::new(Mutex::new(HistBuf {
            window: VecDeque::with_capacity(cap.min(1024)),
            cap: cap.max(1),
            total: 0,
        })))
    }

    pub fn observe(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let mut buf = self.0.lock().unwrap_or_else(|p| p.into_inner());
        if buf.window.len() >= buf.cap {
            buf.window.pop_front();
        }
        buf.window.push_back(v);
        buf.total += 1;
    }

    /// Lifetime observation count.
    pub fn count(&self) -> u64 {
        self.0.lock().unwrap_or_else(|p| p.into_inner()).total
    }

    /// Percentile summary over the current window (`None` while empty).
    pub fn summary(&self) -> Option<Summary> {
        let buf = self.0.lock().unwrap_or_else(|p| p.into_inner());
        let samples: Vec<f64> = buf.window.iter().copied().collect();
        Summary::of_opt(&samples)
    }

    /// The current window, oldest first (the serving layer's legacy
    /// latency accessor).
    pub fn samples(&self) -> Vec<f64> {
        self.0.lock().unwrap_or_else(|p| p.into_inner()).window.iter().copied().collect()
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    histograms: BTreeMap<String, Histogram>,
}

/// The process-wide metric namespace. Cloning shares the namespace.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<RegistryInner>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or register the named counter.
    pub fn counter(&self, name: &str) -> Counter {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        inner.counters.entry(name.to_string()).or_default().clone()
    }

    /// Get or register the named gauge.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        inner.gauges.entry(name.to_string()).or_default().clone()
    }

    /// Get or register the named histogram with the default window.
    pub fn histogram(&self, name: &str) -> Histogram {
        self.histogram_windowed(name, DEFAULT_HIST_WINDOW)
    }

    /// Get or register the named histogram with an explicit window
    /// (first registration wins the window size).
    pub fn histogram_windowed(&self, name: &str, window: usize) -> Histogram {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        inner
            .histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(window))
            .clone()
    }

    /// One consistent snapshot of every registered metric, names sorted.
    pub fn snapshot(&self) -> Snapshot {
        let inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        Snapshot {
            counters: inner.counters.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            gauges: inner.gauges.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            histograms: inner
                .histograms
                .iter()
                .map(|(k, v)| (k.clone(), v.count(), v.summary()))
                .collect(),
        }
    }
}

/// A point-in-time view of a [`Registry`], renderable as Prometheus
/// text or JSON.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    /// (name, lifetime count, window summary)
    pub histograms: Vec<(String, u64, Option<Summary>)>,
}

/// Prometheus metric names: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn prom_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

impl Snapshot {
    /// Prometheus-style text exposition.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for (name, count, summary) in &self.histograms {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} summary\n"));
            if let Some(s) = summary {
                for (q, v) in [("0.5", s.median), ("0.95", s.p95), ("0.99", s.p99)] {
                    out.push_str(&format!("{n}{{quantile=\"{q}\"}} {v}\n"));
                }
                out.push_str(&format!("{n}_sum {}\n", s.mean * s.n as f64));
            }
            out.push_str(&format!("{n}_count {count}\n"));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let counters =
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect();
        let gauges =
            self.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect();
        let hists = self
            .histograms
            .iter()
            .map(|(k, count, summary)| {
                let mut fields = vec![("count".into(), Json::Num(*count as f64))];
                if let Some(s) = summary {
                    fields.extend([
                        ("window_n".into(), Json::Num(s.n as f64)),
                        ("mean".into(), Json::Num(s.mean)),
                        ("stddev".into(), Json::Num(s.stddev)),
                        ("p50".into(), Json::Num(s.median)),
                        ("p95".into(), Json::Num(s.p95)),
                        ("p99".into(), Json::Num(s.p99)),
                        ("min".into(), Json::Num(s.min)),
                        ("max".into(), Json::Num(s.max)),
                    ]);
                }
                (k.clone(), Json::Obj(fields))
            })
            .collect();
        Json::Obj(vec![
            ("counters".into(), Json::Obj(counters)),
            ("gauges".into(), Json::Obj(gauges)),
            ("histograms".into(), Json::Obj(hists)),
        ])
    }

    /// Rebuild a snapshot from its [`Snapshot::to_json`] shape — what the
    /// cluster `Stats` RPC ships — so a remote registry renders through
    /// the same Prometheus path as a local one. `None` on any shape
    /// mismatch (the peer may be older or hostile).
    pub fn from_json(j: &Json) -> Option<Snapshot> {
        fn fields(j: &Json) -> Option<&[(String, Json)]> {
            match j {
                Json::Obj(f) => Some(f),
                _ => None,
            }
        }
        let counters = fields(j.get("counters")?)?
            .iter()
            .map(|(k, v)| Some((k.clone(), v.as_u64()?)))
            .collect::<Option<Vec<_>>>()?;
        let gauges = fields(j.get("gauges")?)?
            .iter()
            .map(|(k, v)| Some((k.clone(), v.as_f64()? as i64)))
            .collect::<Option<Vec<_>>>()?;
        let histograms = fields(j.get("histograms")?)?
            .iter()
            .map(|(k, h)| {
                let count = h.get("count").and_then(Json::as_u64)?;
                let summary = h.get("window_n").and_then(Json::as_u64).map(|n| Summary {
                    n: n as usize,
                    mean: h.get("mean").and_then(Json::as_f64).unwrap_or(0.0),
                    median: h.get("p50").and_then(Json::as_f64).unwrap_or(0.0),
                    stddev: h.get("stddev").and_then(Json::as_f64).unwrap_or(0.0),
                    min: h.get("min").and_then(Json::as_f64).unwrap_or(0.0),
                    max: h.get("max").and_then(Json::as_f64).unwrap_or(0.0),
                    p95: h.get("p95").and_then(Json::as_f64).unwrap_or(0.0),
                    p99: h.get("p99").and_then(Json::as_f64).unwrap_or(0.0),
                });
                Some((k.clone(), count, summary))
            })
            .collect::<Option<Vec<_>>>()?;
        Some(Snapshot { counters, gauges, histograms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_live_and_shared() {
        let reg = Registry::new();
        let a = reg.counter("serve.submitted");
        let b = reg.counter("serve.submitted");
        a.inc();
        b.add(2);
        assert_eq!(reg.counter("serve.submitted").get(), 3);

        let g = reg.gauge("serve.queue_depth");
        g.set(5);
        g.add(-2);
        assert_eq!(reg.gauge("serve.queue_depth").get(), 3);
    }

    #[test]
    fn histograms_window_and_filter_non_finite() {
        let reg = Registry::new();
        let h = reg.histogram_windowed("lat", 4);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert!(h.summary().is_none());
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            h.observe(v);
        }
        // window of 4 keeps the most recent samples; total counts all
        let s = h.summary().unwrap();
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(h.count(), 5);
    }

    #[test]
    fn snapshot_renders_prometheus_and_json() {
        let reg = Registry::new();
        reg.counter("serve.submitted").add(7);
        reg.gauge("cluster.node.0.in_flight").set(2);
        let h = reg.histogram("serve.latency_ns");
        h.observe(10.0);
        h.observe(20.0);

        let snap = reg.snapshot();
        let text = snap.render_prometheus();
        assert!(text.contains("# TYPE serve_submitted counter"), "{text}");
        assert!(text.contains("serve_submitted 7"), "{text}");
        assert!(text.contains("cluster_node_0_in_flight 2"), "{text}");
        assert!(text.contains("serve_latency_ns{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("serve_latency_ns_count 2"), "{text}");

        let j = snap.to_json();
        assert_eq!(j.get("counters").and_then(|c| c.get("serve.submitted")).and_then(Json::as_u64), Some(7));
        let hist = j.get("histograms").and_then(|h| h.get("serve.latency_ns")).unwrap();
        assert_eq!(hist.get("count").and_then(Json::as_u64), Some(2));
        assert_eq!(hist.get("p50").and_then(Json::as_f64), Some(15.0));
        // render round-trips through the parser (it is real JSON)
        let parsed = Json::parse(&j.render()).unwrap();
        assert_eq!(parsed.get("gauges").and_then(|g| g.get("cluster.node.0.in_flight")).and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn snapshot_survives_the_wire_shape() {
        let reg = Registry::new();
        reg.counter("serve.submitted").add(3);
        reg.gauge("cluster.node.0.in_flight").set(-2);
        let h = reg.histogram("serve.latency_ns");
        h.observe(1.0);
        h.observe(3.0);
        let snap = reg.snapshot();

        // to_json -> render -> parse -> from_json is what `epminer stats
        // --connect` sees for a remote registry
        let wire = Json::parse(&snap.to_json().render()).unwrap();
        let back = Snapshot::from_json(&wire).unwrap();
        assert_eq!(back.counters, snap.counters);
        assert_eq!(back.gauges, snap.gauges);
        assert_eq!(back.render_prometheus(), snap.render_prometheus());

        // shape mismatches are None, not panics
        assert!(Snapshot::from_json(&Json::Num(1.0)).is_none());
        assert!(Snapshot::from_json(&Json::Obj(vec![])).is_none());
    }

    #[test]
    fn prom_names_are_sanitized() {
        assert_eq!(prom_name("serve.latency-ns"), "serve_latency_ns");
        assert_eq!(prom_name("0weird"), "_0weird");
    }
}
