//! Unified observability: end-to-end query tracing, one metrics
//! registry, and the mining-phase profiler.
//!
//! Three pieces, all dependency-free (std + `util/json` only):
//!
//! - [`trace`] — hand-rolled spans. A [`Trace`] is minted per query (at
//!   `serve` admission or at the `epminer` CLI) and carried by value
//!   through the session driver, `MineService` jobs, incremental
//!   commits, and — as an optional envelope field on the cluster wire
//!   protocol — across scatter-gather RPCs, so the coordinator can
//!   render one merged span tree covering remote counting work. A
//!   disabled trace ([`Trace::off`]) is a `None` inside: starting and
//!   dropping spans performs no allocation and no clock reads, so the
//!   hot mining loop is unaffected by default (pinned by
//!   `tests/obs_zero_alloc.rs`).
//! - [`registry`] — a single [`Registry`] of named typed counters,
//!   gauges, and histograms (windowed, summarized via
//!   [`crate::util::stats::Summary`]). The serving pool, the scatter
//!   coordinator, and `coordinator::Metrics` publish into one registry
//!   instead of owning disjoint ad-hoc fields; one [`Snapshot`] API
//!   renders both Prometheus-style text and JSON (`epminer stats`, the
//!   `Stats` RPC on `ClusterNode`).
//! - [`profile`] — the mining-phase profiler: an optional
//!   [`MineProfile`] on `MineResult` recording per-level generate /
//!   count / prune wall time, candidate rows materialized, and blocks
//!   streamed, enabled by `SessionBuilder::profile(true)` / `--profile`.
//!   Phase profiles are the input the accelerator crossover model
//!   (ROADMAP item 2) needs to pick CPU-vs-device per batch.

pub mod profile;
pub mod registry;
pub mod trace;

pub use profile::{LevelProfile, MineProfile};
pub use registry::{Counter, Gauge, Histogram, Registry, Snapshot};
pub use trace::{SpanGuard, SpanRecord, Trace, TraceId};
