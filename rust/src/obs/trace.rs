//! Hand-rolled spans: a bounded per-query recorder with lossless JSON
//! export and a text tree render.
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when off.** `Trace` is a `Option<Arc<_>>` by value;
//!    [`Trace::off`] is `None`. Starting a span on a disabled trace
//!    reads no clock, takes no lock, and allocates nothing — the guard
//!    is a few plain words. The mining hot loop can therefore be
//!    instrumented unconditionally.
//! 2. **Send + Sync.** The scatter coordinator records spans from scoped
//!    threads (one per counting window), so the recorder is a mutexed
//!    ring behind an `Arc`, with monotonic times taken relative to the
//!    trace's own epoch (`Instant` deltas, never wall clock).
//! 3. **Bounded.** The buffer holds at most [`MAX_SPANS`] records;
//!    overflow drops the newest record and counts it (`dropped`), so a
//!    pathological query cannot balloon coordinator memory.
//! 4. **Mergeable.** Remote spans arrive as decoded [`SpanRecord`]s from
//!    a node's own trace (its own epoch, its own span ids). [`Trace::graft`]
//!    re-ids them into this trace's id space under a chosen parent and
//!    stamps the peer name, so one tree covers local and remote work.
//!    Remote times stay on the node's clock — durations are exact,
//!    absolute offsets are per-node.

use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::MineError;
use crate::util::json::Json;

/// Hard bound on recorded spans per trace (local + grafted).
pub const MAX_SPANS: usize = 8192;

/// Trace ids travel as lowercase hex, at most this many digits (u64).
pub const MAX_TRACE_ID_HEX: usize = 16;

/// A per-query identity, minted once at admission (serve) or at the CLI
/// and carried across every hop — including the wire — as 16 lowercase
/// hex digits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TraceId(pub u64);

/// Process-local uniqueness for minted ids (mixed with wall time).
static MINT_SEQ: AtomicU64 = AtomicU64::new(0);

impl TraceId {
    /// Mint a fresh id: wall-clock nanos, a process-local sequence, and
    /// the pid, FNV-mixed. Not cryptographic — collision just merges two
    /// traces' names, never their data.
    pub fn mint() -> TraceId {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let seq = MINT_SEQ.fetch_add(1, Ordering::Relaxed);
        let mut h = 0xcbf29ce484222325u64;
        for word in [nanos, seq, std::process::id() as u64] {
            for b in word.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
        TraceId(h)
    }

    /// The wire form: 16 lowercase hex digits.
    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }

    /// Parse the wire form. Hostile inputs — empty, oversized, or
    /// non-hex — are typed errors, never panics: trace ids arrive from
    /// untrusted peers on the cluster envelope.
    pub fn from_hex(s: &str) -> Result<TraceId, MineError> {
        if s.is_empty() || s.len() > MAX_TRACE_ID_HEX {
            return Err(MineError::invalid(format!(
                "trace id must be 1..={MAX_TRACE_ID_HEX} hex digits, got {} chars",
                s.len()
            )));
        }
        if !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return Err(MineError::invalid(format!("trace id {s:?} is not hex")));
        }
        u64::from_str_radix(s, 16)
            .map(TraceId)
            .map_err(|_| MineError::invalid(format!("trace id {s:?} is not a u64")))
    }
}

/// One recorded span: a named interval with a parent link (0 = root)
/// and the peer name for grafted remote spans ("" = this process).
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRecord {
    pub id: u64,
    /// parent span id; 0 means top-level
    pub parent: u64,
    pub name: Cow<'static, str>,
    /// peer that recorded the span ("" locally; set by [`Trace::graft`])
    pub node: Cow<'static, str>,
    pub start_ns: u64,
    pub end_ns: u64,
}

impl SpanRecord {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("id".into(), Json::Num(self.id as f64)),
            ("parent".into(), Json::Num(self.parent as f64)),
            ("name".into(), Json::Str(self.name.to_string())),
            ("node".into(), Json::Str(self.node.to_string())),
            ("start_ns".into(), Json::Num(self.start_ns as f64)),
            ("end_ns".into(), Json::Num(self.end_ns as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SpanRecord, MineError> {
        let field = |k: &str| {
            v.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| MineError::invalid(format!("span record missing u64 {k:?}")))
        };
        let text = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| MineError::invalid(format!("span record missing string {k:?}")))
        };
        Ok(SpanRecord {
            id: field("id")?,
            parent: field("parent")?,
            name: Cow::Owned(text("name")?),
            node: Cow::Owned(text("node")?),
            start_ns: field("start_ns")?,
            end_ns: field("end_ns")?,
        })
    }
}

/// Encode a span list (the wire form used on cluster `ok` envelopes).
pub fn spans_to_json(spans: &[SpanRecord]) -> Json {
    Json::Arr(spans.iter().map(SpanRecord::to_json).collect())
}

/// Decode a span list from an untrusted peer: shape errors are typed,
/// and the count is clamped to [`MAX_SPANS`] so a hostile reply cannot
/// balloon coordinator memory.
pub fn spans_from_json(v: &Json) -> Result<Vec<SpanRecord>, MineError> {
    let arr = v
        .as_arr()
        .ok_or_else(|| MineError::invalid("span list must be a JSON array"))?;
    arr.iter().take(MAX_SPANS).map(SpanRecord::from_json).collect()
}

struct SpanBuf {
    spans: Vec<SpanRecord>,
    dropped: u64,
}

struct TraceInner {
    id: TraceId,
    epoch: Instant,
    next_span: AtomicU64,
    buf: Mutex<SpanBuf>,
}

/// A per-query span recorder, cheap to clone and pass by value. See the
/// module docs for the cost model; the practical API is
/// [`Trace::span`] → [`SpanGuard::child`] with explicit nesting (no
/// thread-locals — the scatter threads make implicit context a trap).
#[derive(Clone)]
pub struct Trace {
    inner: Option<Arc<TraceInner>>,
}

impl Trace {
    /// The disabled trace: every operation is a no-op.
    pub fn off() -> Trace {
        Trace { inner: None }
    }

    /// An enabled trace under an existing id (the remote side of a
    /// propagated trace context).
    pub fn with_id(id: TraceId) -> Trace {
        Trace {
            inner: Some(Arc::new(TraceInner {
                id,
                epoch: Instant::now(),
                next_span: AtomicU64::new(0),
                buf: Mutex::new(SpanBuf { spans: Vec::new(), dropped: 0 }),
            })),
        }
    }

    /// An enabled trace with a freshly minted id.
    pub fn started() -> Trace {
        Trace::with_id(TraceId::mint())
    }

    pub fn is_on(&self) -> bool {
        self.inner.is_some()
    }

    pub fn id(&self) -> Option<TraceId> {
        self.inner.as_ref().map(|i| i.id)
    }

    /// Start a top-level span. On a disabled trace this is free: no
    /// clock read, no allocation, no lock.
    pub fn span(&self, name: &'static str) -> SpanGuard {
        self.start(0, Cow::Borrowed(name))
    }

    /// Start a root span with a computed name; the closure runs only
    /// when the trace is enabled, so the disabled path stays
    /// allocation-free.
    pub fn span_fmt(&self, name: impl FnOnce() -> String) -> SpanGuard {
        if self.is_on() {
            self.start(0, Cow::Owned(name()))
        } else {
            self.start(0, Cow::Borrowed(""))
        }
    }

    fn start(&self, parent: u64, name: Cow<'static, str>) -> SpanGuard {
        match &self.inner {
            None => SpanGuard { trace: Trace::off(), id: 0, parent: 0, name: Cow::Borrowed(""), start_ns: 0 },
            Some(inner) => {
                let id = inner.next_span.fetch_add(1, Ordering::Relaxed) + 1;
                let start_ns = inner.epoch.elapsed().as_nanos() as u64;
                SpanGuard { trace: self.clone(), id, parent, name, start_ns }
            }
        }
    }

    fn record(&self, rec: SpanRecord) {
        if let Some(inner) = &self.inner {
            let mut buf = inner.buf.lock().unwrap_or_else(|p| p.into_inner());
            if buf.spans.len() >= MAX_SPANS {
                buf.dropped += 1;
            } else {
                buf.spans.push(rec);
            }
        }
    }

    /// Adopt spans recorded by a remote peer under the local span
    /// `under`: ids are re-based into this trace's id space (so they
    /// cannot collide with local spans), top-level remote spans hang off
    /// `under`, and every record is stamped with the peer's name. Times
    /// stay on the peer's clock (durations exact, offsets node-local).
    pub fn graft(&self, under: u64, node: &str, remote: &[SpanRecord]) {
        let Some(inner) = &self.inner else { return };
        if remote.is_empty() {
            return;
        }
        let max_id = remote.iter().map(|s| s.id).max().unwrap_or(0);
        let base = inner.next_span.fetch_add(max_id, Ordering::Relaxed);
        let remote_ids: std::collections::HashSet<u64> = remote.iter().map(|s| s.id).collect();
        for s in remote.iter().take(MAX_SPANS) {
            let parent = if s.parent == 0 || !remote_ids.contains(&s.parent) {
                under
            } else {
                base + s.parent
            };
            self.record(SpanRecord {
                id: base + s.id,
                parent,
                name: Cow::Owned(s.name.to_string()),
                node: Cow::Owned(if s.node.is_empty() { node.to_string() } else { s.node.to_string() }),
                start_ns: s.start_ns,
                end_ns: s.end_ns,
            });
        }
    }

    /// Spans recorded so far, in completion order.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        match &self.inner {
            None => vec![],
            Some(inner) => inner.buf.lock().unwrap_or_else(|p| p.into_inner()).spans.clone(),
        }
    }

    /// Records dropped to the [`MAX_SPANS`] bound.
    pub fn dropped(&self) -> u64 {
        match &self.inner {
            None => 0,
            Some(inner) => inner.buf.lock().unwrap_or_else(|p| p.into_inner()).dropped,
        }
    }

    /// Lossless JSON export: `{trace_id, dropped, spans: [...]}`.
    pub fn to_json(&self) -> Json {
        let id = self.id().map(|i| i.to_hex()).unwrap_or_default();
        Json::Obj(vec![
            ("trace_id".into(), Json::Str(id)),
            ("dropped".into(), Json::Num(self.dropped() as f64)),
            ("spans".into(), spans_to_json(&self.snapshot())),
        ])
    }

    /// Text flamegraph: one line per span, children indented under
    /// parents, siblings in start order, remote spans tagged `@peer`.
    pub fn render_tree(&self) -> String {
        let spans = self.snapshot();
        let id = self.id().map(|i| i.to_hex()).unwrap_or_default();
        let mut out = format!("trace {id} ({} spans", spans.len());
        let dropped = self.dropped();
        if dropped > 0 {
            out.push_str(&format!(", {dropped} dropped"));
        }
        out.push_str(")\n");
        let known: std::collections::HashSet<u64> = spans.iter().map(|s| s.id).collect();
        let mut children: HashMap<u64, Vec<&SpanRecord>> = HashMap::new();
        for s in &spans {
            // a span whose parent never completed (still open at export)
            // renders at top level rather than vanishing
            let parent = if s.parent != 0 && known.contains(&s.parent) { s.parent } else { 0 };
            children.entry(parent).or_default().push(s);
        }
        for kids in children.values_mut() {
            kids.sort_by_key(|s| (s.start_ns, s.id));
        }
        fn walk(
            out: &mut String,
            children: &HashMap<u64, Vec<&SpanRecord>>,
            id: u64,
            depth: usize,
        ) {
            let Some(kids) = children.get(&id) else { return };
            for s in kids {
                let ms = s.duration_ns() as f64 / 1e6;
                let tag = if s.node.is_empty() { String::new() } else { format!(" @{}", s.node) };
                out.push_str(&format!("{:indent$}{}{tag} {ms:.3}ms\n", "", s.name, indent = depth * 2));
                walk(out, children, s.id, depth + 1);
            }
        }
        walk(&mut out, &children, 0, 1);
        out
    }
}

/// An in-flight span. Records itself (one buffer push) on drop; create
/// children with [`SpanGuard::child`] for explicit nesting.
pub struct SpanGuard {
    trace: Trace,
    id: u64,
    parent: u64,
    name: Cow<'static, str>,
    start_ns: u64,
}

impl SpanGuard {
    /// This span's id — the graft point for remote spans.
    pub fn span_id(&self) -> u64 {
        self.id
    }

    /// Start a child span.
    pub fn child(&self, name: &'static str) -> SpanGuard {
        self.trace.start(self.id, Cow::Borrowed(name))
    }

    /// Start a child span with a computed name; the closure runs only
    /// when the trace is enabled, so the disabled path stays
    /// allocation-free.
    pub fn child_fmt(&self, name: impl FnOnce() -> String) -> SpanGuard {
        if self.trace.is_on() {
            self.trace.start(self.id, Cow::Owned(name()))
        } else {
            self.trace.start(self.id, Cow::Borrowed(""))
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(inner) = &self.trace.inner else { return };
        let end_ns = inner.epoch.elapsed().as_nanos() as u64;
        self.trace.record(SpanRecord {
            id: self.id,
            parent: self.parent,
            name: std::mem::replace(&mut self.name, Cow::Borrowed("")),
            node: Cow::Borrowed(""),
            start_ns: self.start_ns,
            end_ns,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_id_round_trips_and_rejects_hostile_input() {
        let id = TraceId(0xdeadbeefcafef00d);
        assert_eq!(id.to_hex(), "deadbeefcafef00d");
        assert_eq!(TraceId::from_hex(&id.to_hex()).unwrap(), id);
        assert_eq!(TraceId::from_hex("0").unwrap(), TraceId(0));
        for bad in ["", "12345678901234567", "xyz", "deadbeef!", "деад"] {
            assert!(TraceId::from_hex(bad).is_err(), "{bad:?} should be rejected");
        }
        // minted ids are distinct within a process
        assert_ne!(TraceId::mint(), TraceId::mint());
    }

    #[test]
    fn disabled_trace_is_inert() {
        let t = Trace::off();
        assert!(!t.is_on());
        assert!(t.id().is_none());
        {
            let root = t.span("root");
            let _child = root.child("child");
            assert_eq!(root.span_id(), 0);
        }
        assert!(t.snapshot().is_empty());
        assert_eq!(t.render_tree().lines().count(), 1);
    }

    #[test]
    fn spans_nest_and_render() {
        let t = Trace::started();
        {
            let root = t.span("mine");
            {
                let l1 = root.child_fmt(|| "level 1".to_string());
                let _c = l1.child("count");
            }
            let _l2 = root.child("level 2");
        }
        let spans = t.snapshot();
        assert_eq!(spans.len(), 4);
        let tree = t.render_tree();
        let lines: Vec<&str> = tree.lines().collect();
        assert!(lines[1].trim_start().starts_with("mine"), "{tree}");
        // children are indented under mine, grandchild deeper still
        assert!(tree.contains("\n    level 1"), "{tree}");
        assert!(tree.contains("\n      count"), "{tree}");
        assert!(tree.contains("\n    level 2"), "{tree}");
    }

    #[test]
    fn graft_rebases_ids_and_tags_the_peer() {
        let t = Trace::started();
        let rpc_id = {
            let root = t.span("scatter");
            let rpc = root.child("rpc");
            rpc.span_id()
        };
        let remote = vec![
            SpanRecord {
                id: 1,
                parent: 0,
                name: "node:map_count".into(),
                node: "".into(),
                start_ns: 10,
                end_ns: 50,
            },
            SpanRecord {
                id: 2,
                parent: 1,
                name: "scan".into(),
                node: "".into(),
                start_ns: 12,
                end_ns: 40,
            },
        ];
        t.graft(rpc_id, "local#3", &remote);
        let spans = t.snapshot();
        let top = spans.iter().find(|s| s.name == "node:map_count").unwrap();
        let scan = spans.iter().find(|s| s.name == "scan").unwrap();
        assert_eq!(top.parent, rpc_id);
        assert_eq!(scan.parent, top.id);
        assert_eq!(top.node, "local#3");
        assert!(t.render_tree().contains("@local#3"), "{}", t.render_tree());
    }

    #[test]
    fn span_buffer_is_bounded() {
        let t = Trace::started();
        for _ in 0..(MAX_SPANS + 10) {
            let _s = t.span("x");
        }
        assert_eq!(t.snapshot().len(), MAX_SPANS);
        assert_eq!(t.dropped(), 10);
    }

    #[test]
    fn span_json_round_trips() {
        let t = Trace::started();
        {
            let root = t.span("mine");
            let _c = root.child("count");
        }
        let j = spans_to_json(&t.snapshot());
        let back = spans_from_json(&j).unwrap();
        assert_eq!(back, t.snapshot());
        assert!(spans_from_json(&Json::Num(3.0)).is_err());
        assert!(spans_from_json(&Json::Arr(vec![Json::Obj(vec![])])).is_err());
    }
}
