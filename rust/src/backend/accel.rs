//! Accelerated counting engines over the PJRT runtime: PTPE (§5.2.1),
//! MapConcatenate (§5.2.2), and the Hybrid composition (§5.2.3, Alg. 2).
//!
//! All three keep the paper's CPU/GPU split: episode batching, padding and
//! chunk carry happen in `runtime::exec`; segmentation planning and the
//! Concatenate merge happen on the host (`coordinator::mapconcat`); only
//! the inner counting loops run on the accelerator. Episode sizes without
//! an artifact fall back to the CPU engines — callers see counts, not
//! errors.

use std::rc::Rc;

use crate::backend::{count_grouped, group_by_size, uniform_size, CountBackend, CountReport};
use crate::coordinator::{mapconcat, Metrics};
use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::EventStream;
use crate::gpu_model::crossover::{CostModel, CrossoverModel};
use crate::mining::{cpu_parallel, serial};
use crate::runtime::{exec, Runtime};

/// How a [`HybridBackend`] picks its inner engine per uniform batch.
#[derive(Clone, Copy, Debug)]
pub enum Dispatch {
    /// the paper's Eq. 2 form: S > f(N) with f fitted to crossovers
    Crossover(CrossoverModel),
    /// stream-length-aware cost model calibrated on this substrate
    /// (DESIGN.md §6; the default)
    Cost(CostModel),
}

impl Dispatch {
    /// true = run the PTPE-shaped engine, false = the MapConcatenate one.
    pub fn choose_ptpe(&self, n_episodes: usize, n: usize, stream_len: usize) -> bool {
        match self {
            Dispatch::Crossover(m) => m.choose_ptpe(n_episodes, n),
            Dispatch::Cost(m) => m.choose_ptpe(n_episodes, n, stream_len),
        }
    }
}

/// Relaxed (A2) counting shared by the accelerated engines: the A2
/// artifact when one exists for the size, the serial CPU relaxation
/// otherwise.
fn count_relaxed_accel(
    rt: &Runtime,
    episodes: &[Episode],
    stream: &EventStream,
) -> Result<CountReport, MineError> {
    let mut metrics = Metrics::default();
    let counts = count_grouped(episodes, stream, &mut metrics, |n, group, m| {
        if rt.supports_n(n) {
            exec::count_a2(rt, group, stream)
        } else {
            m.cpu_fallbacks += 1;
            Ok(group.iter().map(|e| serial::count_a2(e, stream)).collect())
        }
    })?;
    Ok(CountReport { counts, culled: 0, metrics })
}

/// Per-thread-per-episode counting on the accelerator: one exact A1
/// automaton per episode lane, batched and chunk-carried by the runtime.
pub struct PtpeBackend {
    rt: Rc<Runtime>,
    cpu_threads: usize,
}

impl PtpeBackend {
    pub fn new(rt: Rc<Runtime>, cpu_threads: usize) -> PtpeBackend {
        PtpeBackend { rt, cpu_threads: cpu_threads.max(1) }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl CountBackend for PtpeBackend {
    fn name(&self) -> &str {
        "ptpe"
    }

    fn supports_n(&self, n: usize) -> bool {
        n == 1 || self.rt.supports_n(n)
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let mut metrics = Metrics::default();
        let counts = count_grouped(episodes, stream, &mut metrics, |n, group, m| {
            if !self.rt.supports_n(n) {
                m.cpu_fallbacks += 1;
                return Ok(cpu_parallel::count_all_parallel(group, stream, self.cpu_threads));
            }
            m.ptpe_calls += 1;
            exec::count_a1(&self.rt, group, stream)
        })?;
        Ok(CountReport { counts, culled: 0, metrics })
    }

    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        count_relaxed_accel(&self.rt, episodes, stream)
    }
}

/// Segment-parallel Map on the accelerator plus the host-side Concatenate
/// merge. Episodes whose boundary-machine chain lost synchronization (a
/// flagged Concatenate miss) are recounted exactly via PTPE; infeasible
/// segmentations fall back to PTPE wholesale, and unsupported sizes to the
/// CPU baseline.
pub struct MapConcatBackend {
    rt: Rc<Runtime>,
    cpu_threads: usize,
}

impl MapConcatBackend {
    pub fn new(rt: Rc<Runtime>, cpu_threads: usize) -> MapConcatBackend {
        MapConcatBackend { rt, cpu_threads: cpu_threads.max(1) }
    }
}

impl CountBackend for MapConcatBackend {
    fn name(&self) -> &str {
        "mapconcat"
    }

    fn supports_n(&self, n: usize) -> bool {
        n == 1 || self.rt.supports_n(n)
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let mut metrics = Metrics::default();
        let counts = count_grouped(episodes, stream, &mut metrics, |n, group, m| {
            match mapconcat::plan(&self.rt, group, stream) {
                Some(plan) if self.rt.supports_n(n) => {
                    m.mapcat_calls += 1;
                    let (mut counts, misses) =
                        mapconcat::count(&self.rt, group, stream, &plan)?;
                    // Matched chains are exact; a mismatch is always flagged
                    // by a miss (see mapconcat::count) — recount those
                    // episodes exactly via PTPE.
                    let missed: Vec<usize> =
                        (0..group.len()).filter(|&i| misses[i] > 0).collect();
                    if !missed.is_empty() {
                        m.concat_misses += missed.len() as u64;
                        let subset: Vec<Episode> =
                            missed.iter().map(|&i| group[i].clone()).collect();
                        let exact = exec::count_a1(&self.rt, &subset, stream)?;
                        for (&i, c) in missed.iter().zip(exact) {
                            counts[i] = c;
                        }
                    }
                    Ok(counts)
                }
                _ if self.rt.supports_n(n) => {
                    // segmentation infeasible (stream too large / too short,
                    // or constraint windows wider than a segment): PTPE.
                    m.mapcat_fallbacks += 1;
                    m.ptpe_calls += 1;
                    exec::count_a1(&self.rt, group, stream)
                }
                _ => {
                    m.mapcat_fallbacks += 1;
                    m.cpu_fallbacks += 1;
                    Ok(cpu_parallel::count_all_parallel(group, stream, self.cpu_threads))
                }
            }
        })?;
        Ok(CountReport { counts, culled: 0, metrics })
    }

    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        count_relaxed_accel(&self.rt, episodes, stream)
    }
}

/// Hybrid dispatch (Alg. 2): for each uniform-size batch, run the
/// PTPE-shaped engine when the batch is large enough to fill its lanes,
/// the MapConcatenate-shaped engine otherwise. Composes *any* two
/// backends — tests inject CPU or mock engines on both sides.
pub struct HybridBackend {
    ptpe: Box<dyn CountBackend>,
    mapcat: Box<dyn CountBackend>,
    dispatch: Dispatch,
}

impl HybridBackend {
    pub fn new(
        ptpe: Box<dyn CountBackend>,
        mapcat: Box<dyn CountBackend>,
        dispatch: Dispatch,
    ) -> HybridBackend {
        HybridBackend { ptpe, mapcat, dispatch }
    }

    /// The standard composition: PTPE + MapConcatenate over a shared
    /// runtime, dispatched by the substrate-calibrated cost model.
    pub fn with_runtime(rt: Rc<Runtime>, cpu_threads: usize) -> HybridBackend {
        let mf = rt.manifest();
        let dispatch = Dispatch::Cost(CostModel::substrate_default(mf.m_episodes, mf.c_chunk));
        HybridBackend::with_runtime_dispatch(rt, cpu_threads, dispatch)
    }

    pub fn with_runtime_dispatch(
        rt: Rc<Runtime>,
        cpu_threads: usize,
        dispatch: Dispatch,
    ) -> HybridBackend {
        HybridBackend::new(
            Box::new(PtpeBackend::new(rt.clone(), cpu_threads)),
            Box::new(MapConcatBackend::new(rt, cpu_threads)),
            dispatch,
        )
    }

    /// All-CPU hybrid, no runtime required: episode-axis workers
    /// ([`crate::backend::cpu::CpuParallelBackend`]) when a batch has
    /// enough candidates to fill the cores, stream-axis time shards
    /// ([`crate::backend::sharded::ShardedBackend`]) when it does not —
    /// the same few-episodes regime §5.2.3's dispatch sends to
    /// MapConcatenate, transplanted to the host. Wrap it in
    /// [`crate::backend::two_pass::TwoPassBackend`] for two-pass
    /// elimination, as with any other engine.
    pub fn cpu_sharded(threads: usize) -> HybridBackend {
        HybridBackend::new(
            Box::new(crate::backend::cpu::CpuParallelBackend::new(threads)),
            Box::new(crate::backend::sharded::ShardedBackend::new(threads)),
            Dispatch::Crossover(CrossoverModel::paper_default()),
        )
    }

    pub fn dispatch(&self) -> Dispatch {
        self.dispatch
    }

    pub fn set_dispatch(&mut self, dispatch: Dispatch) {
        self.dispatch = dispatch;
    }
}

impl CountBackend for HybridBackend {
    fn name(&self) -> &str {
        "hybrid"
    }

    fn supports_n(&self, n: usize) -> bool {
        self.ptpe.supports_n(n) || self.mapcat.supports_n(n)
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        // Mining levels are uniform batches: dispatch the slice whole,
        // no clone-and-scatter.
        if let Some(n) = uniform_size(episodes) {
            let ptpe = n < 2 || self.dispatch.choose_ptpe(episodes.len(), n, stream.len());
            return if ptpe {
                self.ptpe.count(episodes, stream)
            } else {
                self.mapcat.count(episodes, stream)
            };
        }
        let mut out = vec![0u64; episodes.len()];
        let mut metrics = Metrics::default();
        for (indices, group) in group_by_size(episodes) {
            let n = group[0].n();
            let ptpe = n < 2 || self.dispatch.choose_ptpe(group.len(), n, stream.len());
            let rep = if ptpe {
                self.ptpe.count(&group, stream)?
            } else {
                self.mapcat.count(&group, stream)?
            };
            metrics.merge(&rep.metrics);
            for (slot, c) in indices.into_iter().zip(rep.counts) {
                out[slot] = c;
            }
        }
        Ok(CountReport { counts: out, culled: 0, metrics })
    }

    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        // The relaxed pass has a single accelerated form (A2); the PTPE
        // side owns it.
        self.ptpe.count_relaxed(episodes, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::{CpuParallelBackend, CpuSerialBackend};
    use crate::episodes::Interval;
    use crate::util::rng::Rng;

    #[test]
    fn hybrid_composes_arbitrary_backends() {
        let mut rng = Rng::new(4);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..300 {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 3), t));
        }
        let stream = EventStream::from_pairs(pairs, 4);
        let iv = Interval::new(0, 8);
        let eps: Vec<Episode> = (0..10)
            .map(|i| Episode::new(vec![i % 4, (i + 1) % 4], vec![iv]))
            .collect();

        let mut hybrid = HybridBackend::new(
            Box::new(CpuSerialBackend::new()),
            Box::new(CpuParallelBackend::new(2)),
            Dispatch::Crossover(CrossoverModel::paper_default()),
        );
        let got = hybrid.count(&eps, &stream).unwrap().counts;
        let want = CpuSerialBackend::new().count(&eps, &stream).unwrap().counts;
        assert_eq!(got, want);
        assert!(hybrid.supports_n(7));
        assert_eq!(hybrid.name(), "hybrid");
    }

    #[test]
    fn cpu_sharded_hybrid_matches_serial_on_both_arms() {
        let mut rng = Rng::new(8);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..500 {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 3), t));
        }
        let stream = EventStream::from_pairs(pairs, 4);
        let iv = Interval::new(0, 6);
        // n=2 batch lands on the episode-axis arm (small levels always
        // dispatch PTPE-shaped); a single n=3 episode sits far below the
        // crossover and lands on the stream-axis arm.
        let many: Vec<Episode> = (0..20)
            .map(|i| Episode::new(vec![i % 4, (i + 1) % 4], vec![iv]))
            .collect();
        let few = vec![Episode::new(vec![0, 1, 2], vec![iv; 2])];
        let mut hybrid = HybridBackend::cpu_sharded(4);
        for eps in [&many, &few] {
            let got = hybrid.count(eps, &stream).unwrap().counts;
            let want = CpuSerialBackend::new().count(eps, &stream).unwrap().counts;
            assert_eq!(got, want);
        }
    }
}
