//! CPU counting engines: the serial reference (Algorithm 1/3) and the
//! paper's optimized multithreaded baseline (§6.4). Both handle mixed
//! episode sizes natively (no per-size grouping needed) and never fail —
//! they are the floor every other backend falls back to.

use crate::backend::{CountBackend, CountReport, EpisodeBatch};
use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::EventStream;
use crate::mining::{cpu_parallel, serial};

/// Serial Algorithm 1 (exact) / Algorithm 3 (relaxed), one automaton at a
/// time — the bit-for-bit reference every other engine is tested against.
#[derive(Clone, Copy, Debug, Default)]
pub struct CpuSerialBackend;

impl CpuSerialBackend {
    pub fn new() -> CpuSerialBackend {
        CpuSerialBackend
    }
}

impl CountBackend for CpuSerialBackend {
    fn name(&self) -> &str {
        "cpu-serial"
    }

    fn supports_n(&self, _n: usize) -> bool {
        true
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let mut report = CountReport::from_counts(
            episodes.iter().map(|e| serial::count_a1(e, stream)).collect(),
        );
        report.metrics.episodes_counted = episodes.len() as u64;
        Ok(report)
    }

    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let mut report = CountReport::from_counts(
            episodes.iter().map(|e| serial::count_a2(e, stream)).collect(),
        );
        report.metrics.episodes_counted = episodes.len() as u64;
        Ok(report)
    }

    fn count_batch(
        &mut self,
        batch: &EpisodeBatch<'_>,
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        // Walk the arena view with one reusable scratch episode instead
        // of materializing the whole block.
        let mut scratch = Episode { types: vec![], intervals: vec![] };
        let mut counts = Vec::with_capacity(batch.len());
        for i in 0..batch.len() {
            batch.materialize_into(i, &mut scratch);
            counts.push(serial::count_a1(&scratch, stream));
        }
        let mut report = CountReport::from_counts(counts);
        report.metrics.episodes_counted = batch.len() as u64;
        Ok(report)
    }
}

/// The paper's multithreaded CPU baseline: worker threads own disjoint
/// episode subsets and make one pass over the stream with the event-type
/// watcher index.
#[derive(Clone, Copy, Debug)]
pub struct CpuParallelBackend {
    pub threads: usize,
}

impl CpuParallelBackend {
    pub fn new(threads: usize) -> CpuParallelBackend {
        CpuParallelBackend { threads: threads.max(1) }
    }
}

impl Default for CpuParallelBackend {
    fn default() -> CpuParallelBackend {
        let threads =
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        CpuParallelBackend::new(threads)
    }
}

impl CountBackend for CpuParallelBackend {
    fn name(&self) -> &str {
        "cpu-parallel"
    }

    fn supports_n(&self, _n: usize) -> bool {
        true
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let mut report = CountReport::from_counts(cpu_parallel::count_all_parallel(
            episodes,
            stream,
            self.threads,
        ));
        report.metrics.episodes_counted = episodes.len() as u64;
        Ok(report)
    }

    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        // Same worker split as the exact pass: the relaxed pre-pass sees
        // the *full* candidate set (that is its job), so it must scale
        // with threads too.
        let counts = cpu_parallel::scatter_parallel(episodes, self.threads, |eps| {
            eps.iter().map(|e| serial::count_a2(e, stream)).collect()
        });
        let mut report = CountReport::from_counts(counts);
        report.metrics.episodes_counted = episodes.len() as u64;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;
    use crate::util::rng::Rng;

    fn world(seed: u64) -> (Vec<Episode>, EventStream) {
        let mut rng = Rng::new(seed);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..400 {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 4), t));
        }
        let stream = EventStream::from_pairs(pairs, 5);
        let mut eps = vec![Episode::single(2)];
        for _ in 0..12 {
            let n = rng.range_i32(2, 4) as usize;
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 4)).collect();
            let ivs: Vec<Interval> = (0..n - 1)
                .map(|_| {
                    let lo = rng.range_i32(0, 2);
                    Interval::new(lo, lo + rng.range_i32(1, 8))
                })
                .collect();
            eps.push(Episode::new(types, ivs));
        }
        (eps, stream)
    }

    #[test]
    fn serial_and_parallel_backends_agree() {
        let (eps, stream) = world(9);
        let a = CpuSerialBackend::new().count(&eps, &stream).unwrap();
        let b = CpuParallelBackend::new(4).count(&eps, &stream).unwrap();
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.metrics.episodes_counted, eps.len() as u64);
    }

    #[test]
    fn relaxed_dominates_exact() {
        let (eps, stream) = world(10);
        let mut be = CpuSerialBackend::new();
        let exact = be.count(&eps, &stream).unwrap().counts;
        let relaxed = be.count_relaxed(&eps, &stream).unwrap().counts;
        for (r, x) in relaxed.iter().zip(&exact) {
            assert!(r >= x);
        }
    }
}
