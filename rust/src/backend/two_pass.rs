//! Two-pass elimination A2+A1 as backend composition (paper §5.3, Alg. 4).
//!
//! Pass 1 counts every candidate under the relaxed constraints α′ with the
//! wrapped engine's cheap relaxed path; candidates whose relaxed count is
//! below the support threshold are eliminated — sound because
//! `count(α′) ≥ count(α)` (Theorem 5.1, property-tested in
//! `rust/tests/invariants.rs`). Pass 2 runs the exact path on the
//! survivors only. Wrapping *any* [`CountBackend`] this way is what the
//! old `CountMode::TwoPass` enum used to hard-wire to the Hybrid engine.

use crate::backend::{CountBackend, CountReport};
use crate::coordinator::Metrics;
use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::EventStream;

/// Full outcome of a two-pass count (the shape the Fig. 9 bench reports).
#[derive(Clone, Debug)]
pub struct TwoPassOutcome {
    /// Per-episode counts: exact counts for survivors; the (relaxed,
    /// sub-threshold) upper bound for culled candidates. Either way the
    /// `count >= theta` decision is exact.
    pub counts: Vec<u64>,
    /// relaxed-pass counts for every candidate
    pub relaxed_counts: Vec<u64>,
    pub culled: u64,
    pub survivors: u64,
}

/// Wraps an exact engine with the A2 elimination pre-pass at a fixed
/// support threshold.
pub struct TwoPassBackend {
    inner: Box<dyn CountBackend>,
    theta: u64,
    name: String,
}

impl TwoPassBackend {
    pub fn new(inner: Box<dyn CountBackend>, theta: u64) -> TwoPassBackend {
        let name = format!("two-pass({})", inner.name());
        TwoPassBackend { inner, theta, name }
    }

    pub fn theta(&self) -> u64 {
        self.theta
    }

    /// Run both passes and return the full outcome plus the work metrics.
    pub fn run(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<(TwoPassOutcome, Metrics), MineError> {
        let relaxed_rep = self.inner.count_relaxed(episodes, stream)?;
        let mut metrics = relaxed_rep.metrics;
        // `episodes_counted` means episodes through the *exact* path (its
        // 0.1 semantics); the relaxed pre-pass reports its work through
        // a2_culled/a2_survivors below, so drop its tally here rather
        // than double-counting survivors.
        metrics.episodes_counted = 0;
        let relaxed = relaxed_rep.counts;

        let survivor_idx: Vec<usize> =
            (0..episodes.len()).filter(|&i| relaxed[i] >= self.theta).collect();
        let survivors: Vec<Episode> =
            survivor_idx.iter().map(|&i| episodes[i].clone()).collect();
        metrics.a2_culled += (episodes.len() - survivors.len()) as u64;
        metrics.a2_survivors += survivors.len() as u64;

        let exact_rep = self.inner.count(&survivors, stream)?;
        metrics.merge(&exact_rep.metrics);

        let mut counts = relaxed.clone();
        for (&i, c) in survivor_idx.iter().zip(exact_rep.counts) {
            counts[i] = c;
        }
        let outcome = TwoPassOutcome {
            culled: (episodes.len() - survivor_idx.len()) as u64,
            survivors: survivor_idx.len() as u64,
            counts,
            relaxed_counts: relaxed,
        };
        Ok((outcome, metrics))
    }
}

impl CountBackend for TwoPassBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn supports_n(&self, n: usize) -> bool {
        self.inner.supports_n(n)
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let (outcome, metrics) = self.run(episodes, stream)?;
        Ok(CountReport { counts: outcome.counts, culled: outcome.culled, metrics })
    }

    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        self.inner.count_relaxed(episodes, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuSerialBackend;
    use crate::episodes::Interval;
    use crate::mining::serial;
    use crate::util::rng::Rng;

    #[test]
    fn two_pass_is_exact_at_threshold() {
        let mut rng = Rng::new(0x2B2B);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..800 {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 4), t));
        }
        let stream = crate::events::EventStream::from_pairs(pairs, 5);
        let eps: Vec<Episode> = (0..40)
            .map(|_| {
                let n = rng.range_i32(2, 4) as usize;
                let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 4)).collect();
                let ivs: Vec<Interval> = (0..n - 1)
                    .map(|_| {
                        let lo = rng.range_i32(0, 2);
                        Interval::new(lo, lo + rng.range_i32(1, 8))
                    })
                    .collect();
                Episode::new(types, ivs)
            })
            .collect();

        let theta = 6;
        let mut tp = TwoPassBackend::new(Box::new(CpuSerialBackend::new()), theta);
        assert_eq!(tp.name(), "two-pass(cpu-serial)");
        let (out, metrics) = tp.run(&eps, &stream).unwrap();
        assert_eq!(out.culled + out.survivors, eps.len() as u64);
        assert_eq!(metrics.a2_culled, out.culled);
        for (i, ep) in eps.iter().enumerate() {
            let exact = serial::count_a1(ep, &stream);
            // frequency decision must be exact
            assert_eq!(out.counts[i] >= theta, exact >= theta, "{}", ep.display());
            // survivors carry exact counts
            if out.relaxed_counts[i] >= theta {
                assert_eq!(out.counts[i], exact, "{}", ep.display());
            }
            // Theorem 5.1
            assert!(out.relaxed_counts[i] >= exact);
        }
    }
}
