//! Stream-sharded CPU counting: MapConcatenate's data parallelism
//! (paper §5.2.2) transplanted onto the host thread pool.
//!
//! [`cpu::CpuParallelBackend`](crate::backend::cpu::CpuParallelBackend)
//! parallelizes along the *episode* axis: with T threads and S surviving
//! candidates, late mining levels where S < T leave cores idle — exactly
//! the regime the companion transformation paper (arXiv:0905.2203)
//! identifies as the motivation for stream segmentation. This engine
//! parallelizes along the *stream* axis instead: the event stream is split
//! into per-thread time shards (planned by
//! [`mapconcat::plan_even`](crate::coordinator::mapconcat::plan_even), the
//! same feasibility rules the accelerator's segmentation uses), every
//! shard runs the boundary-machine Map step concurrently
//! ([`serial::mapcat_map`], the CPU reference for the Pallas Map kernel),
//! and shard results are stitched with the host Concatenate fold.
//!
//! Exactness: matched `b == a` chains reproduce the single-machine count
//! bit for bit, and a mismatch is always flagged by a nonzero miss count
//! (the invariant `prop_mapcat_equals_serial` pins) — episodes with misses
//! are recounted via the serial path, so reported counts always equal the
//! serial reference at the engine's K (unbounded by default).

use crate::backend::{count_grouped, CountBackend, CountReport};
use crate::coordinator::mapconcat::{self, Plan};
use crate::coordinator::Metrics;
use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::{EventStream, Tick};
use crate::mining::{cpu_parallel, serial};

/// Stream-axis CPU engine: one boundary-machine Map worker per time shard.
pub struct ShardedBackend {
    shards: usize,
    k: usize,
}

impl ShardedBackend {
    /// One time shard (and one Map worker thread) per `shards`, with
    /// unbounded occurrence lists — counts equal `serial::count_a1`.
    pub fn new(shards: usize) -> ShardedBackend {
        ShardedBackend { shards: shards.max(1), k: usize::MAX }
    }

    /// Bound the per-level occurrence lists to the K most recent entries
    /// (the accelerator kernel's semantics); counts then equal
    /// `serial::count_a1_bounded` at the same K.
    pub fn with_k(mut self, k: usize) -> ShardedBackend {
        self.k = k.max(1);
        self
    }

    /// The planned shard count (== Map worker threads).
    pub fn shards(&self) -> usize {
        self.shards
    }
}

/// The exact serial reference at this engine's K (the miss-recount path
/// and the fallback when the stream cannot be sharded).
fn recount_serial(ep: &Episode, stream: &EventStream, k: usize) -> u64 {
    if k == usize::MAX {
        serial::count_a1(ep, stream)
    } else {
        serial::count_a1_bounded(ep, stream, k)
    }
}

/// Run the Map step for every (shard, episode) pair, one scoped worker
/// thread per shard. Returns `[shard][episode] -> N machine tuples`.
///
/// Each worker scans only its shard's time window plus a halo of the
/// group's widest constraint window on both sides: boundary machine `mk`
/// starts up to `sum(t_high)` before the shard boundary, and a crossing
/// occurrence may complete up to `sum(t_high)` past it. The window
/// sub-stream therefore contains every event the machines can touch, and
/// the per-shard tuples are identical to a full-stream Map.
fn map_shards(
    group: &[Episode],
    stream: &EventStream,
    plan: &Plan,
    k: usize,
) -> Vec<Vec<Vec<(Tick, u64, Tick)>>> {
    let halo: Tick = group.iter().map(|e| e.span_max()).max().unwrap_or(0);
    let mut out = Vec::with_capacity(plan.taus.len() - 1);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(plan.taus.len() - 1);
        for bounds in plan.taus.windows(2) {
            handles.push(scope.spawn(move || {
                let (lo, hi) = (bounds[0], bounds[1]);
                let sub = stream.window(lo - halo, hi + halo);
                group
                    .iter()
                    .map(|ep| serial::mapcat_map(ep, &sub, &[lo, hi], k).swap_remove(0))
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            out.push(h.join().expect("shard worker panicked"));
        }
    });
    out
}

impl CountBackend for ShardedBackend {
    fn name(&self) -> &str {
        "cpu-sharded"
    }

    fn supports_n(&self, _n: usize) -> bool {
        true
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let (shards, k) = (self.shards, self.k);
        let mut metrics = Metrics::default();
        let counts = count_grouped(episodes, stream, &mut metrics, |_n, group, m| {
            let Some(plan) = mapconcat::plan_even(group, stream, shards) else {
                // stream too short for the shard count, or a constraint
                // window wider than a shard: episode-axis fallback.
                m.cpu_fallbacks += 1;
                return Ok(cpu_parallel::scatter_parallel(group, shards, |eps| {
                    eps.iter().map(|e| recount_serial(e, stream, k)).collect()
                }));
            };
            m.shard_map_calls += 1;
            let per_shard = map_shards(group, stream, &plan, k);
            let mut counts = Vec::with_capacity(group.len());
            let mut missed: Vec<usize> = vec![];
            for i in 0..group.len() {
                let segments: Vec<Vec<(Tick, u64, Tick)>> =
                    per_shard.iter().map(|s| s[i].clone()).collect();
                let (total, misses) = mapconcat::concatenate_fold(&segments);
                if misses > 0 {
                    // A flagged miss means the chain may have desynchronized;
                    // restore exactness via the serial reference.
                    m.concat_misses += misses;
                    missed.push(i);
                }
                counts.push(total);
            }
            if !missed.is_empty() {
                // Recount flagged episodes across the worker pool (misses
                // are rare by construction, but when they cluster a serial
                // recount loop would forfeit all parallelism).
                let subset: Vec<Episode> =
                    missed.iter().map(|&i| group[i].clone()).collect();
                let exact = cpu_parallel::scatter_parallel(&subset, shards, |eps| {
                    eps.iter().map(|e| recount_serial(e, stream, k)).collect()
                });
                for (&i, c) in missed.iter().zip(exact) {
                    counts[i] = c;
                }
            }
            Ok(counts)
        })?;
        Ok(CountReport { counts, culled: 0, metrics })
    }

    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        // The relaxed A2 pre-pass always sees the full candidate set (that
        // is its job), which fills the episode axis by construction — so
        // shard along episodes like the CPU baseline rather than building
        // A2 boundary machines.
        let counts = cpu_parallel::scatter_parallel(episodes, self.shards, |eps| {
            eps.iter().map(|e| serial::count_a2(e, stream)).collect()
        });
        let mut report = CountReport::from_counts(counts);
        report.metrics.episodes_counted = episodes.len() as u64;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;
    use crate::util::rng::Rng;

    fn world(seed: u64, n_events: usize) -> (Vec<Episode>, EventStream) {
        let mut rng = Rng::new(seed);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..n_events {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 4), t));
        }
        let stream = EventStream::from_pairs(pairs, 5);
        let mut eps = vec![Episode::single(1)];
        for _ in 0..6 {
            let n = rng.range_i32(2, 4) as usize;
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 4)).collect();
            let ivs: Vec<Interval> = (0..n - 1)
                .map(|_| {
                    let lo = rng.range_i32(0, 2);
                    Interval::new(lo, lo + rng.range_i32(1, 6))
                })
                .collect();
            eps.push(Episode::new(types, ivs));
        }
        (eps, stream)
    }

    #[test]
    fn sharded_matches_serial_on_mixed_batch() {
        let (eps, stream) = world(21, 900);
        let want: Vec<u64> =
            eps.iter().map(|e| serial::count_a1(e, &stream)).collect();
        for shards in [1, 3, 8] {
            let rep = ShardedBackend::new(shards).count(&eps, &stream).unwrap();
            assert_eq!(rep.counts, want, "shards {shards}");
            assert_eq!(rep.metrics.episodes_counted, eps.len() as u64);
        }
    }

    #[test]
    fn infeasible_sharding_falls_back_to_episode_axis() {
        // 3-tick stream cannot be cut into 8 shards; counts must still be
        // exact and the fallback must be visible in the metrics.
        let stream = EventStream::from_pairs(vec![(0, 1), (1, 2), (0, 3), (1, 4)], 2);
        let eps = vec![Episode::new(vec![0, 1], vec![Interval::new(0, 5)])];
        let rep = ShardedBackend::new(8).count(&eps, &stream).unwrap();
        assert_eq!(rep.counts, vec![serial::count_a1(&eps[0], &stream)]);
        assert_eq!(rep.metrics.cpu_fallbacks, 1);
        assert_eq!(rep.metrics.shard_map_calls, 0);
    }

    #[test]
    fn bounded_k_matches_bounded_serial() {
        let (eps, stream) = world(33, 700);
        let want: Vec<u64> =
            eps.iter().map(|e| serial::count_a1_bounded(e, &stream, 4)).collect();
        let rep = ShardedBackend::new(4).with_k(4).count(&eps, &stream).unwrap();
        assert_eq!(rep.counts, want);
    }

    #[test]
    fn relaxed_dominates_exact() {
        let (eps, stream) = world(5, 600);
        let mut be = ShardedBackend::new(4);
        let exact = be.count(&eps, &stream).unwrap().counts;
        let relaxed = be.count_relaxed(&eps, &stream).unwrap().counts;
        for (r, x) in relaxed.iter().zip(&exact) {
            assert!(r >= x);
        }
    }
}
