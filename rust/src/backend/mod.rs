//! Pluggable counting engines: the abstraction the paper's CPU/GPU division
//! of labor is written against.
//!
//! The mining driver (candidate generation, level loop, support filtering)
//! lives on the host and talks to a [`CountBackend`] — *some* engine that
//! can count non-overlapped occurrences of a batch of episodes over an
//! event stream. Concrete engines:
//!
//! - [`cpu::CpuSerialBackend`] — Algorithm 1, one automaton at a time.
//! - [`cpu::CpuParallelBackend`] — the paper's multithreaded baseline (§6.4),
//!   parallel along the *episode* axis.
//! - [`sharded::ShardedBackend`] — the MapConcatenate construction (§5.2.2)
//!   on the CPU thread pool, parallel along the *stream* axis.
//! - [`accel::PtpeBackend`] — per-thread-per-episode on the PJRT runtime
//!   (§5.2.1), CPU fallback for unsupported sizes.
//! - [`accel::MapConcatBackend`] — segment-parallel Map + host Concatenate
//!   (§5.2.2), PTPE/CPU fallback when segmentation is infeasible.
//! - [`accel::HybridBackend`] — composes any two backends under the
//!   crossover/cost dispatch rule (§5.2.3, Alg. 2).
//! - [`two_pass::TwoPassBackend`] — wraps any backend with the A2+A1
//!   elimination pipeline (§5.3): one-pass vs two-pass is backend
//!   *composition*, not a parallel mode enum.
//!
//! New substrates (multi-GPU, sharded CPU pools, remote accelerators) slot
//! in by implementing the trait; nothing in the lattice logic changes.

pub mod accel;
pub mod cpu;
pub mod sharded;
pub mod two_pass;

use std::rc::Rc;

use crate::coordinator::{Metrics, Strategy};
use crate::episodes::arena::{CandidateChunk, EpisodeArena};
use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::EventStream;
use crate::runtime::Runtime;

/// What one counting call did: per-episode counts plus the work metrics
/// accumulated while producing them.
#[derive(Clone, Debug, Default)]
pub struct CountReport {
    /// Non-overlapped occurrence counts, in input episode order. Backends
    /// that run an elimination pre-pass (see [`two_pass::TwoPassBackend`])
    /// return exact counts for survivors and the sub-threshold relaxed
    /// bound for culled candidates — the `count >= theta` decision is exact
    /// either way.
    pub counts: Vec<u64>,
    /// Candidates eliminated by a relaxed pre-pass (0 for one-pass engines).
    pub culled: u64,
    /// Work-counter delta for this call (merge into session totals).
    pub metrics: Metrics,
}

impl CountReport {
    /// A plain one-pass report carrying only counts.
    pub fn from_counts(counts: Vec<u64>) -> CountReport {
        CountReport { counts, culled: 0, metrics: Metrics::default() }
    }
}

/// One bounded block of arena-generated candidates, presented to
/// backends without forcing per-episode materialization: rows live in
/// the chunk's SoA columns, and
/// [`EpisodeBatch::materialize_into`] walks the arena's parent links
/// into a caller-owned scratch episode on demand. All rows share one
/// episode size ([`EpisodeBatch::n`]) — arena levels are uniform, which
/// is exactly the per-size dispatch unit accelerator backends want.
pub struct EpisodeBatch<'a> {
    arena: &'a EpisodeArena,
    chunk: &'a CandidateChunk,
}

impl<'a> EpisodeBatch<'a> {
    /// View a chunk generated against `arena`'s current top block (i.e.
    /// inside the [`EpisodeArena::generate_next`] sink, before the next
    /// level's block is pushed).
    pub fn new(arena: &'a EpisodeArena, chunk: &'a CandidateChunk) -> EpisodeBatch<'a> {
        EpisodeBatch { arena, chunk }
    }

    pub fn len(&self) -> usize {
        self.chunk.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunk.is_empty()
    }

    /// The episode size shared by every row in the batch.
    pub fn n(&self) -> usize {
        self.arena.num_levels() + 1
    }

    /// Materialize row `i` into a reusable scratch episode.
    pub fn materialize_into(&self, i: usize, ep: &mut Episode) {
        self.arena.materialize_chunk_row(self.chunk, i, ep);
    }

    /// Materialize the whole batch — the default-path bridge for engines
    /// that count `&[Episode]` slices.
    pub fn to_episodes(&self) -> Vec<Episode> {
        let mut scratch = Episode { types: vec![], intervals: vec![] };
        (0..self.len())
            .map(|i| {
                self.materialize_into(i, &mut scratch);
                scratch.clone()
            })
            .collect()
    }
}

/// A counting engine. Implementations may keep internal state (runtime
/// handles, thread pools, caches) — hence `&mut self`.
pub trait CountBackend {
    /// Stable human-readable engine name (used in reports and errors).
    fn name(&self) -> &str;

    /// Can this engine count episodes of size `n` natively? Engines with a
    /// CPU fallback still return `Ok` from [`CountBackend::count`] for
    /// unsupported sizes; this query reports the *native* capability.
    fn supports_n(&self, n: usize) -> bool;

    /// Count every episode's non-overlapped occurrences. Episodes may mix
    /// sizes; results return in input order.
    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError>;

    /// Count under the relaxed constraints α′ (paper Observation 5.1) —
    /// the cheap upper-bound pass two-pass elimination builds on. The
    /// default uses the exact counts, which are a sound (tight) upper
    /// bound; engines with a cheaper A2 path override this.
    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        self.count(episodes, stream)
    }

    /// Count one arena-generated candidate block. The default
    /// materializes the block and defers to [`CountBackend::count`] —
    /// correct for every engine; engines that can walk the SoA view with
    /// a scratch episode (see `cpu::CpuSerialBackend`) override this to
    /// skip the per-episode allocation entirely.
    fn count_batch(
        &mut self,
        batch: &EpisodeBatch<'_>,
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        self.count(&batch.to_episodes(), stream)
    }
}

/// Group episode indices by episode size, preserving order within groups.
/// Accelerator artifacts are compiled per size N, so uniform-size batches
/// are the unit of dispatch.
pub fn group_by_size(episodes: &[Episode]) -> Vec<(Vec<usize>, Vec<Episode>)> {
    let mut groups: Vec<(usize, Vec<usize>)> = vec![];
    for (i, ep) in episodes.iter().enumerate() {
        match groups.iter_mut().find(|(n, _)| *n == ep.n()) {
            Some((_, v)) => v.push(i),
            None => groups.push((ep.n(), vec![i])),
        }
    }
    groups
        .into_iter()
        .map(|(_, idx)| {
            let eps = idx.iter().map(|&i| episodes[i].clone()).collect();
            (idx, eps)
        })
        .collect()
}

/// The single episode size of a batch, if it is uniform (and non-empty).
/// Mining levels always produce uniform batches — the fast path the
/// grouping shells below take to avoid cloning the candidate set.
pub fn uniform_size(episodes: &[Episode]) -> Option<usize> {
    let n = episodes.first()?.n();
    episodes.iter().all(|e| e.n() == n).then_some(n)
}

/// Shared batching shell for per-size engines: groups a mixed batch by
/// episode size, answers 1-node episodes from host-side type frequencies
/// (no kernel exists or is needed for N=1), and scatters per-group results
/// back into input order. `count_uniform` sees only uniform groups with
/// n >= 2. Uniform batches (every mining level) pass through without the
/// clone-and-scatter.
///
/// A 1-node episode whose type lies outside the stream's alphabet is a
/// typed [`MineError::OutOfAlphabet`] — the frequency table is
/// alphabet-sized, and `EventStream` only `debug_assert`s its alphabet, so
/// indexing blindly here used to panic in release builds.
pub fn count_grouped<F>(
    episodes: &[Episode],
    stream: &EventStream,
    metrics: &mut Metrics,
    mut count_uniform: F,
) -> Result<Vec<u64>, MineError>
where
    F: FnMut(usize, &[Episode], &mut Metrics) -> Result<Vec<u64>, MineError>,
{
    metrics.episodes_counted += episodes.len() as u64;
    let n1_counts = |group: &[Episode]| -> Result<Vec<u64>, MineError> {
        let freq = stream.type_counts();
        group
            .iter()
            .map(|e| {
                let ty = e.types[0];
                if ty < 0 || ty as usize >= stream.n_types {
                    Err(MineError::OutOfAlphabet { type_id: ty, n_types: stream.n_types })
                } else {
                    Ok(freq[ty as usize])
                }
            })
            .collect()
    };
    if let Some(n) = uniform_size(episodes) {
        return if n == 1 {
            n1_counts(episodes)
        } else {
            count_uniform(n, episodes, metrics)
        };
    }
    let mut out = vec![0u64; episodes.len()];
    for (indices, group) in group_by_size(episodes) {
        let n = group[0].n();
        let counts = if n == 1 {
            n1_counts(&group)?
        } else {
            count_uniform(n, &group, metrics)?
        };
        for (slot, c) in indices.into_iter().zip(counts) {
            out[slot] = c;
        }
    }
    Ok(out)
}

/// Build the backend for a named [`Strategy`]. Accelerated strategies need
/// an open [`Runtime`]; CPU strategies ignore it.
pub fn for_strategy(
    strategy: Strategy,
    rt: Option<Rc<Runtime>>,
    cpu_threads: usize,
) -> Result<Box<dyn CountBackend>, MineError> {
    match strategy {
        Strategy::CpuSerial => Ok(Box::new(cpu::CpuSerialBackend::new())),
        Strategy::CpuParallel => Ok(Box::new(cpu::CpuParallelBackend::new(cpu_threads))),
        Strategy::CpuSharded => Ok(Box::new(sharded::ShardedBackend::new(cpu_threads))),
        Strategy::PtpeA1 => {
            Ok(Box::new(accel::PtpeBackend::new(require_rt(rt)?, cpu_threads)))
        }
        Strategy::MapConcat => {
            Ok(Box::new(accel::MapConcatBackend::new(require_rt(rt)?, cpu_threads)))
        }
        Strategy::Hybrid => {
            Ok(Box::new(accel::HybridBackend::with_runtime(require_rt(rt)?, cpu_threads)))
        }
    }
}

fn require_rt(rt: Option<Rc<Runtime>>) -> Result<Rc<Runtime>, MineError> {
    rt.ok_or_else(|| {
        MineError::runtime_unavailable(
            "this strategy counts on the accelerator; open a runtime with \
             Runtime::open_default() or pick a cpu strategy",
        )
    })
}

/// The default engine: accelerated Hybrid when the PJRT runtime opens,
/// otherwise the multithreaded CPU baseline. Mining is never blocked on
/// the accelerator being present.
pub fn default_backend(cpu_threads: usize) -> Box<dyn CountBackend> {
    match Runtime::open_default() {
        Ok(rt) => Box::new(accel::HybridBackend::with_runtime(Rc::new(rt), cpu_threads)),
        Err(_) => Box::new(cpu::CpuParallelBackend::new(cpu_threads)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;

    #[test]
    fn group_by_size_preserves_order() {
        let iv = Interval::new(0, 5);
        let eps = vec![
            Episode::single(0),
            Episode::new(vec![1, 2], vec![iv]),
            Episode::single(3),
            Episode::new(vec![4, 5], vec![iv]),
        ];
        let groups = group_by_size(&eps);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].0, vec![0, 2]);
        assert_eq!(groups[1].0, vec![1, 3]);
    }

    #[test]
    fn count_grouped_answers_n1_on_host() {
        let stream = EventStream::from_pairs(vec![(0, 1), (0, 3), (1, 5)], 2);
        let eps = vec![Episode::single(0), Episode::single(1)];
        let mut m = Metrics::default();
        let counts = count_grouped(&eps, &stream, &mut m, |_, _, _| {
            panic!("no uniform group expected for pure n=1 batches")
        })
        .unwrap();
        assert_eq!(counts, vec![2, 1]);
        assert_eq!(m.episodes_counted, 2);
    }

    #[test]
    fn accelerated_strategy_without_runtime_is_unavailable() {
        let err = for_strategy(Strategy::Hybrid, None, 2).err().unwrap();
        assert!(matches!(err, MineError::RuntimeUnavailable { .. }));
        assert!(for_strategy(Strategy::CpuSerial, None, 2).is_ok());
        assert!(for_strategy(Strategy::CpuSharded, None, 2).is_ok());
    }

    #[test]
    fn count_grouped_out_of_alphabet_is_typed_error() {
        let stream = EventStream::from_pairs(vec![(0, 1), (1, 5)], 2);
        let mut m = Metrics::default();
        // uniform n=1 batch with a type past the alphabet
        let err = count_grouped(&[Episode::single(7)], &stream, &mut m, |_, _, _| {
            panic!("n=1 must not reach count_uniform")
        })
        .err()
        .unwrap();
        assert!(
            matches!(err, MineError::OutOfAlphabet { type_id: 7, n_types: 2 }),
            "{err}"
        );
        // negative types are out of alphabet too, also on the mixed path
        let iv = Interval::new(0, 5);
        let mixed = vec![Episode::new(vec![0, 1], vec![iv]), Episode::single(-3)];
        let err = count_grouped(&mixed, &stream, &mut m, |_, _, _| Ok(vec![0]))
            .err()
            .unwrap();
        assert!(matches!(err, MineError::OutOfAlphabet { type_id: -3, .. }), "{err}");
    }
}
