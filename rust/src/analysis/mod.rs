//! Post-mining analysis: turning frequent episodes into neuroscience
//! artifacts (paper Fig. 1: "frequent episodes ... summarized to
//! reconstruct the underlying neuronal circuitry", §6.5 evolving
//! cultures).
//!
//! Grown in 0.3 into a statistically-grounded connectivity pipeline
//! (ROADMAP item 4): `surrogate` builds seeded jitter null models,
//! `batch` fans `1 + n_surrogates` mines across thread-local engines,
//! `significance` turns the surrogate count distribution into
//! per-episode p-values and excess scores, and `connectivity` ranks the
//! resulting putative-connection graph by significance instead of raw
//! support. Served as the `connectivity` query type and the
//! `epminer connectivity` subcommand.

pub mod batch;
pub mod connectivity;
pub mod raster;
pub mod significance;
pub mod summarize;
pub mod surrogate;
