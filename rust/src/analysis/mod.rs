//! Post-mining analysis: turning frequent episodes into neuroscience
//! artifacts (paper Fig. 1: "frequent episodes ... summarized to
//! reconstruct the underlying neuronal circuitry", §6.5 evolving
//! cultures).

pub mod connectivity;
pub mod summarize;
pub mod raster;
