//! Episode-set summarization: reduce the mined lattice to its maximal,
//! non-redundant members — what a neuroscientist actually reads.
//!
//! A frequent episode is *subsumed* by a longer frequent episode that
//! contains it as a contiguous sub-episode with the same constraints
//! (its count is then explained by the longer chain). The summary keeps
//! only non-subsumed episodes, optionally merging near-duplicate chains.

use crate::episodes::CountedEpisode;

/// Is `a` a contiguous sub-episode of `b` (same types and intervals)?
pub fn is_sub_episode(a: &CountedEpisode, b: &CountedEpisode) -> bool {
    let (ea, eb) = (&a.episode, &b.episode);
    let (na, nb) = (ea.n(), eb.n());
    if na > nb {
        return false;
    }
    if na == nb {
        return ea == eb;
    }
    (0..=nb - na).any(|off| {
        ea.types[..] == eb.types[off..off + na]
            && ea.intervals[..] == eb.intervals[off..off + na - 1]
    })
}

/// Keep only maximal episodes: those not subsumed by any other frequent
/// episode. `slack` tolerates support decay along the chain: a
/// sub-episode is only pruned if the superset's count is at least
/// `slack * sub.count` (slack in (0, 1]; 1.0 = prune only when counts
/// match exactly).
pub fn maximal_episodes(frequent: &[CountedEpisode], slack: f64) -> Vec<CountedEpisode> {
    assert!(slack > 0.0 && slack <= 1.0);
    let mut out: Vec<CountedEpisode> = vec![];
    for (i, cand) in frequent.iter().enumerate() {
        let subsumed = frequent.iter().enumerate().any(|(j, other)| {
            i != j
                && other.episode.n() > cand.episode.n()
                && is_sub_episode(cand, other)
                && other.count as f64 >= slack * cand.count as f64
        });
        if !subsumed {
            out.push(cand.clone());
        }
    }
    out.sort_by_key(|c| (std::cmp::Reverse(c.episode.n()), std::cmp::Reverse(c.count)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::{Episode, Interval};

    fn counted(types: Vec<i32>, count: u64) -> CountedEpisode {
        let iv = Interval::new(2, 10);
        let n = types.len();
        CountedEpisode { episode: Episode::new(types, vec![iv; n - 1]), count }
    }

    #[test]
    fn sub_episode_detection() {
        let a = counted(vec![1, 2], 10);
        let b = counted(vec![0, 1, 2, 3], 9);
        assert!(is_sub_episode(&a, &b));
        let c = counted(vec![2, 1], 10);
        assert!(!is_sub_episode(&c, &b));
    }

    #[test]
    fn sub_episode_requires_same_intervals() {
        let a = CountedEpisode {
            episode: Episode::new(vec![1, 2], vec![Interval::new(0, 5)]),
            count: 5,
        };
        let b = counted(vec![0, 1, 2], 5); // intervals (2,10]
        assert!(!is_sub_episode(&a, &b));
    }

    #[test]
    fn maximal_keeps_longest_chain_only() {
        let set = vec![
            counted(vec![0, 1], 12),
            counted(vec![1, 2], 11),
            counted(vec![0, 1, 2], 10),
        ];
        let max = maximal_episodes(&set, 0.5);
        assert_eq!(max.len(), 1);
        assert_eq!(max[0].episode.types, vec![0, 1, 2]);
    }

    #[test]
    fn slack_protects_much_stronger_subchains() {
        // sub-chain occurs 100x, super-chain only 10x: with slack 0.5 the
        // sub-chain is NOT explained away by the longer one
        let set = vec![counted(vec![0, 1], 100), counted(vec![0, 1, 2], 10)];
        let max = maximal_episodes(&set, 0.5);
        assert_eq!(max.len(), 2);
    }

    #[test]
    fn unrelated_episodes_survive() {
        let set = vec![counted(vec![0, 1, 2], 10), counted(vec![5, 6], 8)];
        let max = maximal_episodes(&set, 0.9);
        assert_eq!(max.len(), 2);
    }
}
