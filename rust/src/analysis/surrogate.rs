//! Surrogate null models for significance testing (arXiv:0902.3725 §3).
//!
//! A surrogate stream answers "how often would this episode occur if the
//! spike *timing* carried no information?" — it must preserve everything
//! about the recording except the fine temporal structure the episodes
//! measure. The generator here is spike-time **jitter** (dither): every
//! event keeps its type but its time is displaced by a uniform draw from
//! `[-jitter, +jitter]`, clamped into the original recording window.
//! Firing rates, per-type counts, and the overall envelope survive;
//! millisecond-scale causal delays (the `(t_low, t_high]` bands the miner
//! screens for) are destroyed when `jitter` is on the order of the band.
//!
//! Determinism contract: surrogate `index` under `seed` is a pure
//! function of `(stream, jitter, seed, index)` — independent of how many
//! surrogates are generated, in what order, or on which thread. The
//! batched executor and the serial reference loop therefore mine
//! byte-identical inputs (pinned in `tests/connectivity.rs`).

use crate::error::MineError;
use crate::events::{EventStream, Tick};
use crate::util::rng::Rng;

/// Jitter every event's time by a uniform draw from `[-jitter, +jitter]`,
/// clamped to the original window `[t_begin, t_end]`, then re-sort
/// (stable, so simultaneous events keep a deterministic order).
///
/// Draws come from per-type forked RNG streams: event `k` of type `ty`
/// consumes draw `k` of `rng.fork(ty)`, so the dither applied to one
/// neuron's spikes does not depend on how other neurons interleave.
pub fn jitter_stream(stream: &EventStream, jitter: Tick, mut rng: Rng) -> EventStream {
    if stream.is_empty() {
        return stream.clone();
    }
    let (lo, hi) = (stream.t_begin(), stream.t_end());
    let mut per_type: Vec<Rng> =
        (0..stream.n_types).map(|ty| rng.fork(ty as u64 + 1)).collect();
    let mut pairs = Vec::with_capacity(stream.len());
    for i in 0..stream.len() {
        let ty = stream.types[i];
        let d = per_type[ty as usize].range_i32(-jitter, jitter);
        let t = stream.times[i].saturating_add(d).clamp(lo, hi);
        pairs.push((ty, t));
    }
    EventStream::from_pairs(pairs, stream.n_types)
}

/// The RNG for surrogate `index` under `seed`: a fresh fork, so any
/// surrogate can be regenerated in isolation (the executor's workers
/// claim indices in arbitrary order).
pub fn surrogate_rng(seed: u64, index: usize) -> Rng {
    Rng::new(seed).fork(index as u64 + 1)
}

/// Surrogate `index` of `stream` under `seed`.
pub fn surrogate(stream: &EventStream, jitter: Tick, seed: u64, index: usize) -> EventStream {
    jitter_stream(stream, jitter, surrogate_rng(seed, index))
}

/// Generate surrogates `0..n`. Validates the knobs the way the serve/
/// admission path does, so the CLI and the service reject the same
/// configs.
pub fn surrogates(
    stream: &EventStream,
    n: usize,
    jitter: Tick,
    seed: u64,
) -> Result<Vec<EventStream>, MineError> {
    validate(n, jitter)?;
    Ok((0..n).map(|i| surrogate(stream, jitter, seed, i)).collect())
}

/// Shared knob validation (also used by `serve/`'s admission path).
pub fn validate(n_surrogates: usize, jitter: Tick) -> Result<(), MineError> {
    if n_surrogates == 0 {
        return Err(MineError::invalid(
            "n_surrogates must be >= 1 (empirical p-values need a null sample)",
        ));
    }
    if jitter < 1 {
        return Err(MineError::invalid(
            "jitter must be >= 1 tick (a zero-jitter surrogate is the real stream)",
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sym26::{self, Sym26Config};

    fn small_stream() -> EventStream {
        let cfg = Sym26Config { duration_ms: 5_000, ..Sym26Config::default() };
        sym26::generate(&cfg, 7)
    }

    #[test]
    fn same_seed_same_surrogate() {
        let s = small_stream();
        assert_eq!(surrogate(&s, 10, 42, 3), surrogate(&s, 10, 42, 3));
        assert_ne!(surrogate(&s, 10, 42, 3), surrogate(&s, 10, 43, 3));
        assert_ne!(surrogate(&s, 10, 42, 3), surrogate(&s, 10, 42, 4));
    }

    #[test]
    fn index_is_order_independent() {
        // surrogate k is the same whether generated alone or as part of a
        // batch — the executor depends on this
        let s = small_stream();
        let batch = surrogates(&s, 5, 8, 11).unwrap();
        for (i, surr) in batch.iter().enumerate() {
            assert_eq!(*surr, surrogate(&s, 8, 11, i));
        }
    }

    #[test]
    fn preserves_counts_and_window() {
        let s = small_stream();
        let j = jitter_stream(&s, 25, Rng::new(9));
        assert_eq!(j.len(), s.len());
        assert_eq!(j.type_counts(), s.type_counts());
        assert!(j.check_sorted());
        assert!(j.t_begin() >= s.t_begin() && j.t_end() <= s.t_end());
    }

    #[test]
    fn jitter_actually_moves_spikes() {
        let s = small_stream();
        let j = jitter_stream(&s, 10, Rng::new(9));
        assert_ne!(s, j);
    }

    #[test]
    fn empty_stream_is_fine() {
        let s = EventStream::new(4);
        assert_eq!(jitter_stream(&s, 10, Rng::new(1)).len(), 0);
    }

    #[test]
    fn knob_validation() {
        assert!(validate(0, 10).is_err());
        assert!(validate(5, 0).is_err());
        assert!(validate(1, 1).is_ok());
    }
}
