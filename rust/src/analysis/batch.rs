//! Batched multi-mine executor: N independent streams, one query config,
//! fanned across thread-local engines.
//!
//! This is the substrate the connectivity pipeline's `1 + n_surrogates`
//! fan-out runs on (and the shape ROADMAP item 2's batched device
//! dispatch needs: many mines of the same query config are exactly what
//! the MapConcatenate mapping batches onto one device launch). The
//! executor mirrors how `serve/`'s worker pool runs engines — each worker
//! thread builds **one** engine via [`session::engine_for`] and reuses it
//! across every job it claims, instead of paying engine construction per
//! mine the way a naive serial re-mine loop would — and every job funnels
//! through the single [`session::dispatch_mine`] dispatch point, which is
//! where the profile-driven CPU-vs-device crossover will later plug in.
//!
//! Determinism: jobs are claimed from a shared index and results are
//! stored back by index, so the output order (and content — engines are
//! deterministic and carry no state across mines) is independent of
//! thread scheduling. `parallelism = 1` degenerates to the serial
//! reference loop; `tests/connectivity.rs` pins batched == serial.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::{MineResult, Strategy};
use crate::error::MineError;
use crate::events::EventStream;
use crate::obs::Trace;
use crate::session::{self, MineOptions};

/// How the executor builds and spreads its engines.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// counting strategy for every engine (accelerated strategies open a
    /// thread-local runtime per worker)
    pub strategy: Strategy,
    /// two-pass A2+A1 elimination, as in `SessionBuilder::two_pass`
    pub two_pass: bool,
    /// engine-internal threads (the sharded backend's shard count)
    pub cpu_threads: usize,
    /// executor fan-out: worker threads each holding one engine.
    /// `1` is the serial reference loop the equivalence tests compare
    /// against; `0` is treated as `1`.
    pub parallelism: usize,
    /// attach a `MineProfile` to every job's result
    pub profile: bool,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig {
            strategy: Strategy::CpuParallel,
            two_pass: true,
            cpu_threads: 1,
            parallelism: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            profile: false,
        }
    }
}

/// Mine every stream in `jobs` under the same `opts`, returning results
/// in job order. Fails with the lowest-index job error if any job fails
/// (the same error a serial loop would surface first).
pub fn mine_batch(
    jobs: &[&EventStream],
    opts: &MineOptions,
    cfg: &BatchConfig,
    trace: &Trace,
) -> Result<Vec<MineResult>, MineError> {
    opts.validate()?;
    if jobs.is_empty() {
        return Ok(vec![]);
    }
    let workers = cfg.parallelism.max(1).min(jobs.len());
    let span = trace.span_fmt(|| format!("batch mine ({} jobs, {workers} workers)", jobs.len()));

    if workers == 1 {
        // serial reference loop: one engine, one job at a time
        let mut engine =
            session::engine_for(cfg.strategy, None, cfg.two_pass, opts.theta, cfg.cpu_threads)?;
        let mut out = Vec::with_capacity(jobs.len());
        for (i, stream) in jobs.iter().enumerate() {
            let job_span = span.child_fmt(|| format!("job {i}"));
            let r = session::dispatch_mine(engine.as_mut(), stream, opts, trace, cfg.profile);
            drop(job_span);
            out.push(r?);
        }
        return Ok(out);
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<MineResult, MineError>>>> =
        jobs.iter().map(|_| Mutex::new(None)).collect();
    let engine_errors: Mutex<Vec<MineError>> = Mutex::new(vec![]);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                // one engine per worker, reused across every claimed job
                // (the thread-local-engine pattern serve/'s pool uses)
                let mut engine = match session::engine_for(
                    cfg.strategy,
                    None,
                    cfg.two_pass,
                    opts.theta,
                    cfg.cpu_threads,
                ) {
                    Ok(e) => e,
                    Err(e) => {
                        engine_errors.lock().unwrap_or_else(|p| p.into_inner()).push(e);
                        return;
                    }
                };
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        return;
                    }
                    let job_span = span.child_fmt(|| format!("job {i}"));
                    let r = session::dispatch_mine(
                        engine.as_mut(),
                        jobs[i],
                        opts,
                        trace,
                        cfg.profile,
                    );
                    drop(job_span);
                    *slots[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(r);
                }
            });
        }
    });

    let mut out = Vec::with_capacity(jobs.len());
    for slot in slots {
        match slot.into_inner().unwrap_or_else(|p| p.into_inner()) {
            Some(Ok(r)) => out.push(r),
            Some(Err(e)) => return Err(e),
            // every worker's engine failed to build before this job ran
            None => {
                let mut errs = engine_errors.into_inner().unwrap_or_else(|p| p.into_inner());
                return Err(errs.pop().unwrap_or_else(|| {
                    MineError::internal("batch job never ran and no engine error was recorded")
                }));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sym26::{self, Sym26Config};
    use crate::episodes::Interval;

    fn opts() -> MineOptions {
        MineOptions {
            theta: 10,
            intervals: vec![Interval::new(5, 15)],
            max_level: 3,
            max_candidates_per_level: 2_000_000,
            candidate_block: crate::session::DEFAULT_CANDIDATE_BLOCK,
        }
    }

    #[test]
    fn batched_matches_serial_loop() {
        let cfg = Sym26Config { duration_ms: 4_000, ..Sym26Config::default() };
        let streams: Vec<EventStream> =
            (0..5).map(|s| sym26::generate(&cfg, 100 + s)).collect();
        let jobs: Vec<&EventStream> = streams.iter().collect();
        let serial = BatchConfig { parallelism: 1, ..BatchConfig::default() };
        let batched = BatchConfig { parallelism: 4, ..BatchConfig::default() };
        let a = mine_batch(&jobs, &opts(), &serial, &Trace::off()).unwrap();
        let b = mine_batch(&jobs, &opts(), &batched, &Trace::off()).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.frequent, y.frequent);
        }
    }

    #[test]
    fn empty_job_list() {
        let cfg = BatchConfig::default();
        assert!(mine_batch(&[], &opts(), &cfg, &Trace::off()).unwrap().is_empty());
    }

    #[test]
    fn invalid_options_rejected_up_front() {
        let cfg = BatchConfig::default();
        let bad = MineOptions { theta: 0, ..opts() };
        let s = sym26::generate(&Sym26Config { duration_ms: 1_000, ..Sym26Config::default() }, 1);
        assert!(mine_batch(&[&s], &bad, &cfg, &Trace::off()).is_err());
    }
}
