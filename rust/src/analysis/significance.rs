//! Per-episode significance against a surrogate null distribution
//! (arXiv:0902.3725's statistical framing).
//!
//! Given the real mine and N surrogate mines of the same query, each
//! real frequent episode gets:
//!
//! - an **empirical p-value** `p = (1 + #{surrogates with count >= real
//!   count}) / (1 + N)` — the add-one form, so `p` is never 0 and the
//!   best attainable value with N surrogates is `1/(N+1)`;
//! - an **excess count** `real - mean(surrogate counts)` — how many
//!   occurrences the timing structure adds over what rate alone
//!   produces.
//!
//! An episode absent from a surrogate's frequent set counts as 0 there.
//! That truncation is safe for the p-value: a sub-theta surrogate count
//! is strictly below theta, and the real count (of a frequent episode)
//! is >= theta, so the `>= real` comparison can never be flipped by the
//! truncation. The excess is then an *over*-estimate by at most theta
//! per truncated surrogate — fine for ranking, and exact in the regime
//! that matters (significant episodes dwarf their null counts).

use std::collections::HashMap;

use crate::coordinator::MineResult;
use crate::episodes::Episode;

/// One episode's evidence against the null.
#[derive(Clone, Debug, PartialEq)]
pub struct EpisodeScore {
    pub episode: Episode,
    /// non-overlapped count in the real stream
    pub count: u64,
    /// mean surrogate count (truncated-at-theta counts enter as 0)
    pub null_mean: f64,
    /// largest surrogate count observed
    pub null_max: u64,
    /// add-one empirical p-value; floor is `1/(n_surrogates+1)`
    pub p_value: f64,
    /// `count - null_mean`
    pub excess: f64,
}

/// The scored real mine: every real frequent episode of size >= 2,
/// ranked most-significant first (p ascending, then excess descending).
#[derive(Clone, Debug, PartialEq)]
pub struct SignificanceReport {
    pub scores: Vec<EpisodeScore>,
    pub n_surrogates: usize,
}

impl SignificanceReport {
    /// The smallest p-value this many surrogates can resolve.
    pub fn p_floor(&self) -> f64 {
        1.0 / (self.n_surrogates as f64 + 1.0)
    }

    /// Scores at or below `max_p`.
    pub fn significant(&self, max_p: f64) -> impl Iterator<Item = &EpisodeScore> {
        self.scores.iter().filter(move |s| s.p_value <= max_p)
    }
}

/// Score the real mine against its surrogate mines. Size-1 episodes are
/// rate statements, not timing structure — jitter preserves them by
/// construction — so only sizes >= 2 are scored.
pub fn score_against_surrogates(
    real: &MineResult,
    surrogates: &[MineResult],
) -> SignificanceReport {
    let n = surrogates.len();
    let null_counts: Vec<HashMap<&Episode, u64>> = surrogates
        .iter()
        .map(|s| s.frequent.iter().map(|c| (&c.episode, c.count)).collect())
        .collect();

    let mut scores: Vec<EpisodeScore> = real
        .frequent
        .iter()
        .filter(|c| c.episode.n() >= 2)
        .map(|c| {
            let mut at_least = 0usize;
            let mut sum = 0u64;
            let mut max = 0u64;
            for counts in &null_counts {
                let sc = counts.get(&c.episode).copied().unwrap_or(0);
                if sc >= c.count {
                    at_least += 1;
                }
                sum += sc;
                max = max.max(sc);
            }
            let null_mean = if n == 0 { 0.0 } else { sum as f64 / n as f64 };
            EpisodeScore {
                episode: c.episode.clone(),
                count: c.count,
                null_mean,
                null_max: max,
                p_value: (1 + at_least) as f64 / (1 + n) as f64,
                excess: c.count as f64 - null_mean,
            }
        })
        .collect();

    // most significant first; episode order (already deterministic from
    // the mine) breaks exact ties, keeping the ranked graph byte-stable
    scores.sort_by(|a, b| {
        a.p_value
            .total_cmp(&b.p_value)
            .then(b.excess.total_cmp(&a.excess))
            .then(b.count.cmp(&a.count))
            .then(a.episode.cmp(&b.episode))
    });
    SignificanceReport { scores, n_surrogates: n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::{CountedEpisode, Interval};

    fn ep(types: &[i32]) -> Episode {
        let iv = Interval::new(2, 10);
        Episode::new(types.to_vec(), vec![iv; types.len().saturating_sub(1)])
    }

    fn mine_of(counts: &[(&[i32], u64)]) -> MineResult {
        MineResult {
            frequent: counts
                .iter()
                .map(|(t, c)| CountedEpisode { episode: ep(t), count: *c })
                .collect(),
            levels: vec![],
            profile: None,
        }
    }

    #[test]
    fn p_value_counts_surrogates_at_or_above() {
        let real = mine_of(&[(&[0, 1], 50)]);
        let surr = vec![
            mine_of(&[(&[0, 1], 10)]),
            mine_of(&[(&[0, 1], 50)]), // ties count against significance
            mine_of(&[]),              // absent -> 0
            mine_of(&[(&[0, 1], 60)]),
        ];
        let rep = score_against_surrogates(&real, &surr);
        assert_eq!(rep.scores.len(), 1);
        let s = &rep.scores[0];
        assert_eq!(s.p_value, 3.0 / 5.0);
        assert_eq!(s.null_max, 60);
        assert_eq!(s.null_mean, 30.0);
        assert_eq!(s.excess, 20.0);
    }

    #[test]
    fn floor_when_no_surrogate_reaches_real_count() {
        let real = mine_of(&[(&[0, 1], 40)]);
        let surr = vec![mine_of(&[]); 9];
        let rep = score_against_surrogates(&real, &surr);
        assert_eq!(rep.scores[0].p_value, rep.p_floor());
        assert_eq!(rep.p_floor(), 0.1);
    }

    #[test]
    fn size_one_episodes_are_not_scored() {
        let real = mine_of(&[(&[3], 100), (&[0, 1], 20)]);
        let rep = score_against_surrogates(&real, &[mine_of(&[])]);
        assert_eq!(rep.scores.len(), 1);
        assert_eq!(rep.scores[0].episode, ep(&[0, 1]));
    }

    #[test]
    fn ranking_is_p_then_excess() {
        let real = mine_of(&[(&[0, 1], 20), (&[2, 3], 80), (&[4, 5], 20)]);
        // [2,3] and [4,5] share the p floor; [2,3] has more excess.
        // [0,1] is matched by the surrogate -> p = 1.
        let surr = vec![mine_of(&[(&[0, 1], 25)])];
        let rep = score_against_surrogates(&real, &surr);
        let order: Vec<&Episode> = rep.scores.iter().map(|s| &s.episode).collect();
        assert_eq!(order, vec![&ep(&[2, 3]), &ep(&[4, 5]), &ep(&[0, 1])]);
        assert_eq!(rep.significant(0.6).count(), 2);
    }
}
