//! Functional-connectivity reconstruction from mined episodes (paper
//! Fig. 1 right-to-left arrow; the end product of chip-on-chip mining).
//!
//! Every adjacent pair inside a frequent episode is evidence for a
//! directed functional edge A -> B with the episode's inter-event delay.
//! Edges are scored by the maximum support among the episodes that
//! contain them; the reconstructed graph is compared against a generator
//! ground truth with precision/recall.

use std::collections::HashMap;

use crate::episodes::{CountedEpisode, Episode};
use crate::events::EventType;

/// A directed functional edge with its evidence.
#[derive(Clone, Debug, PartialEq)]
pub struct Edge {
    pub from: EventType,
    pub to: EventType,
    /// strongest support among episodes containing this edge
    pub support: u64,
    /// delay bounds of the supporting constraint
    pub t_low: i32,
    pub t_high: i32,
}

/// The reconstructed functional-connectivity graph.
#[derive(Clone, Debug, Default)]
pub struct Circuit {
    pub edges: Vec<Edge>,
}

impl Circuit {
    /// Build from mined episodes: every adjacent pair contributes an edge
    /// candidate; keep the strongest evidence per (from, to).
    pub fn reconstruct(frequent: &[CountedEpisode]) -> Circuit {
        let mut best: HashMap<(EventType, EventType), Edge> = HashMap::new();
        for c in frequent {
            let ep = &c.episode;
            for i in 0..ep.n().saturating_sub(1) {
                let key = (ep.types[i], ep.types[i + 1]);
                let iv = &ep.intervals[i];
                let e = best.entry(key).or_insert(Edge {
                    from: key.0,
                    to: key.1,
                    support: 0,
                    t_low: iv.t_low,
                    t_high: iv.t_high,
                });
                if c.count > e.support {
                    e.support = c.count;
                    e.t_low = iv.t_low;
                    e.t_high = iv.t_high;
                }
            }
        }
        let mut edges: Vec<Edge> = best.into_values().collect();
        edges.sort_by_key(|e| (std::cmp::Reverse(e.support), e.from, e.to));
        Circuit { edges }
    }

    /// Keep only edges with support >= threshold.
    pub fn thresholded(&self, min_support: u64) -> Circuit {
        Circuit {
            edges: self.edges.iter().filter(|e| e.support >= min_support).cloned().collect(),
        }
    }

    pub fn contains(&self, from: EventType, to: EventType) -> bool {
        self.edges.iter().any(|e| e.from == from && e.to == to)
    }

    /// Precision/recall against ground-truth chains (the generator's
    /// embedded circuits).
    pub fn score(&self, truth_chains: &[Episode]) -> Score {
        let mut truth: Vec<(EventType, EventType)> = vec![];
        for ch in truth_chains {
            for w in ch.types.windows(2) {
                truth.push((w[0], w[1]));
            }
        }
        truth.sort_unstable();
        truth.dedup();
        let tp = self
            .edges
            .iter()
            .filter(|e| truth.contains(&(e.from, e.to)))
            .count();
        Score {
            true_positives: tp,
            predicted: self.edges.len(),
            actual: truth.len(),
        }
    }

    /// Graphviz dot rendering for the supplementary-style visuals.
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph circuit {\n  rankdir=LR;\n");
        for e in &self.edges {
            s.push_str(&format!(
                "  n{} -> n{} [label=\"{} ({},{}]\"];\n",
                e.from, e.to, e.support, e.t_low, e.t_high
            ));
        }
        s.push_str("}\n");
        s
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Score {
    pub true_positives: usize,
    pub predicted: usize,
    pub actual: usize,
}

impl Score {
    pub fn precision(&self) -> f64 {
        if self.predicted == 0 {
            return 1.0;
        }
        self.true_positives as f64 / self.predicted as f64
    }

    pub fn recall(&self) -> f64 {
        if self.actual == 0 {
            return 1.0;
        }
        self.true_positives as f64 / self.actual as f64
    }

    pub fn f1(&self) -> f64 {
        let (p, r) = (self.precision(), self.recall());
        if p + r == 0.0 {
            return 0.0;
        }
        2.0 * p * r / (p + r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;

    fn counted(types: Vec<i32>, count: u64) -> CountedEpisode {
        let iv = Interval::new(2, 10);
        let n = types.len();
        CountedEpisode { episode: Episode::new(types, vec![iv; n - 1]), count }
    }

    #[test]
    fn reconstruct_takes_max_support_per_edge() {
        let c = Circuit::reconstruct(&[
            counted(vec![0, 1], 5),
            counted(vec![0, 1, 2], 9),
            counted(vec![1, 2], 3),
        ]);
        let e01 = c.edges.iter().find(|e| e.from == 0 && e.to == 1).unwrap();
        assert_eq!(e01.support, 9);
        let e12 = c.edges.iter().find(|e| e.from == 1 && e.to == 2).unwrap();
        assert_eq!(e12.support, 9);
        assert_eq!(c.edges.len(), 2);
    }

    #[test]
    fn threshold_filters() {
        let c = Circuit::reconstruct(&[counted(vec![0, 1], 5), counted(vec![2, 3], 50)]);
        let t = c.thresholded(10);
        assert_eq!(t.edges.len(), 1);
        assert!(t.contains(2, 3));
    }

    #[test]
    fn score_precision_recall() {
        let truth = vec![Episode::new(
            vec![0, 1, 2],
            vec![Interval::new(2, 10); 2],
        )];
        let c = Circuit::reconstruct(&[
            counted(vec![0, 1], 5), // true edge
            counted(vec![5, 6], 5), // false edge
        ]);
        let s = c.score(&truth);
        assert_eq!(s.true_positives, 1);
        assert_eq!(s.predicted, 2);
        assert_eq!(s.actual, 2); // (0,1), (1,2)
        assert!((s.precision() - 0.5).abs() < 1e-9);
        assert!((s.recall() - 0.5).abs() < 1e-9);
        assert!(s.f1() > 0.0);
    }

    #[test]
    fn dot_output_contains_edges() {
        let c = Circuit::reconstruct(&[counted(vec![3, 7], 12)]);
        let dot = c.to_dot();
        assert!(dot.contains("n3 -> n7"));
        assert!(dot.contains("digraph"));
    }
}
