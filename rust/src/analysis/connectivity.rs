//! Functional-connectivity reconstruction from mined episodes (paper
//! Fig. 1; arXiv:0709.0218's screen, arXiv:0902.3725's statistics).
//!
//! Pipeline (`infer_connectivity`):
//!
//! ```text
//!   real stream ──┬────────────────────────── mine ──┐
//!                 │ jitter ×N (surrogate.rs)         │ score (significance.rs)
//!                 └─ surrogate streams ── mine ×N ───┴─→ p / excess per episode
//!                        (batch.rs fan-out)               │
//!                                                         ▼
//!                                    Circuit: edges ranked by significance
//! ```
//!
//! An edge `A → B` is putative connectivity evidence: some significant
//! episode walks `A` then `B` under an inter-event delay band. The
//! seed-era reconstruction ranked edges by raw max support, which
//! conflates firing rate with timing structure — two fast-firing
//! neurons coincide often by chance alone. Ranking by surrogate-null
//! significance (p ascending, excess descending) keeps only edges whose
//! delay structure survives jitter; [`Circuit::from_support`] preserves
//! the old support-max behaviour for callers that have no null model
//! (e.g. `epminer reconstruct`).

use std::collections::HashSet;

use crate::analysis::batch::{self, BatchConfig};
use crate::analysis::significance::{self, SignificanceReport};
use crate::analysis::surrogate;
use crate::coordinator::MineResult;
use crate::episodes::{CountedEpisode, Episode};
use crate::error::MineError;
use crate::events::{EventStream, EventType, Tick};
use crate::obs::Trace;
use crate::session::MineOptions;

/// A putative connection, with the best evidence seen for it.
#[derive(Clone, Debug, PartialEq)]
pub struct Edge {
    pub from: EventType,
    pub to: EventType,
    /// support of the strongest episode asserting this edge
    pub support: u64,
    /// delay bounds of that episode's adjacent pair
    pub t_low: Tick,
    pub t_high: Tick,
    /// significance of the best witnessing episode; `1.0` under
    /// [`Circuit::from_support`], which carries no null model
    pub p_value: f64,
    /// excess count of that episode over the surrogate mean; `0.0`
    /// under [`Circuit::from_support`]
    pub excess: f64,
}

/// The reconstructed putative circuit.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Circuit {
    /// ranked most-credible first: significance order under
    /// [`Circuit::reconstruct`], support order under
    /// [`Circuit::from_support`]
    pub edges: Vec<Edge>,
}

impl Circuit {
    /// Build the significance-ranked graph: every adjacent pair of every
    /// scored episode asserts an edge, and each `(from, to)` keeps the
    /// evidence of its most significant witness (lowest p, then largest
    /// excess, then largest support).
    pub fn reconstruct(report: &SignificanceReport) -> Circuit {
        let mut edges: Vec<Edge> = vec![];
        for s in &report.scores {
            for i in 0..s.episode.n() - 1 {
                let cand = Edge {
                    from: s.episode.types[i],
                    to: s.episode.types[i + 1],
                    support: s.count,
                    t_low: s.episode.intervals[i].t_low,
                    t_high: s.episode.intervals[i].t_high,
                    p_value: s.p_value,
                    excess: s.excess,
                };
                match edges.iter_mut().find(|e| e.from == cand.from && e.to == cand.to) {
                    None => edges.push(cand),
                    Some(e) => {
                        let better = cand
                            .p_value
                            .total_cmp(&e.p_value)
                            .then(e.excess.total_cmp(&cand.excess))
                            .then(e.support.cmp(&cand.support))
                            .is_lt();
                        if better {
                            *e = cand;
                        }
                    }
                }
            }
        }
        edges.sort_by(|a, b| {
            a.p_value
                .total_cmp(&b.p_value)
                .then(b.excess.total_cmp(&a.excess))
                .then(b.support.cmp(&a.support))
                .then(a.from.cmp(&b.from))
                .then(a.to.cmp(&b.to))
        });
        Circuit { edges }
    }

    /// The pre-0.3 reconstruction: max support per adjacent pair, no
    /// null model (`p_value = 1.0`, `excess = 0.0`), ranked by support.
    pub fn from_support(frequent: &[CountedEpisode]) -> Circuit {
        let mut edges: Vec<Edge> = vec![];
        for c in frequent {
            for i in 0..c.episode.n().saturating_sub(1) {
                let (from, to) = (c.episode.types[i], c.episode.types[i + 1]);
                let iv = c.episode.intervals[i];
                match edges.iter_mut().find(|e| e.from == from && e.to == to) {
                    None => edges.push(Edge {
                        from,
                        to,
                        support: c.count,
                        t_low: iv.t_low,
                        t_high: iv.t_high,
                        p_value: 1.0,
                        excess: 0.0,
                    }),
                    Some(e) => {
                        if c.count > e.support {
                            e.support = c.count;
                            e.t_low = iv.t_low;
                            e.t_high = iv.t_high;
                        }
                    }
                }
            }
        }
        edges.sort_by(|a, b| {
            b.support.cmp(&a.support).then(a.from.cmp(&b.from)).then(a.to.cmp(&b.to))
        });
        Circuit { edges }
    }

    /// Keep only edges with support >= threshold.
    pub fn thresholded(&self, min_support: u64) -> Circuit {
        Circuit {
            edges: self.edges.iter().filter(|e| e.support >= min_support).cloned().collect(),
        }
    }

    /// Edges at or below `max_p` (meaningful only for significance-
    /// ranked circuits; [`Circuit::from_support`] edges all carry
    /// `p = 1.0`).
    pub fn significant(&self, max_p: f64) -> Circuit {
        Circuit {
            edges: self.edges.iter().filter(|e| e.p_value <= max_p).cloned().collect(),
        }
    }

    pub fn contains(&self, from: EventType, to: EventType) -> bool {
        self.edges.iter().any(|e| e.from == from && e.to == to)
    }

    /// Precision/recall against ground-truth chains (the generator's
    /// embedded circuits — see `datasets::ground_truth`).
    pub fn score(&self, truth_chains: &[Episode]) -> Score {
        let actual: HashSet<(EventType, EventType)> = truth_chains
            .iter()
            .flat_map(|ch| ch.types.windows(2).map(|w| (w[0], w[1])))
            .collect();
        let predicted: HashSet<(EventType, EventType)> =
            self.edges.iter().map(|e| (e.from, e.to)).collect();
        Score {
            true_positives: predicted.intersection(&actual).count(),
            predicted: predicted.len(),
            actual: actual.len(),
        }
    }

    /// Graphviz dot rendering; significance annotated when present.
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph circuit {\n  rankdir=LR;\n");
        for e in &self.edges {
            let label = if e.p_value < 1.0 {
                format!("p={:.3} +{:.1} ({}x)", e.p_value, e.excess, e.support)
            } else {
                format!("{} ({},{}]", e.support, e.t_low, e.t_high)
            };
            s.push_str(&format!("  n{} -> n{} [label=\"{label}\"];\n", e.from, e.to));
        }
        s.push_str("}\n");
        s
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Score {
    pub true_positives: usize,
    pub predicted: usize,
    pub actual: usize,
}

impl Score {
    pub fn precision(&self) -> f64 {
        if self.predicted == 0 {
            return 1.0;
        }
        self.true_positives as f64 / self.predicted as f64
    }

    pub fn recall(&self) -> f64 {
        if self.actual == 0 {
            return 1.0;
        }
        self.true_positives as f64 / self.actual as f64
    }

    pub fn f1(&self) -> f64 {
        let (p, r) = (self.precision(), self.recall());
        if p + r == 0.0 {
            return 0.0;
        }
        2.0 * p * r / (p + r)
    }
}

/// The connectivity pipeline's knobs on top of one mine config.
#[derive(Clone, Debug)]
pub struct ConnectivityConfig {
    /// null-model sample size; the p-value floor is `1/(n+1)`
    pub n_surrogates: usize,
    /// jitter half-width in ticks — pick it on the order of the delay
    /// band it is meant to destroy
    pub jitter: Tick,
    /// surrogate RNG seed; the whole pipeline is deterministic under it
    pub seed: u64,
    /// how the `1 + n_surrogates` mines execute
    pub batch: BatchConfig,
}

/// Everything one connectivity query produces.
#[derive(Clone, Debug)]
pub struct ConnectivityResult {
    /// the real stream's mine (profile attached when requested)
    pub base: MineResult,
    /// per-episode significance, ranked
    pub report: SignificanceReport,
    /// the ranked putative-connection graph
    pub circuit: Circuit,
}

/// Run the full pipeline: mine the real stream and `n_surrogates`
/// jittered nulls through the batched executor, score, reconstruct.
/// Deterministic under `(stream, opts, n_surrogates, jitter, seed)` and
/// independent of `batch.parallelism` (pinned in `tests/connectivity.rs`).
pub fn infer_connectivity(
    stream: &EventStream,
    opts: &MineOptions,
    cfg: &ConnectivityConfig,
    trace: &Trace,
) -> Result<ConnectivityResult, MineError> {
    surrogate::validate(cfg.n_surrogates, cfg.jitter)?;
    opts.validate()?;
    let root = trace.span("connectivity");

    let surr_streams = {
        let _g = root.child("surrogate gen");
        surrogate::surrogates(stream, cfg.n_surrogates, cfg.jitter, cfg.seed)?
    };

    // job 0 is the real stream; the executor's span tree records the
    // fan-out shape
    let mut jobs: Vec<&EventStream> = Vec::with_capacity(1 + surr_streams.len());
    jobs.push(stream);
    jobs.extend(surr_streams.iter());
    let mut results = batch::mine_batch(&jobs, opts, &cfg.batch, trace)?;

    let base = results.remove(0);
    let _g = root.child("score");
    let report = significance::score_against_surrogates(&base, &results);
    let circuit = Circuit::reconstruct(&report);
    Ok(ConnectivityResult { base, report, circuit })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::significance::EpisodeScore;
    use crate::episodes::Interval;

    fn ep(types: &[EventType]) -> Episode {
        Episode::new(types.to_vec(), vec![Interval::new(2, 10); types.len() - 1])
    }

    fn counted(types: &[EventType], count: u64) -> CountedEpisode {
        CountedEpisode { episode: ep(types), count }
    }

    fn scored(types: &[EventType], count: u64, p: f64, excess: f64) -> EpisodeScore {
        EpisodeScore {
            episode: ep(types),
            count,
            null_mean: count as f64 - excess,
            null_max: 0,
            p_value: p,
            excess,
        }
    }

    #[test]
    fn from_support_takes_max_support_per_edge() {
        let c = Circuit::from_support(&[
            counted(&[0, 1], 5),
            counted(&[0, 1, 2], 9),
            counted(&[1, 2], 3),
        ]);
        let e01 = c.edges.iter().find(|e| e.from == 0 && e.to == 1).unwrap();
        assert_eq!(e01.support, 9);
        assert_eq!(e01.p_value, 1.0);
        let e12 = c.edges.iter().find(|e| e.from == 1 && e.to == 2).unwrap();
        assert_eq!(e12.support, 9);
        assert_eq!(c.edges.len(), 2);
    }

    #[test]
    fn reconstruct_ranks_by_significance_not_support() {
        let rep = SignificanceReport {
            scores: vec![
                scored(&[4, 5], 30, 0.1, 25.0), // significant, modest support
                scored(&[1, 2], 90, 0.8, 2.0),  // busy but explained by rate
                scored(&[4, 5, 6], 20, 0.1, 18.0), // ties 4->5's p, less excess
            ],
            n_surrogates: 9,
        };
        let c = Circuit::reconstruct(&rep);
        assert_eq!((c.edges[0].from, c.edges[0].to), (4, 5));
        // best witness for 4->5 is the pair episode, not the triple
        assert_eq!(c.edges[0].support, 30);
        assert_eq!(c.edges[0].excess, 25.0);
        // the high-support, high-p edge ranks last
        let last = c.edges.last().unwrap();
        assert_eq!((last.from, last.to), (1, 2));
        assert_eq!(c.significant(0.5).edges.len(), 2);
    }

    #[test]
    fn threshold_filters() {
        let c = Circuit::from_support(&[counted(&[0, 1], 5), counted(&[2, 3], 50)]);
        let t = c.thresholded(10);
        assert_eq!(t.edges.len(), 1);
        assert!(t.contains(2, 3));
    }

    #[test]
    fn score_precision_recall() {
        let truth = vec![ep(&[0, 1, 2])];
        let c = Circuit::from_support(&[
            counted(&[0, 1], 5), // true edge
            counted(&[5, 6], 5), // false edge
        ]);
        let s = c.score(&truth);
        assert_eq!(s.true_positives, 1);
        assert_eq!(s.predicted, 2);
        assert_eq!(s.actual, 2); // (0,1), (1,2)
        assert!((s.precision() - 0.5).abs() < 1e-9);
        assert!((s.recall() - 0.5).abs() < 1e-9);
        assert!(s.f1() > 0.0);
    }

    #[test]
    fn dot_output_contains_edges() {
        let sup = Circuit::from_support(&[counted(&[3, 7], 12)]);
        assert!(sup.to_dot().contains("n3 -> n7"));
        assert!(sup.to_dot().contains("digraph"));
        let sig = Circuit::reconstruct(&SignificanceReport {
            scores: vec![scored(&[3, 7], 12, 0.05, 11.0)],
            n_surrogates: 19,
        });
        assert!(sig.to_dot().contains("p=0.050"));
    }
}
