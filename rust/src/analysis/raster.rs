//! ASCII raster plots of spike trains with episode-occurrence overlays —
//! the terminal stand-in for the paper's supplementary visualizations
//! ("fast-forward and slow-play facilities", §7).

use crate::episodes::Episode;
use crate::events::{EventStream, Tick};

/// Render a raster of the stream window `(t0, t1]`: one row per event
/// type (top `max_rows` busiest), one column per `bin` ticks; cell shows
/// event density. Rows participating in `highlight` are marked.
pub fn render(
    stream: &EventStream,
    t0: Tick,
    t1: Tick,
    width: usize,
    max_rows: usize,
    highlight: Option<&Episode>,
) -> String {
    assert!(t1 > t0 && width > 0);
    let win = stream.window(t0, t1);
    let bin = ((t1 - t0) as f64 / width as f64).max(1.0);
    // busiest rows first
    let counts = win.type_counts();
    let mut order: Vec<usize> = (0..stream.n_types).collect();
    order.sort_by_key(|&ty| std::cmp::Reverse(counts.get(ty).copied().unwrap_or(0)));
    order.truncate(max_rows);
    order.sort_unstable();

    let mut grid = vec![vec![0u32; width]; order.len()];
    for (e, t) in win.iter() {
        if let Some(row) = order.iter().position(|&ty| ty == e as usize) {
            let col = (((t - t0) as f64 - 1.0) / bin).max(0.0) as usize;
            grid[row][col.min(width - 1)] += 1;
        }
    }

    let mut s = String::new();
    s.push_str(&format!("raster ({t0}, {t1}] — {} events, bin {bin:.0} ticks\n", win.len()));
    for (row, &ty) in order.iter().enumerate() {
        let mark = highlight
            .map(|ep| if ep.types.contains(&(ty as i32)) { '*' } else { ' ' })
            .unwrap_or(' ');
        s.push_str(&format!("{mark}{ty:>4} |"));
        for &c in &grid[row] {
            s.push(match c {
                0 => ' ',
                1 => '.',
                2..=3 => ':',
                4..=7 => '+',
                _ => '#',
            });
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;

    fn stream() -> EventStream {
        EventStream::from_pairs(
            vec![(0, 10), (1, 15), (0, 20), (2, 25), (0, 25), (1, 30)],
            3,
        )
    }

    #[test]
    fn renders_expected_shape() {
        let s = stream();
        let out = render(&s, 0, 40, 20, 3, None);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4); // header + 3 rows
        assert!(lines[0].contains("6 events"));
        assert!(lines[1].contains('|'));
    }

    #[test]
    fn highlight_marks_episode_rows() {
        let s = stream();
        let ep = Episode::new(vec![0, 1], vec![Interval::new(1, 10)]);
        let out = render(&s, 0, 40, 20, 3, Some(&ep));
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[1].starts_with('*')); // type 0
        assert!(lines[2].starts_with('*')); // type 1
        assert!(lines[3].starts_with(' ')); // type 2
    }

    #[test]
    fn respects_max_rows() {
        let s = stream();
        let out = render(&s, 0, 40, 10, 2, None);
        assert_eq!(out.lines().count(), 3);
    }
}
