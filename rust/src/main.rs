//! `epminer`: CLI front-end for the episodes-gpu miner.
//!
//! Subcommands:
//!   mine        — level-wise mining over a dataset (name, file: or log:)
//!   count       — count explicit episodes (debugging/inspection)
//!   gen         — generate a dataset to a file (binary or csv)
//!   ingest      — replay a dataset through the streaming producer into a
//!                 durable segmented spike log (ingest/)
//!   log-mine    — time-range / electrode-projection mining over a log
//!   watch       — tail a live log and mine incrementally (stream/), one
//!                 commit + frequent-set diff per sealed segment
//!   node        — serve a log replica to a scatter coordinator (cluster/)
//!   scatter     — distributed range mining across nodes, byte-identical
//!                 to a single-process mine over the same range
//!   connectivity — statistical connectivity inference: mine the real
//!                 stream plus N jitter-surrogate mines, rank putative
//!                 edges by empirical significance (analysis/)
//!   serve-bench — load-test the multi-tenant mining service (serve/)
//!   stats       — render the unified metrics registry (obs/), local demo
//!                 or a remote node's via the cluster Stats RPC
//!   bench       — run registered perf suites (machine-readable output,
//!                 baseline regression checking; see bench/)
//!   info        — runtime/artifact information
//!
//! Examples:
//!   epminer mine --dataset sym26 --theta 60 --mode two-pass
//!   epminer gen --dataset 2-1-35 --out /tmp/d35.bin
//!   epminer mine --dataset file:/tmp/d35.bin --theta 40
//!   epminer ingest --dataset sym26 --out /tmp/rec
//!   epminer log-mine --log /tmp/rec --from 10000 --to 30000 --types 3,7,9 --theta 20
//!   epminer watch --log /tmp/rec --theta 20 --window 8 --follow
//!   epminer node --listen 0.0.0.0:7400 --log /tmp/rec
//!   epminer scatter --nodes host1:7400,host2:7400 --log /tmp/rec --theta 20
//!   epminer scatter --nodes host1:7400,host2:7400 --log /tmp/rec --theta 20 --profile
//!   epminer connectivity --dataset 2-1-35 --theta 40 --surrogates 19 --jitter 10
//!   epminer serve-bench --smoke
//!   epminer stats --connect host1:7400
//!   epminer bench --suite all --smoke --json-out . --check benches/baselines
//!   epminer info
//!
//! Everything mining-shaped runs through the `Session` facade; `--strategy`
//! picks a counting backend by name and falls back per `Session` defaults
//! when the PJRT runtime/artifacts are absent.

use episodes_gpu::coordinator::Strategy;
use episodes_gpu::datasets;
use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::io;
use episodes_gpu::obs::Trace;
use episodes_gpu::util::cli::Args;
use episodes_gpu::{MineError, Session, SessionBuilder};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), MineError> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("mine") => cmd_mine(&args),
        Some("count") => cmd_count(&args),
        Some("gen") => cmd_gen(&args),
        Some("ingest") => cmd_ingest(&args),
        Some("log-mine") => cmd_log_mine(&args),
        Some("watch") => cmd_watch(&args),
        Some("node") => cmd_node(&args),
        Some("scatter") => cmd_scatter(&args),
        Some("reconstruct") => cmd_reconstruct(&args),
        Some("connectivity") => cmd_connectivity(&args),
        Some("raster") => cmd_raster(&args),
        Some("profile") => cmd_profile(&args),
        Some("serve-bench") => cmd_serve_bench(&args),
        Some("stats") => cmd_stats(&args),
        Some("bench") => cmd_bench(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: epminer <mine|count|gen|ingest|log-mine|watch|node|scatter|reconstruct|connectivity|raster|profile|serve-bench|stats|bench|info> [options]\n\
                 \n\
                 mine        --dataset <{names}> --theta <u64>\n\
                 \x20            [--mode two-pass|one-pass] [--strategy {strategies}]\n\
                 \x20            [--max-level <n>] [--seed <u64>] [--threads <n>]\n\
                 \x20            [--profile] [--trace-out <path>] — phase profile + span tree\n\
                 count       --dataset <name> --episode 0,1,2 --low 5 --high 15 [--seed <u64>]\n\
                 gen         --dataset <name> --out <path> [--format bin|csv] [--seed <u64>]\n\
                 ingest      --dataset <name> --out <dir> [--append] [--segment-events <n>]\n\
                 \x20            [--segment-width <ticks>] [--width <ticks>] [--speedup <x>]\n\
                 \x20            — replay through the streaming producer into a durable log\n\
                 log-mine    --log <dir> --theta <u64> [--from <tick> --to <tick>]\n\
                 \x20            [--types 3,7,9] — range/projection mining over recorded history\n\
                 watch       --log <dir> --theta <u64> [--window <segments>] [--follow]\n\
                 \x20            [--poll-ms <n>] [--max-commits <n>] [--low <t> --high <t>]\n\
                 \x20            [--max-level <n>] [--k <n>] — incremental live mining: replay\n\
                 \x20            sealed history, then push a frequent-set diff per new segment\n\
                 node        --listen <addr:port> --log <dir> [--workers <n>]\n\
                 \x20            [--strategy <name>] — serve this log replica's counting to a\n\
                 \x20            scatter coordinator (runs until killed)\n\
                 scatter     --nodes <addr,addr,...> --log <dir> --theta <u64>\n\
                 \x20            [--from <tick> --to <tick>] [--low <t> --high <t>]\n\
                 \x20            [--mode two-pass|one-pass] [--max-level <n>]\n\
                 \x20            [--group-segments <n>] [--deadline-ms <n>] [--retries <n>]\n\
                 \x20            [--hedge-ms <n>] [--k <n>] [--profile] [--trace-out <path>]\n\
                 \x20            — distributed range mine, byte-identical to mining the\n\
                 \x20            same range in one process; --profile merges every node's\n\
                 \x20            spans into one trace tree\n\
                 reconstruct --dataset <name> --theta <u64> [--dot <path>] — mine + circuit graph\n\
                 connectivity --dataset <name> --theta <u64> [--surrogates <n>]\n\
                 \x20            [--jitter <ticks>] [--seed <u64>] [--parallelism <n>]\n\
                 \x20            [--max-p <p>] [--dot <path>] [--strategy {strategies}]\n\
                 \x20            [--threads <n>] [--mode two-pass|one-pass] [--max-level <n>]\n\
                 \x20            [--low <t> --high <t>] [--profile] [--trace-out <path>]\n\
                 \x20            — mine + N jitter-surrogate mines through the batched\n\
                 \x20            executor; edges ranked by empirical p / excess count,\n\
                 \x20            scored against generator ground truth when known\n\
                 raster      --dataset <name> [--from <tick> --to <tick>] [--episode 0,1,2]\n\
                 profile     --dataset <name> --size <n> --episodes <count> — Fig-10 counters\n\
                 serve-bench [--clients <n>] [--requests <n>] [--workers <n>] [--queue <n>]\n\
                 \x20            [--cache <entries>] [--strategy <name>] [--events <n>]\n\
                 \x20            [--dataset <spec>] [--seed <u64>] [--subscribers <n>] [--smoke]\n\
                 \x20            [--profile] [--slow-ms <n>] [--metrics-every <secs>]\n\
                 \x20            [--stats-out <path>] [--trace-out <path>]\n\
                 \x20            — load-test the service (with a live push feed when\n\
                 \x20            --subscribers > 0); --stats-out / --trace-out write the\n\
                 \x20            registry snapshot and one traced query as JSON\n\
                 stats       [--connect <addr:port>] [--json] — the unified metrics\n\
                 \x20            registry, Prometheus text by default; --connect asks a\n\
                 \x20            running node over the cluster Stats RPC, otherwise a\n\
                 \x20            local demo query populates one\n\
                 bench       [--suite <{suites}|all>] [--smoke]\n\
                 \x20            [--json-out <dir>] [--check <baseline.json|dir>]\n\
                 \x20            [--tolerance <rel>] [--write-baseline <dir>] — run perf suites,\n\
                 \x20            write BENCH_<suite>.json, gate against committed baselines\n\
                 info\n\
                 \n\
                 --dataset also accepts file:<path.bin> and log:<segment-dir>",
                names = datasets::names().join("|"),
                strategies = Strategy::NAMES.join("|"),
                suites = episodes_gpu::bench::SUITES
                    .iter()
                    .map(|s| s.name)
                    .collect::<Vec<_>>()
                    .join("|"),
            );
            std::process::exit(2);
        }
    }
}

fn load_dataset(args: &Args) -> Result<(episodes_gpu::events::EventStream, String), MineError> {
    let spec = args.get_or("dataset", "sym26");
    let seed = args.get_u64("seed", 7)?;
    datasets::resolve(spec, seed)
}

/// Default delay band for a dataset comes from the registry; `--low` /
/// `--high` override it.
fn interval_from(args: &Args, dataset: &str) -> Result<Interval, MineError> {
    let d = datasets::default_interval(dataset).unwrap_or_else(|| Interval::new(2, 10));
    Ok(Interval::new(args.get_i32("low", d.t_low)?, args.get_i32("high", d.t_high)?))
}

/// Shared `Session` setup for the mining-shaped subcommands.
fn session_builder(
    args: &Args,
    stream: episodes_gpu::events::EventStream,
    dataset: &str,
    theta: u64,
) -> Result<SessionBuilder, MineError> {
    let mut b = Session::builder()
        .stream(stream)
        .theta(theta)
        .interval(interval_from(args, dataset)?)
        .max_level(args.get_usize("max-level", 8)?);
    // Worker threads for the CPU engines: episode-axis workers for
    // cpu-parallel, time shards for cpu-sharded (default: all cores).
    if args.get("threads").is_some() {
        b = b.cpu_threads(args.get_usize("threads", 1)?);
    }
    // --profile attaches the per-level phase breakdown to every result
    // of this session (mine, log-mine, reconstruct alike)
    if args.flag("profile") {
        b = b.profile(true);
    }
    match args.get_or("mode", "two-pass") {
        "two-pass" => {}
        "one-pass" => b = b.one_pass(),
        other => {
            return Err(MineError::invalid(format!(
                "bad --mode {other} (expected two-pass or one-pass)"
            )))
        }
    }
    // An explicit --strategy pins the backend (and fails hard if it needs
    // an absent runtime); otherwise the Session default applies —
    // accelerated Hybrid when the runtime opens, CPU-parallel fallback.
    if let Some(s) = args.get("strategy") {
        b = b.strategy(Strategy::parse(s)?);
    }
    Ok(b)
}

fn cmd_mine(args: &Args) -> Result<(), MineError> {
    let (stream, name) = load_dataset(args)?;
    println!(
        "dataset {name}: {} events, {} types, {:.1}s span, {:.0} Hz mean",
        stream.len(),
        stream.n_types,
        stream.span() as f64 / 1000.0,
        stream.mean_rate_hz()
    );
    let theta = args.get_u64("theta", 100)?;
    let mut session = session_builder(args, stream, &name, theta)?.build()?;
    println!("backend: {}", session.backend_name());

    let trace = trace_from(args);
    let t0 = std::time::Instant::now();
    let result = session.mine_traced(&trace)?;
    print_levels(&result);
    println!(
        "\ntotal {:.3}s; metrics: {}",
        t0.elapsed().as_secs_f64(),
        session.metrics().report()
    );
    print_observability(args, &result, &trace)?;
    print_top_episodes(&result);
    Ok(())
}

/// `--profile` / `--trace-out` turn on span recording; otherwise the
/// trace is the free disabled one.
fn trace_from(args: &Args) -> Trace {
    if args.flag("profile") || args.get("trace-out").is_some() {
        Trace::started()
    } else {
        Trace::off()
    }
}

/// Shared tail for the mining subcommands: render the phase profile and
/// the span tree when enabled, and export the trace JSON on request.
fn print_observability(
    args: &Args,
    result: &episodes_gpu::coordinator::miner::MineResult,
    trace: &Trace,
) -> Result<(), MineError> {
    if let Some(p) = &result.profile {
        println!();
        print!("{}", p.render());
    }
    if trace.is_on() {
        println!();
        print!("{}", trace.render_tree());
        if let Some(path) = args.get("trace-out") {
            std::fs::write(path, trace.to_json().render_pretty())
                .map_err(|e| MineError::io(format!("writing {path}"), e))?;
            println!("wrote trace json to {path}");
        }
    }
    Ok(())
}

fn print_levels(result: &episodes_gpu::coordinator::miner::MineResult) {
    println!("\nlevel  candidates  frequent  a2-culled  count-time");
    for l in &result.levels {
        println!(
            "{:>5}  {:>10}  {:>8}  {:>9}  {:>9.3}s",
            l.level, l.candidates, l.frequent, l.culled_by_a2, l.count_seconds
        );
    }
}

fn print_top_episodes(result: &episodes_gpu::coordinator::miner::MineResult) {
    let mut top: Vec<_> = result.frequent.iter().filter(|c| c.episode.n() >= 2).collect();
    top.sort_by_key(|c| std::cmp::Reverse((c.episode.n(), c.count)));
    println!("\ntop frequent episodes:");
    for c in top.iter().take(12) {
        println!("  [{}] {}", c.count, c.episode.display());
    }
}

fn cmd_count(args: &Args) -> Result<(), MineError> {
    let (stream, name) = load_dataset(args)?;
    let ep_spec = args
        .get("episode")
        .ok_or_else(|| MineError::invalid("--episode 0,1,2 required"))?;
    let types: Vec<i32> = ep_spec
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<i32>()
                .map_err(|_| MineError::invalid(format!("bad --episode element {s:?}")))
        })
        .collect::<Result<_, _>>()?;
    let iv = interval_from(args, &name)?;
    let n_nodes = types.len();
    let ep = Episode::new(types, vec![iv; n_nodes - 1]);

    let mut b = Session::builder().stream(stream).theta(1).interval(iv).one_pass();
    if let Some(s) = args.get("strategy") {
        b = b.strategy(Strategy::parse(s)?);
    }
    let mut session = b.build()?;
    let counts = session.count(std::slice::from_ref(&ep))?;
    println!("{} -> {}", ep.display(), counts[0]);
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<(), MineError> {
    let (stream, name) = load_dataset(args)?;
    let out = args.get("out").ok_or_else(|| MineError::invalid("--out required"))?;
    let path = std::path::Path::new(out);
    match args.get_or("format", "bin") {
        "bin" => io::save_binary(&stream, path)?,
        "csv" => io::save_csv(&stream, path)?,
        other => return Err(MineError::invalid(format!("bad --format {other} (bin|csv)"))),
    }
    println!("wrote {name} ({} events) to {out}", stream.len());
    Ok(())
}

fn cmd_ingest(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::coordinator::streaming::{spawn_producer_with, ProducerConfig};
    use episodes_gpu::ingest::{RollPolicy, SpikeLog};

    let (stream, name) = load_dataset(args)?;
    let out = args.get("out").ok_or_else(|| MineError::invalid("--out <dir> required"))?;
    let policy = RollPolicy {
        max_events: args.get_usize("segment-events", 8_192)?,
        max_width_ticks: args.get_i32("segment-width", 10_000)?,
    };
    // Replay through the chip-on-chip partition producer (accelerated by
    // default — `--speedup 1` replays the recording in real time, which
    // is the acquisition-side simulation).
    let width = args.get_i32("width", 5_000)?;
    let speedup = args.get_f64("speedup", 1e9)?;
    let total = stream.len();
    let n_types = stream.n_types;
    println!(
        "ingesting {name}: {total} events over {} types, partition width {width} ticks",
        n_types
    );

    let rx = spawn_producer_with(stream, width, ProducerConfig { speedup, ..Default::default() })?;
    // --append attaches to an existing log (continuing its seq/time line
    // and running the writer-side crash repair: torn tails quarantined,
    // stale MANIFEST.tmp discarded); default is a fresh log.
    let out_path = std::path::Path::new(out);
    let log = if args.flag("append") {
        let log = SpikeLog::open(out_path)?;
        if log.n_types() != n_types {
            return Err(MineError::invalid(format!(
                "log at {out} records {} types but dataset {name} has {n_types}",
                log.n_types()
            )));
        }
        log
    } else {
        SpikeLog::create(out_path, n_types)?
    };
    let mut ingestor = log.ingestor(policy)?;
    let t0 = std::time::Instant::now();
    let events = ingestor.ingest_partitions(rx)?;
    let log = ingestor.finish()?;
    let secs = t0.elapsed().as_secs_f64();

    println!(
        "sealed {} segments ({events} events) at {out} in {secs:.3}s — {:.0} events/s",
        log.segments().len(),
        events as f64 / secs.max(1e-9),
    );
    for m in log.segments().iter().take(8) {
        println!(
            "  seg {:>4}  {:>8} events  ticks [{}, {}]  checksum {:016x}",
            m.seq, m.n_events, m.t_min, m.t_max, m.checksum
        );
    }
    if log.segments().len() > 8 {
        println!("  ... {} more", log.segments().len() - 8);
    }
    Ok(())
}

fn cmd_log_mine(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::ingest::{RangeQuery, SpikeLog};

    let dir = args.get("log").ok_or_else(|| MineError::invalid("--log <dir> required"))?;
    let log = SpikeLog::open(std::path::Path::new(dir))?;
    let rec = log.recovery();
    if !rec.torn_tails.is_empty() {
        println!(
            "recovery: {} torn segment file(s) detected — never mined; run \
             `epminer ingest --append --out {dir}` to quarantine: {}",
            rec.torn_tails.len(),
            rec.torn_tails.join(", ")
        );
    }
    if rec.stale_tmp_manifest {
        println!("recovery: stale MANIFEST.tmp from an interrupted seal (manifest wins)");
    }

    let mut query = RangeQuery::all();
    if args.get("from").is_some() {
        query.t_from = Some(args.get_i32("from", 0)?);
    }
    if args.get("to").is_some() {
        query.t_to = Some(args.get_i32("to", 0)?);
    }
    if let Some(spec) = args.get("types") {
        let types: Vec<i32> = spec
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<i32>()
                    .map_err(|_| MineError::invalid(format!("bad --types element {s:?}")))
            })
            .collect::<Result<_, _>>()?;
        query.alphabet = Some(types);
    }

    let (stream, stats) = log.read(&query)?;
    println!(
        "log {dir}: {} sealed segments, {} events; query read {}/{} segments \
         ({} pruned by time, {} by alphabet) -> scanned {} events, returned {}",
        stats.segments_total,
        log.len(),
        stats.segments_read,
        stats.segments_total,
        stats.pruned_by_time,
        stats.pruned_by_alphabet,
        stats.events_scanned,
        stats.events_returned,
    );
    // Pruning efficacy: how much I/O the segment footers saved this query.
    let pruned = stats.pruned_by_time + stats.pruned_by_alphabet;
    if stats.segments_total > 0 {
        println!(
            "pruning: skipped {pruned}/{} segments ({:.0}%) without reading their columns",
            stats.segments_total,
            100.0 * pruned as f64 / stats.segments_total as f64,
        );
    }
    if stream.is_empty() {
        println!("nothing to mine in the queried range");
        return Ok(());
    }

    let theta = args.get_u64("theta", 20)?;
    let spec = format!("log:{dir}");
    let mut session = session_builder(args, stream, &spec, theta)?.build()?;
    println!("backend: {}", session.backend_name());
    let result = session.mine()?;
    print_levels(&result);
    print_top_episodes(&result);
    Ok(())
}

fn cmd_watch(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::stream::{IncrementalConfig, LogWatcher};

    let dir = args.get("log").ok_or_else(|| MineError::invalid("--log <dir> required"))?;
    let theta = args.get_u64("theta", 20)?;
    // No dataset registry entry to consult here — the generic path-scheme
    // default band (2, 10] applies unless --low/--high override it.
    let iv = Interval::new(args.get_i32("low", 2)?, args.get_i32("high", 10)?);
    let window = args.get_usize("window", 0)?;
    let mut cfg = IncrementalConfig::new(theta, vec![iv])
        .max_level(args.get_usize("max-level", 8)?)
        .window_segments(window);
    if args.get("k").is_some() {
        cfg = cfg.bounded_k(args.get_usize("k", usize::MAX)?);
    }
    let follow = args.flag("follow");
    let poll_ms = args.get_u64("poll-ms", 200)?;
    let max_commits = args.get_u64("max-commits", 0)?;

    let mut watcher = LogWatcher::new(std::path::Path::new(dir), cfg)?;
    match window {
        0 => println!("watching {dir}: theta {theta}, unbounded window"),
        n => println!("watching {dir}: theta {theta}, sliding window of {n} segments"),
    }

    // the watch loop publishes into its own registry and prints a compact
    // metrics line every --metrics-every commits (0 disables)
    let metrics_every = args.get_u64("metrics-every", 5)?;
    let registry = episodes_gpu::obs::Registry::new();
    let m_commits = registry.counter("watch.commits");
    let m_rescanned = registry.counter("watch.events_rescanned");
    let m_misses = registry.counter("watch.concat_misses");
    let m_recounts = registry.counter("watch.serial_recounts");
    let m_frequent = registry.gauge("watch.frequent");
    let m_events = registry.gauge("watch.window_events");

    let mut commits = 0u64;
    loop {
        let updates = watcher.poll()?;
        for u in &updates {
            println!("{}", u.report());
            for e in u.diff.entered.iter().take(8) {
                println!("  + [{}] {}", e.count, e.episode.display());
            }
            for e in u.diff.left.iter().take(8) {
                println!("  - [{}] {}", e.count, e.episode.display());
            }
            for c in u.diff.count_changed.iter().take(8) {
                println!("  ~ {} {} -> {}", c.episode.display(), c.previous, c.current);
            }
            commits += 1;
            m_commits.inc();
            m_rescanned.add(u.stats.events_rescanned as u64);
            m_misses.add(u.stats.concat_misses);
            m_recounts.add(u.stats.serial_recounts as u64);
            m_frequent.set(u.frequent.len() as i64);
            m_events.set(u.window_events as i64);
            if metrics_every > 0 && commits % metrics_every == 0 {
                println!("metrics: {}", metrics_line(&registry.snapshot()));
            }
            if max_commits > 0 && commits >= max_commits {
                return Ok(());
            }
        }
        if updates.is_empty() {
            if !follow {
                // caught up with the sealed history; without --follow the
                // watch is a one-shot replay
                println!("caught up after {commits} commit(s)");
                return Ok(());
            }
            std::thread::sleep(std::time::Duration::from_millis(poll_ms));
        }
    }
}

/// One `k=v`-per-metric line from a registry snapshot (the periodic
/// heartbeat format for `watch` and `serve-bench`).
fn metrics_line(snap: &episodes_gpu::obs::Snapshot) -> String {
    let mut parts: Vec<String> =
        snap.counters.iter().map(|(k, v)| format!("{k}={v}")).collect();
    parts.extend(snap.gauges.iter().map(|(k, v)| format!("{k}={v}")));
    for (k, count, summary) in &snap.histograms {
        match summary {
            Some(s) => parts.push(format!("{k}.count={count} {k}.p95={:.0}", s.p95)),
            None => parts.push(format!("{k}.count={count}")),
        }
    }
    parts.join(" ")
}

fn cmd_node(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::cluster::ClusterNode;
    use episodes_gpu::serve::ServiceConfig;

    let listen = args
        .get("listen")
        .ok_or_else(|| MineError::invalid("--listen <addr:port> required"))?;
    let dir = args.get("log").ok_or_else(|| MineError::invalid("--log <dir> required"))?;
    let d = ServiceConfig::default();
    let sc = ServiceConfig {
        workers: args.get_usize("workers", d.workers)?,
        strategy: match args.get("strategy") {
            Some(s) => Strategy::parse(s)?,
            None => d.strategy,
        },
        ..d
    };
    let node = ClusterNode::bind(listen, std::path::Path::new(dir), sc)?;
    println!("node: serving {dir} on {}", node.local_addr()?);
    node.run()
}

fn cmd_scatter(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::cluster::{ScatterConfig, ScatterMiner};
    use episodes_gpu::session::{MineOptions, DEFAULT_CANDIDATE_BLOCK};
    use std::time::Duration;

    let nodes_spec = args
        .get("nodes")
        .ok_or_else(|| MineError::invalid("--nodes <addr,addr,...> required"))?;
    let addrs: Vec<String> = nodes_spec
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let dir = args.get("log").ok_or_else(|| MineError::invalid("--log <dir> required"))?;
    let theta = args.get_u64("theta", 20)?;
    // same generic path-scheme default band as watch/log-mine
    let iv = Interval::new(args.get_i32("low", 2)?, args.get_i32("high", 10)?);
    let two_pass = match args.get_or("mode", "two-pass") {
        "two-pass" => true,
        "one-pass" => false,
        other => {
            return Err(MineError::invalid(format!(
                "bad --mode {other} (expected two-pass or one-pass)"
            )))
        }
    };
    let opts = MineOptions {
        theta,
        intervals: vec![iv],
        max_level: args.get_usize("max-level", 8)?,
        max_candidates_per_level: 2_000_000,
        candidate_block: DEFAULT_CANDIDATE_BLOCK,
    };

    let d = ScatterConfig::default();
    let cfg = ScatterConfig {
        group_segments: args.get_usize("group-segments", d.group_segments)?,
        deadline: Duration::from_millis(
            args.get_u64("deadline-ms", d.deadline.as_millis() as u64)?,
        ),
        retries: args.get_usize("retries", d.retries)?,
        hedge_after: match args.get("hedge-ms") {
            Some(_) => Some(Duration::from_millis(args.get_u64("hedge-ms", 0)?)),
            None => d.hedge_after,
        },
        k: args.get_usize("k", d.k)?,
        ..d
    };

    let miner = ScatterMiner::over_tcp(std::path::Path::new(dir), &addrs, cfg)?;
    println!(
        "scatter: {} over {} nodes ({} sealed segments)",
        dir,
        addrs.len(),
        miner.log().segments().len()
    );
    // --profile merges the coordinator's plan/merge spans with every
    // node's grafted counting spans into one trace tree
    let trace = trace_from(args);
    let profile = args.flag("profile");
    let t0 = std::time::Instant::now();
    // (t_from, t_to] half-open-left, like every range API here; the
    // defaults cover the whole recording (== mine_all)
    let t_from = args.get_i32("from", miner.log().t_begin().map(|t| t - 1).unwrap_or(-1))?;
    let t_to = args.get_i32("to", miner.log().t_end().unwrap_or(0))?;
    let result = miner.mine_traced(t_from, t_to, &opts, two_pass, "cli", &trace, profile)?;
    print_levels(&result);
    println!("\ntotal {:.3}s", t0.elapsed().as_secs_f64());
    print!("{}", miner.metrics().report());
    print_observability(args, &result, &trace)?;
    print_top_episodes(&result);
    Ok(())
}

fn cmd_reconstruct(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::analysis::connectivity::Circuit;
    use episodes_gpu::analysis::summarize::maximal_episodes;
    let (stream, name) = load_dataset(args)?;
    let theta = args.get_u64("theta", 60)?;
    let mut session = session_builder(args, stream, &name, theta)?.build()?;
    let result = session.mine()?;

    let maximal = maximal_episodes(&result.frequent, 0.5);
    println!("frequent episodes: {} ({} maximal)", result.frequent.len(), maximal.len());
    println!("\nmaximal episodes:");
    for c in maximal.iter().take(15).filter(|c| c.episode.n() >= 2) {
        println!("  [{:>4}] {}", c.count, c.episode.display());
    }

    let deep: Vec<_> =
        result.frequent.iter().filter(|c| c.episode.n() >= 2).cloned().collect();
    let circuit = Circuit::from_support(&deep).thresholded(theta);
    println!("\nreconstructed functional edges ({}):", circuit.edges.len());
    for e in circuit.edges.iter().take(20) {
        println!(
            "  {} -> {}  [support {}, delay ({},{}]]",
            e.from, e.to, e.support, e.t_low, e.t_high
        );
    }
    if let Some(path) = args.get("dot") {
        std::fs::write(path, circuit.to_dot())
            .map_err(|e| MineError::io(format!("writing {path}"), e))?;
        println!("\nwrote graphviz to {path}");
    }
    Ok(())
}

fn cmd_connectivity(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::analysis::batch::BatchConfig;
    use episodes_gpu::analysis::connectivity::{infer_connectivity, ConnectivityConfig};
    use episodes_gpu::session::{MineOptions, DEFAULT_CANDIDATE_BLOCK};

    let (stream, name) = load_dataset(args)?;
    println!(
        "dataset {name}: {} events, {} types, {:.1}s span, {:.0} Hz mean",
        stream.len(),
        stream.n_types,
        stream.span() as f64 / 1000.0,
        stream.mean_rate_hz()
    );
    let theta = args.get_u64("theta", 60)?;
    let iv = interval_from(args, &name)?;
    let opts = MineOptions {
        theta,
        intervals: vec![iv],
        max_level: args.get_usize("max-level", 8)?,
        max_candidates_per_level: 2_000_000,
        candidate_block: DEFAULT_CANDIDATE_BLOCK,
    };
    let two_pass = match args.get_or("mode", "two-pass") {
        "two-pass" => true,
        "one-pass" => false,
        other => {
            return Err(MineError::invalid(format!(
                "bad --mode {other} (expected two-pass or one-pass)"
            )))
        }
    };
    let d = BatchConfig::default();
    let batch = BatchConfig {
        strategy: match args.get("strategy") {
            Some(s) => Strategy::parse(s)?,
            None => d.strategy,
        },
        two_pass,
        cpu_threads: args.get_usize("threads", d.cpu_threads)?,
        parallelism: args.get_usize("parallelism", d.parallelism)?,
        profile: args.flag("profile"),
    };
    let cfg = ConnectivityConfig {
        n_surrogates: args.get_usize("surrogates", 19)?,
        // default jitter: the upper delay bound, sized to destroy exactly
        // the timing structure the delay band asserts
        jitter: args.get_i32("jitter", iv.t_high.max(1))?,
        // the dataset seed doubles as the surrogate seed (streams are
        // forked per surrogate, so sharing the root is safe)
        seed: args.get_u64("seed", 7)?,
        batch,
    };
    println!(
        "null model: {} jitter surrogates, half-width {} ticks, seed {} \
         ({} mines over {} worker(s))",
        cfg.n_surrogates,
        cfg.jitter,
        cfg.seed,
        cfg.n_surrogates + 1,
        cfg.batch.parallelism.max(1),
    );

    let trace = trace_from(args);
    let t0 = std::time::Instant::now();
    let result = infer_connectivity(&stream, &opts, &cfg, &trace)?;
    print_levels(&result.base);
    println!("\ntotal {:.3}s", t0.elapsed().as_secs_f64());

    let report = &result.report;
    println!(
        "\nsignificance over {} episodes of size >= 2 (p floor {:.3}):",
        report.scores.len(),
        report.p_floor()
    );
    for s in report.scores.iter().take(12) {
        println!(
            "  p={:.3}  excess {:+.1}  null mean {:>6.1}  [{:>4}] {}",
            s.p_value,
            s.excess,
            s.null_mean,
            s.count,
            s.episode.display()
        );
    }

    // --max-p keeps only edges whose best witness clears the cut
    let circuit = match args.get("max-p") {
        Some(_) => result.circuit.significant(args.get_f64("max-p", 0.05)?),
        None => result.circuit.clone(),
    };
    println!("\nputative circuit ({} edges, most credible first):", circuit.edges.len());
    for e in circuit.edges.iter().take(20) {
        println!(
            "  {} -> {}  p={:.3}  excess {:+.1}  [support {}, delay ({},{}]]",
            e.from, e.to, e.p_value, e.excess, e.support, e.t_low, e.t_high
        );
    }
    if let Some(truth) = datasets::ground_truth(&name) {
        let s = circuit.score(&truth.chains);
        println!(
            "\nvs ground truth ({} chains, {} true edges): \
             precision {:.2}  recall {:.2}  f1 {:.2}",
            truth.chains.len(),
            s.actual,
            s.precision(),
            s.recall(),
            s.f1()
        );
    }
    if let Some(path) = args.get("dot") {
        std::fs::write(path, circuit.to_dot())
            .map_err(|e| MineError::io(format!("writing {path}"), e))?;
        println!("\nwrote graphviz to {path}");
    }
    print_observability(args, &result.base, &trace)?;
    Ok(())
}

fn cmd_raster(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::analysis::raster;
    let (stream, name) = load_dataset(args)?;
    let from = args.get_i32("from", stream.t_begin())?;
    let to = args.get_i32("to", (stream.t_begin() + 2000).min(stream.t_end()))?;
    let ep = match args.get("episode") {
        None => None,
        Some(spec) => {
            let types: Vec<i32> = spec
                .split(',')
                .map(|s| {
                    s.trim().parse::<i32>().map_err(|_| {
                        MineError::invalid(format!("bad --episode element {s:?}"))
                    })
                })
                .collect::<Result<_, _>>()?;
            let iv = interval_from(args, &name)?;
            let n_nodes = types.len();
            Some(Episode::new(types, vec![iv; n_nodes - 1]))
        }
    };
    print!("{}", raster::render(&stream, from, to, 100, 30, ep.as_ref()));
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::mining::telemetry::{profile_a1, profile_a2};
    use episodes_gpu::util::rng::Rng;
    let (stream, name) = load_dataset(args)?;
    let n = args.get_usize("size", 4)?;
    let count = args.get_usize("episodes", 256)?;
    let iv = interval_from(args, &name)?;
    let mut rng = Rng::new(args.get_u64("seed", 7)?);
    let eps: Vec<Episode> = (0..count)
        .map(|_| {
            let types: Vec<i32> =
                (0..n).map(|_| rng.range_i32(0, stream.n_types as i32 - 1)).collect();
            Episode::new(types, vec![iv; n - 1])
        })
        .collect();
    let c1 = profile_a1(&eps, &stream, 8);
    let c2 = profile_a2(&eps, &stream);
    println!("SIMT-warp profile, {count} episodes of size {n} over {name}:");
    println!("  A1: branches={} divergent={} local_loads={} local_stores={}",
        c1.branches, c1.divergent_branches, c1.local_loads, c1.local_stores);
    println!("  A2: branches={} divergent={} local_loads={} local_stores={}",
        c2.branches, c2.divergent_branches, c2.local_loads, c2.local_stores);
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::serve::loadgen::{self, LoadGenConfig, Workload};
    use episodes_gpu::serve::{MineService, ServiceConfig};

    // --smoke shrinks everything so CI can exercise the full path in
    // seconds; explicit flags still override either profile.
    let smoke = args.flag("smoke");
    let mut lg = if smoke { LoadGenConfig::smoke() } else { LoadGenConfig::default() };
    lg.clients = args.get_usize("clients", lg.clients)?;
    lg.requests_per_client = args.get_usize("requests", lg.requests_per_client)?;
    lg.base_events = args.get_usize("events", lg.base_events)?;
    lg.seed = args.get_u64("seed", lg.seed)?;
    // `--dataset sym26` / `--dataset log:/path`: drive the hot/sweep/
    // sliding scenarios from a named or recorded stream instead of the
    // synthetic one.
    lg.base_dataset = args.get("dataset").map(|s| s.to_string());
    lg.subscribers = args.get_usize("subscribers", lg.subscribers)?;

    let d = ServiceConfig::default();
    let slow_ms = args.get_u64("slow-ms", 0)?;
    let sc = ServiceConfig {
        workers: args.get_usize("workers", d.workers)?,
        queue_capacity: args.get_usize("queue", d.queue_capacity)?,
        cache_capacity: args.get_usize("cache", d.cache_capacity)?,
        strategy: match args.get("strategy") {
            Some(s) => Strategy::parse(s)?,
            None => d.strategy,
        },
        profile: args.flag("profile"),
        tracing: args.flag("profile") || slow_ms > 0,
        slow_query_threshold: (slow_ms > 0)
            .then(|| std::time::Duration::from_millis(slow_ms)),
        ..d
    };

    println!(
        "serve-bench: {} clients x {} requests over {} workers \
         (queue {}, cache {}, strategy {:?})",
        lg.clients,
        lg.requests_per_client,
        sc.workers,
        sc.queue_capacity,
        sc.cache_capacity,
        sc.strategy,
    );
    let workload = Workload::build(&lg)?;
    let service = MineService::start(sc)?;
    // a heartbeat thread prints one registry-derived metrics line every
    // --metrics-every seconds while the load runs (0 disables)
    let metrics_every = args.get_u64("metrics-every", 0)?;
    let stop = std::sync::atomic::AtomicBool::new(false);
    let report = std::thread::scope(|scope| {
        if metrics_every > 0 {
            let (svc, stop) = (&service, &stop);
            scope.spawn(move || {
                let tick = std::time::Duration::from_millis(50);
                let mut next = std::time::Instant::now()
                    + std::time::Duration::from_secs(metrics_every);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    if std::time::Instant::now() >= next {
                        let _ = svc.metrics(); // refresh derived gauges
                        println!("metrics: {}", metrics_line(&svc.registry().snapshot()));
                        next += std::time::Duration::from_secs(metrics_every);
                    }
                }
            });
        }
        let report = loadgen::run(&service, &workload, &lg);
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        report
    });

    for slow in service.slow_queries() {
        println!(
            "slow query {} ({:.1}ms):\n{}",
            slow.trace_id,
            slow.latency.as_secs_f64() * 1e3,
            slow.tree
        );
    }
    // artifact exports: the full registry snapshot, and one traced demo
    // query (same dataset family the load ran over) as a span-tree JSON
    if let Some(path) = args.get("stats-out") {
        let _ = service.metrics(); // refresh derived gauges
        std::fs::write(path, service.registry().snapshot().to_json().render_pretty())
            .map_err(|e| MineError::io(format!("writing {path}"), e))?;
        println!("wrote metrics snapshot to {path}");
    }
    let metrics = service.shutdown();
    if let Some(path) = args.get("trace-out") {
        let spec = lg.base_dataset.as_deref().unwrap_or("sym26");
        let (stream, name) = episodes_gpu::datasets::resolve(spec, lg.seed)?;
        let trace = Trace::started();
        let mut session =
            session_builder(args, stream, &name, args.get_u64("theta", 100)?)?
                .profile(true)
                .build()?;
        let _ = session.mine_traced(&trace)?;
        std::fs::write(path, trace.to_json().render_pretty())
            .map_err(|e| MineError::io(format!("writing {path}"), e))?;
        println!("wrote trace json to {path}");
    }

    println!(
        "\ncompleted {} rejected {} errors {} in {:.2}s -> {:.1} qps",
        report.completed,
        report.rejected,
        report.errors,
        report.wall.as_secs_f64(),
        report.qps,
    );
    if let Some(lat) = &report.latency_ns {
        println!(
            "client latency: p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms",
            lat.median / 1e6,
            lat.p95 / 1e6,
            lat.p99 / 1e6,
        );
    }
    if lg.subscribers > 0 {
        println!(
            "live push: {} commits published, {} received across {} subscribers",
            report.updates_published, report.updates_received, lg.subscribers,
        );
    }
    println!("service: {}", metrics.report());
    println!("\n{}", report.to_json());
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<(), MineError> {
    use episodes_gpu::cluster::proto::{self, Request, Response};
    use episodes_gpu::cluster::{NodeLink, TcpLink};
    use episodes_gpu::obs::Snapshot;
    use episodes_gpu::serve::{MineService, Query, ServiceConfig};

    let snapshot = match args.get("connect") {
        // ask a running `epminer node` for its registry over the wire
        Some(addr) => {
            let deadline =
                std::time::Duration::from_millis(args.get_u64("deadline-ms", 5_000)?);
            let link = TcpLink::new(addr);
            let reply = link.call(&proto::encode_request(1, &Request::Stats), deadline)?;
            let (_, outcome) = proto::decode_response(&reply)?;
            match outcome? {
                Response::Stats { snapshot } => snapshot,
                _ => {
                    return Err(MineError::corrupt(
                        proto::WIRE,
                        format!("{addr} answered Stats with a different response kind"),
                    ))
                }
            }
        }
        // no peer: run one query through a local service so the demo
        // snapshot shows the real metric namespace
        None => {
            eprintln!("stats: no --connect, demo registry from one local query");
            let (stream, name) = load_dataset(args)?;
            let theta = args.get_u64("theta", 100)?;
            let iv = interval_from(args, &name)?;
            let sc = ServiceConfig {
                workers: 1,
                tracing: true,
                profile: true,
                ..ServiceConfig::default()
            };
            let service = MineService::start(sc)?;
            let registry = service.registry();
            service.submit(Query::new(std::sync::Arc::new(stream), theta, vec![iv]))?.wait()?;
            let _ = service.shutdown(); // refreshes derived gauges
            registry.snapshot().to_json()
        }
    };
    if args.flag("json") {
        print!("{}", snapshot.render_pretty());
    } else {
        match Snapshot::from_json(&snapshot) {
            Some(snap) => print!("{}", snap.render_prometheus()),
            // an unrecognized (older/newer peer) shape still prints
            None => print!("{}", snapshot.render_pretty()),
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<(), MineError> {
    // run_from_args reports per-suite tables/check verdicts itself; a
    // false return means a suite failed or a baseline check regressed.
    if !episodes_gpu::bench::cli::run_from_args(args)? {
        eprintln!("bench: FAILED (suite error or baseline regression)");
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_info() -> Result<(), MineError> {
    let dir = episodes_gpu::runtime::Runtime::default_dir();
    println!("artifact dir: {dir:?}");
    match episodes_gpu::runtime::Runtime::new(&dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!("manifest: {:?}", rt.manifest());
        }
        Err(e) => {
            println!("runtime: unavailable ({e})");
            println!("mining still works on the CPU backends (cpu, cpu-parallel).");
        }
    }
    Ok(())
}
