//! `epminer`: CLI front-end for the episodes-gpu miner.
//!
//! Subcommands:
//!   mine      — level-wise mining over a named dataset
//!   count     — count explicit episodes (debugging/inspection)
//!   gen       — generate a dataset to a file (binary or csv)
//!   info      — runtime/artifact information
//!
//! Examples:
//!   epminer mine --dataset sym26 --theta 60 --mode two-pass
//!   epminer gen --dataset 2-1-35 --out /tmp/d35.bin
//!   epminer info

use anyhow::{bail, Context, Result};

use episodes_gpu::coordinator::miner::{CountMode, MineConfig};
use episodes_gpu::coordinator::{Coordinator, Strategy};
use episodes_gpu::datasets;
use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::io;
use episodes_gpu::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("mine") => cmd_mine(&args),
        Some("count") => cmd_count(&args),
        Some("gen") => cmd_gen(&args),
        Some("reconstruct") => cmd_reconstruct(&args),
        Some("raster") => cmd_raster(&args),
        Some("profile") => cmd_profile(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: epminer <mine|count|gen|reconstruct|raster|profile|info> [options]\n\
                 \n\
                 mine        --dataset <sym26|2-1-33|2-1-34|2-1-35> --theta <u64>\n\
                 \x20            [--mode two-pass|one-pass] [--strategy ptpe|mapconcat|hybrid|cpu|cpu-parallel]\n\
                 \x20            [--max-level <n>] [--seed <u64>]\n\
                 count       --dataset <name> --episode 0,1,2 --low 5 --high 15 [--seed <u64>]\n\
                 gen         --dataset <name> --out <path> [--format bin|csv] [--seed <u64>]\n\
                 reconstruct --dataset <name> --theta <u64> [--dot <path>] — mine + circuit graph\n\
                 raster      --dataset <name> [--from <tick> --to <tick>] [--episode 0,1,2]\n\
                 profile     --dataset <name> --size <n> --episodes <count> — Fig-10 counters\n\
                 info"
            );
            std::process::exit(2);
        }
    }
}

fn load_dataset(args: &Args) -> Result<(episodes_gpu::events::EventStream, String)> {
    let name = args.get_or("dataset", "sym26").to_string();
    let seed = args.get_u64("seed", 7);
    let (stream, tag) =
        datasets::by_name(&name, seed).with_context(|| format!("unknown dataset {name}"))?;
    Ok((stream, tag.to_string()))
}

fn interval_from(args: &Args, stream_name: &str) -> Interval {
    // dataset-appropriate default physiological delay band
    let (dl, dh) = if stream_name == "sym26" { (5, 15) } else { (2, 10) };
    Interval::new(args.get_i32("low", dl), args.get_i32("high", dh))
}

fn cmd_mine(args: &Args) -> Result<()> {
    let (stream, name) = load_dataset(args)?;
    println!(
        "dataset {name}: {} events, {} types, {:.1}s span, {:.0} Hz mean",
        stream.len(),
        stream.n_types,
        stream.span() as f64 / 1000.0,
        stream.mean_rate_hz()
    );
    let theta = args.get_u64("theta", 100);
    let iv = interval_from(args, &name);
    let mode = match args.get_or("mode", "two-pass") {
        "two-pass" => CountMode::TwoPass,
        "one-pass" => {
            let strategy = Strategy::parse(args.get_or("strategy", "hybrid"))
                .context("bad --strategy")?;
            CountMode::OnePass(strategy)
        }
        other => bail!("bad --mode {other}"),
    };
    let mut cfg = MineConfig::new(theta, vec![iv]);
    cfg.mode = mode;
    cfg.max_level = args.get_usize("max-level", 8);

    let mut coord = Coordinator::open_default()?;
    println!("runtime: platform={}", coord.rt.platform());
    let t0 = std::time::Instant::now();
    let result = coord.mine(&stream, &cfg)?;
    println!("\nlevel  candidates  frequent  a2-culled  count-time");
    for l in &result.levels {
        println!(
            "{:>5}  {:>10}  {:>8}  {:>9}  {:>9.3}s",
            l.level, l.candidates, l.frequent, l.culled_by_a2, l.count_seconds
        );
    }
    println!("\ntotal {:.3}s; metrics: {}", t0.elapsed().as_secs_f64(), coord.metrics.report());
    let mut top: Vec<_> = result.frequent.iter().filter(|c| c.episode.n() >= 2).collect();
    top.sort_by_key(|c| std::cmp::Reverse((c.episode.n(), c.count)));
    println!("\ntop frequent episodes:");
    for c in top.iter().take(12) {
        println!("  [{}] {}", c.count, c.episode.display());
    }
    Ok(())
}

fn cmd_count(args: &Args) -> Result<()> {
    let (stream, name) = load_dataset(args)?;
    let ep_spec = args.get("episode").context("--episode 0,1,2 required")?;
    let types: Vec<i32> = ep_spec
        .split(',')
        .map(|s| s.trim().parse::<i32>().context("bad --episode"))
        .collect::<Result<_>>()?;
    let iv = interval_from(args, &name);
    let ep = Episode::new(types.clone(), vec![iv; types.len() - 1]);
    let strategy = Strategy::parse(args.get_or("strategy", "hybrid")).context("bad --strategy")?;

    let mut coord = Coordinator::open_default()?;
    let counts = coord.count(std::slice::from_ref(&ep), &stream, strategy)?;
    println!("{} -> {}", ep.display(), counts[0]);
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let (stream, name) = load_dataset(args)?;
    let out = args.get("out").context("--out required")?;
    let path = std::path::Path::new(out);
    match args.get_or("format", "bin") {
        "bin" => io::write_binary(&stream, path)?,
        "csv" => io::write_csv(&stream, path)?,
        other => bail!("bad --format {other}"),
    }
    println!("wrote {name} ({} events) to {out}", stream.len());
    Ok(())
}

fn cmd_reconstruct(args: &Args) -> Result<()> {
    use episodes_gpu::analysis::connectivity::Circuit;
    use episodes_gpu::analysis::summarize::maximal_episodes;
    let (stream, name) = load_dataset(args)?;
    let theta = args.get_u64("theta", 60);
    let iv = interval_from(args, &name);
    let mut cfg = MineConfig::new(theta, vec![iv]);
    cfg.max_level = args.get_usize("max-level", 8);
    let mut coord = Coordinator::open_default()?;
    let result = coord.mine(&stream, &cfg)?;

    let maximal = maximal_episodes(&result.frequent, 0.5);
    println!("frequent episodes: {} ({} maximal)", result.frequent.len(), maximal.len());
    println!("\nmaximal episodes:");
    for c in maximal.iter().take(15).filter(|c| c.episode.n() >= 2) {
        println!("  [{:>4}] {}", c.count, c.episode.display());
    }

    let deep: Vec<_> =
        result.frequent.iter().filter(|c| c.episode.n() >= 2).cloned().collect();
    let circuit = Circuit::reconstruct(&deep).thresholded(theta);
    println!("\nreconstructed functional edges ({}):", circuit.edges.len());
    for e in circuit.edges.iter().take(20) {
        println!("  {} -> {}  [support {}, delay ({},{}]]", e.from, e.to, e.support, e.t_low, e.t_high);
    }
    if let Some(path) = args.get("dot") {
        std::fs::write(path, circuit.to_dot())?;
        println!("\nwrote graphviz to {path}");
    }
    Ok(())
}

fn cmd_raster(args: &Args) -> Result<()> {
    use episodes_gpu::analysis::raster;
    let (stream, name) = load_dataset(args)?;
    let from = args.get_i32("from", stream.t_begin());
    let to = args.get_i32("to", (stream.t_begin() + 2000).min(stream.t_end()));
    let ep = args.get("episode").map(|spec| {
        let types: Vec<i32> =
            spec.split(',').map(|s| s.trim().parse().unwrap()).collect();
        let iv = interval_from(args, &name);
        Episode::new(types.clone(), vec![iv; types.len() - 1])
    });
    print!("{}", raster::render(&stream, from, to, 100, 30, ep.as_ref()));
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    use episodes_gpu::mining::telemetry::{profile_a1, profile_a2};
    use episodes_gpu::util::rng::Rng;
    let (stream, name) = load_dataset(args)?;
    let n = args.get_usize("size", 4);
    let count = args.get_usize("episodes", 256);
    let iv = interval_from(args, &name);
    let mut rng = Rng::new(args.get_u64("seed", 7));
    let eps: Vec<Episode> = (0..count)
        .map(|_| {
            let types: Vec<i32> =
                (0..n).map(|_| rng.range_i32(0, stream.n_types as i32 - 1)).collect();
            Episode::new(types, vec![iv; n - 1])
        })
        .collect();
    let c1 = profile_a1(&eps, &stream, 8);
    let c2 = profile_a2(&eps, &stream);
    println!("SIMT-warp profile, {count} episodes of size {n} over {name}:");
    println!("  A1: branches={} divergent={} local_loads={} local_stores={}",
        c1.branches, c1.divergent_branches, c1.local_loads, c1.local_stores);
    println!("  A2: branches={} divergent={} local_loads={} local_stores={}",
        c2.branches, c2.divergent_branches, c2.local_loads, c2.local_stores);
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = episodes_gpu::runtime::Runtime::default_dir();
    println!("artifact dir: {dir:?}");
    let rt = episodes_gpu::runtime::Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    let m = rt.manifest();
    println!("manifest: {m:?}");
    Ok(())
}
