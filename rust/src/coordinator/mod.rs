//! L3 coordinator: the paper's system contribution.
//!
//! Owns algorithm dispatch (PTPE vs MapConcatenate vs Hybrid, paper §5.2),
//! the two-pass A2+A1 elimination pipeline (§5.3), the level-wise mining
//! driver (§5), and the streaming "chip-on-chip" partition processor (§1
//! contribution 3). Counting executes on the PJRT runtime; candidate
//! generation and concatenation stay here on the host — exactly the
//! paper's CPU/GPU split.

pub mod mapconcat;
pub mod metrics;
pub mod miner;
pub mod streaming;
pub mod two_pass;

use anyhow::Result;

use crate::episodes::Episode;
use crate::events::EventStream;
use crate::gpu_model::crossover::{CostModel, CrossoverModel};
use crate::mining::{cpu_parallel, serial};
use crate::runtime::{exec, Runtime};

pub use metrics::Metrics;

/// Counting strategy (the paper's algorithm menu).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// per-thread-per-episode on the accelerator, exact constraints (§5.2.1)
    PtpeA1,
    /// segment-parallel Map + host Concatenate (§5.2.2)
    MapConcat,
    /// Hybrid: crossover-model dispatch between the two (§5.2.3, Alg. 2)
    Hybrid,
    /// serial CPU reference (Algorithm 1)
    CpuSerial,
    /// the paper's multithreaded CPU baseline (§6.4)
    CpuParallel,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        Some(match s {
            "ptpe" | "a1" => Strategy::PtpeA1,
            "mapconcat" | "mc" => Strategy::MapConcat,
            "hybrid" => Strategy::Hybrid,
            "cpu" | "cpu-serial" => Strategy::CpuSerial,
            "cpu-parallel" => Strategy::CpuParallel,
            _ => return None,
        })
    }
}

/// How the Hybrid strategy picks PTPE vs MapConcatenate.
#[derive(Clone, Copy, Debug)]
pub enum Dispatch {
    /// the paper's Eq. 2 form: S > f(N) with f fitted to crossovers
    Crossover(CrossoverModel),
    /// stream-length-aware cost model calibrated on this substrate
    /// (DESIGN.md §6; the default)
    Cost(CostModel),
}

/// The coordinator: runtime handle + dispatch model + run metrics.
pub struct Coordinator {
    pub rt: Runtime,
    pub dispatch: Dispatch,
    pub metrics: Metrics,
    /// worker threads for the CPU-parallel strategy
    pub cpu_threads: usize,
}

impl Coordinator {
    pub fn new(rt: Runtime) -> Coordinator {
        let mf = rt.manifest();
        let cost = CostModel::substrate_default(mf.m_episodes, mf.c_chunk);
        Coordinator {
            rt,
            dispatch: Dispatch::Cost(cost),
            metrics: Metrics::default(),
            cpu_threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
        }
    }

    /// Switch the Hybrid dispatch rule (benches compare both).
    pub fn with_dispatch(mut self, d: Dispatch) -> Coordinator {
        self.dispatch = d;
        self
    }

    pub fn open_default() -> Result<Coordinator> {
        Ok(Coordinator::new(Runtime::open_default()?))
    }

    /// Count every episode's non-overlapped occurrences under the given
    /// strategy. Episodes may mix sizes; they are grouped by size
    /// internally and results return in input order.
    pub fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
        strategy: Strategy,
    ) -> Result<Vec<u64>> {
        let mut out = vec![0u64; episodes.len()];
        for (indices, group) in group_by_size(episodes) {
            let counts = self.count_uniform(&group, stream, strategy)?;
            for (slot, c) in indices.into_iter().zip(counts) {
                out[slot] = c;
            }
        }
        Ok(out)
    }

    /// Count a uniform-size group.
    fn count_uniform(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
        strategy: Strategy,
    ) -> Result<Vec<u64>> {
        let n = episodes[0].n();
        self.metrics.episodes_counted += episodes.len() as u64;
        // 1-node episodes are plain frequencies — no kernel needed (§7 of
        // DESIGN.md: N=1 handled on the host).
        if n == 1 {
            let freq = stream.type_counts();
            return Ok(episodes.iter().map(|e| freq[e.types[0] as usize]).collect());
        }
        match strategy {
            Strategy::CpuSerial => {
                Ok(episodes.iter().map(|e| serial::count_a1(e, stream)).collect())
            }
            Strategy::CpuParallel => {
                Ok(cpu_parallel::count_all_parallel(episodes, stream, self.cpu_threads))
            }
            Strategy::PtpeA1 => {
                if !self.rt.supports_n(n) {
                    self.metrics.cpu_fallbacks += 1;
                    return Ok(cpu_parallel::count_all_parallel(
                        episodes,
                        stream,
                        self.cpu_threads,
                    ));
                }
                self.metrics.ptpe_calls += 1;
                exec::count_a1(&self.rt, episodes, stream)
            }
            Strategy::MapConcat => self.count_mapconcat(episodes, stream),
            Strategy::Hybrid => {
                // Alg. 2: PTPE when S exceeds the level-dependent
                // crossover, MapConcatenate otherwise.
                let ptpe = match self.dispatch {
                    Dispatch::Crossover(m) => m.choose_ptpe(episodes.len(), n),
                    Dispatch::Cost(m) => m.choose_ptpe(episodes.len(), n, stream.len()),
                };
                if ptpe {
                    self.count_uniform(episodes, stream, Strategy::PtpeA1)
                } else {
                    self.count_uniform(episodes, stream, Strategy::MapConcat)
                }
            }
        }
    }

    fn count_mapconcat(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<Vec<u64>> {
        let n = episodes[0].n();
        match mapconcat::plan(&self.rt, episodes, stream) {
            Some(plan) if self.rt.supports_n(n) => {
                self.metrics.mapcat_calls += 1;
                let (mut counts, misses) =
                    mapconcat::count(&self.rt, episodes, stream, &plan)?;
                // Concatenate misses flag episodes whose boundary-machine
                // chain lost synchronization (matched chains are exact;
                // see mapconcat::count). Recount those exactly via PTPE.
                let missed: Vec<usize> =
                    (0..episodes.len()).filter(|&i| misses[i] > 0).collect();
                if !missed.is_empty() {
                    self.metrics.concat_misses += missed.len() as u64;
                    let subset: Vec<Episode> =
                        missed.iter().map(|&i| episodes[i].clone()).collect();
                    let exact = exec::count_a1(&self.rt, &subset, stream)?;
                    for (&i, c) in missed.iter().zip(exact) {
                        counts[i] = c;
                    }
                }
                Ok(counts)
            }
            _ => {
                // segmentation infeasible (stream too large / too short, or
                // constraint windows wider than a segment): PTPE fallback.
                self.metrics.mapcat_fallbacks += 1;
                self.count_uniform(episodes, stream, Strategy::PtpeA1)
            }
        }
    }
}

/// Group episode indices by episode size, preserving order within groups.
fn group_by_size(episodes: &[Episode]) -> Vec<(Vec<usize>, Vec<Episode>)> {
    let mut groups: Vec<(usize, Vec<usize>)> = vec![];
    for (i, ep) in episodes.iter().enumerate() {
        match groups.iter_mut().find(|(n, _)| *n == ep.n()) {
            Some((_, v)) => v.push(i),
            None => groups.push((ep.n(), vec![i])),
        }
    }
    groups
        .into_iter()
        .map(|(_, idx)| {
            let eps = idx.iter().map(|&i| episodes[i].clone()).collect();
            (idx, eps)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;

    #[test]
    fn group_by_size_preserves_order() {
        let iv = Interval::new(0, 5);
        let eps = vec![
            Episode::single(0),
            Episode::new(vec![1, 2], vec![iv]),
            Episode::single(3),
            Episode::new(vec![4, 5], vec![iv]),
        ];
        let groups = group_by_size(&eps);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].0, vec![0, 2]);
        assert_eq!(groups[1].0, vec![1, 3]);
    }
}
