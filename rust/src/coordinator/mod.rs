//! L3 coordinator: the paper's system contribution, now expressed as
//! backend composition.
//!
//! Algorithm dispatch (PTPE vs MapConcatenate vs Hybrid, paper §5.2), the
//! two-pass A2+A1 elimination pipeline (§5.3) and the level-wise mining
//! driver (§5) live in [`crate::backend`] and [`crate::session`]; this
//! module keeps the strategy name menu, the run metrics, the streaming
//! partition producer, and the old [`Coordinator`] entry points as thin
//! **deprecated** shims so existing benches and tests migrate
//! incrementally. New code should start from [`crate::Session`].

pub mod mapconcat;
pub mod metrics;
pub mod miner;
pub mod streaming;
pub mod two_pass;

use std::rc::Rc;

use crate::backend::two_pass::{TwoPassBackend, TwoPassOutcome};
use crate::backend::{self, accel, CountBackend};
use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::EventStream;
use crate::gpu_model::crossover::CostModel;
use crate::runtime::Runtime;

pub use crate::backend::accel::Dispatch;
pub use metrics::Metrics;

/// Counting strategy (the paper's algorithm menu). Each name resolves to a
/// [`CountBackend`] via [`crate::backend::for_strategy`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// per-thread-per-episode on the accelerator, exact constraints (§5.2.1)
    PtpeA1,
    /// segment-parallel Map + host Concatenate (§5.2.2)
    MapConcat,
    /// Hybrid: crossover-model dispatch between the two (§5.2.3, Alg. 2)
    Hybrid,
    /// serial CPU reference (Algorithm 1)
    CpuSerial,
    /// the paper's multithreaded CPU baseline (§6.4), episode-axis workers
    CpuParallel,
    /// stream-axis CPU sharding: the MapConcatenate construction (§5.2.2)
    /// on the host thread pool — one boundary-machine Map worker per time
    /// shard, host Concatenate stitch, serial recount on flagged misses
    CpuSharded,
}

impl Strategy {
    /// Every accepted strategy name (aliases included).
    pub const NAMES: &'static [&'static str] = &[
        "ptpe",
        "a1",
        "mapconcat",
        "mc",
        "hybrid",
        "cpu",
        "cpu-serial",
        "cpu-parallel",
        "cpu-sharded",
        "sharded",
    ];

    /// Parse a strategy name; unknown names report the full valid list.
    pub fn parse(s: &str) -> Result<Strategy, MineError> {
        match s {
            "ptpe" | "a1" => Ok(Strategy::PtpeA1),
            "mapconcat" | "mc" => Ok(Strategy::MapConcat),
            "hybrid" => Ok(Strategy::Hybrid),
            "cpu" | "cpu-serial" => Ok(Strategy::CpuSerial),
            "cpu-parallel" => Ok(Strategy::CpuParallel),
            "cpu-sharded" | "sharded" => Ok(Strategy::CpuSharded),
            _ => Err(MineError::UnknownStrategy {
                given: s.to_string(),
                valid: Strategy::NAMES,
            }),
        }
    }

    /// Does this strategy count on the accelerator (needs an open
    /// [`Runtime`])?
    pub fn needs_runtime(self) -> bool {
        matches!(self, Strategy::PtpeA1 | Strategy::MapConcat | Strategy::Hybrid)
    }
}

impl std::str::FromStr for Strategy {
    type Err = MineError;

    fn from_str(s: &str) -> Result<Strategy, MineError> {
        Strategy::parse(s)
    }
}

/// The legacy coordinator: runtime handle + dispatch model + run metrics.
///
/// Deprecated in favor of [`crate::Session`] (which owns backend
/// construction, per-level reporting and streaming partition mining); the
/// methods below are thin shims over the same backend layer and will be
/// removed after one release.
pub struct Coordinator {
    pub rt: Rc<Runtime>,
    pub dispatch: Dispatch,
    pub metrics: Metrics,
    /// worker threads for the CPU-parallel strategy
    pub cpu_threads: usize,
}

impl Coordinator {
    pub fn new(rt: Runtime) -> Coordinator {
        let mf = rt.manifest();
        let cost = CostModel::substrate_default(mf.m_episodes, mf.c_chunk);
        Coordinator {
            rt: Rc::new(rt),
            dispatch: Dispatch::Cost(cost),
            metrics: Metrics::default(),
            cpu_threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
        }
    }

    /// Switch the Hybrid dispatch rule (benches compare both).
    pub fn with_dispatch(mut self, d: Dispatch) -> Coordinator {
        self.dispatch = d;
        self
    }

    pub fn open_default() -> Result<Coordinator, MineError> {
        Ok(Coordinator::new(Runtime::open_default()?))
    }

    /// Build the backend a strategy names, honoring this coordinator's
    /// dispatch model for Hybrid. (The non-deprecated internal the shims
    /// share.)
    pub(crate) fn strategy_backend(
        &self,
        strategy: Strategy,
    ) -> Result<Box<dyn CountBackend>, MineError> {
        if strategy == Strategy::Hybrid {
            return Ok(Box::new(accel::HybridBackend::with_runtime_dispatch(
                self.rt.clone(),
                self.cpu_threads,
                self.dispatch,
            )));
        }
        backend::for_strategy(strategy, Some(self.rt.clone()), self.cpu_threads)
    }

    /// Count every episode's non-overlapped occurrences under the given
    /// strategy. Episodes may mix sizes; results return in input order.
    #[deprecated(since = "0.2.0", note = "use Session::count or a CountBackend directly")]
    pub fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
        strategy: Strategy,
    ) -> Result<Vec<u64>, MineError> {
        let mut be = self.strategy_backend(strategy)?;
        let report = be.count(episodes, stream)?;
        self.metrics.merge(&report.metrics);
        Ok(report.counts)
    }

    /// Two-pass count at support threshold `theta` (paper CTh).
    #[deprecated(since = "0.2.0", note = "use backend::two_pass::TwoPassBackend")]
    pub fn count_two_pass(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
        theta: u64,
    ) -> Result<TwoPassOutcome, MineError> {
        let inner = self.strategy_backend(Strategy::Hybrid)?;
        let mut tp = TwoPassBackend::new(inner, theta);
        let (outcome, metrics) = tp.run(episodes, stream)?;
        self.metrics.merge(&metrics);
        Ok(outcome)
    }

    /// Pass 1 only: relaxed counts via the A2 path (CPU fallback for
    /// unsupported sizes).
    #[deprecated(since = "0.2.0", note = "use CountBackend::count_relaxed")]
    pub fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<Vec<u64>, MineError> {
        let mut be = self.strategy_backend(Strategy::Hybrid)?;
        let report = be.count_relaxed(episodes, stream)?;
        self.metrics.merge(&report.metrics);
        Ok(report.counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrips_all_names() {
        for &name in Strategy::NAMES {
            assert!(Strategy::parse(name).is_ok(), "{name}");
        }
    }

    #[test]
    fn strategy_parse_error_lists_valid_names() {
        let err = Strategy::parse("warp-speed").err().unwrap();
        let msg = err.to_string();
        assert!(msg.contains("warp-speed"));
        for &name in Strategy::NAMES {
            assert!(msg.contains(name), "missing {name} in {msg}");
        }
    }

    #[test]
    fn needs_runtime_splits_cpu_from_accel() {
        assert!(Strategy::Hybrid.needs_runtime());
        assert!(Strategy::PtpeA1.needs_runtime());
        assert!(!Strategy::CpuSerial.needs_runtime());
        assert!(!Strategy::CpuParallel.needs_runtime());
        assert!(!Strategy::CpuSharded.needs_runtime());
    }
}
