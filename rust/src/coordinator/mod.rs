//! L3 coordinator: the paper's system contribution, now expressed as
//! backend composition.
//!
//! Algorithm dispatch (PTPE vs MapConcatenate vs Hybrid, paper §5.2), the
//! two-pass A2+A1 elimination pipeline (§5.3) and the level-wise mining
//! driver (§5) live in [`crate::backend`] and [`crate::session`]; this
//! module keeps the strategy name menu ([`Strategy`]), the run metrics,
//! the streaming partition producer, and the level/mine report types.
//! The pre-0.2 `Coordinator` entry points (`mine`, `count`,
//! `count_two_pass`, `count_relaxed`, `mine_stream`) spent the 0.2 cycle
//! as migration shims and were removed in 0.3 — start from
//! [`crate::Session`], or compose a [`crate::backend::CountBackend`]
//! directly (see the README's "removed in 0.3" note for the exact
//! replacements).

pub mod mapconcat;
pub mod metrics;
pub mod miner;
pub mod streaming;
pub mod two_pass;

use crate::error::MineError;

pub use crate::backend::accel::Dispatch;
pub use metrics::Metrics;
pub use miner::{LevelReport, MineResult};

/// Counting strategy (the paper's algorithm menu). Each name resolves to a
/// [`CountBackend`] via [`crate::backend::for_strategy`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// per-thread-per-episode on the accelerator, exact constraints (§5.2.1)
    PtpeA1,
    /// segment-parallel Map + host Concatenate (§5.2.2)
    MapConcat,
    /// Hybrid: crossover-model dispatch between the two (§5.2.3, Alg. 2)
    Hybrid,
    /// serial CPU reference (Algorithm 1)
    CpuSerial,
    /// the paper's multithreaded CPU baseline (§6.4), episode-axis workers
    CpuParallel,
    /// stream-axis CPU sharding: the MapConcatenate construction (§5.2.2)
    /// on the host thread pool — one boundary-machine Map worker per time
    /// shard, host Concatenate stitch, serial recount on flagged misses
    CpuSharded,
}

impl Strategy {
    /// Every accepted strategy name (aliases included).
    pub const NAMES: &'static [&'static str] = &[
        "ptpe",
        "a1",
        "mapconcat",
        "mc",
        "hybrid",
        "cpu",
        "cpu-serial",
        "cpu-parallel",
        "cpu-sharded",
        "sharded",
    ];

    /// Parse a strategy name; unknown names report the full valid list.
    pub fn parse(s: &str) -> Result<Strategy, MineError> {
        match s {
            "ptpe" | "a1" => Ok(Strategy::PtpeA1),
            "mapconcat" | "mc" => Ok(Strategy::MapConcat),
            "hybrid" => Ok(Strategy::Hybrid),
            "cpu" | "cpu-serial" => Ok(Strategy::CpuSerial),
            "cpu-parallel" => Ok(Strategy::CpuParallel),
            "cpu-sharded" | "sharded" => Ok(Strategy::CpuSharded),
            _ => Err(MineError::UnknownStrategy {
                given: s.to_string(),
                valid: Strategy::NAMES,
            }),
        }
    }

    /// Does this strategy count on the accelerator (needs an open
    /// [`Runtime`])?
    pub fn needs_runtime(self) -> bool {
        matches!(self, Strategy::PtpeA1 | Strategy::MapConcat | Strategy::Hybrid)
    }
}

impl std::str::FromStr for Strategy {
    type Err = MineError;

    fn from_str(s: &str) -> Result<Strategy, MineError> {
        Strategy::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrips_all_names() {
        for &name in Strategy::NAMES {
            assert!(Strategy::parse(name).is_ok(), "{name}");
        }
    }

    #[test]
    fn strategy_parse_error_lists_valid_names() {
        let err = Strategy::parse("warp-speed").err().unwrap();
        let msg = err.to_string();
        assert!(msg.contains("warp-speed"));
        for &name in Strategy::NAMES {
            assert!(msg.contains(name), "missing {name} in {msg}");
        }
    }

    #[test]
    fn needs_runtime_splits_cpu_from_accel() {
        assert!(Strategy::Hybrid.needs_runtime());
        assert!(Strategy::PtpeA1.needs_runtime());
        assert!(!Strategy::CpuSerial.needs_runtime());
        assert!(!Strategy::CpuParallel.needs_runtime());
        assert!(!Strategy::CpuSharded.needs_runtime());
    }
}
