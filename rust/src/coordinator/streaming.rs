//! Chip-on-chip streaming (paper §1 contribution 3, §6.5): one chip (the
//! MEA) produces spikes, the other mines them, partition by partition.
//!
//! The paper's solution is explicitly *not* a full streaming algorithm —
//! it achieves real-time responsiveness by processing partitions of the
//! stream in turn. We reproduce that: a producer thread plays a recording
//! back at a configurable speed-up into a bounded channel; the miner
//! consumes whole partitions and must finish each before the next arrives
//! (the real-time criterion reported by `examples/streaming_realtime.rs`).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::miner::{MineConfig, MineResult};
use super::Coordinator;
use crate::events::{EventStream, Tick};

/// A partition of the stream handed to the miner.
#[derive(Clone, Debug)]
pub struct Partition {
    pub index: usize,
    /// wall-clock duration this partition represents
    pub recording: Duration,
    pub stream: EventStream,
}

/// Per-partition mining outcome.
#[derive(Debug)]
pub struct PartitionReport {
    pub index: usize,
    pub events: usize,
    pub frequent: usize,
    pub mine_time: Duration,
    /// recording time the partition spans — mining is "real-time" when
    /// mine_time <= recording
    pub recording: Duration,
    pub result: MineResult,
}

impl PartitionReport {
    pub fn realtime_ok(&self) -> bool {
        self.mine_time <= self.recording
    }
}

/// Spawn a producer thread that replays `stream` in `width_ticks`
/// partitions, `speedup`× faster than real time (1.0 = real time).
pub fn spawn_producer(
    stream: EventStream,
    width_ticks: Tick,
    speedup: f64,
) -> Receiver<Partition> {
    let (tx, rx): (SyncSender<Partition>, Receiver<Partition>) = sync_channel(4);
    std::thread::spawn(move || {
        let parts = stream.partitions(width_ticks);
        for (index, part) in parts.into_iter().enumerate() {
            let recording = Duration::from_millis(width_ticks as u64);
            let wait = recording.div_f64(speedup.max(1e-9));
            std::thread::sleep(wait.min(Duration::from_millis(500)));
            if tx.send(Partition { index, recording, stream: part }).is_err() {
                break; // consumer hung up
            }
        }
    });
    rx
}

impl Coordinator {
    /// Mine each partition as it arrives; returns per-partition reports.
    pub fn mine_stream(
        &mut self,
        rx: Receiver<Partition>,
        cfg: &MineConfig,
    ) -> Result<Vec<PartitionReport>> {
        let mut reports = vec![];
        while let Ok(part) = rx.recv() {
            let t0 = Instant::now();
            let result = self.mine(&part.stream, cfg)?;
            reports.push(PartitionReport {
                index: part.index,
                events: part.stream.len(),
                frequent: result.frequent.len(),
                mine_time: t0.elapsed(),
                recording: part.recording,
                result,
            });
        }
        Ok(reports)
    }
}
