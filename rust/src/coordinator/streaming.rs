//! Chip-on-chip streaming (paper §1 contribution 3, §6.5): one chip (the
//! MEA) produces spikes, the other mines them, partition by partition.
//!
//! The paper's solution is explicitly *not* a full streaming algorithm —
//! it achieves real-time responsiveness by processing partitions of the
//! stream in turn. We reproduce that: a producer thread plays a recording
//! back at a configurable speed-up into a bounded channel; the miner
//! consumes whole partitions and must finish each before the next arrives
//! (the real-time criterion reported by `examples/streaming_realtime.rs`).
//! Consume the receiver with [`crate::Session::mine_partitions`].

use std::sync::mpsc::{sync_channel, Receiver};
use std::time::Duration;

use super::miner::MineResult;
use crate::error::MineError;
use crate::events::{EventStream, Tick};

/// A partition of the stream handed to the miner.
#[derive(Clone, Debug)]
pub struct Partition {
    pub index: usize,
    /// window start: this partition covers the ticks `(start, start + width]`
    /// (the tail may end early — see `recording`). Incremental consumers
    /// ([`crate::Session::mine_incremental`]) need the absolute position;
    /// batch consumers mine `stream` and never look.
    pub start: Tick,
    /// wall-clock duration this partition represents
    pub recording: Duration,
    pub stream: EventStream,
}

/// Per-partition mining outcome.
#[derive(Debug)]
pub struct PartitionReport {
    pub index: usize,
    pub events: usize,
    pub frequent: usize,
    pub mine_time: Duration,
    /// recording time the partition spans — mining is "real-time" when
    /// mine_time <= recording
    pub recording: Duration,
    pub result: MineResult,
}

impl PartitionReport {
    pub fn realtime_ok(&self) -> bool {
        self.mine_time <= self.recording
    }
}

/// Producer pacing and buffering knobs for [`spawn_producer_with`].
#[derive(Clone, Copy, Debug)]
pub struct ProducerConfig {
    /// Replay speed relative to real time (1.0 = real time). Values <= 1.0
    /// are honored exactly — a real-time or slowed replay must sleep the
    /// full partition duration or the real-time criterion it exists to
    /// exercise is meaningless.
    pub speedup: f64,
    /// Bound of the partition channel (how many partitions may queue
    /// before the producer blocks). The paper's setup is a 2-chip
    /// hand-off; a small bound models the MEA-side buffer.
    pub channel_bound: usize,
    /// Upper bound on the inter-partition sleep, applied **only when
    /// `speedup > 1.0`** (an accelerated replay is a test-bench
    /// convenience, so capping its sleeps merely speeds the bench up; at
    /// real-time speeds a cap would silently break pacing for partitions
    /// wider than the cap).
    pub max_wait: Duration,
}

impl Default for ProducerConfig {
    fn default() -> ProducerConfig {
        ProducerConfig {
            speedup: 1.0,
            channel_bound: 4,
            max_wait: Duration::from_millis(500),
        }
    }
}

/// Spawn a producer thread that replays `stream` in `width_ticks`
/// partitions at `speedup`× real time with default buffering.
pub fn spawn_producer(
    stream: EventStream,
    width_ticks: Tick,
    speedup: f64,
) -> Result<Receiver<Partition>, MineError> {
    spawn_producer_with(stream, width_ticks, ProducerConfig { speedup, ..Default::default() })
}

/// Spawn a producer thread with explicit pacing/buffering configuration.
///
/// A non-finite or non-positive `speedup` is rejected up front as
/// [`MineError::InvalidConfig`]: silently clamping it (the pre-0.3
/// behavior) turned a typo like `speedup: 0.0` into a ~31-year sleep per
/// 1 s partition on a detached thread — the kind of failure that must
/// surface at the call site, not hang the pipeline.
pub fn spawn_producer_with(
    stream: EventStream,
    width_ticks: Tick,
    cfg: ProducerConfig,
) -> Result<Receiver<Partition>, MineError> {
    if !cfg.speedup.is_finite() || cfg.speedup <= 0.0 {
        return Err(MineError::invalid(format!(
            "ProducerConfig::speedup must be finite and > 0, got {}",
            cfg.speedup
        )));
    }
    if width_ticks <= 0 {
        // Same failure class, one parameter over: the partitioner's
        // width assert would otherwise fire on the detached thread and
        // silently yield an empty partition stream.
        return Err(MineError::invalid(format!(
            "partition width must be > 0 ticks, got {width_ticks}"
        )));
    }
    let (tx, rx) = sync_channel(cfg.channel_bound.max(1));
    std::thread::spawn(move || {
        let t_end = stream.t_end();
        let parts = stream.partitions_with_starts(width_ticks);
        for (index, (part_start, part)) in parts.into_iter().enumerate() {
            // A partition covers (part_start, part_start + width], except
            // the tail, which the recording ends inside. Stamping the tail
            // with a full width would overstate its real-time budget (and
            // pre-send sleep), letting `realtime_ok()` pass a miner that is
            // actually too slow — use the actual covered span.
            let covered = (t_end - part_start).clamp(0, width_ticks);
            let recording = Duration::from_millis(covered as u64);
            let mut wait = recording.div_f64(cfg.speedup);
            if cfg.speedup > 1.0 {
                wait = wait.min(cfg.max_wait);
            }
            std::thread::sleep(wait);
            if tx.send(Partition { index, start: part_start, recording, stream: part }).is_err() {
                break; // consumer hung up
            }
        }
    });
    Ok(rx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn stream_ms(total: Tick) -> EventStream {
        let pairs: Vec<(i32, Tick)> = (1..=total).step_by(10).map(|t| (0, t)).collect();
        EventStream::from_pairs(pairs, 1)
    }

    #[test]
    fn accelerated_replay_caps_waits() {
        // 4 partitions of 2000 ms at 1000x: waits are 2 ms, well under the
        // cap — the whole replay must finish quickly.
        let rx = spawn_producer_with(
            stream_ms(8000),
            2000,
            ProducerConfig { speedup: 1000.0, ..Default::default() },
        )
        .unwrap();
        let t0 = Instant::now();
        let parts: Vec<Partition> = rx.iter().collect();
        assert_eq!(parts.len(), 4);
        assert!(t0.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn realtime_replay_is_not_capped() {
        // One 1200 ms partition at real time must take >= ~1200 ms even
        // though it exceeds the old hard-coded 500 ms cap.
        let rx = spawn_producer_with(
            stream_ms(1200),
            1200,
            ProducerConfig { speedup: 1.0, ..Default::default() },
        )
        .unwrap();
        let t0 = Instant::now();
        let parts: Vec<Partition> = rx.iter().collect();
        assert_eq!(parts.len(), 1);
        assert!(
            t0.elapsed() >= Duration::from_millis(1100),
            "real-time pacing was capped: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn tail_partition_recording_is_covered_span_not_width() {
        // Events span (0, 1491]; width 1000 → two partitions, the second
        // covering only 491 ms of recording. Budgeting it a full 1000 ms
        // would let a 600 ms mine pass the real-time criterion it should
        // fail.
        let rx = spawn_producer_with(
            stream_ms(1500),
            1000,
            ProducerConfig { speedup: 1e6, ..Default::default() },
        )
        .unwrap();
        let parts: Vec<Partition> = rx.iter().collect();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].recording, Duration::from_millis(1000));
        assert_eq!(parts[1].recording, Duration::from_millis(491));
        // start stamps the absolute window: partition i covers
        // (start, start + width] with start spaced by the width
        assert_eq!(parts[1].start, parts[0].start + 1000);

        let report = PartitionReport {
            index: 1,
            events: parts[1].stream.len(),
            frequent: 0,
            mine_time: Duration::from_millis(600),
            recording: parts[1].recording,
            result: Default::default(),
        };
        assert!(!report.realtime_ok(), "600ms mine must miss a 491ms budget");
    }

    #[test]
    fn bad_speedups_are_rejected_up_front() {
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let err = spawn_producer_with(
                stream_ms(100),
                50,
                ProducerConfig { speedup: bad, ..Default::default() },
            )
            .err()
            .unwrap_or_else(|| panic!("speedup {bad} must be rejected"));
            assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
        }
        // tiny-but-positive finite speedups remain the caller's choice
        assert!(spawn_producer(stream_ms(10), 1000, 1e6).is_ok());
        // width is validated in the same up-front pass
        for bad_width in [0, -5] {
            let err = spawn_producer(stream_ms(100), bad_width, 10.0).err().unwrap();
            assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
        }
    }

    #[test]
    fn channel_bound_is_configurable() {
        // A bound of 1 with an instant producer: the producer can run at
        // most one partition ahead of the consumer; all partitions still
        // arrive.
        let rx = spawn_producer_with(
            stream_ms(5000),
            500,
            ProducerConfig { speedup: 1e6, channel_bound: 1, ..Default::default() },
        )
        .unwrap();
        let n = rx.iter().count();
        assert_eq!(n, 10);
    }
}
