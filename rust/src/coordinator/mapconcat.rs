//! MapConcatenate: segment planning and the host-side Concatenate step
//! (paper §5.2.2).
//!
//! The Map step runs on the accelerator (`runtime::exec::mapcat_map`,
//! kernel `python/compile/kernels/mapconcat.py`); this module plans the
//! segmentation and merges the per-segment boundary-machine tuples.
//! Merging is implemented both as a left fold (the production path — O(P)
//! with tiny constants) and as the paper's log-tree (what the GPU's
//! Concatenate kernel does in `q+1` levels); the two are property-tested
//! equal.

use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::{EventStream, Tick};
use crate::runtime::{exec, Runtime};

/// A planned segmentation: P+1 boundary times.
#[derive(Clone, Debug)]
pub struct Plan {
    pub taus: Vec<Tick>,
}

/// Plan an even time segmentation into the manifest's P segments, or
/// `None` if MapConcatenate is infeasible for this workload:
/// - the stream exceeds the Map chunk capacity, or
/// - some episode's constraint window (`sum t_high`) is wider than a
///   segment (boundary machines would need to reach beyond the adjacent
///   segment, which the Map kernel does not scan).
pub fn plan(rt: &Runtime, episodes: &[Episode], stream: &EventStream) -> Option<Plan> {
    let mf = rt.manifest();
    if stream.len() > mf.mc_chunk {
        return None;
    }
    plan_even(episodes, stream, mf.mc_segments)
}

/// The host-side core of [`plan`]: an even time segmentation into `p`
/// segments with the same feasibility rules, but no manifest/runtime in
/// sight — this is what the stream-sharded CPU backend plans its per-thread
/// time shards with. `None` when the stream is empty, has fewer ticks than
/// segments, or some episode's constraint window (`sum t_high`) is at
/// least as wide as the narrowest segment.
pub fn plan_even(episodes: &[Episode], stream: &EventStream, p: usize) -> Option<Plan> {
    if p == 0 || stream.is_empty() {
        return None;
    }
    let p = p as i64;
    let t0 = stream.t_begin() as i64 - 1;
    let t1 = stream.t_end() as i64;
    let span = t1 - t0;
    if span < p {
        return None; // degenerate: fewer ticks than segments
    }
    let seg_width = span / p; // narrowest segment width
    let max_span = episodes.iter().map(|e| e.span_max() as i64).max().unwrap_or(0);
    if max_span >= seg_width {
        return None;
    }
    let taus: Vec<Tick> = (0..p).map(|i| (t0 + span * i / p) as Tick).chain([t1 as Tick]).collect();
    Some(Plan { taus })
}

/// Run Map on the accelerator and Concatenate on the host. Returns the
/// per-episode counts and per-episode concatenate miss counts.
///
/// A *miss* is a chain step whose `cur_b` matched no machine's `a` in the
/// next segment: the paper's N boundary machines do not cover every
/// automaton entry state (rare, but real — see DESIGN.md §6), and a missed
/// segment can silently drop occurrences. Crucially a mismatch is always
/// accompanied by a miss: whenever some machine's `a` equals the chain's
/// `cur_b`, that machine's first completion coincides with the reference
/// automaton's, after which both are reset-synchronized — so matched
/// chains are exact. The coordinator therefore recounts only episodes
/// whose miss count is nonzero (via PTPE) to restore exactness.
pub fn count(
    rt: &Runtime,
    episodes: &[Episode],
    stream: &EventStream,
    plan: &Plan,
) -> Result<(Vec<u64>, Vec<u64>), MineError> {
    let tuples = exec::mapcat_map(rt, episodes, stream, &plan.taus)?;
    let mut counts = Vec::with_capacity(episodes.len());
    let mut misses = Vec::with_capacity(episodes.len());
    for per_seg in &tuples {
        let (c, m) = concatenate_fold(per_seg);
        counts.push(c);
        misses.push(m);
    }
    Ok((counts, misses))
}

/// Left-fold Concatenate: start from segment 0's machine 0 (the true
/// stream-start automaton) and chain `b == a` matches. Degenerate inputs
/// no longer panic: an empty segment list folds to `(0, 0)`, and a segment
/// with no machines (which a well-formed Map never produces) is flagged as
/// a miss — so callers that recount on `misses > 0` never trust a count
/// built over a hollow segment.
pub fn concatenate_fold(segments: &[Vec<(Tick, u64, Tick)>]) -> (u64, u64) {
    let Some(first) = segments.first() else {
        return (0, 0);
    };
    let Some(&(_, mut total, mut cur_b)) = first.first() else {
        // no machine 0 to anchor the chain: every step is unverifiable
        return (0, segments.len() as u64);
    };
    let mut misses = 0u64;
    for seg in &segments[1..] {
        match seg.iter().find(|(a, _, _)| *a == cur_b) {
            Some(&(_, c, b)) => {
                total += c;
                cur_b = b;
            }
            None => {
                misses += 1;
                if let Some(&(_, c, b)) = seg.first() {
                    total += c;
                    cur_b = b;
                }
            }
        }
    }
    (total, misses)
}

/// The paper's log-tree Concatenate (§5.2.2 steps 2-3): adjacent segment
/// pairs merge level by level in `q+1 = log2(P)+1` levels. Functionally
/// equal to the fold; used by the ablation bench to compare merge costs.
pub fn concatenate_tree(segments: &[Vec<(Tick, u64, Tick)>]) -> (u64, u64) {
    let mut level: Vec<Vec<(Tick, u64, Tick)>> = segments.to_vec();
    if level.is_empty() {
        return (0, 0);
    }
    let mut misses = 0u64;
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.chunks(2);
        for pair in &mut it {
            if pair.len() == 1 {
                next.push(pair[0].clone());
                continue;
            }
            let (left, right) = (&pair[0], &pair[1]);
            let merged: Vec<(Tick, u64, Tick)> = left
                .iter()
                .map(|&(a, c, b)| match right.iter().find(|(a2, _, _)| *a2 == b) {
                    Some(&(_, c2, b2)) => (a, c + c2, b2),
                    None => {
                        misses += 1;
                        let (_, c2, b2) = right[0];
                        (a, c + c2, b2)
                    }
                })
                .collect();
            next.push(merged);
        }
        level = next;
    }
    (level[0].first().map(|&(_, c, _)| c).unwrap_or(0), misses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;
    use crate::mining::serial;
    use crate::util::rng::Rng;

    fn world(seed: u64) -> (Episode, EventStream) {
        let mut rng = Rng::new(seed);
        let mut pairs = vec![];
        let mut t = 0;
        for _ in 0..600 {
            t += rng.range_i32(0, 3);
            pairs.push((rng.range_i32(0, 4), t));
        }
        let ep = Episode::new(
            vec![0, 1, 2],
            vec![Interval::new(0, 8), Interval::new(1, 6)],
        );
        (ep, EventStream::from_pairs(pairs, 5))
    }

    fn taus_for(stream: &EventStream, p: usize) -> Vec<Tick> {
        let t0 = stream.t_begin() as i64 - 1;
        let t1 = stream.t_end() as i64;
        let span = t1 - t0;
        (0..p as i64).map(|i| (t0 + span * i / p as i64) as Tick).chain([t1 as Tick]).collect()
    }

    #[test]
    fn fold_equals_tree_on_cpu_map() {
        for seed in 0..10 {
            let (ep, stream) = world(seed);
            let taus = taus_for(&stream, 8);
            let tuples = serial::mapcat_map(&ep, &stream, &taus, 8);
            let (cf, _) = concatenate_fold(&tuples);
            let (ct, _) = concatenate_tree(&tuples);
            assert_eq!(cf, ct, "seed {seed}");
        }
    }

    #[test]
    fn cpu_map_concat_equals_serial_count() {
        for seed in 0..10 {
            let (ep, stream) = world(seed);
            for p in [2usize, 4, 8, 16] {
                let taus = taus_for(&stream, p);
                let tuples = serial::mapcat_map(&ep, &stream, &taus, 8);
                let (total, misses) = concatenate_fold(&tuples);
                let want = serial::count_a1_bounded(&ep, &stream, 8);
                assert_eq!(total, want, "seed {seed} p {p} misses {misses}");
            }
        }
    }

    #[test]
    fn tree_handles_non_power_of_two() {
        let (ep, stream) = world(3);
        let taus = taus_for(&stream, 5);
        let tuples = serial::mapcat_map(&ep, &stream, &taus, 8);
        let (cf, _) = concatenate_fold(&tuples);
        let (ct, _) = concatenate_tree(&tuples);
        assert_eq!(cf, ct);
    }
}
