//! Two-pass elimination A2+A1 (paper §5.3, Algorithm 4).
//!
//! Pass 1 counts every candidate under the relaxed constraints α′ with the
//! cheap A2 kernel; candidates whose relaxed count is below the support
//! threshold are eliminated — sound because `count(α′) ≥ count(α)`
//! (Theorem 5.1, property-tested in `rust/tests/invariants.rs`). Pass 2
//! runs the exact A1 kernel on the survivors only.

use anyhow::Result;

use super::{Coordinator, Strategy};
use crate::episodes::Episode;
use crate::events::EventStream;

/// Result of a two-pass count.
#[derive(Clone, Debug)]
pub struct TwoPassOutcome {
    /// Per-episode counts: exact A1 counts for survivors; the (relaxed,
    /// sub-threshold) A2 upper bound for culled candidates. Either way the
    /// `count >= theta` decision is exact.
    pub counts: Vec<u64>,
    /// relaxed-pass counts for every candidate
    pub relaxed_counts: Vec<u64>,
    pub culled: u64,
    pub survivors: u64,
}

impl Coordinator {
    /// Two-pass count at support threshold `theta` (paper CTh).
    pub fn count_two_pass(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
        theta: u64,
    ) -> Result<TwoPassOutcome> {
        let relaxed = self.count_relaxed(episodes, stream)?;
        let survivor_idx: Vec<usize> =
            (0..episodes.len()).filter(|&i| relaxed[i] >= theta).collect();
        let survivors: Vec<Episode> =
            survivor_idx.iter().map(|&i| episodes[i].clone()).collect();
        self.metrics.a2_culled += (episodes.len() - survivors.len()) as u64;
        self.metrics.a2_survivors += survivors.len() as u64;

        let exact = self.count(&survivors, stream, Strategy::Hybrid)?;
        let mut counts = relaxed.clone();
        for (&i, c) in survivor_idx.iter().zip(exact) {
            counts[i] = c;
        }
        Ok(TwoPassOutcome {
            culled: (episodes.len() - survivor_idx.len()) as u64,
            survivors: survivor_idx.len() as u64,
            counts,
            relaxed_counts: relaxed,
        })
    }

    /// Pass 1: relaxed counts via the A2 artifacts (CPU fallback for
    /// unsupported sizes).
    pub fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<Vec<u64>> {
        use crate::mining::serial;
        let mut out = vec![0u64; episodes.len()];
        // group by size (A2 artifacts are per-N too)
        let mut by_n: Vec<(usize, Vec<usize>)> = vec![];
        for (i, ep) in episodes.iter().enumerate() {
            match by_n.iter_mut().find(|(n, _)| *n == ep.n()) {
                Some((_, v)) => v.push(i),
                None => by_n.push((ep.n(), vec![i])),
            }
        }
        for (n, idx) in by_n {
            let group: Vec<Episode> = idx.iter().map(|&i| episodes[i].clone()).collect();
            let counts = if n == 1 {
                let freq = stream.type_counts();
                group.iter().map(|e| freq[e.types[0] as usize]).collect()
            } else if self.rt.supports_n(n) {
                crate::runtime::exec::count_a2(&self.rt, &group, stream)?
            } else {
                self.metrics.cpu_fallbacks += 1;
                group.iter().map(|e| serial::count_a2(e, stream)).collect()
            };
            for (&i, c) in idx.iter().zip(counts) {
                out[i] = c;
            }
        }
        Ok(out)
    }
}
