//! Two-pass elimination A2+A1 (paper §5.3, Algorithm 4) — compatibility
//! surface.
//!
//! The implementation moved to [`crate::backend::two_pass`], where the
//! pipeline is a [`TwoPassBackend`](crate::backend::two_pass::TwoPassBackend)
//! wrapping any exact engine (the pre-0.2 `Coordinator::count_two_pass` /
//! `count_relaxed` shims over it were removed in 0.3). This module
//! re-exports the outcome type so `coordinator::two_pass::TwoPassOutcome`
//! keeps resolving.

pub use crate::backend::two_pass::TwoPassOutcome;
