//! Run metrics: what the coordinator did and what it cost.

use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// episodes passed through `count`
    pub episodes_counted: u64,
    /// PTPE artifact invocations
    pub ptpe_calls: u64,
    /// MapConcatenate Map invocations
    pub mapcat_calls: u64,
    /// MapConcatenate plans that fell back to PTPE
    pub mapcat_fallbacks: u64,
    /// stream-sharded Map invocations on the CPU thread pool
    pub shard_map_calls: u64,
    /// Concatenate chain steps with no b==a match
    pub concat_misses: u64,
    /// episode sizes with no artifact, counted on CPU
    pub cpu_fallbacks: u64,
    /// candidates culled by the A2 first pass
    pub a2_culled: u64,
    /// candidates that survived to the A1 second pass
    pub a2_survivors: u64,
    /// total accelerator wall time
    pub accel_time: Duration,
    /// total host (generation + concatenate) wall time
    pub host_time: Duration,
}

impl Metrics {
    /// Fold another metrics delta into this one (backends report per-call
    /// deltas; sessions and coordinators accumulate them here).
    pub fn merge(&mut self, other: &Metrics) {
        self.episodes_counted += other.episodes_counted;
        self.ptpe_calls += other.ptpe_calls;
        self.mapcat_calls += other.mapcat_calls;
        self.mapcat_fallbacks += other.mapcat_fallbacks;
        self.shard_map_calls += other.shard_map_calls;
        self.concat_misses += other.concat_misses;
        self.cpu_fallbacks += other.cpu_fallbacks;
        self.a2_culled += other.a2_culled;
        self.a2_survivors += other.a2_survivors;
        self.accel_time += other.accel_time;
        self.host_time += other.host_time;
    }

    /// Publish this run's counters into the unified observability
    /// registry (additive, under the `coordinator.` prefix). The plain
    /// pub fields stay the hot-path accumulation surface — backends
    /// bump them lock-free per call — and a finished run folds into the
    /// registry in one shot, so the registry never sits on the counting
    /// fast path.
    pub fn publish_to(&self, registry: &crate::obs::Registry) {
        for (name, v) in [
            ("coordinator.episodes_counted", self.episodes_counted),
            ("coordinator.ptpe_calls", self.ptpe_calls),
            ("coordinator.mapcat_calls", self.mapcat_calls),
            ("coordinator.mapcat_fallbacks", self.mapcat_fallbacks),
            ("coordinator.shard_map_calls", self.shard_map_calls),
            ("coordinator.concat_misses", self.concat_misses),
            ("coordinator.cpu_fallbacks", self.cpu_fallbacks),
            ("coordinator.a2_culled", self.a2_culled),
            ("coordinator.a2_survivors", self.a2_survivors),
            ("coordinator.accel_time_ns", self.accel_time.as_nanos() as u64),
            ("coordinator.host_time_ns", self.host_time.as_nanos() as u64),
        ] {
            if v > 0 {
                registry.counter(name).add(v);
            }
        }
    }

    pub fn report(&self) -> String {
        format!(
            "episodes={} ptpe_calls={} mapcat_calls={} mapcat_fallbacks={} \
             shard_map_calls={} concat_misses={} cpu_fallbacks={} a2_culled={} \
             a2_survivors={} accel={:?} host={:?}",
            self.episodes_counted,
            self.ptpe_calls,
            self.mapcat_calls,
            self.mapcat_fallbacks,
            self.shard_map_calls,
            self.concat_misses,
            self.cpu_fallbacks,
            self.a2_culled,
            self.a2_survivors,
            self.accel_time,
            self.host_time,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_contains_counters() {
        let mut m = Metrics::default();
        m.a2_culled = 42;
        assert!(m.report().contains("a2_culled=42"));
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics { ptpe_calls: 2, cpu_fallbacks: 1, ..Metrics::default() };
        let b = Metrics { ptpe_calls: 3, a2_culled: 7, ..Metrics::default() };
        a.merge(&b);
        assert_eq!(a.ptpe_calls, 5);
        assert_eq!(a.cpu_fallbacks, 1);
        assert_eq!(a.a2_culled, 7);
    }
}
