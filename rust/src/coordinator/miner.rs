//! Level-wise mining reports.
//!
//! The mining loop itself lives in [`crate::session::mine_with_backend`]
//! (one implementation for `Session`, streaming partitions, and the
//! batched executor [`crate::analysis::batch`]); this module keeps the
//! report types that benches and tests consume. The pre-0.2
//! `MineConfig`/`CountMode` shims were removed in 0.3 — configuration is
//! [`crate::session::MineOptions`], and counting mode is backend
//! composition (a bare engine, or
//! [`crate::backend::two_pass::TwoPassBackend`] wrapping one).

use crate::episodes::CountedEpisode;

/// Per-level mining report (the numbers Figs. 7/9 are built from).
#[derive(Clone, Debug)]
pub struct LevelReport {
    pub level: usize,
    pub candidates: usize,
    pub frequent: usize,
    pub culled_by_a2: u64,
    pub count_seconds: f64,
    pub gen_seconds: f64,
}

#[derive(Clone, Debug, Default)]
pub struct MineResult {
    /// frequent episodes of every size, with exact counts
    pub frequent: Vec<CountedEpisode>,
    pub levels: Vec<LevelReport>,
    /// phase profile, present only when profiling was requested
    /// (`SessionBuilder::profile(true)` / `--profile`); optional on the
    /// cluster wire too, so old peers interoperate unchanged
    pub profile: Option<crate::obs::MineProfile>,
}

impl MineResult {
    pub fn frequent_of_size(&self, n: usize) -> Vec<&CountedEpisode> {
        self.frequent.iter().filter(|c| c.episode.n() == n).collect()
    }

    pub fn total_count_seconds(&self) -> f64 {
        self.levels.iter().map(|l| l.count_seconds).sum()
    }
}
