//! Level-wise mining configuration and reports.
//!
//! The mining loop itself lives in [`crate::session::mine_with_backend`]
//! (one implementation for `Session`, streaming partitions, and the
//! deprecated [`Coordinator::mine`] shim below); this module keeps the
//! config/report types that benches and tests consume.

use crate::backend::two_pass::TwoPassBackend;
use crate::backend::CountBackend;
use crate::episodes::{CountedEpisode, Interval};
use crate::error::MineError;
use crate::events::EventStream;
use crate::session::{mine_with_backend, MineOptions};

use super::{Coordinator, Strategy};

/// Counting mode for each mining level.
///
/// Superseded by backend composition: one-pass is a bare engine, two-pass
/// is [`TwoPassBackend`] wrapping it. Kept for the deprecated
/// [`Coordinator::mine`] shim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CountMode {
    /// one pass with the given strategy
    OnePass(Strategy),
    /// the paper's two-pass elimination (A2 filter + Hybrid exact pass)
    TwoPass,
}

#[derive(Clone, Debug)]
pub struct MineConfig {
    /// support threshold theta (non-overlapped occurrence count)
    pub theta: u64,
    /// the inter-event constraint set I (paper Problem 1)
    pub intervals: Vec<Interval>,
    pub mode: CountMode,
    /// stop after this episode size (the paper mines to ~7-8)
    pub max_level: usize,
    /// guardrail: abort a level whose candidate set exceeds this (a
    /// too-low theta on bursty data grows the lattice combinatorially;
    /// production systems must fail fast, not OOM)
    pub max_candidates_per_level: usize,
}

impl MineConfig {
    pub fn new(theta: u64, intervals: Vec<Interval>) -> MineConfig {
        MineConfig {
            theta,
            intervals,
            mode: CountMode::TwoPass,
            max_level: 8,
            max_candidates_per_level: 2_000_000,
        }
    }

    pub(crate) fn options(&self) -> MineOptions {
        MineOptions {
            theta: self.theta,
            intervals: self.intervals.clone(),
            max_level: self.max_level,
            max_candidates_per_level: self.max_candidates_per_level,
            candidate_block: crate::session::DEFAULT_CANDIDATE_BLOCK,
        }
    }
}

/// Per-level mining report (the numbers Figs. 7/9 are built from).
#[derive(Clone, Debug)]
pub struct LevelReport {
    pub level: usize,
    pub candidates: usize,
    pub frequent: usize,
    pub culled_by_a2: u64,
    pub count_seconds: f64,
    pub gen_seconds: f64,
}

#[derive(Clone, Debug, Default)]
pub struct MineResult {
    /// frequent episodes of every size, with exact counts
    pub frequent: Vec<CountedEpisode>,
    pub levels: Vec<LevelReport>,
    /// phase profile, present only when profiling was requested
    /// (`SessionBuilder::profile(true)` / `--profile`); optional on the
    /// cluster wire too, so old peers interoperate unchanged
    pub profile: Option<crate::obs::MineProfile>,
}

impl MineResult {
    pub fn frequent_of_size(&self, n: usize) -> Vec<&CountedEpisode> {
        self.frequent.iter().filter(|c| c.episode.n() == n).collect()
    }

    pub fn total_count_seconds(&self) -> f64 {
        self.levels.iter().map(|l| l.count_seconds).sum()
    }
}

impl Coordinator {
    /// The backend a [`MineConfig`]'s mode names (shared by the deprecated
    /// mine/mine_stream shims).
    pub(crate) fn mode_backend(
        &self,
        cfg: &MineConfig,
    ) -> Result<Box<dyn CountBackend>, MineError> {
        match cfg.mode {
            CountMode::OnePass(strategy) => self.strategy_backend(strategy),
            CountMode::TwoPass => {
                let inner = self.strategy_backend(Strategy::Hybrid)?;
                Ok(Box::new(TwoPassBackend::new(inner, cfg.theta)))
            }
        }
    }

    pub(crate) fn mine_impl(
        &mut self,
        stream: &EventStream,
        cfg: &MineConfig,
    ) -> Result<MineResult, MineError> {
        let mut backend = self.mode_backend(cfg)?;
        mine_with_backend(backend.as_mut(), stream, &cfg.options(), &mut self.metrics)
    }

    /// Run the full level-wise mining loop.
    #[deprecated(since = "0.2.0", note = "use Session::builder()...build()?.mine()")]
    pub fn mine(
        &mut self,
        stream: &EventStream,
        cfg: &MineConfig,
    ) -> Result<MineResult, MineError> {
        self.mine_impl(stream, cfg)
    }
}
