//! Level-wise frequent-episode mining driver (paper §5: candidate
//! generation on the host alternating with counting on the accelerator).

use std::time::Instant;

use anyhow::Result;

use super::{Coordinator, Strategy};
use crate::episodes::{candidates, CountedEpisode, Episode, Interval};
use crate::events::EventStream;

/// Counting mode for each mining level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CountMode {
    /// one pass with the given strategy
    OnePass(Strategy),
    /// the paper's two-pass elimination (A2 filter + Hybrid exact pass)
    TwoPass,
}

#[derive(Clone, Debug)]
pub struct MineConfig {
    /// support threshold theta (non-overlapped occurrence count)
    pub theta: u64,
    /// the inter-event constraint set I (paper Problem 1)
    pub intervals: Vec<Interval>,
    pub mode: CountMode,
    /// stop after this episode size (the paper mines to ~7-8)
    pub max_level: usize,
    /// guardrail: abort a level whose candidate set exceeds this (a
    /// too-low theta on bursty data grows the lattice combinatorially;
    /// production systems must fail fast, not OOM)
    pub max_candidates_per_level: usize,
}

impl MineConfig {
    pub fn new(theta: u64, intervals: Vec<Interval>) -> MineConfig {
        MineConfig {
            theta,
            intervals,
            mode: CountMode::TwoPass,
            max_level: 8,
            max_candidates_per_level: 2_000_000,
        }
    }
}

/// Per-level mining report (the numbers Figs. 7/9 are built from).
#[derive(Clone, Debug)]
pub struct LevelReport {
    pub level: usize,
    pub candidates: usize,
    pub frequent: usize,
    pub culled_by_a2: u64,
    pub count_seconds: f64,
    pub gen_seconds: f64,
}

#[derive(Clone, Debug, Default)]
pub struct MineResult {
    /// frequent episodes of every size, with exact counts
    pub frequent: Vec<CountedEpisode>,
    pub levels: Vec<LevelReport>,
}

impl MineResult {
    pub fn frequent_of_size(&self, n: usize) -> Vec<&CountedEpisode> {
        self.frequent.iter().filter(|c| c.episode.n() == n).collect()
    }

    pub fn total_count_seconds(&self) -> f64 {
        self.levels.iter().map(|l| l.count_seconds).sum()
    }
}

impl Coordinator {
    /// Run the full level-wise mining loop.
    pub fn mine(&mut self, stream: &EventStream, cfg: &MineConfig) -> Result<MineResult> {
        let mut result = MineResult::default();
        let mut frontier: Vec<Episode> = vec![];
        for level in 1..=cfg.max_level {
            let t_gen = Instant::now();
            let cands = if level == 1 {
                candidates::level1(stream.n_types)
            } else {
                candidates::next_level(&frontier, &cfg.intervals)
            };
            let gen_seconds = t_gen.elapsed().as_secs_f64();
            if cands.is_empty() {
                break;
            }
            anyhow::ensure!(
                cands.len() <= cfg.max_candidates_per_level,
                "level {level} generated {} candidates (> {} cap) — raise theta \
                 or max_candidates_per_level",
                cands.len(),
                cfg.max_candidates_per_level
            );

            let t_count = Instant::now();
            let (counts, culled) = match cfg.mode {
                CountMode::OnePass(strategy) => {
                    (self.count(&cands, stream, strategy)?, 0)
                }
                CountMode::TwoPass => {
                    let out = self.count_two_pass(&cands, stream, cfg.theta)?;
                    (out.counts, out.culled)
                }
            };
            let count_seconds = t_count.elapsed().as_secs_f64();

            frontier = cands
                .iter()
                .zip(&counts)
                .filter(|(_, &c)| c >= cfg.theta)
                .map(|(e, _)| e.clone())
                .collect();
            result.levels.push(LevelReport {
                level,
                candidates: cands.len(),
                frequent: frontier.len(),
                culled_by_a2: culled,
                count_seconds,
                gen_seconds,
            });
            result.frequent.extend(
                cands
                    .into_iter()
                    .zip(counts)
                    .filter(|(_, c)| *c >= cfg.theta)
                    .map(|(episode, count)| CountedEpisode { episode, count }),
            );
            if frontier.is_empty() {
                break;
            }
        }
        Ok(result)
    }
}
