//! Flat structure-of-arrays episode lattice: the arena-backed candidate
//! engine behind `session::mine_with_backend`.
//!
//! Level-wise generation used to materialize every candidate as an owned
//! [`Episode`] — two heap `Vec`s per candidate — and join frequent sets
//! with an O(F²) scan. At realistic multi-electrode-array scales (10³–10⁴
//! types) level 2 alone is 10⁶–10⁸ candidates, so the representation, not
//! counting, becomes the bottleneck (ROADMAP item 5; the BFS-extension
//! idiom of the Pangolin/GPU graph-mining exemplars). The arena stores
//! the whole lattice as parallel columns instead:
//!
//! ```text
//! blocks[0] (1-node)  last_type: [t0 t1 t2 ...]              (links unused)
//! blocks[1] (2-node)  last_type | last_iv | parent | suffix
//! blocks[2] (3-node)  last_type | last_iv | parent | suffix
//!      ...                 parent/suffix are rows in blocks[k-1]
//! ```
//!
//! A stored episode is one row: its last node type, the interned id of
//! its last gap interval ([`EpisodeArena::intervals`] is the run's
//! constraint set `I`), a `parent` link to the row holding its
//! tail-dropped prefix, and a `suffix` link to the row holding its
//! head-dropped suffix — [`ROW_BYTES`] bytes, no per-episode allocation.
//! Full episodes are materialized only on demand by walking parent links.
//!
//! The dual links turn the suffix-prefix join into integer bucketing.
//! For same-size episodes `a`, `b` stored in the top block, the join
//! condition "a's last N-1 nodes equal b's first N-1 nodes (types *and*
//! gaps)" is exactly `suffix(a) == parent(b)`: both links point into the
//! previous block, blocks hold no duplicate episodes (by induction from
//! the duplicate-free singles), so row equality is episode equality.
//! Bucketing frontier rows by `parent` value and probing with `suffix`
//! values is a counting sort — O(F + output), no hashing, and the exact
//! output size is known *before* anything is emitted
//! ([`EpisodeArena::next_level_count`]), which is what lets the mining
//! loop fail fast on `max_candidates_per_level` during generation.
//!
//! Generation streams candidates in bounded [`CandidateChunk`] blocks
//! (the `candidate_block` knob) so peak memory for a level is O(block +
//! frequent) rather than O(candidates). Chunk emission order is exactly
//! the legacy generator's order: `a` in frontier order, matching `b` in
//! frontier order, interval innermost at level 2 — so results and
//! reports are byte-identical to the pre-arena engine.

use super::{Episode, Interval};
use crate::error::MineError;
use crate::events::{EventStream, EventType};

/// Flat storage cost of one stored candidate row: `last_type` (4) +
/// `last_iv` (2) + `parent` (4) + `suffix` (4) bytes.
pub const ROW_BYTES: usize = std::mem::size_of::<EventType>()
    + std::mem::size_of::<u16>()
    + 2 * std::mem::size_of::<u32>();

/// Link value used in the singles block, which has no previous level.
pub const NO_LINK: u32 = u32::MAX;

/// One lattice level: parallel columns, one row per stored episode.
/// Rows in `blocks[k]` are (k+1)-node episodes; `parent`/`suffix` index
/// `blocks[k-1]`.
#[derive(Clone, Debug, Default)]
pub struct LevelBlock {
    /// type of the episode's last node
    pub last_type: Vec<EventType>,
    /// interned id (into the arena's constraint set) of the last gap;
    /// 0 and meaningless in the singles block
    pub last_iv: Vec<u16>,
    /// row of the tail-dropped prefix in the previous block
    pub parent: Vec<u32>,
    /// row of the head-dropped suffix in the previous block
    pub suffix: Vec<u32>,
}

impl LevelBlock {
    pub fn len(&self) -> usize {
        self.last_type.len()
    }

    pub fn is_empty(&self) -> bool {
        self.last_type.is_empty()
    }

    pub fn push(&mut self, last_type: EventType, last_iv: u16, parent: u32, suffix: u32) {
        self.last_type.push(last_type);
        self.last_iv.push(last_iv);
        self.parent.push(parent);
        self.suffix.push(suffix);
    }

    /// Append every row of a generated chunk (the incremental miner
    /// stores full candidate blocks; the batch loop appends survivors
    /// row by row instead).
    pub fn extend_from_chunk(&mut self, chunk: &CandidateChunk) {
        self.last_type.extend_from_slice(&chunk.last_type);
        self.last_iv.extend_from_slice(&chunk.last_iv);
        self.parent.extend_from_slice(&chunk.parent);
        self.suffix.extend_from_slice(&chunk.suffix);
    }
}

/// A bounded block of generated candidates: SoA columns parallel by row,
/// `parent`/`suffix` indexing the arena's *top* block at generation time.
/// One buffer is reused across sink calls — copy out what must survive.
#[derive(Clone, Debug, Default)]
pub struct CandidateChunk {
    pub last_type: Vec<EventType>,
    pub last_iv: Vec<u16>,
    pub parent: Vec<u32>,
    pub suffix: Vec<u32>,
}

impl CandidateChunk {
    pub fn len(&self) -> usize {
        self.last_type.len()
    }

    pub fn is_empty(&self) -> bool {
        self.last_type.is_empty()
    }

    fn clear(&mut self) {
        self.last_type.clear();
        self.last_iv.clear();
        self.parent.clear();
        self.suffix.clear();
    }

    fn push(&mut self, last_type: EventType, last_iv: u16, parent: u32, suffix: u32) {
        self.last_type.push(last_type);
        self.last_iv.push(last_iv);
        self.parent.push(parent);
        self.suffix.push(suffix);
    }
}

/// The episode lattice: the run's interned interval constraint set plus
/// one [`LevelBlock`] per stored level. See the module docs for layout
/// and join semantics.
#[derive(Clone, Debug)]
pub struct EpisodeArena {
    intervals: Vec<Interval>,
    blocks: Vec<LevelBlock>,
}

impl EpisodeArena {
    /// New arena for one mining run. `i_set` is interned once; every
    /// stored gap is a `u16` id into it (an alphabet of interval
    /// constraints wider than `u16` is not a realistic configuration and
    /// is rejected by assertion).
    pub fn new(i_set: &[Interval]) -> EpisodeArena {
        assert!(
            i_set.len() <= u16::MAX as usize,
            "interval constraint set too large to intern ({} > {})",
            i_set.len(),
            u16::MAX
        );
        EpisodeArena { intervals: i_set.to_vec(), blocks: vec![] }
    }

    /// The interned constraint set, in id order.
    pub fn intervals(&self) -> &[Interval] {
        &self.intervals
    }

    /// Number of stored levels (episodes of size 1..=num_levels).
    pub fn num_levels(&self) -> usize {
        self.blocks.len()
    }

    pub fn block(&self, level_block: usize) -> &LevelBlock {
        &self.blocks[level_block]
    }

    pub fn block_len(&self, level_block: usize) -> usize {
        self.blocks.get(level_block).map_or(0, LevelBlock::len)
    }

    /// Install the singles block (must be the first block pushed). Order
    /// matters: every later level's emission order follows it.
    pub fn push_singles(&mut self, types: impl IntoIterator<Item = EventType>) {
        assert!(self.blocks.is_empty(), "singles must be the first block");
        let mut block = LevelBlock::default();
        for ty in types {
            block.push(ty, 0, NO_LINK, NO_LINK);
        }
        self.blocks.push(block);
    }

    /// Append the next level's block. Rows' `parent`/`suffix` must index
    /// the current top block (i.e. come from [`EpisodeArena::generate_next`]
    /// chunks emitted against it).
    pub fn push_block(&mut self, block: LevelBlock) {
        assert!(!self.blocks.is_empty(), "push_singles first");
        self.blocks.push(block);
    }

    /// Drop every block above `keep` levels (the incremental miner's
    /// cascade invalidation: refs into a rebuilt block are meaningless,
    /// so a regen at level L discards everything deeper).
    pub fn truncate_blocks(&mut self, keep: usize) {
        self.blocks.truncate(keep);
    }

    /// Exact number of candidates the next generation step will emit
    /// from `frontier` (rows of the top block) — O(frontier), computed
    /// before anything is materialized. Level 2 is the full cross
    /// `|frontier|² · |I|`; deeper levels sum the join buckets.
    pub fn next_level_count(&self, frontier: &[u32]) -> usize {
        let top = self.blocks.len().checked_sub(1).expect("push_singles first");
        if top == 0 {
            return frontier
                .len()
                .saturating_mul(frontier.len())
                .saturating_mul(self.intervals.len());
        }
        let blk = &self.blocks[top];
        let mut bucket_sizes = vec![0usize; self.blocks[top - 1].len()];
        for &b in frontier {
            bucket_sizes[blk.parent[b as usize] as usize] += 1;
        }
        frontier
            .iter()
            .map(|&a| bucket_sizes[blk.suffix[a as usize] as usize])
            .sum()
    }

    /// Stream the next level's candidates in chunks of at most
    /// `block_size` rows. `frontier` holds the frequent rows of the top
    /// block, in the order counting saw them; emitted `parent`/`suffix`
    /// links index that same block. Emission order matches the legacy
    /// generator exactly (see module docs). The chunk buffer is reused
    /// between sink calls.
    pub fn generate_next<F>(
        &self,
        frontier: &[u32],
        block_size: usize,
        mut sink: F,
    ) -> Result<(), MineError>
    where
        F: FnMut(&CandidateChunk) -> Result<(), MineError>,
    {
        let top = self.blocks.len().checked_sub(1).expect("push_singles first");
        let block_size = block_size.max(1);
        let mut chunk = CandidateChunk::default();
        let blk = &self.blocks[top];
        if top == 0 {
            // level 2: full cross product × interval set (legacy order:
            // a-major, then b, interval innermost)
            for &a in frontier {
                for &b in frontier {
                    for iv in 0..self.intervals.len() as u16 {
                        chunk.push(blk.last_type[b as usize], iv, a, b);
                        if chunk.len() >= block_size {
                            sink(&chunk)?;
                            chunk.clear();
                        }
                    }
                }
            }
        } else {
            // deeper levels: counting-sort frontier rows into buckets by
            // parent link, probe each row's suffix link. Within a bucket
            // rows keep frontier order, so emission order matches the
            // legacy quadratic join (a-major, b in frontier order).
            let domain = self.blocks[top - 1].len();
            let mut start = vec![0u32; domain + 1];
            for &b in frontier {
                start[blk.parent[b as usize] as usize + 1] += 1;
            }
            for i in 0..domain {
                start[i + 1] += start[i];
            }
            let mut bucketed = vec![0u32; frontier.len()];
            let mut cursor = start.clone();
            for &b in frontier {
                let p = blk.parent[b as usize] as usize;
                bucketed[cursor[p] as usize] = b;
                cursor[p] += 1;
            }
            for &a in frontier {
                let s = blk.suffix[a as usize] as usize;
                for &b in &bucketed[start[s] as usize..cursor[s] as usize] {
                    chunk.push(blk.last_type[b as usize], blk.last_iv[b as usize], a, b);
                    if chunk.len() >= block_size {
                        sink(&chunk)?;
                        chunk.clear();
                    }
                }
            }
        }
        if !chunk.is_empty() {
            sink(&chunk)?;
        }
        Ok(())
    }

    /// Materialize a stored row into a reusable scratch episode (types
    /// and gaps in episode order) by walking parent links.
    pub fn materialize_into(&self, level_block: usize, row: usize, ep: &mut Episode) {
        ep.types.clear();
        ep.intervals.clear();
        self.extend_with_chain(level_block, row, ep);
        ep.types.reverse();
        ep.intervals.reverse();
    }

    /// Materialize a stored row as an owned [`Episode`].
    pub fn episode(&self, level_block: usize, row: usize) -> Episode {
        let mut ep = Episode { types: vec![], intervals: vec![] };
        self.materialize_into(level_block, row, &mut ep);
        ep
    }

    /// Materialize row `i` of a chunk generated from the *current* top
    /// block (its links index that block — call before pushing the next
    /// level's block).
    pub fn materialize_chunk_row(&self, chunk: &CandidateChunk, i: usize, ep: &mut Episode) {
        ep.types.clear();
        ep.intervals.clear();
        ep.types.push(chunk.last_type[i]);
        ep.intervals.push(self.intervals[chunk.last_iv[i] as usize]);
        self.extend_with_chain(self.blocks.len() - 1, chunk.parent[i] as usize, ep);
        ep.types.reverse();
        ep.intervals.reverse();
    }

    /// Append the chain ending at (`level_block`, `row`) in *reverse*
    /// episode order; callers reverse once at the end.
    fn extend_with_chain(&self, level_block: usize, row: usize, ep: &mut Episode) {
        let mut b = level_block;
        let mut r = row;
        loop {
            let blk = &self.blocks[b];
            ep.types.push(blk.last_type[r]);
            if b == 0 {
                break;
            }
            ep.intervals.push(self.intervals[blk.last_iv[r] as usize]);
            r = blk.parent[r] as usize;
            b -= 1;
        }
    }
}

/// Frequency-sorted alphabet remapping: a bijective relabeling where
/// dense id = rank by descending level-1 count (ties broken by ascending
/// original id), so counting and pruning at levels ≥ 2 walk the densest
/// types in the smallest id range (cache-friendly, and the natural order
/// for device-side type tables). Relabeling never changes a count — only
/// type *equality* and event times matter to the automata — and reports
/// invert the map, so results are expressed in original ids end to end.
#[derive(Clone, Debug)]
pub struct AlphabetRemap {
    dense_of: Vec<EventType>,
    orig_of: Vec<EventType>,
}

impl AlphabetRemap {
    /// Build from per-type level-1 counts (index = original type id).
    pub fn from_counts(counts: &[u64]) -> AlphabetRemap {
        let mut order: Vec<usize> = (0..counts.len()).collect();
        order.sort_by(|&a, &b| counts[b].cmp(&counts[a]).then(a.cmp(&b)));
        let mut dense_of = vec![0; counts.len()];
        let orig_of: Vec<EventType> = order.iter().map(|&o| o as EventType).collect();
        for (dense, &orig) in order.iter().enumerate() {
            dense_of[orig] = dense as EventType;
        }
        AlphabetRemap { dense_of, orig_of }
    }

    /// The identity relabeling (used where remapping is disabled).
    pub fn identity(n_types: usize) -> AlphabetRemap {
        let ids: Vec<EventType> = (0..n_types as EventType).collect();
        AlphabetRemap { dense_of: ids.clone(), orig_of: ids }
    }

    pub fn n_types(&self) -> usize {
        self.dense_of.len()
    }

    /// original id → dense id
    pub fn dense(&self, orig: EventType) -> EventType {
        self.dense_of[orig as usize]
    }

    /// dense id → original id
    pub fn orig(&self, dense: EventType) -> EventType {
        self.orig_of[dense as usize]
    }

    /// A relabeled clone of the stream: same times, same alphabet size,
    /// every event type mapped to its dense id.
    pub fn apply(&self, stream: &EventStream) -> EventStream {
        let mut out = EventStream::new(stream.n_types);
        out.types = stream.types.iter().map(|&t| self.dense_of[t as usize]).collect();
        out.times = stream.times.clone();
        out
    }

    /// Rewrite a dense-id episode back into original ids (in place).
    pub fn invert_episode(&self, ep: &mut Episode) {
        for t in &mut ep.types {
            *t = self.orig_of[*t as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::candidates;

    fn ivs() -> Vec<Interval> {
        vec![Interval::new(0, 10), Interval::new(5, 20)]
    }

    /// Drive the arena and the legacy generator side by side for a few
    /// levels, pruning the same survivor subset at each level, and
    /// assert episode-for-episode (order included) equality.
    #[test]
    fn arena_generation_matches_legacy_level_by_level() {
        let i_set = ivs();
        let n_types = 5;
        let mut arena = EpisodeArena::new(&i_set);
        arena.push_singles(0..n_types as EventType);
        let mut legacy_frontier = candidates::level1(n_types);

        for level in 2..=5 {
            let legacy_cands = candidates::next_level(&legacy_frontier, &i_set);
            let top = arena.num_levels() - 1;
            let frontier: Vec<u32> = (0..arena.block_len(top) as u32).collect();
            assert_eq!(arena.next_level_count(&frontier), legacy_cands.len(), "level {level}");

            let mut got: Vec<Episode> = vec![];
            let mut block = LevelBlock::default();
            let mut scratch = Episode { types: vec![], intervals: vec![] };
            arena
                .generate_next(&frontier, 7, |chunk| {
                    for i in 0..chunk.len() {
                        arena.materialize_chunk_row(chunk, i, &mut scratch);
                        got.push(scratch.clone());
                    }
                    block.extend_from_chunk(chunk);
                    Ok(())
                })
                .unwrap();
            assert_eq!(got, legacy_cands, "level {level} candidates diverge");
            arena.push_block(block);

            // prune to every third candidate (same subset on both sides)
            let keep: Vec<usize> = (0..legacy_cands.len()).step_by(3).collect();
            legacy_frontier = keep.iter().map(|&i| legacy_cands[i].clone()).collect();
            let survivors: Vec<u32> = keep.iter().map(|&i| i as u32).collect();
            let new_top = arena.num_levels() - 1;
            let mut pruned = LevelBlock::default();
            let full = arena.block(new_top).clone();
            for &i in &survivors {
                let i = i as usize;
                pruned.push(full.last_type[i], full.last_iv[i], full.parent[i], full.suffix[i]);
            }
            // rebuild the top block as survivors only (batch-mode shape);
            // parent/suffix still index the block below, which is intact
            arena.truncate_blocks(new_top);
            arena.push_block(pruned);
            if legacy_frontier.is_empty() {
                break;
            }
        }
    }

    #[test]
    fn materialize_walks_links() {
        let i_set = ivs();
        let mut arena = EpisodeArena::new(&i_set);
        arena.push_singles([3, 7]);
        // 3 -(0,10]-> 7 stored as row 0 of block 1
        let mut b1 = LevelBlock::default();
        b1.push(7, 0, 0, 1);
        arena.push_block(b1);
        // (3 -(0,10]-> 7) -(5,20]-> 3
        let mut b2 = LevelBlock::default();
        b2.push(3, 1, 0, 0);
        arena.push_block(b2);
        assert_eq!(arena.episode(0, 1), Episode::single(7));
        assert_eq!(
            arena.episode(2, 0),
            Episode::new(vec![3, 7, 3], vec![Interval::new(0, 10), Interval::new(5, 20)])
        );
    }

    #[test]
    fn remap_sorts_densest_first_and_inverts() {
        let remap = AlphabetRemap::from_counts(&[5, 40, 40, 2]);
        // counts sort 1,2 (40) ahead of 0 (5) ahead of 3 (2); ties by id
        assert_eq!(remap.dense(1), 0);
        assert_eq!(remap.dense(2), 1);
        assert_eq!(remap.dense(0), 2);
        assert_eq!(remap.dense(3), 3);
        for ty in 0..4 {
            assert_eq!(remap.orig(remap.dense(ty)), ty);
        }
        let stream = EventStream::from_pairs(vec![(0, 1), (1, 2), (3, 5)], 4);
        let dense = remap.apply(&stream);
        assert_eq!(dense.types, vec![2, 0, 3]);
        assert_eq!(dense.times, stream.times);
        assert_eq!(dense.n_types, 4);
        let ep = Episode::new(vec![0, 1], vec![Interval::new(0, 5)]);
        let mut dense_ep = ep.clone();
        dense_ep.types = vec![remap.dense(0), remap.dense(1)];
        remap.invert_episode(&mut dense_ep);
        assert_eq!(dense_ep, ep);
    }

    #[test]
    fn row_bytes_is_fourteen() {
        assert_eq!(ROW_BYTES, 14);
    }
}
