//! Episode substrate: serial episodes with inter-event constraints
//! (paper Def. 2.2 / Problem 1), level-wise candidate generation, and the
//! flat SoA candidate arena ([`arena`]) the mining loop generates into.

pub mod arena;
pub mod candidates;

use crate::events::{EventType, Tick};

/// An inter-event constraint interval `(t_low, t_high]` (paper §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Interval {
    pub t_low: Tick,
    pub t_high: Tick,
}

impl Interval {
    pub fn new(t_low: Tick, t_high: Tick) -> Interval {
        assert!(0 <= t_low && t_low < t_high, "need 0 <= t_low < t_high");
        Interval { t_low, t_high }
    }

    /// Does a delay `d` satisfy `(t_low, t_high]`?
    #[inline]
    pub fn admits(&self, d: Tick) -> bool {
        self.t_low < d && d <= self.t_high
    }

    /// The relaxed counterpart used by A2 (lower bound dropped; see the
    /// kernel docs for why the relaxation is effectively `[0, t_high]`).
    pub fn relaxed(&self) -> Interval {
        Interval { t_low: 0, t_high: self.t_high }
    }
}

/// A serial episode with inter-event constraints:
/// `E(1) -(I1]-> E(2) ... -(I(N-1)]-> E(N)`.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Episode {
    pub types: Vec<EventType>,
    pub intervals: Vec<Interval>,
}

impl Episode {
    pub fn new(types: Vec<EventType>, intervals: Vec<Interval>) -> Episode {
        assert!(!types.is_empty());
        assert_eq!(intervals.len(), types.len() - 1, "need N-1 intervals");
        Episode { types, intervals }
    }

    /// 1-node episode (no constraints).
    pub fn single(e: EventType) -> Episode {
        Episode { types: vec![e], intervals: vec![] }
    }

    /// Episode size N (number of nodes / levels).
    pub fn n(&self) -> usize {
        self.types.len()
    }

    pub fn tlow(&self) -> Vec<Tick> {
        self.intervals.iter().map(|i| i.t_low).collect()
    }

    pub fn thigh(&self) -> Vec<Tick> {
        self.intervals.iter().map(|i| i.t_high).collect()
    }

    /// Sum of upper bounds: the maximum time an occurrence can span, and
    /// the straddle window of MapConcatenate boundary machines.
    pub fn span_max(&self) -> Tick {
        self.intervals.iter().map(|i| i.t_high).sum()
    }

    /// Human-readable form, e.g. `3 -(5,15]-> 7 -(5,15]-> 1`.
    pub fn display(&self) -> String {
        let mut s = String::new();
        for (i, &e) in self.types.iter().enumerate() {
            if i > 0 {
                let iv = &self.intervals[i - 1];
                s.push_str(&format!(" -({},{}]-> ", iv.t_low, iv.t_high));
            }
            s.push_str(&e.to_string());
        }
        s
    }
}

/// An episode with its mined support count.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CountedEpisode {
    pub episode: Episode,
    pub count: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_semantics() {
        let iv = Interval::new(5, 15);
        assert!(!iv.admits(5)); // strict lower
        assert!(iv.admits(6));
        assert!(iv.admits(15)); // inclusive upper
        assert!(!iv.admits(16));
        assert_eq!(iv.relaxed(), Interval { t_low: 0, t_high: 15 });
    }

    #[test]
    #[should_panic]
    fn degenerate_interval_rejected() {
        Interval::new(5, 5);
    }

    #[test]
    fn episode_shape() {
        let ep = Episode::new(
            vec![0, 1, 2],
            vec![Interval::new(5, 15), Interval::new(0, 10)],
        );
        assert_eq!(ep.n(), 3);
        assert_eq!(ep.span_max(), 25);
        assert_eq!(ep.tlow(), vec![5, 0]);
        assert_eq!(ep.thigh(), vec![15, 10]);
        assert_eq!(ep.display(), "0 -(5,15]-> 1 -(0,10]-> 2");
    }

    #[test]
    #[should_panic]
    fn wrong_interval_arity_rejected() {
        Episode::new(vec![0, 1, 2], vec![Interval::new(0, 5)]);
    }
}
