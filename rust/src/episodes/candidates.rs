//! Level-wise (Apriori-style) candidate generation (paper §5, first phase).
//!
//! The paper's mining loop alternates candidate generation (on the CPU —
//! this module) with counting (on the accelerator). Size-(N+1) candidates
//! are generated from frequent size-N episodes with the standard
//! suffix-prefix join: α joins β when α's last N-1 (type, interval) pairs
//! equal β's first N-1 pairs; the candidate is α extended by β's last node.
//! Every gap's interval is drawn from the run's constraint set `I`
//! (paper Problem 1); |I| = 1 in all of the paper's experiments.

use std::collections::HashMap;

use super::{Episode, Interval};
use crate::error::MineError;
use crate::events::EventType;

/// Level-1 candidates: one single-node episode per event type.
pub fn level1(n_types: usize) -> Vec<Episode> {
    (0..n_types as EventType).map(Episode::single).collect()
}

/// Level-2 candidates: all ordered pairs of frequent 1-episodes × all
/// intervals in `i_set` (self-pairs included: A->A episodes are valid).
pub fn level2(frequent1: &[Episode], i_set: &[Interval]) -> Vec<Episode> {
    let mut out = vec![];
    for a in frequent1 {
        for b in frequent1 {
            for &iv in i_set {
                out.push(Episode::new(vec![a.types[0], b.types[0]], vec![iv]));
            }
        }
    }
    out
}

/// Size N -> N+1 suffix-prefix join over frequent size-N episodes.
///
/// Only candidates whose every size-N sub-episode (obtained by dropping
/// the first or last node) is frequent are kept — the anti-monotonicity
/// prune. (Dropping interior nodes does not yield a sub-episode under
/// inter-event constraints, so only the two end prunes apply.)
pub fn join(frequent: &[Episode]) -> Vec<Episode> {
    if frequent.is_empty() {
        return vec![];
    }
    let n = frequent[0].n();
    debug_assert!(frequent.iter().all(|e| e.n() == n));
    // The prune set only backs the debug_assert below; release builds
    // must not pay an O(F) hash-set build per level for it.
    #[cfg(debug_assertions)]
    let set: std::collections::HashSet<(&[EventType], &[Interval])> =
        frequent.iter().map(|e| (e.types.as_slice(), e.intervals.as_slice())).collect();
    let mut out = vec![];
    for a in frequent {
        for b in frequent {
            if a.types[1..] == b.types[..n - 1] && a.intervals[1..] == b.intervals[..n - 2] {
                // suffix of a == prefix of b (types and intervals)
                let mut types = a.types.clone();
                types.push(b.types[n - 1]);
                let mut intervals = a.intervals.clone();
                intervals.push(*b.intervals.last().unwrap());
                // anti-monotone prune: the head-dropped sub-episode is b,
                // the tail-dropped one is a — both frequent by construction.
                // (kept explicit for clarity with |I| > 1 interval sets)
                #[cfg(debug_assertions)]
                debug_assert!(set.contains(&(b.types.as_slice(), b.intervals.as_slice())));
                out.push(Episode::new(types, intervals));
            }
        }
    }
    out
}

/// Generate the next level's candidates from this level's frequent set.
pub fn next_level(frequent: &[Episode], i_set: &[Interval]) -> Vec<Episode> {
    if frequent.is_empty() {
        return vec![];
    }
    if frequent[0].n() == 1 {
        level2(frequent, i_set)
    } else {
        join(frequent)
    }
}

/// [`level2`] with the candidate-cap guardrail enforced *before*
/// materialization: the full cross is exactly `|F1|² · |I|` candidates, so
/// a too-low theta on a wide alphabet fails fast with the typed
/// [`MineError::CandidateExplosion`] instead of OOMing first.
pub fn level2_capped(
    frequent1: &[Episode],
    i_set: &[Interval],
    cap: usize,
) -> Result<Vec<Episode>, MineError> {
    let candidates = frequent1
        .len()
        .saturating_mul(frequent1.len())
        .saturating_mul(i_set.len());
    if candidates > cap {
        return Err(MineError::CandidateExplosion { level: 2, candidates, cap });
    }
    Ok(level2(frequent1, i_set))
}

/// Bucketed suffix-prefix join with the candidate cap enforced before
/// materialization. Frequent episodes are hashed by their (N-1)-node
/// prefix key; each episode's suffix key probes the bucket map, so the
/// exact output size is the sum of probed bucket sizes — known in
/// O(F) before a single candidate `Vec` is allocated. Generation then
/// walks the same buckets, emitting exactly [`join`]'s candidates in
/// exactly [`join`]'s order (a in input order, matching b in input
/// order) in O(F + output) instead of O(F²).
pub fn join_capped(frequent: &[Episode], cap: usize) -> Result<Vec<Episode>, MineError> {
    if frequent.is_empty() {
        return Ok(vec![]);
    }
    let n = frequent[0].n();
    debug_assert!(frequent.iter().all(|e| e.n() == n));
    let mut buckets: HashMap<(&[EventType], &[Interval]), Vec<u32>> = HashMap::new();
    for (bi, b) in frequent.iter().enumerate() {
        buckets
            .entry((&b.types[..n - 1], &b.intervals[..n - 2]))
            .or_default()
            .push(bi as u32);
    }
    let mut candidates = 0usize;
    for a in frequent {
        if let Some(bs) = buckets.get(&(&a.types[1..], &a.intervals[1..])) {
            candidates += bs.len();
        }
    }
    if candidates > cap {
        return Err(MineError::CandidateExplosion { level: n + 1, candidates, cap });
    }
    let mut out = Vec::with_capacity(candidates);
    for a in frequent {
        if let Some(bs) = buckets.get(&(&a.types[1..], &a.intervals[1..])) {
            for &bi in bs {
                let b = &frequent[bi as usize];
                let mut types = a.types.clone();
                types.push(b.types[n - 1]);
                let mut intervals = a.intervals.clone();
                intervals.push(*b.intervals.last().unwrap());
                out.push(Episode::new(types, intervals));
            }
        }
    }
    Ok(out)
}

/// [`next_level`] with the candidate cap enforced inside generation:
/// same episodes in the same order, but the typed
/// [`MineError::CandidateExplosion`] (with the exact would-be candidate
/// count) is returned *before* the output is materialized.
pub fn next_level_capped(
    frequent: &[Episode],
    i_set: &[Interval],
    cap: usize,
) -> Result<Vec<Episode>, MineError> {
    if frequent.is_empty() {
        return Ok(vec![]);
    }
    if frequent[0].n() == 1 {
        level2_capped(frequent, i_set, cap)
    } else {
        join_capped(frequent, cap)
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    use super::*;

    fn iv() -> Interval {
        Interval::new(0, 10)
    }

    #[test]
    fn level1_covers_alphabet() {
        let l1 = level1(3);
        assert_eq!(l1.len(), 3);
        assert_eq!(l1[2].types, vec![2]);
    }

    #[test]
    fn level2_is_full_cross() {
        let l1 = level1(3);
        let l2 = level2(&l1, &[iv()]);
        assert_eq!(l2.len(), 9); // self-pairs included
        let l2b = level2(&l1, &[iv(), Interval::new(5, 20)]);
        assert_eq!(l2b.len(), 18);
    }

    #[test]
    fn join_requires_suffix_prefix_match() {
        // frequent 2-episodes: 0->1, 1->2, 1->0
        let f = vec![
            Episode::new(vec![0, 1], vec![iv()]),
            Episode::new(vec![1, 2], vec![iv()]),
            Episode::new(vec![1, 0], vec![iv()]),
        ];
        let c = join(&f);
        let got: HashSet<Vec<i32>> = c.iter().map(|e| e.types.clone()).collect();
        // 0->1 joins 1->2 and 1->0; 1->0 joins 0->1
        let want: HashSet<Vec<i32>> =
            [vec![0, 1, 2], vec![0, 1, 0], vec![1, 0, 1]].into_iter().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn join_respects_interval_identity() {
        let a = Episode::new(vec![0, 1], vec![Interval::new(0, 10)]);
        let b = Episode::new(vec![1, 2], vec![Interval::new(5, 20)]);
        // join is allowed regardless of differing gap intervals — only the
        // *shared* (suffix/prefix) gaps must agree, and for size 2 there is
        // no shared gap.
        let c = join(&[a, b]);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].types, vec![0, 1, 2]);
        assert_eq!(c[0].intervals, vec![Interval::new(0, 10), Interval::new(5, 20)]);
    }

    #[test]
    fn capped_level2_reports_exact_size_before_materializing() {
        let l1 = level1(3);
        let ivs = [iv(), Interval::new(5, 20)];
        match level2_capped(&l1, &ivs, 17) {
            Err(MineError::CandidateExplosion { level, candidates, cap }) => {
                assert_eq!((level, candidates, cap), (2, 18, 17));
            }
            other => panic!("expected explosion, got {other:?}"),
        }
        assert_eq!(level2_capped(&l1, &ivs, 18).unwrap(), level2(&l1, &ivs));
    }

    #[test]
    fn bucketed_join_matches_quadratic_join_exactly() {
        // a mixed frequent set (some pairs missing, two interval choices)
        // must join identically — content *and* order
        let i1 = Interval::new(0, 10);
        let i2 = Interval::new(5, 20);
        let mut f = vec![];
        for a in 0..4 {
            for b in 0..4 {
                for &g in &[i1, i2] {
                    if (a + 2 * b + g.t_low) % 3 != 0 {
                        f.push(Episode::new(vec![a, b], vec![g]));
                    }
                }
            }
        }
        let legacy = join(&f);
        assert!(!legacy.is_empty());
        let bucketed = join_capped(&f, usize::MAX).unwrap();
        assert_eq!(bucketed, legacy);
        // the cap fires with the exact would-be size, before generation
        let err = join_capped(&f, legacy.len() - 1).unwrap_err();
        match err {
            MineError::CandidateExplosion { level, candidates, cap } => {
                assert_eq!((level, candidates, cap), (3, legacy.len(), legacy.len() - 1));
            }
            other => panic!("expected explosion, got {other:?}"),
        }
    }

    #[test]
    fn join_three_node_shares_middle_gap() {
        let i1 = Interval::new(0, 10);
        let i2 = Interval::new(5, 20);
        let a = Episode::new(vec![0, 1, 2], vec![i1, i2]);
        let b_match = Episode::new(vec![1, 2, 3], vec![i2, i1]);
        let b_clash = Episode::new(vec![1, 2, 3], vec![i1, i1]);
        let c = join(&[a.clone(), b_match, b_clash]);
        // only b_match's prefix interval (i2) equals a's suffix interval
        let with_a_prefix: Vec<_> =
            c.iter().filter(|e| e.types == vec![0, 1, 2, 3]).collect();
        assert_eq!(with_a_prefix.len(), 1);
        assert_eq!(with_a_prefix[0].intervals, vec![i1, i2, i1]);
    }
}
