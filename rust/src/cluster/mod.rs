//! Distributed mining: scatter-gather over log segments (ROADMAP item 3).
//!
//! The ingest log is the sharding unit the paper's pipeline was always
//! pointing at: time-bounded, checksummed, individually-readable
//! segments. This layer fans a `log:` range query out across mining
//! nodes and merges the answers **byte-identical** to a single-process
//! mine — the MapConcatenate stitch (paper §5.2.2), generalized across
//! machines instead of GPU segments, with the same flagged-miss +
//! recount exactness contract the in-process engines pin.
//!
//! The pieces, coordinator-side to node-side:
//!
//! - [`scatter`] — the coordinator ([`ScatterMiner`]): runs the exact
//!   level-wise driver locally and distributes only the counting
//!   (per-window `MapCount`/`RelaxedCount` RPCs with `span_max` halos),
//!   with deadlines, bounded retry onto surviving nodes, hedged
//!   duplicates for stragglers, and per-node latency metrics. Includes
//!   the in-process [`LocalCluster`] harness (threads as nodes,
//!   injectable drop/delay/corrupt/die faults) so tests and benches run
//!   the full codec path without sockets.
//! - [`node`] — the worker ([`ClusterNode`], `epminer node`): a
//!   [`SpikeLog`](crate::ingest::SpikeLog) replica plus an embedded
//!   [`MineService`](crate::serve::MineService), answering requests only
//!   after verifying the coordinator's content fingerprint against its
//!   own log.
//! - [`proto`] — the length-prefixed JSON wire protocol: versioned
//!   envelopes, typed [`MineError`](crate::error::MineError) round-trip,
//!   hostile-input-safe decoding.
//! - [`admission`] — tenant-aware coordinator admission: per-tenant
//!   in-flight quotas, priority-then-arrival granting, bounded queueing
//!   that sheds into typed `Busy`.

pub mod admission;
pub mod node;
pub mod proto;
pub mod scatter;

pub use admission::{AdmissionConfig, AdmissionController, TenantQuota};
pub use node::{ClusterNode, NodeState};
pub use scatter::{
    ClusterMetrics, ClusterNodeMetrics, Fault, LocalCluster, NodeLink, ScatterConfig,
    ScatterMiner, TcpLink,
};
