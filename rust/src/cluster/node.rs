//! The cluster worker: a mining node serving its local log copy.
//!
//! A node owns exactly three things — an opened [`SpikeLog`], one
//! cached full read of it, and an embedded [`MineService`] — and
//! answers the six [`Request`](super::proto::Request) shapes. The
//! request dispatcher ([`NodeState::handle_frame`]) is transport-free:
//! the TCP accept loop ([`ClusterNode`]) and the in-process
//! `LocalCluster` test harness both feed it raw frame bytes, so fault
//! injection in tests exercises the *same* codec and dispatch path
//! production traffic takes.
//!
//! # Exactness obligations
//!
//! The scatter coordinator's merge is only byte-identical to a
//! single-process mine if every node counts exactly what the
//! coordinator planned:
//!
//! - **Fingerprint check** — every counting request names the windowed
//!   stream it was planned against
//!   ([`proto::range_fingerprint`](super::proto::range_fingerprint));
//!   the node recomputes the fingerprint from its own log and refuses
//!   a mismatch with [`MineError::Corrupt`]. A node holding a stale or
//!   diverged log replica fails the sub-mine rather than merging wrong
//!   counts. Verified fingerprints are cached per window, so the
//!   O(events) check is paid once per (range, log-state), not per RPC.
//! - **Clamped halos** — a `MapCount` for shard `(lo, hi]` scans
//!   `(lo - halo, hi + halo]` *clamped to the query range*
//!   `(t_from, t_to]`. The coordinator's reference stream is
//!   range-windowed, so an unclamped halo would let a node see (and
//!   count into boundary machines) events outside the query range that
//!   the single-process mine never sees.
//! - **Untrusted input** — episodes are alphabet-checked against the
//!   node's log before counting (`mapcat_map` would panic, and
//!   per-type tables would index out of bounds, on a hostile frame).

use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::{EventStream, Tick};
use crate::ingest::SpikeLog;
use crate::mining::serial;
use crate::obs::Trace;
use crate::serve::{MineService, Query, ServiceConfig};
use crate::util::json::Json;

use super::proto::{self, Request, Response, PROTO_VERSION};

/// One worker's state: log + cached stream + embedded service.
pub struct NodeState {
    service: MineService,
    inner: Mutex<NodeInner>,
}

struct NodeInner {
    log: SpikeLog,
    /// one full read of the log, shared by every counting request
    stream: Arc<EventStream>,
    /// windows whose [`range_fingerprint`](proto::range_fingerprint)
    /// this log state has already been checked against
    fingerprints: std::collections::HashMap<(Tick, Tick), u64>,
}

impl NodeState {
    /// Open `log_dir` and start the embedded service.
    pub fn open(log_dir: &Path, service: ServiceConfig) -> Result<NodeState, MineError> {
        let log = SpikeLog::open(log_dir)?;
        let (stream, _) = log.read_all()?;
        let service = MineService::start(service)?;
        Ok(NodeState {
            service,
            inner: Mutex::new(NodeInner {
                log,
                stream: Arc::new(stream),
                fingerprints: std::collections::HashMap::new(),
            }),
        })
    }

    /// Pick up segments sealed since open (or the last refresh);
    /// returns how many arrived. New data invalidates the cached
    /// stream and every verified fingerprint.
    pub fn refresh(&self) -> Result<usize, MineError> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let fresh = inner.log.refresh()?;
        if fresh > 0 {
            let (stream, _) = inner.log.read_all()?;
            inner.stream = Arc::new(stream);
            inner.fingerprints.clear();
        }
        Ok(fresh)
    }

    /// The embedded service (metrics, subscriptions).
    pub fn service(&self) -> &MineService {
        &self.service
    }

    /// Verify `fingerprint` names this log's `(t_from, t_to]` window,
    /// returning the cached full stream on success.
    fn checked_stream(
        &self,
        fingerprint: u64,
        t_from: Tick,
        t_to: Tick,
    ) -> Result<Arc<EventStream>, MineError> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let local = match inner.fingerprints.get(&(t_from, t_to)) {
            Some(&fp) => fp,
            None => {
                let fp = proto::range_fingerprint(&inner.stream, t_from, t_to);
                inner.fingerprints.insert((t_from, t_to), fp);
                fp
            }
        };
        if local != fingerprint {
            return Err(MineError::corrupt(
                inner.log.dir().display().to_string(),
                format!(
                    "log window ({t_from},{t_to}] fingerprint {local:016x} does not match \
                     the coordinator's {fingerprint:016x} — node replica diverged?"
                ),
            ));
        }
        Ok(Arc::clone(&inner.stream))
    }

    fn validate_episodes(
        episodes: &[Episode],
        n_types: usize,
        min_n: usize,
    ) -> Result<(), MineError> {
        for ep in episodes {
            if ep.n() < min_n {
                return Err(MineError::invalid(format!(
                    "request episode has {} node(s); this RPC needs >= {min_n}",
                    ep.n()
                )));
            }
            if let Some(&ty) =
                ep.types.iter().find(|&&t| t < 0 || t as usize >= n_types)
            {
                return Err(MineError::OutOfAlphabet { type_id: ty, n_types });
            }
        }
        Ok(())
    }

    /// Execute one request. Pure dispatch — no transport, no framing.
    pub fn handle_request(&self, req: Request) -> Result<Response, MineError> {
        self.handle_request_traced(req, &Trace::off())
    }

    /// [`handle_request`](NodeState::handle_request) with span recording:
    /// a request that arrived carrying a trace context gets a root span
    /// per request shape, with the fingerprint check and the counting
    /// work as children. The recorded spans ride back on the reply
    /// envelope for the coordinator to graft into its own tree.
    pub fn handle_request_traced(
        &self,
        req: Request,
        trace: &Trace,
    ) -> Result<Response, MineError> {
        match req {
            Request::Ping => Ok(Response::Pong { version: PROTO_VERSION }),
            Request::Metrics => {
                let metrics = Json::parse(&self.service.metrics().to_json())?;
                Ok(Response::Metrics { metrics })
            }
            Request::Stats => {
                // metrics() refreshes the derived gauges (queue depth,
                // cache occupancy) into the registry before snapshotting
                let _ = self.service.metrics();
                Ok(Response::Stats { snapshot: self.service.registry().snapshot().to_json() })
            }
            Request::Mine { fingerprint, options, two_pass, t_from, t_to } => {
                let root = trace.span("node.mine");
                let full = {
                    let _fp = root.child("fingerprint");
                    self.checked_stream(fingerprint, t_from, t_to)?
                };
                let mut query = Query::new(
                    Arc::new(full.window(t_from, t_to)),
                    options.theta,
                    options.intervals,
                );
                query.max_level = options.max_level;
                query.max_candidates_per_level = options.max_candidates_per_level;
                query.two_pass = two_pass;
                let result = {
                    let _mine = root.child("service mine");
                    self.service.submit(query)?.wait()?
                };
                Ok(Response::Mine { result: (*result).clone() })
            }
            Request::MapCount { fingerprint, episodes, t_from, t_to, lo, hi, halo, k } => {
                let root = trace.span("node.map_count");
                let full = {
                    let _fp = root.child("fingerprint");
                    self.checked_stream(fingerprint, t_from, t_to)?
                };
                Self::validate_episodes(&episodes, full.n_types, 2)?;
                if !(t_from <= lo && lo < hi && hi <= t_to) || halo < 0 || k == 0 {
                    return Err(MineError::invalid(format!(
                        "MapCount window ({lo},{hi}] halo {halo} k {k} is not inside \
                         the query range ({t_from},{t_to}]"
                    )));
                }
                // halo clamped to the query range: the single-process
                // reference never sees events outside (t_from, t_to]
                let sub = full
                    .window(lo.saturating_sub(halo).max(t_from), hi.saturating_add(halo).min(t_to));
                let _count =
                    root.child_fmt(|| format!("map {} episode(s)", episodes.len()));
                let machines = episodes
                    .iter()
                    .map(|ep| serial::mapcat_map(ep, &sub, &[lo, hi], k).swap_remove(0))
                    .collect();
                Ok(Response::MapCount { machines })
            }
            Request::RelaxedCount { fingerprint, episodes, t_from, t_to } => {
                let root = trace.span("node.relaxed_count");
                let full = {
                    let _fp = root.child("fingerprint");
                    self.checked_stream(fingerprint, t_from, t_to)?
                };
                Self::validate_episodes(&episodes, full.n_types, 1)?;
                let sub = full.window(t_from, t_to);
                let _count =
                    root.child_fmt(|| format!("a2 count {} episode(s)", episodes.len()));
                let counts =
                    episodes.iter().map(|ep| serial::count_a2(ep, &sub)).collect();
                Ok(Response::RelaxedCount { counts })
            }
        }
    }

    /// Decode one frame, execute it, encode the reply. Never fails:
    /// codec errors become typed `err` envelopes (correlation id 0,
    /// since a frame that would not decode has no trustworthy id).
    /// A frame carrying a trace context gets its node-side spans
    /// recorded and attached to the reply envelope.
    pub fn handle_frame(&self, bytes: &[u8]) -> Vec<u8> {
        match proto::decode_request_traced(bytes) {
            Ok((id, req, trace_id)) => {
                let trace = match trace_id {
                    Some(tid) => Trace::with_id(tid),
                    None => Trace::off(),
                };
                let outcome = self.handle_request_traced(req, &trace);
                proto::encode_response_traced(id, &outcome, &trace.snapshot())
            }
            Err(e) => proto::encode_response(0, &Err(e)),
        }
    }
}

/// The TCP face of a node: `epminer node --listen <addr> --log <dir>`.
///
/// One thread per connection (coordinators hold few, long-lived
/// connections; an accept storm is not this system's threat model),
/// frames handled strictly in order per connection.
pub struct ClusterNode {
    state: Arc<NodeState>,
    listener: TcpListener,
}

impl ClusterNode {
    /// Bind `addr` and open the node state (log + service).
    pub fn bind<A: ToSocketAddrs + std::fmt::Display>(
        addr: A,
        log_dir: &Path,
        service: ServiceConfig,
    ) -> Result<ClusterNode, MineError> {
        let listener = TcpListener::bind(&addr)
            .map_err(|e| MineError::io(format!("bind {addr}"), e))?;
        let state = Arc::new(NodeState::open(log_dir, service)?);
        Ok(ClusterNode { state, listener })
    }

    /// The bound address (useful after binding port 0).
    pub fn local_addr(&self) -> Result<SocketAddr, MineError> {
        self.listener.local_addr().map_err(|e| MineError::io("local_addr", e))
    }

    /// Shared node state (tests poke metrics through it).
    pub fn state(&self) -> &Arc<NodeState> {
        &self.state
    }

    fn serve_connection(state: &NodeState, stream: &mut TcpStream) {
        loop {
            match proto::read_frame(stream) {
                Ok(Some(bytes)) => {
                    let reply = state.handle_frame(&bytes);
                    if proto::write_frame(stream, &reply).is_err() {
                        return; // peer gone; nothing to tell it
                    }
                }
                Ok(None) => return, // clean close
                Err(e) => {
                    // a best-effort typed reply, then hang up: the
                    // stream's framing can no longer be trusted
                    let _ = proto::write_frame(stream, &proto::encode_response(0, &Err(e)));
                    return;
                }
            }
        }
    }

    /// Accept loop, one handler thread per connection. Runs until the
    /// process exits (the CLI entry point).
    pub fn run(self) -> Result<(), MineError> {
        for conn in self.listener.incoming() {
            let mut conn = match conn {
                Ok(c) => c,
                Err(e) => return Err(MineError::io("accept", e)),
            };
            let state = Arc::clone(&self.state);
            std::thread::spawn(move || {
                let _ = conn.set_nodelay(true);
                ClusterNode::serve_connection(&state, &mut conn);
            });
        }
        Ok(())
    }

    /// Run the accept loop on a background thread, returning the bound
    /// address and the node state. The thread is detached — it lives
    /// until the process exits (tests bind port 0 on loopback).
    pub fn spawn(self) -> Result<(SocketAddr, Arc<NodeState>), MineError> {
        let addr = self.local_addr()?;
        let state = Arc::clone(&self.state);
        std::thread::spawn(move || {
            let _ = self.run();
        });
        Ok((addr, state))
    }
}
