//! The cluster wire protocol: length-prefixed JSON frames.
//!
//! Scatter-gather needs so little vocabulary that a hand-rolled codec
//! over [`util::json`](crate::util::json) beats pulling in a
//! serialization framework (the offline crate set has none anyway):
//! five request shapes, five response shapes, and a typed
//! [`MineError`] round-trip so a node failure surfaces on the
//! coordinator as the *same* error variant a local mine would raise.
//!
//! # Framing
//!
//! ```text
//!   +----------------+---------------------------------------+
//!   | len: u32 (LE)  | payload: `len` bytes of UTF-8 JSON    |
//!   +----------------+---------------------------------------+
//! ```
//!
//! Every payload is an envelope object
//! `{"v": 1, "id": N, "req" | "ok" | "err": ...}`:
//!
//! - `v` — [`PROTO_VERSION`]. A peer speaking another version is
//!   rejected with a typed [`MineError::InvalidConfig`] *before* the
//!   body is interpreted, so rolling upgrades fail loudly instead of
//!   mis-parsing.
//! - `id` — a caller-chosen correlation id echoed verbatim in the
//!   response, letting a client detect a stale or crossed reply on a
//!   reused connection.
//! - `req` / `ok` / `err` — exactly one of: a [`Request`], a
//!   successful [`Response`], or an encoded [`MineError`].
//! - `trace` (optional, requests) — a propagated
//!   [`TraceId`](crate::obs::TraceId) in hex; `spans` (optional, `ok`
//!   replies) — the node's recorded [`SpanRecord`]s for that trace.
//!   Both keys are additive: decoders ignore unknown envelope keys, so
//!   a v1 peer without tracing interoperates unchanged.
//!
//! Frames larger than [`MAX_FRAME`] are refused on both sides: a
//! corrupt length prefix must not convince a node to allocate
//! gigabytes. Truncated frames (connection died mid-payload) decode to
//! [`MineError::Corrupt`], distinct from clean end-of-stream
//! (`Ok(None)` from [`read_frame`]) — the failover path retries the
//! former and treats the latter as a closed peer.
//!
//! # Integrity fingerprints
//!
//! Counting requests carry a fingerprint of the *windowed* stream the
//! coordinator planned against (the [`QueryKey`] mix over exact stream
//! contents, with the semantic parameters pinned — see
//! [`range_fingerprint`]). A node recomputes the fingerprint from its
//! own log before counting and rejects a mismatch with
//! [`MineError::Corrupt`]: a node replaying a stale or divergent log
//! copy must fail the sub-mine, never silently merge wrong counts.
//!
//! [`QueryKey`]: crate::serve::QueryKey

use std::io::{Read, Write};
use std::sync::Arc;

use crate::coordinator::miner::{LevelReport, MineResult};
use crate::coordinator::Strategy;
use crate::datasets;
use crate::episodes::{CountedEpisode, Episode, Interval};
use crate::error::MineError;
use crate::events::{EventStream, EventType, Tick};
use crate::obs::trace::{spans_from_json, spans_to_json, SpanRecord, TraceId};
use crate::obs::MineProfile;
use crate::serve::Query;
use crate::session::MineOptions;
use crate::util::json::Json;

/// Wire protocol version; bumped on any incompatible frame change.
pub const PROTO_VERSION: u32 = 1;

/// Hard cap on a single frame's payload. Sized for the worst shipped
/// case — a [`DEFAULT_CANDIDATE_BLOCK`](crate::session::DEFAULT_CANDIDATE_BLOCK)
/// of 65,536 episodes at a few dozen JSON bytes each is single-digit
/// megabytes — with an order of magnitude of headroom.
pub const MAX_FRAME: usize = 64 * 1024 * 1024;

/// The placeholder used to satisfy [`MineError::Corrupt`]'s `path`
/// field for failures that live on the wire, not on disk.
pub const WIRE: &str = "<wire>";

// ---------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<(), MineError> {
    if payload.len() > MAX_FRAME {
        return Err(MineError::internal(format!(
            "refusing to send a {}-byte frame (MAX_FRAME is {MAX_FRAME})",
            payload.len()
        )));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())
        .map_err(|e| MineError::io("write frame length", e))?;
    w.write_all(payload).map_err(|e| MineError::io("write frame payload", e))?;
    w.flush().map_err(|e| MineError::io("flush frame", e))
}

/// Read one frame. `Ok(None)` is a clean close *between* frames (the
/// peer hung up with nothing buffered); a close mid-frame is
/// [`MineError::Corrupt`] so callers can tell "done" from "died".
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>, MineError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(MineError::corrupt(
                    WIRE,
                    format!("truncated frame: peer closed after {got} of 4 length bytes"),
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(MineError::io("read frame length", e)),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(MineError::corrupt(
            WIRE,
            format!("frame length {len} exceeds MAX_FRAME {MAX_FRAME}"),
        ));
    }
    let mut payload = vec![0u8; len];
    if let Err(e) = r.read_exact(&mut payload) {
        return Err(match e.kind() {
            std::io::ErrorKind::UnexpectedEof => MineError::corrupt(
                WIRE,
                format!("truncated frame: peer closed before {len} payload bytes arrived"),
            ),
            _ => MineError::io("read frame payload", e),
        });
    }
    Ok(Some(payload))
}

// ---------------------------------------------------------------------
// Requests and responses
// ---------------------------------------------------------------------

/// Everything a coordinator can ask of a node.
///
/// `MapCount` and `RelaxedCount` are the scatter hot path: stateless
/// counting RPCs over a time window of the node's local log, carrying
/// episodes in *original* type ids (the coordinator inverts its dense
/// remap before serializing — nodes never see the coordinator's
/// frequency-sorted alphabet). `Mine` runs a whole sub-mine through the
/// node's `MineService`, giving remote callers the same coalescing /
/// caching / admission the in-process service provides.
#[derive(Clone, Debug)]
pub enum Request {
    /// Liveness + version probe.
    Ping,
    /// Snapshot the node's `ServiceMetrics` as JSON.
    Metrics,
    /// Snapshot the node's unified [`obs::Registry`](crate::obs::Registry)
    /// as JSON (counters/gauges/histograms — the `epminer stats
    /// --connect` surface).
    Stats,
    /// Mine the `(t_from, t_to]` window of the node's log end-to-end.
    Mine {
        /// [`range_fingerprint`] of the windowed stream
        fingerprint: u64,
        options: MineOptions,
        two_pass: bool,
        t_from: Tick,
        t_to: Tick,
    },
    /// Run the MapConcatenate Map phase for one shard window
    /// `(lo, hi]` of the query range `(t_from, t_to]`, extending the
    /// scan by `halo` ticks each side — clamped to the query range —
    /// for boundary machines.
    MapCount {
        fingerprint: u64,
        episodes: Vec<Episode>,
        t_from: Tick,
        t_to: Tick,
        lo: Tick,
        hi: Tick,
        halo: Tick,
        /// bounded-K automaton cap (`usize::MAX` = unbounded; encoded
        /// as JSON `null`)
        k: usize,
    },
    /// Count each episode under relaxed A2 semantics over the whole
    /// query range (the two-pass elimination scan).
    RelaxedCount {
        fingerprint: u64,
        episodes: Vec<Episode>,
        t_from: Tick,
        t_to: Tick,
    },
}

/// The success half of a reply; failures travel as encoded
/// [`MineError`]s in the envelope's `err` slot.
#[derive(Clone, Debug)]
pub enum Response {
    Pong {
        version: u32,
    },
    Metrics {
        metrics: Json,
    },
    /// The node's unified metrics registry snapshot (see
    /// [`obs::Snapshot::to_json`](crate::obs::Snapshot::to_json)).
    Stats {
        snapshot: Json,
    },
    Mine {
        result: MineResult,
    },
    /// Per-episode machine lists `(first_start, count, next_expected)`
    /// for the requested shard window, in request episode order.
    MapCount {
        machines: Vec<Vec<(Tick, u64, Tick)>>,
    },
    RelaxedCount {
        counts: Vec<u64>,
    },
}

/// The canonical integrity token counting requests carry: the
/// [`Query`] fingerprint of the `(t_from, t_to]` window under *pinned*
/// semantic parameters, which reduces the key to a pure content hash
/// of the windowed stream. Coordinator and node both compute it from
/// their own copy of the log; equality proves they are counting the
/// same events.
pub fn range_fingerprint(stream: &Arc<EventStream>, t_from: Tick, t_to: Tick) -> u64 {
    let windowed = Arc::new(stream.window(t_from, t_to));
    Query::new(windowed, 1, vec![Interval::new(0, 1)]).key().fingerprint()
}

// ---------------------------------------------------------------------
// Envelope
// ---------------------------------------------------------------------

fn envelope(id: u64, slot: &str, body: Json) -> Vec<u8> {
    Json::Obj(vec![
        ("v".to_string(), Json::Num(PROTO_VERSION as f64)),
        ("id".to_string(), Json::Num(id as f64)),
        (slot.to_string(), body),
    ])
    .render()
    .into_bytes()
}

fn open_envelope(bytes: &[u8]) -> Result<(u64, Json), MineError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|_| MineError::corrupt(WIRE, "frame payload is not UTF-8"))?;
    let doc = Json::parse(text)?;
    let v = doc
        .req("v")?
        .as_u64()
        .ok_or_else(|| MineError::invalid("envelope \"v\" must be an unsigned integer"))?;
    if v != PROTO_VERSION as u64 {
        return Err(MineError::invalid(format!(
            "protocol version mismatch: peer speaks v{v}, this build speaks v{PROTO_VERSION}"
        )));
    }
    let id = doc
        .req("id")?
        .as_u64()
        .ok_or_else(|| MineError::invalid("envelope \"id\" must be an unsigned integer"))?;
    Ok((id, doc))
}

/// Serialize a request envelope.
pub fn encode_request(id: u64, req: &Request) -> Vec<u8> {
    encode_request_traced(id, req, None)
}

/// Serialize a request envelope carrying an optional trace context: the
/// propagated [`TraceId`] travels as an extra `"trace"` hex-string key,
/// which old peers (whose decoder only reads the keys it knows) skip.
pub fn encode_request_traced(id: u64, req: &Request, trace: Option<TraceId>) -> Vec<u8> {
    let mut fields = vec![
        ("v".to_string(), Json::Num(PROTO_VERSION as f64)),
        ("id".to_string(), Json::Num(id as f64)),
    ];
    if let Some(t) = trace {
        fields.push(("trace".to_string(), Json::Str(t.to_hex())));
    }
    fields.push(("req".to_string(), request_to_json(req)));
    Json::Obj(fields).render().into_bytes()
}

/// Parse a request envelope (node side), discarding any trace context.
pub fn decode_request(bytes: &[u8]) -> Result<(u64, Request), MineError> {
    decode_request_traced(bytes).map(|(id, req, _)| (id, req))
}

/// Parse a request envelope along with its optional trace context. A
/// missing `"trace"` key is simply `None` (old peers); a present but
/// hostile one — non-string, oversized, or non-hex — is a typed error,
/// never a panic.
pub fn decode_request_traced(
    bytes: &[u8],
) -> Result<(u64, Request, Option<TraceId>), MineError> {
    let (id, doc) = open_envelope(bytes)?;
    let trace = match doc.get("trace") {
        None => None,
        Some(t) => {
            let s = t
                .as_str()
                .ok_or_else(|| MineError::invalid("envelope \"trace\" must be a hex string"))?;
            Some(TraceId::from_hex(s)?)
        }
    };
    Ok((id, request_from_json(doc.req("req")?)?, trace))
}

/// Serialize a reply envelope: `ok` for success, `err` for a typed
/// failure.
pub fn encode_response(id: u64, outcome: &Result<Response, MineError>) -> Vec<u8> {
    encode_response_traced(id, outcome, &[])
}

/// [`encode_response`] attaching the node's recorded spans (an extra
/// `"spans"` key on `ok` envelopes only — errors travel bare, and old
/// peers skip the unknown key).
pub fn encode_response_traced(
    id: u64,
    outcome: &Result<Response, MineError>,
    spans: &[SpanRecord],
) -> Vec<u8> {
    match outcome {
        Ok(resp) if !spans.is_empty() => Json::Obj(vec![
            ("v".to_string(), Json::Num(PROTO_VERSION as f64)),
            ("id".to_string(), Json::Num(id as f64)),
            ("spans".to_string(), spans_to_json(spans)),
            ("ok".to_string(), response_to_json(resp)),
        ])
        .render()
        .into_bytes(),
        Ok(resp) => envelope(id, "ok", response_to_json(resp)),
        Err(e) => envelope(id, "err", error_to_json(e)),
    }
}

/// Parse a reply envelope (coordinator side). The outer `Result` is a
/// transport/codec failure; the inner one is the node's own outcome.
#[allow(clippy::type_complexity)]
pub fn decode_response(bytes: &[u8]) -> Result<(u64, Result<Response, MineError>), MineError> {
    decode_response_traced(bytes).map(|(id, outcome, _)| (id, outcome))
}

/// Parse a reply envelope along with any spans the node attached (empty
/// when absent — old peers). Span lists from untrusted peers are shape
/// checked and clamped to [`MAX_SPANS`](crate::obs::trace::MAX_SPANS).
#[allow(clippy::type_complexity)]
pub fn decode_response_traced(
    bytes: &[u8],
) -> Result<(u64, Result<Response, MineError>, Vec<SpanRecord>), MineError> {
    let (id, doc) = open_envelope(bytes)?;
    let spans = match doc.get("spans") {
        None => vec![],
        Some(s) => spans_from_json(s)?,
    };
    if let Some(ok) = doc.get("ok") {
        return Ok((id, Ok(response_from_json(ok)?), spans));
    }
    if let Some(err) = doc.get("err") {
        return Ok((id, Err(error_from_json(err)?), spans));
    }
    Err(MineError::invalid("reply envelope carries neither \"ok\" nor \"err\""))
}

// ---------------------------------------------------------------------
// Scalar helpers
// ---------------------------------------------------------------------

fn as_tick(j: &Json) -> Result<Tick, MineError> {
    match j.as_f64() {
        Some(x) if x.fract() == 0.0 && (i32::MIN as f64..=i32::MAX as f64).contains(&x) => {
            Ok(x as Tick)
        }
        _ => Err(MineError::invalid("expected an integer tick")),
    }
}

fn as_usize(j: &Json) -> Result<usize, MineError> {
    j.as_u64()
        .map(|v| v as usize)
        .ok_or_else(|| MineError::invalid("expected an unsigned integer"))
}

fn as_count(j: &Json) -> Result<u64, MineError> {
    j.as_u64().ok_or_else(|| MineError::invalid("expected an unsigned integer"))
}

// 64-bit fingerprints do not survive a JSON f64 (53-bit mantissa), so
// they travel as fixed-width hex strings.
fn fp_to_json(fp: u64) -> Json {
    Json::Str(format!("{fp:016x}"))
}

fn fp_from_json(j: &Json) -> Result<u64, MineError> {
    let s = j
        .as_str()
        .ok_or_else(|| MineError::invalid("fingerprint must be a hex string"))?;
    u64::from_str_radix(s, 16)
        .map_err(|_| MineError::invalid(format!("fingerprint {s:?} is not 64-bit hex")))
}

// ---------------------------------------------------------------------
// Domain codecs
// ---------------------------------------------------------------------

fn intervals_to_json(ivs: &[Interval]) -> Json {
    Json::Arr(
        ivs.iter()
            .map(|iv| {
                Json::Arr(vec![Json::Num(iv.t_low as f64), Json::Num(iv.t_high as f64)])
            })
            .collect(),
    )
}

fn intervals_from_json(j: &Json) -> Result<Vec<Interval>, MineError> {
    j.as_arr()
        .ok_or_else(|| MineError::invalid("intervals must be an array"))?
        .iter()
        .map(|pair| {
            let pair =
                pair.as_arr().ok_or_else(|| MineError::invalid("interval must be [low, high]"))?;
            if pair.len() != 2 {
                return Err(MineError::invalid("interval must be [low, high]"));
            }
            let (lo, hi) = (as_tick(&pair[0])?, as_tick(&pair[1])?);
            // Interval::new asserts; wire data must reject, not panic
            if !(0 <= lo && lo < hi) {
                return Err(MineError::invalid(format!(
                    "interval ({lo},{hi}] violates 0 <= t_low < t_high"
                )));
            }
            Ok(Interval { t_low: lo, t_high: hi })
        })
        .collect()
}

/// Episode → `{"types": [...], "intervals": [[lo,hi], ...]}`.
pub fn episode_to_json(ep: &Episode) -> Json {
    Json::Obj(vec![
        (
            "types".to_string(),
            Json::Arr(ep.types.iter().map(|&t| Json::Num(t as f64)).collect()),
        ),
        ("intervals".to_string(), intervals_to_json(&ep.intervals)),
    ])
}

/// Parse an episode, enforcing the N-types/N-1-intervals shape that
/// `Episode::new` would otherwise assert on.
pub fn episode_from_json(j: &Json) -> Result<Episode, MineError> {
    let types = j
        .req("types")?
        .as_arr()
        .ok_or_else(|| MineError::invalid("episode types must be an array"))?
        .iter()
        .map(as_tick) // EventType and Tick are the same i32 alias
        .collect::<Result<Vec<EventType>, _>>()?;
    let intervals = intervals_from_json(j.req("intervals")?)?;
    if types.is_empty() {
        return Err(MineError::invalid("episode must have at least one event type"));
    }
    if intervals.len() + 1 != types.len() {
        return Err(MineError::invalid(format!(
            "episode with {} types needs {} intervals, got {}",
            types.len(),
            types.len() - 1,
            intervals.len()
        )));
    }
    Ok(Episode { types, intervals })
}

fn episodes_to_json(eps: &[Episode]) -> Json {
    Json::Arr(eps.iter().map(episode_to_json).collect())
}

fn episodes_from_json(j: &Json) -> Result<Vec<Episode>, MineError> {
    j.as_arr()
        .ok_or_else(|| MineError::invalid("episodes must be an array"))?
        .iter()
        .map(episode_from_json)
        .collect()
}

/// MineOptions → JSON (all fields; `candidate_block` is an execution
/// knob but a sub-mine must still honor the coordinator's choice).
pub fn options_to_json(o: &MineOptions) -> Json {
    Json::Obj(vec![
        ("theta".to_string(), Json::Num(o.theta as f64)),
        ("intervals".to_string(), intervals_to_json(&o.intervals)),
        ("max_level".to_string(), Json::Num(o.max_level as f64)),
        (
            "max_candidates_per_level".to_string(),
            Json::Num(o.max_candidates_per_level as f64),
        ),
        ("candidate_block".to_string(), Json::Num(o.candidate_block as f64)),
    ])
}

/// Parse and validate mining options (the same `MineOptions::validate`
/// every local entry point runs — wire input is untrusted input).
pub fn options_from_json(j: &Json) -> Result<MineOptions, MineError> {
    let o = MineOptions {
        theta: as_count(j.req("theta")?)?,
        intervals: intervals_from_json(j.req("intervals")?)?,
        max_level: as_usize(j.req("max_level")?)?,
        max_candidates_per_level: as_usize(j.req("max_candidates_per_level")?)?,
        candidate_block: as_usize(j.req("candidate_block")?)?,
    };
    o.validate()?;
    Ok(o)
}

fn level_to_json(l: &LevelReport) -> Json {
    Json::Obj(vec![
        ("level".to_string(), Json::Num(l.level as f64)),
        ("candidates".to_string(), Json::Num(l.candidates as f64)),
        ("frequent".to_string(), Json::Num(l.frequent as f64)),
        ("culled_by_a2".to_string(), Json::Num(l.culled_by_a2 as f64)),
        ("count_seconds".to_string(), Json::Num(l.count_seconds)),
        ("gen_seconds".to_string(), Json::Num(l.gen_seconds)),
    ])
}

fn level_from_json(j: &Json) -> Result<LevelReport, MineError> {
    Ok(LevelReport {
        level: as_usize(j.req("level")?)?,
        candidates: as_usize(j.req("candidates")?)?,
        frequent: as_usize(j.req("frequent")?)?,
        culled_by_a2: as_count(j.req("culled_by_a2")?)?,
        count_seconds: j
            .req("count_seconds")?
            .as_f64()
            .ok_or_else(|| MineError::invalid("count_seconds must be a number"))?,
        gen_seconds: j
            .req("gen_seconds")?
            .as_f64()
            .ok_or_else(|| MineError::invalid("gen_seconds must be a number"))?,
    })
}

/// MineResult → JSON. The phase profile is an optional key — absent
/// when profiling was off, skipped by decoders that predate it.
pub fn result_to_json(r: &MineResult) -> Json {
    let mut fields = vec![
        (
            "frequent".to_string(),
            Json::Arr(
                r.frequent
                    .iter()
                    .map(|ce| {
                        Json::Obj(vec![
                            ("episode".to_string(), episode_to_json(&ce.episode)),
                            ("count".to_string(), Json::Num(ce.count as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("levels".to_string(), Json::Arr(r.levels.iter().map(level_to_json).collect())),
    ];
    if let Some(p) = &r.profile {
        fields.push(("profile".to_string(), p.to_json()));
    }
    Json::Obj(fields)
}

/// Parse a MineResult.
pub fn result_from_json(j: &Json) -> Result<MineResult, MineError> {
    let frequent = j
        .req("frequent")?
        .as_arr()
        .ok_or_else(|| MineError::invalid("frequent must be an array"))?
        .iter()
        .map(|ce| {
            Ok(CountedEpisode {
                episode: episode_from_json(ce.req("episode")?)?,
                count: as_count(ce.req("count")?)?,
            })
        })
        .collect::<Result<Vec<_>, MineError>>()?;
    let levels = j
        .req("levels")?
        .as_arr()
        .ok_or_else(|| MineError::invalid("levels must be an array"))?
        .iter()
        .map(level_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    let profile = match j.get("profile") {
        None => None,
        Some(p) => Some(MineProfile::from_json(p)?),
    };
    Ok(MineResult { frequent, levels, profile })
}

fn machines_to_json(machines: &[Vec<(Tick, u64, Tick)>]) -> Json {
    Json::Arr(
        machines
            .iter()
            .map(|per_ep| {
                Json::Arr(
                    per_ep
                        .iter()
                        .map(|&(a, c, b)| {
                            Json::Arr(vec![
                                Json::Num(a as f64),
                                Json::Num(c as f64),
                                Json::Num(b as f64),
                            ])
                        })
                        .collect(),
                )
            })
            .collect(),
    )
}

#[allow(clippy::type_complexity)]
fn machines_from_json(j: &Json) -> Result<Vec<Vec<(Tick, u64, Tick)>>, MineError> {
    j.as_arr()
        .ok_or_else(|| MineError::invalid("machines must be an array"))?
        .iter()
        .map(|per_ep| {
            per_ep
                .as_arr()
                .ok_or_else(|| MineError::invalid("machine list must be an array"))?
                .iter()
                .map(|m| {
                    let m = m
                        .as_arr()
                        .ok_or_else(|| MineError::invalid("machine must be [a, count, b]"))?;
                    if m.len() != 3 {
                        return Err(MineError::invalid("machine must be [a, count, b]"));
                    }
                    Ok((as_tick(&m[0])?, as_count(&m[1])?, as_tick(&m[2])?))
                })
                .collect()
        })
        .collect()
}

// ---------------------------------------------------------------------
// Request / response codecs
// ---------------------------------------------------------------------

fn request_to_json(req: &Request) -> Json {
    match req {
        Request::Ping => Json::Obj(vec![("type".to_string(), Json::Str("ping".to_string()))]),
        Request::Metrics => {
            Json::Obj(vec![("type".to_string(), Json::Str("metrics".to_string()))])
        }
        Request::Stats => {
            Json::Obj(vec![("type".to_string(), Json::Str("stats".to_string()))])
        }
        Request::Mine { fingerprint, options, two_pass, t_from, t_to } => Json::Obj(vec![
            ("type".to_string(), Json::Str("mine".to_string())),
            ("fingerprint".to_string(), fp_to_json(*fingerprint)),
            ("options".to_string(), options_to_json(options)),
            ("two_pass".to_string(), Json::Bool(*two_pass)),
            ("t_from".to_string(), Json::Num(*t_from as f64)),
            ("t_to".to_string(), Json::Num(*t_to as f64)),
        ]),
        Request::MapCount { fingerprint, episodes, t_from, t_to, lo, hi, halo, k } => {
            Json::Obj(vec![
                ("type".to_string(), Json::Str("map_count".to_string())),
                ("fingerprint".to_string(), fp_to_json(*fingerprint)),
                ("episodes".to_string(), episodes_to_json(episodes)),
                ("t_from".to_string(), Json::Num(*t_from as f64)),
                ("t_to".to_string(), Json::Num(*t_to as f64)),
                ("lo".to_string(), Json::Num(*lo as f64)),
                ("hi".to_string(), Json::Num(*hi as f64)),
                ("halo".to_string(), Json::Num(*halo as f64)),
                (
                    "k".to_string(),
                    if *k == usize::MAX { Json::Null } else { Json::Num(*k as f64) },
                ),
            ])
        }
        Request::RelaxedCount { fingerprint, episodes, t_from, t_to } => Json::Obj(vec![
            ("type".to_string(), Json::Str("relaxed_count".to_string())),
            ("fingerprint".to_string(), fp_to_json(*fingerprint)),
            ("episodes".to_string(), episodes_to_json(episodes)),
            ("t_from".to_string(), Json::Num(*t_from as f64)),
            ("t_to".to_string(), Json::Num(*t_to as f64)),
        ]),
    }
}

fn request_from_json(j: &Json) -> Result<Request, MineError> {
    let ty = j
        .req("type")?
        .as_str()
        .ok_or_else(|| MineError::invalid("request \"type\" must be a string"))?;
    match ty {
        "ping" => Ok(Request::Ping),
        "metrics" => Ok(Request::Metrics),
        "stats" => Ok(Request::Stats),
        "mine" => Ok(Request::Mine {
            fingerprint: fp_from_json(j.req("fingerprint")?)?,
            options: options_from_json(j.req("options")?)?,
            two_pass: j
                .req("two_pass")?
                .as_bool()
                .ok_or_else(|| MineError::invalid("two_pass must be a boolean"))?,
            t_from: as_tick(j.req("t_from")?)?,
            t_to: as_tick(j.req("t_to")?)?,
        }),
        "map_count" => Ok(Request::MapCount {
            fingerprint: fp_from_json(j.req("fingerprint")?)?,
            episodes: episodes_from_json(j.req("episodes")?)?,
            t_from: as_tick(j.req("t_from")?)?,
            t_to: as_tick(j.req("t_to")?)?,
            lo: as_tick(j.req("lo")?)?,
            hi: as_tick(j.req("hi")?)?,
            halo: as_tick(j.req("halo")?)?,
            k: match j.req("k")? {
                Json::Null => usize::MAX,
                other => as_usize(other)?,
            },
        }),
        "relaxed_count" => Ok(Request::RelaxedCount {
            fingerprint: fp_from_json(j.req("fingerprint")?)?,
            episodes: episodes_from_json(j.req("episodes")?)?,
            t_from: as_tick(j.req("t_from")?)?,
            t_to: as_tick(j.req("t_to")?)?,
        }),
        other => Err(MineError::invalid(format!("unknown request type {other:?}"))),
    }
}

fn response_to_json(resp: &Response) -> Json {
    match resp {
        Response::Pong { version } => Json::Obj(vec![
            ("type".to_string(), Json::Str("pong".to_string())),
            ("version".to_string(), Json::Num(*version as f64)),
        ]),
        Response::Metrics { metrics } => Json::Obj(vec![
            ("type".to_string(), Json::Str("metrics".to_string())),
            ("metrics".to_string(), metrics.clone()),
        ]),
        Response::Stats { snapshot } => Json::Obj(vec![
            ("type".to_string(), Json::Str("stats".to_string())),
            ("snapshot".to_string(), snapshot.clone()),
        ]),
        Response::Mine { result } => Json::Obj(vec![
            ("type".to_string(), Json::Str("mine".to_string())),
            ("result".to_string(), result_to_json(result)),
        ]),
        Response::MapCount { machines } => Json::Obj(vec![
            ("type".to_string(), Json::Str("map_count".to_string())),
            ("machines".to_string(), machines_to_json(machines)),
        ]),
        Response::RelaxedCount { counts } => Json::Obj(vec![
            ("type".to_string(), Json::Str("relaxed_count".to_string())),
            (
                "counts".to_string(),
                Json::Arr(counts.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
        ]),
    }
}

fn response_from_json(j: &Json) -> Result<Response, MineError> {
    let ty = j
        .req("type")?
        .as_str()
        .ok_or_else(|| MineError::invalid("response \"type\" must be a string"))?;
    match ty {
        "pong" => Ok(Response::Pong {
            version: as_count(j.req("version")?)? as u32,
        }),
        "metrics" => Ok(Response::Metrics { metrics: j.req("metrics")?.clone() }),
        "stats" => Ok(Response::Stats { snapshot: j.req("snapshot")?.clone() }),
        "mine" => Ok(Response::Mine { result: result_from_json(j.req("result")?)? }),
        "map_count" => {
            Ok(Response::MapCount { machines: machines_from_json(j.req("machines")?)? })
        }
        "relaxed_count" => Ok(Response::RelaxedCount {
            counts: j
                .req("counts")?
                .as_arr()
                .ok_or_else(|| MineError::invalid("counts must be an array"))?
                .iter()
                .map(as_count)
                .collect::<Result<Vec<_>, _>>()?,
        }),
        other => Err(MineError::invalid(format!("unknown response type {other:?}"))),
    }
}

// ---------------------------------------------------------------------
// Typed MineError round-trip
// ---------------------------------------------------------------------

/// Encode a [`MineError`] for the envelope's `err` slot. Every variant
/// survives the round-trip with its fields; the two `&'static` validity
/// lists (`UnknownStrategy`, `UnknownDataset`) are reconstructed from
/// this build's registries on decode.
pub fn error_to_json(e: &MineError) -> Json {
    let kv = |k: &str, fields: Vec<(String, Json)>| {
        let mut obj = vec![("kind".to_string(), Json::Str(k.to_string()))];
        obj.extend(fields);
        Json::Obj(obj)
    };
    match e {
        MineError::UnsupportedEpisodeSize { backend, n } => kv(
            "unsupported_episode_size",
            vec![
                ("backend".to_string(), Json::Str(backend.clone())),
                ("n".to_string(), Json::Num(*n as f64)),
            ],
        ),
        MineError::OutOfAlphabet { type_id, n_types } => kv(
            "out_of_alphabet",
            vec![
                ("type_id".to_string(), Json::Num(*type_id as f64)),
                ("n_types".to_string(), Json::Num(*n_types as f64)),
            ],
        ),
        MineError::CandidateExplosion { level, candidates, cap } => kv(
            "candidate_explosion",
            vec![
                ("level".to_string(), Json::Num(*level as f64)),
                ("candidates".to_string(), Json::Num(*candidates as f64)),
                ("cap".to_string(), Json::Num(*cap as f64)),
            ],
        ),
        MineError::Busy { queue_depth, capacity } => kv(
            "busy",
            vec![
                ("queue_depth".to_string(), Json::Num(*queue_depth as f64)),
                ("capacity".to_string(), Json::Num(*capacity as f64)),
            ],
        ),
        MineError::RuntimeUnavailable { reason } => kv(
            "runtime_unavailable",
            vec![("reason".to_string(), Json::Str(reason.clone()))],
        ),
        MineError::InvalidConfig { what } => {
            kv("invalid_config", vec![("what".to_string(), Json::Str(what.clone()))])
        }
        MineError::UnknownStrategy { given, .. } => {
            kv("unknown_strategy", vec![("given".to_string(), Json::Str(given.clone()))])
        }
        MineError::UnknownDataset { given, .. } => {
            kv("unknown_dataset", vec![("given".to_string(), Json::Str(given.clone()))])
        }
        MineError::Io { what, source } => kv(
            "io",
            vec![
                ("what".to_string(), Json::Str(what.clone())),
                ("message".to_string(), Json::Str(source.to_string())),
            ],
        ),
        MineError::Corrupt { path, detail } => kv(
            "corrupt",
            vec![
                ("path".to_string(), Json::Str(path.clone())),
                ("detail".to_string(), Json::Str(detail.clone())),
            ],
        ),
        MineError::Accelerator { what } => {
            kv("accelerator", vec![("what".to_string(), Json::Str(what.clone()))])
        }
        MineError::Internal { what } => {
            kv("internal", vec![("what".to_string(), Json::Str(what.clone()))])
        }
    }
}

/// Decode a wire error back into the same [`MineError`] variant.
pub fn error_from_json(j: &Json) -> Result<MineError, MineError> {
    let str_field = |key: &str| -> Result<String, MineError> {
        Ok(j.req(key)?
            .as_str()
            .ok_or_else(|| MineError::invalid(format!("error field {key:?} must be a string")))?
            .to_string())
    };
    let kind = j
        .req("kind")?
        .as_str()
        .ok_or_else(|| MineError::invalid("error \"kind\" must be a string"))?;
    Ok(match kind {
        "unsupported_episode_size" => MineError::UnsupportedEpisodeSize {
            backend: str_field("backend")?,
            n: as_usize(j.req("n")?)?,
        },
        "out_of_alphabet" => MineError::OutOfAlphabet {
            type_id: as_tick(j.req("type_id")?)?,
            n_types: as_usize(j.req("n_types")?)?,
        },
        "candidate_explosion" => MineError::CandidateExplosion {
            level: as_usize(j.req("level")?)?,
            candidates: as_usize(j.req("candidates")?)?,
            cap: as_usize(j.req("cap")?)?,
        },
        "busy" => MineError::Busy {
            queue_depth: as_usize(j.req("queue_depth")?)?,
            capacity: as_usize(j.req("capacity")?)?,
        },
        "runtime_unavailable" => {
            MineError::RuntimeUnavailable { reason: str_field("reason")? }
        }
        "invalid_config" => MineError::InvalidConfig { what: str_field("what")? },
        "unknown_strategy" => MineError::UnknownStrategy {
            given: str_field("given")?,
            valid: Strategy::NAMES,
        },
        "unknown_dataset" => MineError::UnknownDataset {
            given: str_field("given")?,
            valid: datasets::names_and_schemes(),
        },
        "io" => MineError::Io {
            what: str_field("what")?,
            source: std::io::Error::other(str_field("message")?),
        },
        "corrupt" => {
            MineError::Corrupt { path: str_field("path")?, detail: str_field("detail")? }
        }
        "accelerator" => MineError::Accelerator { what: str_field("what")? },
        "internal" => MineError::Internal { what: str_field("what")? },
        other => return Err(MineError::invalid(format!("unknown error kind {other:?}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_episode() -> Episode {
        Episode::new(vec![3, 1, 4], vec![Interval::new(0, 10), Interval::new(5, 15)])
    }

    fn sample_options() -> MineOptions {
        MineOptions {
            theta: 7,
            intervals: vec![Interval::new(5, 15)],
            max_level: 6,
            max_candidates_per_level: 100_000,
            candidate_block: 4096,
        }
    }

    #[test]
    fn frames_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF between frames");
    }

    #[test]
    fn truncated_frames_are_corrupt_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"payload").unwrap();

        // die inside the length prefix
        let mut r = &buf[..2];
        assert!(matches!(read_frame(&mut r), Err(MineError::Corrupt { .. })));

        // die inside the payload
        let mut r = &buf[..4 + 3];
        assert!(matches!(read_frame(&mut r), Err(MineError::Corrupt { .. })));
    }

    #[test]
    fn oversized_frames_refused_both_directions() {
        let mut buf = Vec::new();
        // a length prefix claiming more than MAX_FRAME must be rejected
        // without allocating
        buf.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let mut r = &buf[..];
        assert!(matches!(read_frame(&mut r), Err(MineError::Corrupt { .. })));
    }

    #[test]
    fn requests_round_trip() {
        let reqs = vec![
            Request::Ping,
            Request::Metrics,
            Request::Stats,
            Request::Mine {
                fingerprint: u64::MAX - 3, // exercises the >2^53 hex path
                options: sample_options(),
                two_pass: true,
                t_from: -1,
                t_to: 5_000,
            },
            Request::MapCount {
                fingerprint: 0xdead_beef_cafe_f00d,
                episodes: vec![sample_episode()],
                t_from: 0,
                t_to: 1_000,
                lo: 100,
                hi: 200,
                halo: 30,
                k: usize::MAX,
            },
            Request::RelaxedCount {
                fingerprint: 1,
                episodes: vec![sample_episode(), Episode::single(2)],
                t_from: 0,
                t_to: 1_000,
            },
        ];
        for (i, req) in reqs.iter().enumerate() {
            let bytes = encode_request(i as u64, req);
            let (id, back) = decode_request(&bytes).unwrap();
            assert_eq!(id, i as u64);
            // compare via re-encode: Request has no PartialEq
            assert_eq!(encode_request(id, &back), bytes, "request {i}");
        }
    }

    #[test]
    fn bounded_k_travels_as_null() {
        let req = Request::MapCount {
            fingerprint: 9,
            episodes: vec![sample_episode()],
            t_from: 0,
            t_to: 10,
            lo: 0,
            hi: 10,
            halo: 0,
            k: 4,
        };
        let text = String::from_utf8(encode_request(0, &req)).unwrap();
        assert!(text.contains("\"k\":4"), "{text}");
        let unbounded = Request::MapCount {
            fingerprint: 9,
            episodes: vec![sample_episode()],
            t_from: 0,
            t_to: 10,
            lo: 0,
            hi: 10,
            halo: 0,
            k: usize::MAX,
        };
        let text = String::from_utf8(encode_request(0, &unbounded)).unwrap();
        assert!(text.contains("\"k\":null"), "{text}");
        let (_, back) = decode_request(text.as_bytes()).unwrap();
        match back {
            Request::MapCount { k, .. } => assert_eq!(k, usize::MAX),
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn responses_round_trip() {
        let result = MineResult {
            frequent: vec![CountedEpisode { episode: sample_episode(), count: 42 }],
            levels: vec![LevelReport {
                level: 1,
                candidates: 26,
                frequent: 9,
                culled_by_a2: 3,
                count_seconds: 0.25,
                gen_seconds: 0.0625,
            }],
            profile: None,
        };
        let mut profiled = result.clone();
        profiled.profile = Some(MineProfile {
            total_seconds: 0.3125,
            levels: vec![crate::obs::LevelProfile {
                level: 1,
                generate_seconds: 0.0625,
                count_seconds: 0.25,
                prune_seconds: 0.001,
                candidates: 26,
                blocks: 1,
            }],
            candidate_rows: 26,
            blocks_streamed: 1,
            concat_misses: 0,
            shard_map_calls: 2,
            serial_recounts: 0,
            cache_outcome: Some("cache".to_string()),
        });
        let resps = vec![
            Response::Pong { version: PROTO_VERSION },
            Response::Metrics {
                metrics: Json::Obj(vec![("queue_depth".to_string(), Json::Num(2.0))]),
            },
            Response::Stats {
                snapshot: Json::Obj(vec![("counters".to_string(), Json::Obj(vec![]))]),
            },
            Response::Mine { result },
            Response::Mine { result: profiled },
            Response::MapCount {
                machines: vec![vec![(5, 3, 20)], vec![]],
            },
            Response::RelaxedCount { counts: vec![0, 7, 123] },
        ];
        for (i, resp) in resps.iter().enumerate() {
            let bytes = encode_response(i as u64, &Ok(resp.clone()));
            let (id, back) = decode_response(&bytes).unwrap();
            assert_eq!(id, i as u64);
            assert_eq!(encode_response(id, &Ok(back.unwrap())), bytes, "response {i}");
        }
    }

    #[test]
    fn every_error_variant_round_trips() {
        let errors = vec![
            MineError::UnsupportedEpisodeSize { backend: "ptpe".to_string(), n: 9 },
            MineError::OutOfAlphabet { type_id: -4, n_types: 26 },
            MineError::CandidateExplosion { level: 3, candidates: 10, cap: 5 },
            MineError::Busy { queue_depth: 8, capacity: 8 },
            MineError::runtime_unavailable("no PJRT plugin"),
            MineError::invalid("theta must be > 0"),
            MineError::UnknownStrategy {
                given: "warp-speed".to_string(),
                valid: Strategy::NAMES,
            },
            MineError::UnknownDataset {
                given: "nope".to_string(),
                valid: datasets::names_and_schemes(),
            },
            MineError::io("open log", std::io::Error::other("disk on fire")),
            MineError::corrupt("seg-0003.epseg", "checksum mismatch"),
            MineError::accel("PJRT execute failed"),
            MineError::internal("machine list misaligned"),
        ];
        for e in errors {
            let bytes = encode_response(7, &Err(e.clone()));
            let (id, outcome) = decode_response(&bytes).unwrap();
            assert_eq!(id, 7);
            let back = outcome.unwrap_err();
            assert_eq!(
                std::mem::discriminant(&back),
                std::mem::discriminant(&e),
                "{e} decoded as {back}"
            );
            // the human-readable rendering survives too (Io embeds the
            // source message)
            assert_eq!(back.to_string(), e.to_string());
        }
    }

    #[test]
    fn trace_context_round_trips_and_old_peers_interop() {
        let id = TraceId(0xfeed_face_0123_4567);
        let bytes = encode_request_traced(9, &Request::Ping, Some(id));
        let (rid, _, trace) = decode_request_traced(&bytes).unwrap();
        assert_eq!(rid, 9);
        assert_eq!(trace, Some(id));

        // an envelope WITHOUT the trace key (an old peer's request)
        // decodes fine as None — and byte-identically to pre-trace builds
        let bare = encode_request(9, &Request::Ping);
        let (_, _, trace) = decode_request_traced(&bare).unwrap();
        assert_eq!(trace, None);
        assert_eq!(bare, encode_request_traced(9, &Request::Ping, None));

        // unknown extra envelope keys are ignored (future additive keys)
        let doc = Json::Obj(vec![
            ("v".to_string(), Json::Num(PROTO_VERSION as f64)),
            ("id".to_string(), Json::Num(3.0)),
            ("future_key".to_string(), Json::Str("ignored".to_string())),
            ("req".to_string(), Json::Obj(vec![(
                "type".to_string(),
                Json::Str("ping".to_string()),
            )])),
        ]);
        let (rid, req, trace) = decode_request_traced(doc.render().as_bytes()).unwrap();
        assert_eq!(rid, 3);
        assert!(matches!(req, Request::Ping));
        assert_eq!(trace, None);
    }

    #[test]
    fn hostile_trace_ids_are_typed_errors() {
        let hostile = [
            Json::Str(String::new()),                     // empty
            Json::Str("1".repeat(17)),                    // oversized
            Json::Str("not-hex!".to_string()),            // non-hex
            Json::Str("х".repeat(400)),                   // oversized non-ascii
            Json::Num(12.0),                              // wrong type
            Json::Arr(vec![]),                            // wrong type
        ];
        for bad in hostile {
            let doc = Json::Obj(vec![
                ("v".to_string(), Json::Num(PROTO_VERSION as f64)),
                ("id".to_string(), Json::Num(0.0)),
                ("trace".to_string(), bad.clone()),
                ("req".to_string(), Json::Obj(vec![(
                    "type".to_string(),
                    Json::Str("ping".to_string()),
                )])),
            ]);
            let err = decode_request_traced(doc.render().as_bytes()).unwrap_err();
            assert!(
                matches!(err, MineError::InvalidConfig { .. }),
                "{bad:?} should be a typed error, got {err}"
            );
        }
    }

    #[test]
    fn spans_attach_to_ok_envelopes_only() {
        let spans = vec![SpanRecord {
            id: 1,
            parent: 0,
            name: "node:map_count".into(),
            node: "".into(),
            start_ns: 5,
            end_ns: 105,
        }];
        let ok: Result<Response, MineError> =
            Ok(Response::Pong { version: PROTO_VERSION });
        let bytes = encode_response_traced(4, &ok, &spans);
        let (id, outcome, back) = decode_response_traced(&bytes).unwrap();
        assert_eq!(id, 4);
        assert!(outcome.is_ok());
        assert_eq!(back, spans);

        // spanless replies stay byte-identical to the legacy encoding,
        // and decode with an empty span list
        let bare = encode_response_traced(4, &ok, &[]);
        assert_eq!(bare, encode_response(4, &ok));
        let (_, _, back) = decode_response_traced(&bare).unwrap();
        assert!(back.is_empty());

        // errors never carry spans
        let err: Result<Response, MineError> = Err(MineError::invalid("boom"));
        let bytes = encode_response_traced(4, &err, &spans);
        assert_eq!(bytes, encode_response(4, &err));

        // a hostile span list is a typed decode error
        let doc = Json::Obj(vec![
            ("v".to_string(), Json::Num(PROTO_VERSION as f64)),
            ("id".to_string(), Json::Num(0.0)),
            ("spans".to_string(), Json::Str("not an array".to_string())),
            ("ok".to_string(), Json::Obj(vec![
                ("type".to_string(), Json::Str("pong".to_string())),
                ("version".to_string(), Json::Num(1.0)),
            ])),
        ]);
        assert!(decode_response_traced(doc.render().as_bytes()).is_err());
    }

    #[test]
    fn version_mismatch_rejected_before_the_body() {
        let doc = Json::Obj(vec![
            ("v".to_string(), Json::Num(99.0)),
            ("id".to_string(), Json::Num(0.0)),
            // body is deliberate garbage: it must never be inspected
            ("req".to_string(), Json::Str("not a request".to_string())),
        ]);
        let err = decode_request(doc.render().as_bytes()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("version mismatch"), "{msg}");
    }

    #[test]
    fn malformed_payloads_are_typed_errors() {
        assert!(decode_request(b"{not json").is_err());
        assert!(decode_request(b"\xff\xfe").is_err());
        assert!(decode_request(b"{\"v\":1}").is_err(), "missing id/req");
        assert!(decode_response(b"{\"v\":1,\"id\":0}").is_err(), "neither ok nor err");
        // an episode with the wrong interval arity must reject, not panic
        let bad = Json::Obj(vec![
            ("types".to_string(), Json::Arr(vec![Json::Num(0.0), Json::Num(1.0)])),
            ("intervals".to_string(), Json::Arr(vec![])),
        ]);
        assert!(episode_from_json(&bad).is_err());
        // and a degenerate interval likewise
        let bad = Json::Obj(vec![
            ("types".to_string(), Json::Arr(vec![Json::Num(0.0), Json::Num(1.0)])),
            (
                "intervals".to_string(),
                Json::Arr(vec![Json::Arr(vec![Json::Num(5.0), Json::Num(5.0)])]),
            ),
        ]);
        assert!(episode_from_json(&bad).is_err());
    }

    #[test]
    fn range_fingerprint_is_content_identity() {
        let stream = Arc::new(EventStream::from_pairs(
            vec![(0, 1), (1, 4), (2, 8), (0, 20), (1, 24)],
            3,
        ));
        let fp = range_fingerprint(&stream, 0, 30);
        assert_eq!(fp, range_fingerprint(&stream, 0, 30), "deterministic");
        assert_ne!(fp, range_fingerprint(&stream, 0, 20), "window matters");
        let moved = Arc::new(EventStream::from_pairs(
            vec![(0, 1), (1, 4), (2, 9), (0, 20), (1, 24)],
            3,
        ));
        assert_ne!(fp, range_fingerprint(&moved, 0, 30), "contents matter");
    }
}
