//! Tenant-aware admission for the scatter coordinator.
//!
//! The serving layer already bounds *queue depth* (`serve/pool.rs`
//! rejects into [`MineError::Busy`] when its job queue fills). A
//! coordinator fronting a whole cluster needs a second, tenant-shaped
//! gate in front of that: one tenant issuing huge range queries must
//! not starve everyone else's small ones, and when the cluster
//! saturates, *who* waits should follow priority, not arrival order.
//!
//! [`AdmissionController`] is a counting gate with three rules:
//!
//! 1. **Quotas** — each tenant holds at most
//!    [`TenantQuota::max_in_flight`] concurrent mines, and the
//!    coordinator holds at most [`AdmissionConfig::total_in_flight`]
//!    overall. Within quota, admission is immediate.
//! 2. **Priority queue** — over-quota arrivals wait (bounded by
//!    [`AdmissionConfig::queue_capacity`]). Releases grant the
//!    highest-priority, earliest-arrived *eligible* waiter — a waiter
//!    whose own tenant is still at quota never blocks a grantable one
//!    behind it.
//! 3. **Load shedding** — when the wait queue itself is full, either
//!    the incoming request is rejected with a typed
//!    [`MineError::Busy`], or — if the arrival outranks the
//!    lowest-priority waiter — that waiter is shed (woken with `Busy`)
//!    to make room. Shedding the cheapest victim under pressure is
//!    what keeps high-priority latency flat while the cluster is
//!    saturated; `sheds` in the metrics counts every such eviction or
//!    rejection.
//!
//! Grants are RAII [`Permit`]s: dropping one releases the slot and
//! wakes the queue, so an early return or panic in the mining path can
//! never leak capacity.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex, MutexGuard};

use crate::error::MineError;

/// Per-tenant admission parameters. Higher `priority` wins queue
/// position and survives shedding longer.
#[derive(Clone, Copy, Debug)]
pub struct TenantQuota {
    /// concurrent mines this tenant may hold
    pub max_in_flight: usize,
    /// queue rank (higher = served first, shed last)
    pub priority: u8,
}

impl Default for TenantQuota {
    fn default() -> TenantQuota {
        TenantQuota { max_in_flight: 4, priority: 0 }
    }
}

/// Coordinator-wide admission parameters.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// total concurrent mines across all tenants
    pub total_in_flight: usize,
    /// bounded wait queue for over-quota arrivals (0 = never queue:
    /// over-quota arrivals shed immediately)
    pub queue_capacity: usize,
    /// quota applied to tenants with no explicit entry
    pub default_quota: TenantQuota,
    /// explicit per-tenant overrides
    pub tenants: Vec<(String, TenantQuota)>,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            total_in_flight: 16,
            queue_capacity: 64,
            default_quota: TenantQuota::default(),
            tenants: Vec::new(),
        }
    }
}

impl AdmissionConfig {
    pub fn validate(&self) -> Result<(), MineError> {
        if self.total_in_flight == 0 {
            return Err(MineError::invalid("AdmissionConfig::total_in_flight must be >= 1"));
        }
        if self.default_quota.max_in_flight == 0 {
            return Err(MineError::invalid(
                "AdmissionConfig::default_quota.max_in_flight must be >= 1",
            ));
        }
        if let Some((t, _)) =
            self.tenants.iter().find(|(_, q)| q.max_in_flight == 0)
        {
            return Err(MineError::invalid(format!(
                "tenant {t:?} quota max_in_flight must be >= 1"
            )));
        }
        Ok(())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WaiterState {
    Waiting,
    Granted,
    Shed,
}

struct Waiter {
    id: u64,
    tenant: String,
    priority: u8,
    state: WaiterState,
}

struct State {
    total: usize,
    per_tenant: HashMap<String, usize>,
    waiters: Vec<Waiter>,
    next_id: u64,
    sheds: u64,
}

/// The tenant-aware counting gate. See the module docs for semantics.
pub struct AdmissionController {
    cfg: AdmissionConfig,
    quotas: HashMap<String, TenantQuota>,
    state: Mutex<State>,
    cv: Condvar,
}

/// An admitted slot. Dropping it releases capacity and wakes the
/// highest-priority eligible waiter.
pub struct Permit<'a> {
    ctl: &'a AdmissionController,
    tenant: String,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.ctl.release(&self.tenant);
    }
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Result<AdmissionController, MineError> {
        cfg.validate()?;
        let quotas = cfg.tenants.iter().cloned().collect();
        Ok(AdmissionController {
            cfg,
            quotas,
            state: Mutex::new(State {
                total: 0,
                per_tenant: HashMap::new(),
                waiters: Vec::new(),
                next_id: 0,
                sheds: 0,
            }),
            cv: Condvar::new(),
        })
    }

    fn quota(&self, tenant: &str) -> TenantQuota {
        self.quotas.get(tenant).copied().unwrap_or(self.cfg.default_quota)
    }

    fn lock(&self) -> MutexGuard<'_, State> {
        // a panicked holder leaves counters consistent (every mutation
        // completes under one lock acquisition), so poisoning is safe
        // to strip
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn can_grant(&self, s: &State, tenant: &str) -> bool {
        s.total < self.cfg.total_in_flight
            && s.per_tenant.get(tenant).copied().unwrap_or(0)
                < self.quota(tenant).max_in_flight
    }

    fn grant(&self, s: &mut State, tenant: &str) {
        s.total += 1;
        *s.per_tenant.entry(tenant.to_string()).or_insert(0) += 1;
    }

    /// Non-blocking admission: a permit if the tenant is within quota
    /// right now, `None` otherwise. Never queues, never sheds.
    pub fn try_admit(&self, tenant: &str) -> Option<Permit<'_>> {
        let mut s = self.lock();
        if self.can_grant(&s, tenant) {
            self.grant(&mut s, tenant);
            Some(Permit { ctl: self, tenant: tenant.to_string() })
        } else {
            None
        }
    }

    /// Blocking admission: returns a permit once capacity frees, or
    /// [`MineError::Busy`] if the wait queue is full (or this waiter is
    /// shed by a higher-priority arrival while queued).
    pub fn admit(&self, tenant: &str) -> Result<Permit<'_>, MineError> {
        let priority = self.quota(tenant).priority;
        let mut s = self.lock();
        if self.can_grant(&s, tenant) {
            self.grant(&mut s, tenant);
            return Ok(Permit { ctl: self, tenant: tenant.to_string() });
        }

        if s.waiters.len() >= self.cfg.queue_capacity {
            // full queue: shed the lowest-priority latest waiter if this
            // arrival outranks it, else reject the arrival itself
            let victim = s
                .waiters
                .iter_mut()
                .filter(|w| w.state == WaiterState::Waiting)
                .min_by_key(|w| (w.priority, std::cmp::Reverse(w.id)));
            match victim {
                Some(v) if v.priority < priority => {
                    v.state = WaiterState::Shed;
                    s.sheds += 1;
                    self.cv.notify_all();
                }
                _ => {
                    s.sheds += 1;
                    let depth = s.waiters.len();
                    return Err(MineError::Busy {
                        queue_depth: depth,
                        capacity: self.cfg.queue_capacity,
                    });
                }
            }
        }

        let id = s.next_id;
        s.next_id += 1;
        s.waiters.push(Waiter {
            id,
            tenant: tenant.to_string(),
            priority,
            state: WaiterState::Waiting,
        });

        loop {
            let outcome = s
                .waiters
                .iter()
                .find(|w| w.id == id)
                .map(|w| w.state)
                .unwrap_or(WaiterState::Shed);
            match outcome {
                WaiterState::Waiting => s = self.cv.wait(s).unwrap_or_else(|p| p.into_inner()),
                done => {
                    s.waiters.retain(|w| w.id != id);
                    return match done {
                        WaiterState::Granted => {
                            Ok(Permit { ctl: self, tenant: tenant.to_string() })
                        }
                        _ => {
                            let depth = s.waiters.len();
                            Err(MineError::Busy {
                                queue_depth: depth,
                                capacity: self.cfg.queue_capacity,
                            })
                        }
                    };
                }
            }
        }
    }

    fn release(&self, tenant: &str) {
        let mut s = self.lock();
        s.total = s.total.saturating_sub(1);
        if let Some(n) = s.per_tenant.get_mut(tenant) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                s.per_tenant.remove(tenant);
            }
        }
        // grant every now-eligible waiter: highest priority first,
        // earliest arrival breaking ties; ineligible (still over their
        // own quota) waiters are skipped, not blocking
        loop {
            let next = s
                .waiters
                .iter()
                .filter(|w| {
                    w.state == WaiterState::Waiting && self.can_grant(&s, &w.tenant)
                })
                .max_by_key(|w| (w.priority, std::cmp::Reverse(w.id)))
                .map(|w| w.id);
            let Some(id) = next else { break };
            let tenant = {
                let w = s.waiters.iter_mut().find(|w| w.id == id).expect("waiter exists");
                w.state = WaiterState::Granted;
                w.tenant.clone()
            };
            self.grant(&mut s, &tenant);
        }
        self.cv.notify_all();
    }

    /// Currently admitted mines.
    pub fn in_flight(&self) -> usize {
        self.lock().total
    }

    /// Waiters currently queued.
    pub fn queued(&self) -> usize {
        self.lock().waiters.iter().filter(|w| w.state == WaiterState::Waiting).count()
    }

    /// Cumulative shed + reject count (the saturation signal).
    pub fn sheds(&self) -> u64 {
        self.lock().sheds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ctl(total: usize, queue: usize, tenants: Vec<(String, TenantQuota)>) -> AdmissionController {
        AdmissionController::new(AdmissionConfig {
            total_in_flight: total,
            queue_capacity: queue,
            default_quota: TenantQuota { max_in_flight: 2, priority: 0 },
            tenants,
        })
        .unwrap()
    }

    #[test]
    fn quotas_bound_each_tenant_and_the_total() {
        let c = ctl(3, 8, vec![]);
        let a1 = c.try_admit("a").unwrap();
        let _a2 = c.try_admit("a").unwrap();
        assert!(c.try_admit("a").is_none(), "tenant quota (2) reached");
        let _b1 = c.try_admit("b").unwrap();
        assert!(c.try_admit("b").is_none(), "total (3) reached");
        drop(a1);
        assert!(c.try_admit("b").is_some(), "release frees the total");
    }

    #[test]
    fn full_queue_rejects_into_busy() {
        let c = ctl(1, 0, vec![]);
        let _hold = c.try_admit("a").unwrap();
        match c.admit("b") {
            Err(MineError::Busy { capacity: 0, .. }) => {}
            other => panic!("expected Busy, got {other:?}"),
        }
        assert_eq!(c.sheds(), 1);
    }

    #[test]
    fn release_grants_by_priority_then_arrival() {
        let quotas = vec![
            ("lo".to_string(), TenantQuota { max_in_flight: 2, priority: 1 }),
            ("hi".to_string(), TenantQuota { max_in_flight: 2, priority: 5 }),
        ];
        let c = Arc::new(ctl(1, 8, quotas));
        let hold = c.try_admit("seed").unwrap();

        let spawn_waiter = |tenant: &str| {
            let c = Arc::clone(&c);
            let tenant = tenant.to_string();
            std::thread::spawn(move || {
                let p = c.admit(&tenant).expect("granted eventually");
                std::thread::sleep(std::time::Duration::from_millis(5));
                drop(p);
                tenant
            })
        };

        let lo = spawn_waiter("lo");
        // ensure lo is queued before hi arrives
        while c.queued() < 1 {
            std::thread::yield_now();
        }
        let hi = spawn_waiter("hi");
        while c.queued() < 2 {
            std::thread::yield_now();
        }

        drop(hold);
        // both eventually complete; hi was granted first (it finishes
        // strictly before lo can even start, since total=1)
        hi.join().unwrap();
        lo.join().unwrap();
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn higher_priority_arrival_sheds_the_lowest_waiter() {
        let quotas = vec![
            ("lo".to_string(), TenantQuota { max_in_flight: 2, priority: 0 }),
            ("hi".to_string(), TenantQuota { max_in_flight: 2, priority: 9 }),
        ];
        let c = Arc::new(ctl(1, 1, quotas));
        let hold = c.try_admit("seed").unwrap();

        let c2 = Arc::clone(&c);
        let lo = std::thread::spawn(move || c2.admit("lo"));
        while c.queued() < 1 {
            std::thread::yield_now();
        }

        // queue is full (capacity 1); hi outranks lo → lo is shed
        let c3 = Arc::clone(&c);
        let hi = std::thread::spawn(move || c3.admit("hi"));
        let lo_result = lo.join().unwrap();
        assert!(
            matches!(lo_result, Err(MineError::Busy { .. })),
            "low-priority waiter shed: {lo_result:?}"
        );
        assert_eq!(c.sheds(), 1);

        drop(hold);
        let hi_permit = hi.join().unwrap();
        assert!(hi_permit.is_ok(), "high-priority waiter granted after release");
    }

    #[test]
    fn permit_drop_is_exception_safe() {
        let c = ctl(1, 4, vec![]);
        {
            let _p = c.try_admit("a").unwrap();
            assert_eq!(c.in_flight(), 1);
        }
        assert_eq!(c.in_flight(), 0, "drop released the slot");
    }

    #[test]
    fn config_validation() {
        assert!(AdmissionController::new(AdmissionConfig {
            total_in_flight: 0,
            ..AdmissionConfig::default()
        })
        .is_err());
        let bad = AdmissionConfig {
            tenants: vec![("t".to_string(), TenantQuota { max_in_flight: 0, priority: 0 })],
            ..AdmissionConfig::default()
        };
        assert!(AdmissionController::new(bad).is_err());
    }
}
