//! The scatter-gather coordinator: one `log:` range query fanned out
//! across mining nodes, merged back byte-identical to a single-process
//! mine.
//!
//! # Where the exactness comes from
//!
//! The coordinator does NOT merge per-node `MineResult`s — episode sets
//! from independently-mined shards cannot be reconciled exactly (an
//! episode frequent in the union may be infrequent in every shard).
//! Instead the coordinator runs the *exact same level-wise driver* a
//! local session runs ([`mine_with_backend`]) over the range stream it
//! reads from its own log replica, and distributes only the *counting*:
//! [`ClusterBackend`] implements [`CountBackend`] by planning the range
//! into per-segment-group time windows and asking each node for the
//! boundary-machine Map tuples of its window (`MapCount`), then folding
//! them with [`mapconcat::concatenate_fold`] exactly like the
//! stream-sharded CPU engine does across threads. Flagged concatenate
//! misses are recounted against the coordinator's own stream, so counts
//! always equal the serial reference — the same invariant
//! `backend/sharded.rs` pins, with machines crossing the wire instead of
//! a `thread::scope`.
//!
//! Three wrinkles the wire adds over in-process sharding:
//!
//! - **Alphabet translation.** Levels ≥ 2 of the driver hand this
//!   backend *dense-id* episodes over the frequency-remapped stream;
//!   nodes hold the raw log in original ids. Episodes are inverted back
//!   to original ids before every RPC (the remap is a count-preserving
//!   bijection, and the coordinator's independently-computed remap is
//!   provably the driver's: level-1 counts are always the type
//!   frequencies, even two-pass, because A2 of a 1-node episode *is* its
//!   frequency). Machine tuples `(a, count, b)` are type-free, so
//!   responses need no mapping.
//! - **Clamped halos.** The coordinator's reference stream is
//!   range-windowed, so nodes clamp their halo scans to the query range —
//!   an unclamped halo would count events the single-process mine never
//!   sees (see `cluster/node.rs`).
//! - **Content fingerprints.** Every counting RPC names the windowed
//!   stream it was planned against; a node whose replica diverged fails
//!   the sub-mine (typed [`MineError::Corrupt`]) rather than merging
//!   wrong counts.
//!
//! # Failure semantics
//!
//! Transport failures (I/O errors, garbled frames — anything tagged with
//! the [`proto::WIRE`] path) mark the node unhealthy for the rest of the
//! query and the window is retried on the next surviving node (a
//! *re-plan*: dead nodes' windows are re-scattered, never dropped). When
//! retries are exhausted or no node survives, the coordinator counts the
//! window itself from its local stream (`local_fallbacks` in
//! [`ClusterMetrics`]) — the query degrades to single-process speed, not
//! to a wrong answer. Application errors (invalid options, fingerprint
//! mismatch, candidate explosion) are *not* retried: they would fail
//! identically everywhere, so they propagate and fail the mine.
//! Stragglers are optionally hedged: if a window's reply is slower than
//! `hedge_after`, a duplicate is dispatched to another healthy node and
//! the first answer wins.
//!
//! # Admission
//!
//! The coordinator front-door is tenant-aware
//! ([`super::admission::AdmissionController`]): per-tenant in-flight
//! quotas, priority-then-arrival granting, and bounded queueing that
//! sheds into typed [`MineError::Busy`] under saturation — cluster
//! capacity is spent by policy, not arrival order.
//!
//! [`mine_with_backend`]: crate::session::mine_with_backend

use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::backend::two_pass::TwoPassBackend;
use crate::backend::{count_grouped, CountBackend, CountReport};
use crate::coordinator::mapconcat;
use crate::coordinator::miner::MineResult;
use crate::coordinator::Metrics;
use crate::episodes::arena::AlphabetRemap;
use crate::episodes::Episode;
use crate::error::MineError;
use crate::events::{EventStream, Tick};
use crate::ingest::SpikeLog;
use crate::mining::serial;
use crate::obs::{Counter, Gauge, Histogram, Registry, SpanGuard, Trace};
use crate::serve::ServiceConfig;
use crate::session::{mine_with_backend_obs, MineOptions};
use crate::util::json::Json;
use crate::util::stats::Summary;

use super::admission::{AdmissionConfig, AdmissionController};
use super::node::NodeState;
use super::proto::{self, Request, Response};

/// Per-node latency samples kept for the metrics percentiles; older
/// samples age out so a long-lived coordinator reflects recent behavior.
const LATENCY_WINDOW: usize = 2048;

/// Grace added on top of the per-RPC deadline when draining hedged
/// results (the calls themselves are deadline-bounded; the slack only
/// covers scheduling).
const DEADLINE_SLACK: Duration = Duration::from_millis(500);

/// One request/response transport to a node. Implementations must be
/// cheap to call concurrently — the coordinator scatters windows from
/// scoped threads.
pub trait NodeLink: Send + Sync {
    /// Send one encoded request frame and wait for the reply frame,
    /// bounded by `deadline`.
    fn call(&self, request: &[u8], deadline: Duration) -> Result<Vec<u8>, MineError>;

    /// Human-readable peer name for metrics (`host:port`, `local#2`).
    fn describe(&self) -> String;
}

/// TCP transport: one short-lived connection per call. Connection setup
/// on a LAN is microseconds against sub-mines that run for milliseconds,
/// and per-call connections mean a node restart needs no reconnect logic
/// anywhere.
pub struct TcpLink {
    addr: String,
}

impl TcpLink {
    pub fn new(addr: impl Into<String>) -> TcpLink {
        TcpLink { addr: addr.into() }
    }
}

impl NodeLink for TcpLink {
    fn call(&self, request: &[u8], deadline: Duration) -> Result<Vec<u8>, MineError> {
        let mut conn = match self.addr.parse::<std::net::SocketAddr>() {
            Ok(sa) => TcpStream::connect_timeout(&sa, deadline),
            Err(_) => TcpStream::connect(&self.addr),
        }
        .map_err(|e| MineError::io(format!("connect {}", self.addr), e))?;
        let _ = conn.set_nodelay(true);
        conn.set_read_timeout(Some(deadline))
            .map_err(|e| MineError::io(format!("configure {}", self.addr), e))?;
        conn.set_write_timeout(Some(deadline))
            .map_err(|e| MineError::io(format!("configure {}", self.addr), e))?;
        proto::write_frame(&mut conn, request)?;
        match proto::read_frame(&mut conn)? {
            Some(reply) => Ok(reply),
            None => Err(MineError::corrupt(
                proto::WIRE,
                format!("{} closed the connection mid-exchange", self.addr),
            )),
        }
    }

    fn describe(&self) -> String {
        self.addr.clone()
    }
}

// ---------------------------------------------------------------------------
// LocalCluster: in-process nodes with injectable faults
// ---------------------------------------------------------------------------

/// Injectable misbehavior for a [`LocalCluster`] node. Every fault acts
/// at the transport boundary, *after* the request bytes are accepted —
/// the same place real networks fail — so the retry/hedge/fallback
/// machinery under test is exactly what production traffic exercises.
#[derive(Clone, Copy, Debug, Default)]
pub enum Fault {
    /// serve normally
    #[default]
    None,
    /// swallow requests without replying (callers see a fast disconnect,
    /// like a RST — not a burned deadline)
    Drop,
    /// serve after sleeping — a straggler, not a failure
    Delay(Duration),
    /// serve, then truncate the reply frame to half (guaranteed garbled)
    Corrupt,
    /// serve `n` more requests, then die mid-request like a crashed
    /// process: the in-hand request and everything queued behind it get
    /// no reply, ever
    DieAfter(usize),
}

enum WorkerAction {
    Serve(Option<Duration>),
    DropIt,
    CorruptIt,
    Die,
}

type Job = (Vec<u8>, mpsc::Sender<Vec<u8>>);

struct LocalNodeInner {
    /// `None` after [`LocalCluster::kill`]; senders are cloned under the
    /// lock per call, so a kill makes every later call fail fast
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    fault: Arc<Mutex<Fault>>,
    name: String,
}

/// Threads-as-nodes harness: each node runs a real [`NodeState`] (its
/// own log handle and embedded service) on a dedicated worker thread,
/// fed raw frame bytes through a channel — the full codec and dispatch
/// path of a TCP node, minus the socket. Tests and the bench suite get
/// genuine multi-node concurrency (workers serve in parallel) and
/// deterministic fault injection without binding a port.
pub struct LocalCluster {
    dir: PathBuf,
    service: ServiceConfig,
    nodes: Vec<Arc<LocalNodeInner>>,
}

fn spawn_worker(
    dir: &Path,
    service: ServiceConfig,
    fault: Arc<Mutex<Fault>>,
) -> Result<mpsc::Sender<Job>, MineError> {
    let (tx, rx) = mpsc::channel::<Job>();
    let (ready_tx, ready_rx) = mpsc::channel();
    let dir = dir.to_path_buf();
    std::thread::spawn(move || {
        // built on the worker thread: startup errors report through the
        // ready channel, and the state never crosses threads
        let state = match NodeState::open(&dir, service) {
            Ok(s) => {
                let _ = ready_tx.send(Ok(()));
                s
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        for (bytes, reply) in rx {
            let action = {
                let mut f = fault.lock().unwrap_or_else(|p| p.into_inner());
                match *f {
                    Fault::None => WorkerAction::Serve(None),
                    Fault::Delay(d) => WorkerAction::Serve(Some(d)),
                    Fault::Drop => WorkerAction::DropIt,
                    Fault::Corrupt => WorkerAction::CorruptIt,
                    Fault::DieAfter(0) => WorkerAction::Die,
                    Fault::DieAfter(n) => {
                        *f = Fault::DieAfter(n - 1);
                        WorkerAction::Serve(None)
                    }
                }
            };
            match action {
                // dropping `reply` (and, for Die, the whole receiver)
                // unblocks callers immediately with a disconnect
                WorkerAction::Die => return,
                WorkerAction::DropIt => continue,
                WorkerAction::Serve(delay) => {
                    if let Some(d) = delay {
                        std::thread::sleep(d);
                    }
                    let _ = reply.send(state.handle_frame(&bytes));
                }
                WorkerAction::CorruptIt => {
                    let mut out = state.handle_frame(&bytes);
                    out.truncate(out.len() / 2);
                    let _ = reply.send(out);
                }
            }
        }
    });
    match ready_rx.recv() {
        Ok(Ok(())) => Ok(tx),
        Ok(Err(e)) => Err(e),
        Err(_) => Err(MineError::internal(
            "local node worker exited before reporting readiness",
        )),
    }
}

impl LocalCluster {
    /// Start `n` nodes, each opening its own handle on the log at `dir`
    /// (the in-process stand-in for n replicas of the same recording).
    pub fn start(dir: &Path, n: usize, service: ServiceConfig) -> Result<LocalCluster, MineError> {
        if n == 0 {
            return Err(MineError::invalid("a LocalCluster needs at least one node"));
        }
        let mut nodes = Vec::with_capacity(n);
        for i in 0..n {
            let fault = Arc::new(Mutex::new(Fault::None));
            let tx = spawn_worker(dir, service.clone(), Arc::clone(&fault))?;
            nodes.push(Arc::new(LocalNodeInner {
                tx: Mutex::new(Some(tx)),
                fault,
                name: format!("local#{i}"),
            }));
        }
        Ok(LocalCluster { dir: dir.to_path_buf(), service, nodes })
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// One [`NodeLink`] per node, in node order — feed these to
    /// [`ScatterMiner::connect`].
    pub fn links(&self) -> Vec<Arc<dyn NodeLink>> {
        self.nodes
            .iter()
            .map(|n| Arc::new(LocalLink { node: Arc::clone(n) }) as Arc<dyn NodeLink>)
            .collect()
    }

    /// Inject (or clear) a fault on node `i`, effective from its next
    /// request.
    pub fn set_fault(&self, i: usize, fault: Fault) {
        *self.nodes[i].fault.lock().unwrap_or_else(|p| p.into_inner()) = fault;
    }

    /// Hard-kill node `i`: pending and future calls fail fast with a
    /// transport error (the worker exits once in-flight sends drain).
    pub fn kill(&self, i: usize) {
        self.nodes[i].tx.lock().unwrap_or_else(|p| p.into_inner()).take();
    }

    /// Restart node `i` with a fresh worker and a clean fault slate.
    pub fn revive(&self, i: usize) -> Result<(), MineError> {
        self.set_fault(i, Fault::None);
        let tx = spawn_worker(&self.dir, self.service.clone(), Arc::clone(&self.nodes[i].fault))?;
        *self.nodes[i].tx.lock().unwrap_or_else(|p| p.into_inner()) = Some(tx);
        Ok(())
    }
}

struct LocalLink {
    node: Arc<LocalNodeInner>,
}

impl NodeLink for LocalLink {
    fn call(&self, request: &[u8], deadline: Duration) -> Result<Vec<u8>, MineError> {
        let tx = {
            let guard = self.node.tx.lock().unwrap_or_else(|p| p.into_inner());
            match &*guard {
                Some(tx) => tx.clone(),
                None => {
                    return Err(MineError::io(
                        format!("send to {}", self.node.name),
                        std::io::Error::new(std::io::ErrorKind::NotConnected, "node is down"),
                    ))
                }
            }
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        if tx.send((request.to_vec(), reply_tx)).is_err() {
            return Err(MineError::io(
                format!("send to {}", self.node.name),
                std::io::Error::new(std::io::ErrorKind::ConnectionAborted, "node worker is gone"),
            ));
        }
        match reply_rx.recv_timeout(deadline) {
            Ok(reply) => Ok(reply),
            Err(RecvTimeoutError::Timeout) => Err(MineError::io(
                format!("await {}", self.node.name),
                std::io::Error::new(std::io::ErrorKind::TimedOut, "deadline exceeded"),
            )),
            Err(RecvTimeoutError::Disconnected) => Err(MineError::io(
                format!("await {}", self.node.name),
                std::io::Error::new(std::io::ErrorKind::ConnectionReset, "node dropped the request"),
            )),
        }
    }

    fn describe(&self) -> String {
        self.node.name.clone()
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// Scatter-side knobs. Defaults suit tests and LAN clusters; production
/// deployments mostly tune `deadline` and `admission`.
#[derive(Clone, Debug)]
pub struct ScatterConfig {
    /// log segments per scatter window (>= 1); larger groups mean fewer,
    /// bigger sub-counts per level
    pub group_segments: usize,
    /// per-RPC deadline (also bounds each hedged duplicate)
    pub deadline: Duration,
    /// extra attempts after the first, each on the next surviving node
    pub retries: usize,
    /// hedge a duplicate request onto another healthy node if the first
    /// has not answered within this; `None` disables hedging
    pub hedge_after: Option<Duration>,
    /// bounded-K occurrence lists (`usize::MAX` = unbounded, exact A1)
    pub k: usize,
    /// coordinator admission: per-tenant quotas, priorities, shedding
    pub admission: AdmissionConfig,
}

impl Default for ScatterConfig {
    fn default() -> ScatterConfig {
        ScatterConfig {
            group_segments: 1,
            deadline: Duration::from_secs(30),
            retries: 2,
            hedge_after: None,
            k: usize::MAX,
            admission: AdmissionConfig::default(),
        }
    }
}

/// Live registry handles for one node's call accounting. Replaces the
/// old lock-per-node `Mutex<NodeStat>`: each handle wraps its own atomic
/// (or, for the latency histogram, its own windowed buffer), so scatter
/// threads on different nodes never contend, and the numbers land in the
/// unified [`Registry`] where `epminer stats` reads them.
struct NodeHandles {
    calls: Counter,
    failures: Counter,
    in_flight: Gauge,
    latency_ns: Histogram,
}

impl NodeHandles {
    fn register(registry: &Registry, i: usize) -> NodeHandles {
        NodeHandles {
            calls: registry.counter(&format!("cluster.node.{i}.calls")),
            failures: registry.counter(&format!("cluster.node.{i}.failures")),
            in_flight: registry.gauge(&format!("cluster.node.{i}.in_flight")),
            latency_ns: registry
                .histogram_windowed(&format!("cluster.node.{i}.latency_ns"), LATENCY_WINDOW),
        }
    }
}

/// State shared by every scatter thread of every query on one miner.
struct ClusterShared {
    links: Vec<Arc<dyn NodeLink>>,
    /// per-query health: reset at mine start, flipped false on transport
    /// failure so later windows skip known-dead nodes
    healthy: Vec<AtomicBool>,
    /// the unified metrics namespace (`cluster.*`); the handles below
    /// are live views into it
    registry: Registry,
    nodes: Vec<NodeHandles>,
    next_id: AtomicU64,
    retries_total: Counter,
    hedges: Counter,
    replans: Counter,
    local_fallbacks: Counter,
    deadline: Duration,
    hedge_after: Option<Duration>,
    retries: usize,
}

/// Transport errors are the node's *delivery* failing — retryable on
/// another replica. Everything else (including a node's on-disk
/// corruption report) is an application answer and must propagate.
fn is_transport(e: &MineError) -> bool {
    match e {
        MineError::Io { .. } => true,
        MineError::Corrupt { path, .. } => path == proto::WIRE,
        _ => false,
    }
}

fn no_survivors() -> MineError {
    MineError::io(
        "scatter",
        std::io::Error::new(std::io::ErrorKind::NotConnected, "no healthy nodes remain"),
    )
}

impl ClusterShared {
    fn healthy_after(&self, start: usize) -> Option<usize> {
        let n = self.links.len();
        (0..n).map(|off| (start + off) % n).find(|&i| self.healthy[i].load(Ordering::Relaxed))
    }

    fn other_healthy(&self, not: usize) -> Option<usize> {
        (0..self.links.len()).find(|&i| i != not && self.healthy[i].load(Ordering::Relaxed))
    }

    /// One stat-recorded exchange with `node`: send, receive, decode,
    /// check the correlation id, unwrap the typed outcome — plus any
    /// node-side spans the reply envelope carried.
    fn raw_call(
        &self,
        node: usize,
        bytes: &[u8],
        id: u64,
    ) -> Result<(Response, Vec<crate::obs::SpanRecord>), MineError> {
        let h = &self.nodes[node];
        h.calls.inc();
        h.in_flight.add(1);
        let t0 = Instant::now();
        let out = self.links[node].call(bytes, self.deadline).and_then(|reply| {
            let (rid, outcome, spans) = proto::decode_response_traced(&reply)?;
            // id 0 is the node's "your frame would not decode" channel
            if rid != id && rid != 0 {
                return Err(MineError::corrupt(
                    proto::WIRE,
                    format!("response correlation id {rid} does not match request {id}"),
                ));
            }
            outcome.map(|resp| (resp, spans))
        });
        h.in_flight.add(-1);
        h.latency_ns.observe(t0.elapsed().as_nanos() as f64);
        if out.is_err() {
            h.failures.inc();
        }
        out
    }
}

/// One possibly-hedged attempt against `node`. Without hedging this is a
/// plain call; with it, a duplicate goes to another healthy node once
/// `hedge_after` elapses, and the first answer (success preferred) wins.
/// Detached call threads are harmless: every call is deadline-bounded,
/// and a late send to the dropped receiver is ignored.
fn attempt(
    shared: &Arc<ClusterShared>,
    node: usize,
    bytes: &Arc<Vec<u8>>,
    id: u64,
) -> Result<(usize, Response, Vec<crate::obs::SpanRecord>), MineError> {
    let Some(hedge_after) = shared.hedge_after else {
        return shared.raw_call(node, bytes, id).map(|(resp, spans)| (node, resp, spans));
    };
    let (tx, rx) = mpsc::channel();
    let spawn_call = |n: usize| {
        let shared = Arc::clone(shared);
        let bytes = Arc::clone(bytes);
        let tx = tx.clone();
        std::thread::spawn(move || {
            let _ = tx.send(shared.raw_call(n, &bytes, id).map(|(resp, spans)| (n, resp, spans)));
        });
    };
    spawn_call(node);
    let mut outstanding = 1usize;
    let mut hedged = false;
    let mut last_err: Option<MineError> = None;
    loop {
        let wait = if hedged { shared.deadline + DEADLINE_SLACK } else { hedge_after };
        match rx.recv_timeout(wait) {
            Ok(Ok(resp)) => return Ok(resp),
            Ok(Err(e)) => {
                last_err = Some(e);
                outstanding -= 1;
                if outstanding == 0 {
                    return Err(last_err.expect("just set"));
                }
            }
            Err(_) if !hedged => {
                // stop waiting at hedge_after exactly once, whether or
                // not a backup exists to hedge onto
                hedged = true;
                if let Some(backup) = shared.other_healthy(node) {
                    shared.hedges.inc();
                    spawn_call(backup);
                    outstanding += 1;
                }
            }
            Err(_) => {
                return Err(last_err.unwrap_or_else(|| {
                    MineError::io(
                        format!("await {}", shared.links[node].describe()),
                        std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "hedged call deadline exceeded",
                        ),
                    )
                }));
            }
        }
    }
}

/// Send `req` to `preferred`, failing over across surviving nodes on
/// transport errors (each failure marks its node unhealthy and burns one
/// retry). Success on a node other than the planned one is a re-plan.
///
/// When `trace` is live, the request carries its trace id and any spans
/// the winning node recorded are grafted into the coordinator's tree
/// under span `under`, tagged with the peer's name — the merged tree a
/// [`Trace::render_tree`] shows per remote RPC.
fn call_with_failover(
    shared: &Arc<ClusterShared>,
    req: &Request,
    preferred: usize,
    trace: &Trace,
    under: u64,
) -> Result<Response, MineError> {
    let id = shared.next_id.fetch_add(1, Ordering::Relaxed) + 1;
    let bytes = Arc::new(proto::encode_request_traced(id, req, trace.id()));
    let mut node = shared.healthy_after(preferred).ok_or_else(no_survivors)?;
    let mut attempts = 0usize;
    loop {
        match attempt(shared, node, &bytes, id) {
            Ok((winner, resp, spans)) => {
                if node != preferred {
                    shared.replans.inc();
                }
                trace.graft(under, &shared.links[winner].describe(), &spans);
                return Ok(resp);
            }
            Err(e) if is_transport(&e) => {
                shared.healthy[node].store(false, Ordering::Relaxed);
                if attempts >= shared.retries {
                    return Err(e);
                }
                attempts += 1;
                shared.retries_total.inc();
                node = match shared.healthy_after(node) {
                    Some(n) => n,
                    None => return Err(e),
                };
            }
            Err(e) => return Err(e),
        }
    }
}

// ---------------------------------------------------------------------------
// The distributed counting backend
// ---------------------------------------------------------------------------

/// The exact serial reference at the cluster's K — the miss-recount path
/// and the no-survivors fallback (same contract as `backend/sharded.rs`).
fn recount_serial(ep: &Episode, stream: &EventStream, k: usize) -> u64 {
    if k == usize::MAX {
        serial::count_a1(ep, stream)
    } else {
        serial::count_a1_bounded(ep, stream, k)
    }
}

/// Thin the base (per-segment-group) boundaries for one level: keep an
/// interior boundary only if it is more than `halo` past the previous
/// kept one, and widen the final window the same way. Narrow windows are
/// legal but wasteful — a boundary machine can span the whole window,
/// making misses (and recounts) likely — so levels with wide constraint
/// windows scatter fewer, wider sub-counts. Exactness never depends on
/// the choice: any window set folds to the reference count or flags a
/// miss.
fn effective_taus(base: &[Tick], halo: Tick) -> Vec<Tick> {
    debug_assert!(base.len() >= 2, "base taus carry at least [t_from, t_to]");
    let t_to = base[base.len() - 1];
    let mut taus = vec![base[0]];
    for &t in &base[1..base.len() - 1] {
        if t - *taus.last().expect("taus is non-empty") > halo {
            taus.push(t);
        }
    }
    while taus.len() > 1 && t_to - *taus.last().expect("taus is non-empty") <= halo {
        taus.pop();
    }
    taus.push(t_to);
    taus
}

/// Scatter-window boundaries for a range: `t_from`, each segment group's
/// last sealed tick (clamped into the range), `t_to`. Segment seals are
/// the natural cut points — they already partition the recording on
/// disk, so a node's window scan prunes whole segment files.
fn base_taus(log: &SpikeLog, group_segments: usize, t_from: Tick, t_to: Tick) -> Vec<Tick> {
    let mut taus = vec![t_from];
    let segs: Vec<_> = log
        .segments()
        .iter()
        .filter(|s| s.t_max > t_from && s.t_min <= t_to)
        .collect();
    for chunk in segs.chunks(group_segments.max(1)) {
        let t = chunk.last().expect("chunks are non-empty").t_max.min(t_to);
        if t > *taus.last().expect("taus is non-empty") && t < t_to {
            taus.push(t);
        }
    }
    taus.push(t_to);
    taus
}

/// [`CountBackend`] over the cluster: MapCount RPCs per scatter window,
/// host-side Concatenate, local recount of flagged misses. Constructed
/// per query by [`ScatterMiner::mine`].
struct ClusterBackend {
    shared: Arc<ClusterShared>,
    remap: AlphabetRemap,
    fingerprint: u64,
    t_from: Tick,
    t_to: Tick,
    base_taus: Vec<Tick>,
    k: usize,
    /// the query's span recorder ([`Trace::off`] when untraced); RPC
    /// requests carry its id and node-side spans graft back into it
    trace: Trace,
}

fn local_map(
    shared: &ClusterShared,
    dense: &[Episode],
    stream: &EventStream,
    lo: Tick,
    hi: Tick,
    halo: Tick,
    k: usize,
) -> Vec<Vec<(Tick, u64, Tick)>> {
    shared.local_fallbacks.inc();
    // the handed stream is already range-restricted, so no clamp here —
    // this window matches the node's clamped scan exactly
    let sub = stream.window(lo - halo, hi + halo);
    dense.iter().map(|ep| serial::mapcat_map(ep, &sub, &[lo, hi], k).swap_remove(0)).collect()
}

fn local_relaxed(
    shared: &ClusterShared,
    idx: &[usize],
    episodes: &[Episode],
    stream: &EventStream,
) -> Vec<u64> {
    shared.local_fallbacks.inc();
    idx.iter().map(|&i| serial::count_a2(&episodes[i], stream)).collect()
}

impl ClusterBackend {
    /// Count one uniform n>=2 group: plan windows, scatter MapCount RPCs
    /// (one scoped thread per window, round-robin preferred nodes), fold
    /// machine chains, recount flagged misses locally.
    fn map_count_group(
        &self,
        group: &[Episode],
        stream: &EventStream,
        m: &mut Metrics,
    ) -> Result<Vec<u64>, MineError> {
        let halo: Tick = group.iter().map(|e| e.span_max()).max().unwrap_or(0);
        let taus = effective_taus(&self.base_taus, halo);
        // wire episodes travel in original ids: nodes hold the raw log,
        // while the driver hands us dense-id episodes at levels >= 2
        let wire: Vec<Episode> = group
            .iter()
            .map(|ep| {
                let mut ep = ep.clone();
                self.remap.invert_episode(&mut ep);
                ep
            })
            .collect();
        m.shard_map_calls += 1;
        let root = self
            .trace
            .span_fmt(|| format!("scatter n={} x{}", group[0].n(), group.len()));
        let per_window = self.scatter_windows(&taus, &wire, group, stream, halo, &root)?;
        let _merge = root.child("merge");
        let mut counts = Vec::with_capacity(group.len());
        for i in 0..group.len() {
            let segments: Vec<Vec<(Tick, u64, Tick)>> =
                per_window.iter().map(|w| w[i].clone()).collect();
            let (total, misses) = mapconcat::concatenate_fold(&segments);
            if misses > 0 {
                // the chain may have desynchronized; restore exactness
                // from the coordinator's own stream (misses are rare, so
                // a serial recount does not dent the win)
                m.concat_misses += misses;
                counts.push(recount_serial(&group[i], stream, self.k));
            } else {
                counts.push(total);
            }
        }
        Ok(counts)
    }

    fn scatter_windows(
        &self,
        taus: &[Tick],
        wire: &[Episode],
        dense: &[Episode],
        stream: &EventStream,
        halo: Tick,
        parent: &SpanGuard,
    ) -> Result<Vec<Vec<Vec<(Tick, u64, Tick)>>>, MineError> {
        let n_nodes = self.shared.links.len();
        let trace = &self.trace;
        let results: Vec<Result<Vec<Vec<(Tick, u64, Tick)>>, MineError>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = taus
                    .windows(2)
                    .enumerate()
                    .map(|(w, bounds)| {
                        let shared = Arc::clone(&self.shared);
                        let (fingerprint, t_from, t_to, k) =
                            (self.fingerprint, self.t_from, self.t_to, self.k);
                        scope.spawn(move || {
                            let (lo, hi) = (bounds[0], bounds[1]);
                            // one span per remote counting RPC; the
                            // node's own spans graft in underneath
                            let rpc =
                                parent.child_fmt(|| format!("rpc map_count ({lo},{hi}]"));
                            let req = Request::MapCount {
                                fingerprint,
                                episodes: wire.to_vec(),
                                t_from,
                                t_to,
                                lo,
                                hi,
                                halo,
                                k,
                            };
                            match call_with_failover(
                                &shared,
                                &req,
                                w % n_nodes,
                                trace,
                                rpc.span_id(),
                            ) {
                                Ok(Response::MapCount { machines })
                                    if machines.len() == dense.len() =>
                                {
                                    Ok(machines)
                                }
                                // a well-formed reply of the wrong shape
                                // is as useless as no reply: count here
                                Ok(_) => Ok(local_map(&shared, dense, stream, lo, hi, halo, k)),
                                Err(e) if is_transport(&e) => {
                                    Ok(local_map(&shared, dense, stream, lo, hi, halo, k))
                                }
                                Err(e) => Err(e),
                            }
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("scatter window worker panicked"))
                    .collect()
            });
        results.into_iter().collect()
    }

    /// Relaxed (A2) counting for the two-pass pre-pass: n=1 answered
    /// locally (A2 of a single node is its type frequency — not worth a
    /// network hop), n>=2 chunked contiguously across healthy nodes.
    fn relaxed_counts(
        &self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<Vec<u64>, MineError> {
        let mut counts = vec![0u64; episodes.len()];
        let mut rest: Vec<usize> = vec![];
        for (i, ep) in episodes.iter().enumerate() {
            if ep.n() == 1 {
                counts[i] = serial::count_a2(ep, stream);
            } else {
                rest.push(i);
            }
        }
        if rest.is_empty() {
            return Ok(counts);
        }
        let wire: Vec<Episode> = rest
            .iter()
            .map(|&i| {
                let mut ep = episodes[i].clone();
                self.remap.invert_episode(&mut ep);
                ep
            })
            .collect();
        let n_nodes = self.shared.links.len();
        let healthy = (0..n_nodes)
            .filter(|&i| self.shared.healthy[i].load(Ordering::Relaxed))
            .count()
            .max(1);
        let per = rest.len().div_ceil(healthy.min(rest.len()));
        let (fingerprint, t_from, t_to) = (self.fingerprint, self.t_from, self.t_to);
        let root =
            self.trace.span_fmt(|| format!("scatter relaxed x{}", rest.len()));
        let parent = &root;
        let trace = &self.trace;
        let results: Vec<Result<Vec<u64>, MineError>> = std::thread::scope(|scope| {
            let handles: Vec<_> = wire
                .chunks(per)
                .zip(rest.chunks(per))
                .enumerate()
                .map(|(c, (wire_chunk, idx_chunk))| {
                    let shared = Arc::clone(&self.shared);
                    scope.spawn(move || {
                        let rpc = parent
                            .child_fmt(|| format!("rpc relaxed_count chunk {c}"));
                        let req = Request::RelaxedCount {
                            fingerprint,
                            episodes: wire_chunk.to_vec(),
                            t_from,
                            t_to,
                        };
                        match call_with_failover(
                            &shared,
                            &req,
                            c % n_nodes,
                            trace,
                            rpc.span_id(),
                        ) {
                            Ok(Response::RelaxedCount { counts })
                                if counts.len() == idx_chunk.len() =>
                            {
                                Ok(counts)
                            }
                            Ok(_) => Ok(local_relaxed(&shared, idx_chunk, episodes, stream)),
                            Err(e) if is_transport(&e) => {
                                Ok(local_relaxed(&shared, idx_chunk, episodes, stream))
                            }
                            Err(e) => Err(e),
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("relaxed chunk worker panicked"))
                .collect()
        });
        let mut slots = rest.iter();
        for chunk in results {
            for c in chunk? {
                counts[*slots.next().expect("one slot per relaxed count")] = c;
            }
        }
        Ok(counts)
    }
}

impl CountBackend for ClusterBackend {
    fn name(&self) -> &str {
        "cluster-scatter"
    }

    fn supports_n(&self, _n: usize) -> bool {
        true
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let mut metrics = Metrics::default();
        let this: &ClusterBackend = self;
        let counts = count_grouped(episodes, stream, &mut metrics, |_n, group, m| {
            this.map_count_group(group, stream, m)
        })?;
        Ok(CountReport { counts, culled: 0, metrics })
    }

    fn count_relaxed(
        &mut self,
        episodes: &[Episode],
        stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        let counts = self.relaxed_counts(episodes, stream)?;
        let mut report = CountReport::from_counts(counts);
        report.metrics.episodes_counted = episodes.len() as u64;
        Ok(report)
    }
}

// ---------------------------------------------------------------------------
// ScatterMiner: the coordinator front door
// ---------------------------------------------------------------------------

/// Per-node metrics snapshot.
#[derive(Clone, Debug)]
pub struct ClusterNodeMetrics {
    pub addr: String,
    /// health as of the most recent query (reset at each mine start)
    pub healthy: bool,
    pub calls: u64,
    pub failures: u64,
    pub in_flight: u64,
    /// recent-call latency percentiles (`None` before the first call)
    pub latency_ns: Option<Summary>,
}

/// Coordinator metrics snapshot: per-node health/latency plus the
/// robustness counters (retries, hedges, re-plans, local fallbacks) and
/// the admission gauges.
#[derive(Clone, Debug)]
pub struct ClusterMetrics {
    pub nodes: Vec<ClusterNodeMetrics>,
    pub retries: u64,
    pub hedges: u64,
    pub replans: u64,
    pub local_fallbacks: u64,
    pub shed: u64,
    pub in_flight: usize,
    pub queued: usize,
}

impl ClusterMetrics {
    pub fn to_json(&self) -> Json {
        let nodes = self
            .nodes
            .iter()
            .map(|n| {
                let latency = match &n.latency_ns {
                    Some(s) => Json::Obj(vec![
                        ("n".into(), Json::Num(s.n as f64)),
                        ("mean".into(), Json::Num(s.mean)),
                        ("median".into(), Json::Num(s.median)),
                        ("p95".into(), Json::Num(s.p95)),
                        ("p99".into(), Json::Num(s.p99)),
                        ("max".into(), Json::Num(s.max)),
                    ]),
                    None => Json::Null,
                };
                Json::Obj(vec![
                    ("addr".into(), Json::Str(n.addr.clone())),
                    ("healthy".into(), Json::Bool(n.healthy)),
                    ("calls".into(), Json::Num(n.calls as f64)),
                    ("failures".into(), Json::Num(n.failures as f64)),
                    ("in_flight".into(), Json::Num(n.in_flight as f64)),
                    ("latency_ns".into(), latency),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("nodes".into(), Json::Arr(nodes)),
            ("retries".into(), Json::Num(self.retries as f64)),
            ("hedges".into(), Json::Num(self.hedges as f64)),
            ("replans".into(), Json::Num(self.replans as f64)),
            ("local_fallbacks".into(), Json::Num(self.local_fallbacks as f64)),
            ("shed".into(), Json::Num(self.shed as f64)),
            ("in_flight".into(), Json::Num(self.in_flight as f64)),
            ("queued".into(), Json::Num(self.queued as f64)),
        ])
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "cluster: retries {} hedges {} replans {} local_fallbacks {} shed {} \
             in_flight {} queued {}\n",
            self.retries,
            self.hedges,
            self.replans,
            self.local_fallbacks,
            self.shed,
            self.in_flight,
            self.queued
        );
        for n in &self.nodes {
            let lat = n
                .latency_ns
                .as_ref()
                .map(|s| format!("p50 {:.0} p99 {:.0}", s.median, s.p99))
                .unwrap_or_else(|| "no samples".to_string());
            out.push_str(&format!(
                "  {} {} calls {} failures {} in_flight {} latency_ns {}\n",
                n.addr,
                if n.healthy { "up" } else { "down" },
                n.calls,
                n.failures,
                n.in_flight,
                lat
            ));
        }
        out
    }
}

/// The coordinator: plans `log:` range queries over its own log replica,
/// scatters counting across nodes, gathers results byte-identical to a
/// single-process mine. Shareable across threads (loadgen drives one
/// from many clients through an `Arc`).
pub struct ScatterMiner {
    shared: Arc<ClusterShared>,
    admission: AdmissionController,
    log: SpikeLog,
    cfg: ScatterConfig,
}

impl ScatterMiner {
    /// Open the coordinator's log replica at `log_dir` and attach to the
    /// given node links (`LocalCluster::links`, or [`TcpLink`]s).
    pub fn connect(
        log_dir: &Path,
        links: Vec<Arc<dyn NodeLink>>,
        cfg: ScatterConfig,
    ) -> Result<ScatterMiner, MineError> {
        if links.is_empty() {
            return Err(MineError::invalid("scatter needs at least one node link"));
        }
        if cfg.group_segments == 0 {
            return Err(MineError::invalid("group_segments must be >= 1"));
        }
        if cfg.k == 0 {
            return Err(MineError::invalid("k must be >= 1 (usize::MAX for unbounded)"));
        }
        let admission = AdmissionController::new(cfg.admission.clone())?;
        let log = SpikeLog::open(log_dir)?;
        let n = links.len();
        let registry = Registry::new();
        let shared = Arc::new(ClusterShared {
            links,
            healthy: (0..n).map(|_| AtomicBool::new(true)).collect(),
            nodes: (0..n).map(|i| NodeHandles::register(&registry, i)).collect(),
            next_id: AtomicU64::new(0),
            retries_total: registry.counter("cluster.retries"),
            hedges: registry.counter("cluster.hedges"),
            replans: registry.counter("cluster.replans"),
            local_fallbacks: registry.counter("cluster.local_fallbacks"),
            deadline: cfg.deadline,
            hedge_after: cfg.hedge_after,
            retries: cfg.retries,
            registry,
        });
        Ok(ScatterMiner { shared, admission, log, cfg })
    }

    /// [`ScatterMiner::connect`] over TCP links — the
    /// `epminer scatter --nodes a:1,b:2` path.
    pub fn over_tcp(
        log_dir: &Path,
        addrs: &[String],
        cfg: ScatterConfig,
    ) -> Result<ScatterMiner, MineError> {
        let links = addrs
            .iter()
            .map(|a| Arc::new(TcpLink::new(a.clone())) as Arc<dyn NodeLink>)
            .collect();
        ScatterMiner::connect(log_dir, links, cfg)
    }

    pub fn log(&self) -> &SpikeLog {
        &self.log
    }

    /// Mine the range `(t_from, t_to]` distributed, returning exactly
    /// what a single-process `Session::mine` over the same range and
    /// options returns. `tenant` is the admission identity.
    pub fn mine(
        &self,
        t_from: Tick,
        t_to: Tick,
        opts: &MineOptions,
        two_pass: bool,
        tenant: &str,
    ) -> Result<MineResult, MineError> {
        self.mine_traced(t_from, t_to, opts, two_pass, tenant, &Trace::off(), false)
    }

    /// [`ScatterMiner::mine`] with observability: a live `trace` records
    /// the coordinator's plan/merge spans, one span per remote counting
    /// RPC, and — grafted underneath those, tagged with the peer name —
    /// whatever spans each node recorded, all in one merged tree.
    /// `profile` attaches the [`MineProfile`](crate::obs::MineProfile)
    /// phase breakdown to the result.
    pub fn mine_traced(
        &self,
        t_from: Tick,
        t_to: Tick,
        opts: &MineOptions,
        two_pass: bool,
        tenant: &str,
        trace: &Trace,
        profile: bool,
    ) -> Result<MineResult, MineError> {
        let _permit = self.admission.admit(tenant)?;
        opts.validate()?;
        // every query starts from a fresh view of node health: nodes
        // that failed a past query may have recovered, and in-query
        // failover re-discovers the dead ones
        for h in &self.shared.healthy {
            h.store(true, Ordering::Relaxed);
        }
        let plan = trace.span("plan");
        let (range_stream, _) = self.log.read_range(t_from, t_to)?;
        let range_stream = Arc::new(range_stream);
        let fingerprint = proto::range_fingerprint(&range_stream, t_from, t_to);
        let base = base_taus(&self.log, self.cfg.group_segments, t_from, t_to);
        // the driver remaps the alphabet from level-1 counts for levels
        // >= 2; level-1 counts are always the type frequencies (even
        // two-pass: A2 of a 1-node episode IS its frequency), so this
        // independently-computed remap is identical to the driver's
        let remap = AlphabetRemap::from_counts(&range_stream.type_counts());
        drop(plan);
        let backend = ClusterBackend {
            shared: Arc::clone(&self.shared),
            remap,
            fingerprint,
            t_from,
            t_to,
            base_taus: base,
            k: self.cfg.k,
            trace: trace.clone(),
        };
        let mut engine: Box<dyn CountBackend> = Box::new(backend);
        if two_pass {
            engine = Box::new(TwoPassBackend::new(engine, opts.theta));
        }
        let mut metrics = Metrics::default();
        let result =
            mine_with_backend_obs(&mut *engine, &range_stream, opts, &mut metrics, trace, profile);
        // fold the run's coordinator counters into the unified registry
        // so a Stats snapshot after the query reflects it
        metrics.publish_to(&self.shared.registry);
        result
    }

    /// Mine the whole recording (`(t_begin - 1, t_end]`).
    pub fn mine_all(
        &self,
        opts: &MineOptions,
        two_pass: bool,
        tenant: &str,
    ) -> Result<MineResult, MineError> {
        let t_from = self.log.t_begin().map(|t| t - 1).unwrap_or(-1);
        let t_to = self.log.t_end().unwrap_or(0);
        self.mine(t_from, t_to, opts, two_pass, tenant)
    }

    /// Point-in-time snapshot from the live registry handles; the
    /// admission gauges (shed, in-flight, queued) and per-node health are
    /// refreshed into the registry here so a
    /// [`registry`](ScatterMiner::registry) snapshot carries them too.
    pub fn metrics(&self) -> ClusterMetrics {
        let s = &self.shared;
        let nodes: Vec<ClusterNodeMetrics> = s
            .links
            .iter()
            .enumerate()
            .map(|(i, link)| {
                let h = &s.nodes[i];
                let healthy = s.healthy[i].load(Ordering::Relaxed);
                s.registry
                    .gauge(&format!("cluster.node.{i}.healthy"))
                    .set(i64::from(healthy));
                ClusterNodeMetrics {
                    addr: link.describe(),
                    healthy,
                    calls: h.calls.get(),
                    failures: h.failures.get(),
                    in_flight: h.in_flight.get().max(0) as u64,
                    latency_ns: h.latency_ns.summary(),
                }
            })
            .collect();
        let (shed, in_flight, queued) =
            (self.admission.sheds(), self.admission.in_flight(), self.admission.queued());
        s.registry.gauge("cluster.shed").set(shed as i64);
        s.registry.gauge("cluster.in_flight").set(in_flight as i64);
        s.registry.gauge("cluster.queued").set(queued as i64);
        ClusterMetrics {
            nodes,
            retries: s.retries_total.get(),
            hedges: s.hedges.get(),
            replans: s.replans.get(),
            local_fallbacks: s.local_fallbacks.get(),
            shed,
            in_flight,
            queued,
        }
    }

    /// The unified metrics registry (`cluster.*` plus, after each query,
    /// the folded `coordinator.*` run counters). Clone it to render
    /// `epminer stats` alongside other subsystems.
    pub fn registry(&self) -> Registry {
        self.shared.registry.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_taus_coalesces_narrow_windows() {
        let base = vec![0, 10, 20, 30, 40];
        assert_eq!(effective_taus(&base, 0), base);
        // halo 10: 10 is too close to 0, 30 too close to 20
        assert_eq!(effective_taus(&base, 10), vec![0, 20, 40]);
        // halo wider than everything: degenerate single window
        assert_eq!(effective_taus(&base, 100), vec![0, 40]);
    }

    #[test]
    fn effective_taus_keeps_the_final_window_wide() {
        // 38 survives the forward pass (38 - 10 > 5) but leaves a 2-tick
        // final window, so the backward pass pops it
        let base = vec![0, 10, 38, 40];
        assert_eq!(effective_taus(&base, 5), vec![0, 10, 40]);
    }

    #[test]
    fn transport_errors_are_distinguished_from_application_errors() {
        let io = MineError::io(
            "x",
            std::io::Error::new(std::io::ErrorKind::TimedOut, "deadline"),
        );
        assert!(is_transport(&io));
        assert!(is_transport(&MineError::corrupt(proto::WIRE, "garbled frame")));
        // a node's on-disk corruption report names its log path, not the
        // wire: that is an application answer, never retried
        assert!(!is_transport(&MineError::corrupt("/data/log", "bad checksum")));
        assert!(!is_transport(&MineError::invalid("nope")));
        assert!(!is_transport(&MineError::Busy { queue_depth: 4, capacity: 4 }));
    }

    #[test]
    fn default_config_is_valid() {
        let cfg = ScatterConfig::default();
        assert!(cfg.group_segments >= 1);
        assert!(cfg.k >= 1);
        assert!(cfg.admission.validate().is_ok());
    }
}
