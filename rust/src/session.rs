//! The `Session` facade: the library's front door.
//!
//! A session owns an event stream, a mining configuration, and a counting
//! engine ([`CountBackend`]), built through a fluent builder:
//!
//! ```no_run
//! use episodes_gpu::Session;
//! use episodes_gpu::episodes::Interval;
//!
//! let mut session = Session::builder()
//!     .dataset("sym26")
//!     .theta(60)
//!     .intervals(vec![Interval::new(5, 15)])
//!     .max_level(8)
//!     .build()?;
//! let result = session.mine()?;
//! for c in result.frequent_of_size(3) {
//!     println!("[{}] {}", c.count, c.episode.display());
//! }
//! # Ok::<(), episodes_gpu::MineError>(())
//! ```
//!
//! By default the session counts two-pass (A2 elimination + exact pass) on
//! the accelerated Hybrid engine when the PJRT runtime opens, falling back
//! to the multithreaded CPU baseline otherwise — mining never requires an
//! accelerator. Callers can pin a [`Strategy`] by name, disable the
//! elimination pass with [`SessionBuilder::one_pass`], or inject any
//! custom [`CountBackend`] (including mocks — no runtime needed).

use std::rc::Rc;
use std::sync::mpsc::Receiver;
use std::time::Instant;

use crate::backend::two_pass::TwoPassBackend;
use crate::backend::{self, CountBackend, EpisodeBatch};
use crate::coordinator::miner::{LevelReport, MineResult};
use crate::coordinator::streaming::{Partition, PartitionReport};
use crate::coordinator::{Metrics, Strategy};
use crate::datasets;
use crate::episodes::arena::{AlphabetRemap, EpisodeArena, LevelBlock};
use crate::episodes::{candidates, CountedEpisode, Episode, Interval};
use crate::error::MineError;
use crate::events::{EventStream, EventType};
use crate::obs::{LevelProfile, MineProfile, SpanGuard, Trace};
use crate::runtime::Runtime;

/// Default candidate block size for streamed generation: large enough to
/// amortize per-batch backend dispatch, small enough that a level's peak
/// memory stays O(block + frequent) even when the level itself is 10⁶+
/// candidates.
pub const DEFAULT_CANDIDATE_BLOCK: usize = 65_536;

/// Mining parameters shared by [`Session`] and the low-level
/// [`mine_with_backend`] driver.
#[derive(Clone, Debug)]
pub struct MineOptions {
    /// support threshold theta (non-overlapped occurrence count)
    pub theta: u64,
    /// the inter-event constraint set I (paper Problem 1)
    pub intervals: Vec<Interval>,
    /// stop after this episode size (the paper mines to ~7-8)
    pub max_level: usize,
    /// guardrail: abort a level whose candidate set exceeds this (a
    /// too-low theta on bursty data grows the lattice combinatorially;
    /// production systems must fail fast, not OOM)
    pub max_candidates_per_level: usize,
    /// streamed-generation block size: candidates are emitted and
    /// counted in blocks of at most this many rows (default
    /// [`DEFAULT_CANDIDATE_BLOCK`]); under a two-pass engine the A2
    /// elimination runs per block, so culled candidates never exist as
    /// materialized episodes at all
    pub candidate_block: usize,
}

impl MineOptions {
    /// The parameter invariants every mining entry point shares — one
    /// validator behind both [`SessionBuilder::build`] and the serving
    /// layer's admission check (`serve::Query::validate`), so the two
    /// paths cannot drift.
    pub fn validate(&self) -> Result<(), MineError> {
        if self.theta == 0 {
            return Err(MineError::invalid(
                "theta must be > 0 (a support threshold of 0 makes every episode frequent)",
            ));
        }
        if self.intervals.is_empty() {
            return Err(MineError::invalid(
                "intervals must be non-empty — candidate generation needs \
                 at least one inter-event constraint",
            ));
        }
        if self.max_level == 0 {
            return Err(MineError::invalid("max_level must be >= 1"));
        }
        if self.max_candidates_per_level == 0 {
            return Err(MineError::invalid("max_candidates_per_level must be >= 1"));
        }
        if self.candidate_block == 0 {
            return Err(MineError::invalid("candidate_block must be >= 1"));
        }
        Ok(())
    }
}

/// The level-wise mining loop (paper §5): candidate generation on the host
/// alternating with counting on whatever engine `backend` is. This is the
/// single implementation behind `Session::mine`, streaming partitions, and
/// the batched multi-mine executor (`analysis::batch`).
///
/// Level 1 runs in original type ids over the caller's stream. Levels ≥ 2
/// run on the arena-backed candidate engine (`episodes::arena`): the
/// alphabet is frequency-sorted into dense ids (a bijection — automaton
/// counts only depend on type *equality* and event times, so per-episode
/// counts are invariant, and reports are inverted back to original ids),
/// candidates live as flat SoA rows with integer parent/suffix links, and
/// generation streams bounded chunks through
/// [`CountBackend::count_batch`]. Peak memory per level is O(block +
/// frequent) instead of O(candidates); `max_candidates_per_level` fires
/// from the exact O(frontier) size pre-pass *before* anything is
/// materialized; and the per-level [`LevelReport`] numbers (candidates,
/// frequent, culled) are identical to the legacy owned-`Vec` generator's,
/// in the same order.
pub fn mine_with_backend(
    backend: &mut dyn CountBackend,
    stream: &EventStream,
    opts: &MineOptions,
    metrics: &mut Metrics,
) -> Result<MineResult, MineError> {
    mine_with_backend_obs(backend, stream, opts, metrics, &Trace::off(), false)
}

/// [`mine_with_backend`] with observability: every span lands on `trace`
/// (free when the trace is disabled — no clock read, no allocation), and
/// `profile` attaches a [`MineProfile`] phase breakdown to the result.
/// The mining arithmetic is identical either way.
pub fn mine_with_backend_obs(
    backend: &mut dyn CountBackend,
    stream: &EventStream,
    opts: &MineOptions,
    metrics: &mut Metrics,
    trace: &Trace,
    profile: bool,
) -> Result<MineResult, MineError> {
    let t_total = Instant::now();
    // profile counters are this run's delta, not the session's lifetime
    let base_misses = metrics.concat_misses;
    let base_maps = metrics.shard_map_calls;
    let base_cpu = metrics.cpu_fallbacks;
    let mut level_profiles: Vec<LevelProfile> = vec![];
    let mut result = {
        let root = trace.span("mine");
        mine_levels(backend, stream, opts, metrics, &root, profile, &mut level_profiles)?
    };
    if profile {
        let candidate_rows = level_profiles.iter().map(|l| l.candidates).sum();
        let blocks_streamed = level_profiles.iter().map(|l| l.blocks).sum();
        result.profile = Some(MineProfile {
            total_seconds: t_total.elapsed().as_secs_f64(),
            levels: level_profiles,
            candidate_rows,
            blocks_streamed,
            concat_misses: metrics.concat_misses - base_misses,
            shard_map_calls: metrics.shard_map_calls - base_maps,
            serial_recounts: metrics.cpu_fallbacks - base_cpu,
            cache_outcome: None,
        });
    }
    Ok(result)
}

fn mine_levels(
    backend: &mut dyn CountBackend,
    stream: &EventStream,
    opts: &MineOptions,
    metrics: &mut Metrics,
    root: &SpanGuard,
    profile: bool,
    level_profiles: &mut Vec<LevelProfile>,
) -> Result<MineResult, MineError> {
    let mut result = MineResult::default();

    // -- level 1: original ids, whole-level counting (the level-1 path is
    //    answered from host-side type frequencies by every engine)
    let span1 = root.child("level 1");
    let t_gen = Instant::now();
    let cands1 = candidates::level1(stream.n_types);
    let gen_seconds = t_gen.elapsed().as_secs_f64();
    if cands1.is_empty() {
        return Ok(result);
    }
    if cands1.len() > opts.max_candidates_per_level {
        return Err(MineError::CandidateExplosion {
            level: 1,
            candidates: cands1.len(),
            cap: opts.max_candidates_per_level,
        });
    }
    let t_count = Instant::now();
    let report = {
        let _count_span = span1.child("count");
        backend.count(&cands1, stream)?
    };
    metrics.merge(&report.metrics);
    let count_seconds = t_count.elapsed().as_secs_f64();
    let counts1 = report.counts;

    let t_prune = Instant::now();
    let frequent1: Vec<EventType> = cands1
        .iter()
        .zip(&counts1)
        .filter(|(_, &c)| c >= opts.theta)
        .map(|(e, _)| e.types[0])
        .collect();
    result.levels.push(LevelReport {
        level: 1,
        candidates: cands1.len(),
        frequent: frequent1.len(),
        culled_by_a2: report.culled,
        count_seconds,
        gen_seconds,
    });
    result.frequent.extend(
        cands1
            .into_iter()
            .zip(counts1.iter().copied())
            .filter(|(_, c)| *c >= opts.theta)
            .map(|(episode, count)| CountedEpisode { episode, count }),
    );
    if profile {
        level_profiles.push(LevelProfile {
            level: 1,
            generate_seconds: gen_seconds,
            count_seconds,
            prune_seconds: t_prune.elapsed().as_secs_f64(),
            candidates: result.levels[0].candidates as u64,
            blocks: 1,
        });
    }
    drop(span1);
    if frequent1.is_empty() || opts.max_level == 1 {
        return Ok(result);
    }

    // -- levels >= 2: dense alphabet, arena-streamed candidate blocks.
    //    The frontier enters the arena in ascending *original* id order,
    //    which keeps every level's emission order identical to the legacy
    //    generator's regardless of the relabeling.
    let remap = AlphabetRemap::from_counts(&counts1);
    let dense_stream = remap.apply(stream);
    let mut arena = EpisodeArena::new(&opts.intervals);
    arena.push_singles(frequent1.iter().map(|&ty| remap.dense(ty)));

    let mut scratch = Episode { types: vec![], intervals: vec![] };
    for level in 2..=opts.max_level {
        let lvl_span = root.child_fmt(|| format!("level {level}"));
        let top = arena.num_levels() - 1;
        let frontier: Vec<u32> = (0..arena.block_len(top) as u32).collect();

        let t_gen = Instant::now();
        let total = arena.next_level_count(&frontier);
        if total == 0 {
            break;
        }
        if total > opts.max_candidates_per_level {
            return Err(MineError::CandidateExplosion {
                level,
                candidates: total,
                cap: opts.max_candidates_per_level,
            });
        }

        let mut gen_seconds = t_gen.elapsed().as_secs_f64();
        let mut count_seconds = 0.0f64;
        let mut count_only_seconds = 0.0f64;
        let mut prune_seconds = 0.0f64;
        let mut blocks = 0u64;
        let mut culled = 0u64;
        let mut survivors = LevelBlock::default();
        let mut frequent: Vec<CountedEpisode> = vec![];
        let mut t_mark = Instant::now();
        arena.generate_next(&frontier, opts.candidate_block, |chunk| {
            gen_seconds += t_mark.elapsed().as_secs_f64();
            let t_chunk = Instant::now();
            let batch = EpisodeBatch::new(&arena, chunk);
            let rep = {
                let _block_span = lvl_span.child("count block");
                backend.count_batch(&batch, &dense_stream)?
            };
            metrics.merge(&rep.metrics);
            culled += rep.culled;
            count_only_seconds += t_chunk.elapsed().as_secs_f64();
            let t_prune = Instant::now();
            for (i, &c) in rep.counts.iter().enumerate() {
                if c >= opts.theta {
                    survivors.push(
                        chunk.last_type[i],
                        chunk.last_iv[i],
                        chunk.parent[i],
                        chunk.suffix[i],
                    );
                    batch.materialize_into(i, &mut scratch);
                    let mut episode = scratch.clone();
                    remap.invert_episode(&mut episode);
                    frequent.push(CountedEpisode { episode, count: c });
                }
            }
            prune_seconds += t_prune.elapsed().as_secs_f64();
            blocks += 1;
            // LevelReport keeps its historical semantics: count time is
            // the whole per-chunk backend+prune stretch
            count_seconds += t_chunk.elapsed().as_secs_f64();
            t_mark = Instant::now();
            Ok(())
        })?;

        let n_frequent = frequent.len();
        result.levels.push(LevelReport {
            level,
            candidates: total,
            frequent: n_frequent,
            culled_by_a2: culled,
            count_seconds,
            gen_seconds,
        });
        result.frequent.append(&mut frequent);
        if profile {
            level_profiles.push(LevelProfile {
                level,
                generate_seconds: gen_seconds,
                count_seconds: count_only_seconds,
                prune_seconds,
                candidates: total as u64,
                blocks,
            });
        }
        if n_frequent == 0 {
            break;
        }
        arena.push_block(survivors);
    }
    Ok(result)
}

/// Build the counting engine a `(strategy, two_pass, theta)` configuration
/// names — the same construction [`SessionBuilder::build`] performs,
/// exposed for callers that drive [`mine_with_backend`] directly. The
/// `serve` worker pool is the motivating caller: `Session` holds an
/// `Rc<Runtime>` and is deliberately not `Send`, so service workers
/// construct an engine on their own thread (passing a thread-local
/// runtime handle, or `None` to have an accelerated strategy open one)
/// and run the driver against it.
pub fn engine_for(
    strategy: Strategy,
    rt: Option<Rc<Runtime>>,
    two_pass: bool,
    theta: u64,
    cpu_threads: usize,
) -> Result<Box<dyn CountBackend>, MineError> {
    let rt = match rt {
        Some(rt) => Some(rt),
        None if strategy.needs_runtime() => Some(Rc::new(Runtime::open_default()?)),
        None => None,
    };
    let exact = backend::for_strategy(strategy, rt, cpu_threads)?;
    Ok(wrap_two_pass(exact, two_pass, theta))
}

/// One mine of the batched multi-mine executor (`analysis::batch`): the
/// single dispatch point every fan-out job goes through, whatever engine
/// the worker holds. Fresh per-run [`Metrics`] (the executor's jobs are
/// independent; nothing accumulates across them) and a [`MineProfile`]
/// attached when `profile` is set — this is the seam where ROADMAP
/// item 2's CPU-vs-device crossover decision plugs in: with per-level
/// phase profiles in hand, a future dispatcher can route each job (or
/// each level's count blocks) to the device backend instead of the
/// engine it was handed.
///
/// [`MineProfile`]: crate::obs::MineProfile
pub fn dispatch_mine(
    backend: &mut dyn CountBackend,
    stream: &EventStream,
    opts: &MineOptions,
    trace: &Trace,
    profile: bool,
) -> Result<MineResult, MineError> {
    let mut metrics = Metrics::default();
    mine_with_backend_obs(backend, stream, opts, &mut metrics, trace, profile)
}

fn wrap_two_pass(
    exact: Box<dyn CountBackend>,
    two_pass: bool,
    theta: u64,
) -> Box<dyn CountBackend> {
    if two_pass {
        Box::new(TwoPassBackend::new(exact, theta))
    } else {
        exact
    }
}

/// A mining session: stream + options + counting engine + run metrics.
pub struct Session {
    backend: Box<dyn CountBackend>,
    stream: EventStream,
    opts: MineOptions,
    metrics: Metrics,
    profile: bool,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Run the full level-wise mining loop over the session's stream.
    pub fn mine(&mut self) -> Result<MineResult, MineError> {
        self.mine_traced(&Trace::off())
    }

    /// [`Session::mine`] recording spans onto a caller-supplied
    /// [`Trace`] (per-level + per-count-block), e.g. the CLI's
    /// `--trace-out` export. With the default disabled trace this is
    /// exactly [`Session::mine`].
    pub fn mine_traced(&mut self, trace: &Trace) -> Result<MineResult, MineError> {
        mine_with_backend_obs(
            &mut *self.backend,
            &self.stream,
            &self.opts,
            &mut self.metrics,
            trace,
            self.profile,
        )
    }

    /// Count explicit episodes over the session's stream (sizes may mix).
    ///
    /// Counts carry the session backend's semantics: under the default
    /// two-pass engine, episodes whose relaxed (A2) count falls below
    /// theta report that sub-threshold upper bound rather than their
    /// exact count (the `>= theta` decision is exact either way). Build
    /// with [`SessionBuilder::one_pass`] when exact counts for infrequent
    /// episodes matter — e.g. when migrating from the removed pre-0.2
    /// `Coordinator::count`, which was always exact.
    ///
    /// Episodes referencing event types outside the stream's alphabet are
    /// rejected with [`MineError::OutOfAlphabet`] before any backend runs
    /// (mining only generates in-alphabet candidates; explicit episodes
    /// come from callers and deserve validation, not a panic).
    pub fn count(&mut self, episodes: &[Episode]) -> Result<Vec<u64>, MineError> {
        let n_types = self.stream.n_types;
        for ep in episodes {
            if let Some(&ty) =
                ep.types.iter().find(|&&ty| ty < 0 || ty as usize >= n_types)
            {
                return Err(MineError::OutOfAlphabet { type_id: ty, n_types });
            }
        }
        let report = self.backend.count(episodes, &self.stream)?;
        self.metrics.merge(&report.metrics);
        Ok(report.counts)
    }

    /// Chip-on-chip streaming (paper §1 contribution 3): mine each
    /// partition as it arrives from a producer (see
    /// `coordinator::streaming::spawn_producer_with`), returning
    /// per-partition real-time reports.
    pub fn mine_partitions(
        &mut self,
        rx: Receiver<Partition>,
    ) -> Result<Vec<PartitionReport>, MineError> {
        let mut reports = vec![];
        while let Ok(part) = rx.recv() {
            let t0 = Instant::now();
            let result = mine_with_backend(
                &mut *self.backend,
                &part.stream,
                &self.opts,
                &mut self.metrics,
            )?;
            reports.push(PartitionReport {
                index: part.index,
                events: part.stream.len(),
                frequent: result.frequent.len(),
                mine_time: t0.elapsed(),
                recording: part.recording,
                result,
            });
        }
        Ok(reports)
    }

    /// Incremental streaming (the `stream/` layer's answer to
    /// [`Session::mine_partitions`]): fold each arriving partition into a
    /// sliding window of the last `window_segments` partitions (0 =
    /// unbounded) and return one [`CommitUpdate`](crate::stream::CommitUpdate)
    /// per partition — the frequent set of the *window*, kept current by
    /// the [`IncrementalMiner`](crate::stream::IncrementalMiner) at a cost
    /// proportional to what changed instead of a full re-mine.
    ///
    /// The incremental engine is its own exact counting path (one-pass
    /// Algorithm-1 semantics); the session's backend/two-pass settings do
    /// not apply. Empty partitions (silent stretches of the recording)
    /// are skipped — they seal no segment.
    pub fn mine_incremental(
        &self,
        rx: Receiver<Partition>,
        window_segments: usize,
    ) -> Result<Vec<crate::stream::CommitUpdate>, MineError> {
        let mut miner: Option<crate::stream::IncrementalMiner> = None;
        let mut updates = vec![];
        while let Ok(part) = rx.recv() {
            if part.stream.is_empty() {
                continue;
            }
            let m = match &mut miner {
                Some(m) => m,
                None => {
                    let cfg = crate::stream::IncrementalConfig::new(
                        self.opts.theta,
                        self.opts.intervals.clone(),
                    )
                    .max_level(self.opts.max_level)
                    .max_candidates_per_level(self.opts.max_candidates_per_level)
                    .window_segments(window_segments);
                    miner.insert(crate::stream::IncrementalMiner::new(
                        part.stream.n_types,
                        cfg,
                    )?)
                }
            };
            updates.push(m.push_segment(part.stream)?);
        }
        Ok(updates)
    }

    pub fn stream(&self) -> &EventStream {
        &self.stream
    }

    pub fn options(&self) -> &MineOptions {
        &self.opts
    }

    /// Cumulative work metrics across every call on this session.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The counting engine's name, e.g. `two-pass(hybrid)`.
    pub fn backend_name(&self) -> &str {
        self.backend.name()
    }
}

/// Fluent builder for [`Session`]. See the module docs for the shape.
pub struct SessionBuilder {
    stream: Option<EventStream>,
    dataset: Option<String>,
    seed: u64,
    theta: Option<u64>,
    intervals: Option<Vec<Interval>>,
    backend: Option<Box<dyn CountBackend>>,
    strategy: Option<Strategy>,
    two_pass: bool,
    max_level: usize,
    max_candidates_per_level: usize,
    candidate_block: usize,
    cpu_threads: usize,
    profile: bool,
}

impl Default for SessionBuilder {
    fn default() -> SessionBuilder {
        SessionBuilder {
            stream: None,
            dataset: None,
            seed: 7,
            theta: None,
            intervals: None,
            backend: None,
            strategy: None,
            two_pass: true,
            max_level: 8,
            max_candidates_per_level: 2_000_000,
            candidate_block: DEFAULT_CANDIDATE_BLOCK,
            cpu_threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
            profile: false,
        }
    }
}

impl SessionBuilder {
    /// Mine over an explicit event stream.
    pub fn stream(mut self, stream: EventStream) -> Self {
        self.stream = Some(stream);
        self
    }

    /// Mine over a named dataset from the registry (`sym26`, `2-1-33`,
    /// `2-1-34`, `2-1-35`), a binary stream on disk (`file:<path>`), or a
    /// sealed ingest log (`log:<dir>`); the dataset's default inter-event
    /// constraint is used unless [`SessionBuilder::intervals`] overrides
    /// it (path-backed streams default to the generic `(2, 10]` band).
    pub fn dataset(mut self, name: impl Into<String>) -> Self {
        self.dataset = Some(name.into());
        self
    }

    /// Generator seed for [`SessionBuilder::dataset`] (default 7).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Support threshold (required, must be > 0).
    pub fn theta(mut self, theta: u64) -> Self {
        self.theta = Some(theta);
        self
    }

    /// The inter-event constraint set I used for candidate generation.
    pub fn intervals(mut self, intervals: Vec<Interval>) -> Self {
        self.intervals = Some(intervals);
        self
    }

    /// Convenience for a single-interval constraint set.
    pub fn interval(self, interval: Interval) -> Self {
        self.intervals(vec![interval])
    }

    /// Inject a counting engine directly (mutually exclusive with
    /// [`SessionBuilder::strategy`]). The engine is still wrapped with
    /// two-pass elimination unless [`SessionBuilder::one_pass`] is set.
    pub fn backend(mut self, backend: Box<dyn CountBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Pick an engine by name. Accelerated strategies open the default
    /// PJRT runtime at build time and fail with
    /// [`MineError::RuntimeUnavailable`] if it is absent.
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = Some(strategy);
        self
    }

    /// Disable the A2 elimination pre-pass (count exact-only, one pass).
    pub fn one_pass(mut self) -> Self {
        self.two_pass = false;
        self
    }

    /// Enable/disable the A2 elimination pre-pass (default enabled).
    pub fn two_pass(mut self, enabled: bool) -> Self {
        self.two_pass = enabled;
        self
    }

    /// Stop after this episode size (default 8).
    pub fn max_level(mut self, max_level: usize) -> Self {
        self.max_level = max_level;
        self
    }

    /// Per-level candidate-count guardrail (default 2,000,000).
    pub fn max_candidates_per_level(mut self, cap: usize) -> Self {
        self.max_candidates_per_level = cap;
        self
    }

    /// Streamed-generation block size (default
    /// [`DEFAULT_CANDIDATE_BLOCK`]): candidates are emitted and counted
    /// in blocks of at most this many rows, bounding a level's peak
    /// memory at O(block + frequent).
    pub fn candidate_block(mut self, block: usize) -> Self {
        self.candidate_block = block;
        self
    }

    /// Worker threads for CPU engines and fallbacks.
    pub fn cpu_threads(mut self, threads: usize) -> Self {
        self.cpu_threads = threads.max(1);
        self
    }

    /// Attach an [`obs::MineProfile`](crate::obs::MineProfile) phase
    /// breakdown (per-level generate/count/prune wall time and work
    /// volumes) to every [`MineResult`] this session produces (default
    /// off — the profile costs a handful of clock reads per block).
    pub fn profile(mut self, enabled: bool) -> Self {
        self.profile = enabled;
        self
    }

    pub fn build(self) -> Result<Session, MineError> {
        let SessionBuilder {
            stream,
            dataset,
            seed,
            theta,
            intervals,
            backend,
            strategy,
            two_pass,
            max_level,
            max_candidates_per_level,
            candidate_block,
            cpu_threads,
            profile,
        } = self;

        let theta = theta
            .ok_or_else(|| MineError::invalid("theta not set — call .theta(...)"))?;

        // Validate the dataset name whenever one was given, even alongside
        // an explicit stream (where it only supplies interval defaults) —
        // a typo should say "unknown dataset", not a misleading
        // missing-intervals error later. `file:`/`log:` specs pass here
        // and surface path problems as typed I/O errors at resolve time.
        if let Some(name) = dataset.as_deref() {
            if !datasets::is_path_scheme(name) && datasets::info(name).is_none() {
                return Err(MineError::UnknownDataset {
                    given: name.to_string(),
                    valid: datasets::names_and_schemes(),
                });
            }
        }
        let (stream, dataset_name) = match (stream, dataset) {
            (Some(s), d) => (s, d),
            (None, Some(name)) => {
                let (s, tag) = datasets::resolve(&name, seed)?;
                (s, Some(tag))
            }
            (None, None) => {
                return Err(MineError::invalid(
                    "no event stream — call .stream(...) or .dataset(...)",
                ))
            }
        };

        // An explicitly-set empty interval list reaches validate() below
        // and reports the shared non-empty-intervals error.
        let intervals = match intervals {
            Some(iv) => iv,
            None => match dataset_name.as_deref().and_then(datasets::default_interval) {
                Some(iv) => vec![iv],
                None => {
                    return Err(MineError::invalid(
                        "no inter-event constraint set — call .intervals(...) \
                         (or .dataset(...) for the dataset default)",
                    ))
                }
            },
        };
        let opts = MineOptions {
            theta,
            intervals,
            max_level,
            max_candidates_per_level,
            candidate_block,
        };
        opts.validate()?;

        let backend: Box<dyn CountBackend> = match (backend, strategy) {
            (Some(_), Some(_)) => {
                return Err(MineError::invalid(
                    "set either .backend(...) or .strategy(...), not both",
                ))
            }
            (Some(b), None) => wrap_two_pass(b, two_pass, theta),
            (None, Some(s)) => engine_for(s, None, two_pass, theta, cpu_threads)?,
            (None, None) => {
                wrap_two_pass(backend::default_backend(cpu_threads), two_pass, theta)
            }
        };

        Ok(Session { backend, stream, opts, metrics: Metrics::default(), profile })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_stream() -> EventStream {
        EventStream::from_pairs(vec![(0, 1), (1, 4), (2, 8), (0, 20), (1, 24)], 3)
    }

    #[test]
    fn builder_requires_a_stream() {
        let err = Session::builder()
            .theta(5)
            .interval(Interval::new(0, 10))
            .build()
            .err()
            .unwrap();
        assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
    }

    #[test]
    fn builder_rejects_zero_theta() {
        let err = Session::builder()
            .stream(tiny_stream())
            .theta(0)
            .interval(Interval::new(0, 10))
            .build()
            .err()
            .unwrap();
        assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
    }

    #[test]
    fn builder_rejects_zero_max_level() {
        let err = Session::builder()
            .stream(tiny_stream())
            .theta(1)
            .interval(Interval::new(0, 10))
            .max_level(0)
            .build()
            .err()
            .unwrap();
        assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
    }

    #[test]
    fn builder_rejects_unknown_dataset() {
        let err =
            Session::builder().dataset("mea-9000").theta(5).build().err().unwrap();
        match err {
            MineError::UnknownDataset { given, valid } => {
                assert_eq!(given, "mea-9000");
                assert!(valid.contains(&"sym26"));
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn dataset_default_interval_is_used() {
        let session = Session::builder()
            .dataset("sym26")
            .theta(60)
            .strategy(Strategy::CpuSerial)
            .build()
            .unwrap();
        assert_eq!(session.options().intervals, vec![Interval::new(5, 15)]);
        assert_eq!(session.backend_name(), "two-pass(cpu-serial)");
    }

    #[test]
    fn candidate_cap_surfaces_explosion() {
        let mut session = Session::builder()
            .stream(tiny_stream())
            .theta(1)
            .interval(Interval::new(0, 10))
            .strategy(Strategy::CpuSerial)
            .max_candidates_per_level(2)
            .build()
            .unwrap();
        let err = session.mine().err().unwrap();
        match err {
            MineError::CandidateExplosion { level, candidates, cap } => {
                assert_eq!(level, 1);
                assert_eq!(candidates, 3); // level 1 = alphabet size
                assert_eq!(cap, 2);
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn cpu_session_mines_end_to_end() {
        let mut session = Session::builder()
            .stream(tiny_stream())
            .theta(1)
            .interval(Interval::new(0, 10))
            .strategy(Strategy::CpuParallel)
            .max_level(3)
            .build()
            .unwrap();
        let result = session.mine().unwrap();
        assert!(!result.frequent.is_empty());
        assert!(session.metrics().episodes_counted > 0);
    }

    #[test]
    fn sharded_session_mines_end_to_end() {
        let mut session = Session::builder()
            .stream(tiny_stream())
            .theta(1)
            .interval(Interval::new(0, 10))
            .strategy(Strategy::CpuSharded)
            .cpu_threads(4)
            .max_level(3)
            .build()
            .unwrap();
        assert_eq!(session.backend_name(), "two-pass(cpu-sharded)");
        let result = session.mine().unwrap();
        assert!(!result.frequent.is_empty());
    }

    #[test]
    fn profile_attaches_phase_breakdown() {
        let build = |profile: bool| {
            Session::builder()
                .stream(tiny_stream())
                .theta(1)
                .interval(Interval::new(0, 10))
                .strategy(Strategy::CpuSerial)
                .max_level(3)
                .profile(profile)
                .build()
                .unwrap()
        };

        // default: no profile, identical results
        let plain = build(false).mine().unwrap();
        assert!(plain.profile.is_none());

        let mut session = build(true);
        let result = session.mine().unwrap();
        let prof = result.profile.as_ref().expect("profile requested");
        assert_eq!(prof.levels.len(), result.levels.len());
        assert_eq!(
            prof.candidate_rows,
            result.levels.iter().map(|l| l.candidates as u64).sum::<u64>()
        );
        assert!(prof.blocks_streamed >= prof.levels.len() as u64);
        assert!(prof.total_seconds >= 0.0);
        // the mining answer itself is byte-identical
        assert_eq!(result.frequent.len(), plain.frequent.len());
    }

    #[test]
    fn mine_traced_records_per_level_spans() {
        let mut session = Session::builder()
            .stream(tiny_stream())
            .theta(1)
            .interval(Interval::new(0, 10))
            .strategy(Strategy::CpuSerial)
            .max_level(3)
            .build()
            .unwrap();
        let trace = crate::obs::Trace::started();
        let result = session.mine_traced(&trace).unwrap();
        let spans = trace.snapshot();
        let mine = spans.iter().find(|s| s.name == "mine").expect("root span");
        for report in &result.levels {
            let name = format!("level {}", report.level);
            let lvl = spans
                .iter()
                .find(|s| s.name == name.as_str())
                .unwrap_or_else(|| panic!("missing span {name}"));
            assert_eq!(lvl.parent, mine.id);
        }
    }

    #[test]
    fn count_rejects_out_of_alphabet_episodes() {
        let mut session = Session::builder()
            .stream(tiny_stream()) // alphabet 0..3
            .theta(1)
            .interval(Interval::new(0, 10))
            .strategy(Strategy::CpuSerial)
            .build()
            .unwrap();
        let err = session.count(&[Episode::single(9)]).err().unwrap();
        assert!(matches!(err, MineError::OutOfAlphabet { type_id: 9, n_types: 3 }), "{err}");
        // any node out of range is rejected, not just N=1 heads
        let bad = Episode::new(vec![0, 7], vec![Interval::new(0, 10)]);
        let err = session.count(std::slice::from_ref(&bad)).err().unwrap();
        assert!(matches!(err, MineError::OutOfAlphabet { type_id: 7, .. }), "{err}");
    }
}
