//! Commit updates and frequent-set diffs: what an incremental commit
//! publishes.
//!
//! Every [`IncrementalMiner::push_segment`] produces a [`CommitUpdate`]:
//! the full frequent set after the commit (shared via `Arc` so the serve
//! layer can fan one update out to many subscribers without copying),
//! plus a [`FrequentDiff`] against the previous commit — episodes that
//! *entered* the frequent set, episodes that *left* it, and episodes whose
//! count *changed* while staying frequent. Subscribers that only render
//! deltas read the diff; subscribers that need the complete answer read
//! `frequent`.
//!
//! [`IncrementalMiner::push_segment`]: super::incremental::IncrementalMiner::push_segment

use std::collections::HashMap;
use std::sync::Arc;

use crate::episodes::{CountedEpisode, Episode};
use crate::events::Tick;

/// A frequent episode whose count moved between two commits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CountChange {
    pub episode: Episode,
    pub previous: u64,
    pub current: u64,
}

/// Set difference between two consecutive frequent sets.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FrequentDiff {
    /// frequent now, not frequent at the previous commit (with current counts)
    pub entered: Vec<CountedEpisode>,
    /// frequent at the previous commit, not anymore (with their last counts)
    pub left: Vec<CountedEpisode>,
    /// frequent at both commits with a different count
    pub count_changed: Vec<CountChange>,
}

impl FrequentDiff {
    /// Diff `next` against `prev`. Order is deterministic: `entered` and
    /// `count_changed` follow `next`'s (level-then-generation) order,
    /// `left` follows `prev`'s.
    pub fn between(prev: &[CountedEpisode], next: &[CountedEpisode]) -> FrequentDiff {
        let prev_counts: HashMap<&Episode, u64> =
            prev.iter().map(|c| (&c.episode, c.count)).collect();
        let next_set: HashMap<&Episode, u64> =
            next.iter().map(|c| (&c.episode, c.count)).collect();
        let mut diff = FrequentDiff::default();
        for c in next {
            match prev_counts.get(&c.episode) {
                None => diff.entered.push(c.clone()),
                Some(&old) if old != c.count => diff.count_changed.push(CountChange {
                    episode: c.episode.clone(),
                    previous: old,
                    current: c.count,
                }),
                Some(_) => {}
            }
        }
        for c in prev {
            if !next_set.contains_key(&c.episode) {
                diff.left.push(c.clone());
            }
        }
        diff
    }

    /// No membership or count movement at all.
    pub fn is_empty(&self) -> bool {
        self.entered.is_empty() && self.left.is_empty() && self.count_changed.is_empty()
    }

    /// Compact human form, e.g. `+3 -1 ~2`.
    pub fn summary(&self) -> String {
        format!(
            "+{} -{} ~{}",
            self.entered.len(),
            self.left.len(),
            self.count_changed.len()
        )
    }
}

/// Work accounting for one incremental commit — the numbers that prove
/// (or disprove) the update cost is proportional to arriving data.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CommitStats {
    /// events in the segment this commit folded in
    pub events_added: usize,
    /// events dropped off the expired end of the window
    pub events_retired: usize,
    /// segments dropped off the expired end of the window
    pub segments_retired: usize,
    /// boundary-machine Map computations ran (episode × partition pairs)
    pub partitions_recomputed: usize,
    /// events scanned by those Map computations (the real per-update cost)
    pub events_rescanned: usize,
    /// concatenate-fold chain misses flagged across all tracked episodes
    pub concat_misses: u64,
    /// episodes recounted serially over the whole window (miss fallback)
    pub serial_recounts: usize,
    /// mining levels whose candidate set had to be regenerated because the
    /// frontier below them moved across theta (0 = fully reused)
    pub candidate_regens: usize,
    /// episodes with cached automaton state after the commit
    pub tracked_episodes: usize,
}

/// What one [`IncrementalMiner`] commit produced: the window it now
/// covers, the full frequent set, the diff against the previous commit,
/// and the work accounting.
///
/// [`IncrementalMiner`]: super::incremental::IncrementalMiner
#[derive(Clone, Debug)]
pub struct CommitUpdate {
    /// 1-based commit number (== segments pushed so far)
    pub seq: u64,
    /// window lower boundary: events with `t > window_start` are covered
    pub window_start: Tick,
    /// window upper boundary (inclusive)
    pub window_end: Tick,
    /// segments currently in the window
    pub window_segments: usize,
    /// events currently in the window
    pub window_events: usize,
    /// the complete frequent set after this commit, level-then-generation
    /// order (identical to a batch re-mine of the window)
    pub frequent: Arc<Vec<CountedEpisode>>,
    pub diff: FrequentDiff,
    pub stats: CommitStats,
}

impl CommitUpdate {
    /// One-line human summary for logs and the `epminer watch` output.
    pub fn report(&self) -> String {
        format!(
            "commit {} window ({}, {}] segs={} events={} frequent={} diff[{}] \
             recomputed={} rescanned={} misses={} recounts={} regens={}",
            self.seq,
            self.window_start,
            self.window_end,
            self.window_segments,
            self.window_events,
            self.frequent.len(),
            self.diff.summary(),
            self.stats.partitions_recomputed,
            self.stats.events_rescanned,
            self.stats.concat_misses,
            self.stats.serial_recounts,
            self.stats.candidate_regens,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;

    fn counted(ty: i32, count: u64) -> CountedEpisode {
        CountedEpisode { episode: Episode::single(ty), count }
    }

    #[test]
    fn diff_classifies_all_three_movements() {
        let prev = vec![counted(0, 5), counted(1, 7), counted(2, 9)];
        let next = vec![counted(1, 8), counted(2, 9), counted(3, 4)];
        let d = FrequentDiff::between(&prev, &next);
        assert_eq!(d.entered, vec![counted(3, 4)]);
        assert_eq!(d.left, vec![counted(0, 5)]);
        assert_eq!(
            d.count_changed,
            vec![CountChange { episode: Episode::single(1), previous: 7, current: 8 }]
        );
        assert!(!d.is_empty());
        assert_eq!(d.summary(), "+1 -1 ~1");
    }

    #[test]
    fn identical_sets_diff_empty() {
        let eps = vec![
            counted(0, 5),
            CountedEpisode {
                episode: Episode::new(vec![0, 1], vec![Interval::new(0, 10)]),
                count: 3,
            },
        ];
        let d = FrequentDiff::between(&eps, &eps);
        assert!(d.is_empty());
        assert_eq!(d.summary(), "+0 -0 ~0");
    }

    #[test]
    fn diff_against_empty_is_all_entered() {
        let next = vec![counted(0, 2), counted(1, 3)];
        let d = FrequentDiff::between(&[], &next);
        assert_eq!(d.entered.len(), 2);
        assert!(d.left.is_empty() && d.count_changed.is_empty());
    }
}
