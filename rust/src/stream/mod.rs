//! Incremental sliding-window mining: keep the frequent-episode set of a
//! *moving* recording window current as segments arrive, at a cost
//! proportional to what changed — not to the window.
//!
//! Everything upstream mines a fixed stream from scratch. This layer
//! generalizes the paper's map-concatenate decomposition (§5.3) from
//! *spatial* partitions mined in parallel to *temporal* partitions
//! arriving over time: each sealed segment is a new partition appended to
//! the window, and the per-partition automaton tuples the batch miner
//! would compute for the old partitions are still valid — they only need
//! recomputing where the new data's halo reaches. Sliding the window is
//! the same argument run backwards: retire the expired prefix's tuples
//! and counts, re-anchor the first partition, and fold.
//!
//! Three pieces:
//!
//! - [`incremental`] — [`IncrementalMiner`]: the engine. Caches per-episode
//!   per-partition machine tuples, recomputes only halo-dirty partitions on
//!   each commit, folds with `concatenate_fold`, and re-runs candidate
//!   generation only when an episode actually crosses the theta boundary.
//!   The invariant (enforced by `tests/stream_incremental.rs`): after every
//!   commit the frequent set is *identical* to a cold batch mine of the
//!   current window.
//! - [`diff`] — what a commit produced: [`CommitUpdate`] with the new
//!   frequent set, a [`FrequentDiff`] (entered / left / count-changed)
//!   against the previous commit, and [`CommitStats`] accounting for how
//!   much work the commit actually did.
//! - [`watch`] — [`LogWatcher`]: ties an
//!   [`ingest::TailReader`](crate::ingest::TailReader) to the miner so a
//!   live [`SpikeLog`](crate::ingest::SpikeLog) directory becomes a feed
//!   of commits. `epminer watch` is the CLI face;
//!   `serve::MineService::publish` pushes commits to subscribers.

pub mod diff;
pub mod incremental;
pub mod watch;

pub use diff::{CommitStats, CommitUpdate, CountChange, FrequentDiff};
pub use incremental::{IncrementalConfig, IncrementalMiner};
pub use watch::LogWatcher;
