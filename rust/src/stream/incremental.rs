//! The incremental sliding-window miner: boundary-machine state carried
//! across *arriving* segments.
//!
//! The batch engines re-mine a window from scratch; this engine keeps,
//! per tracked episode, the per-partition `(a, count, b)` boundary-machine
//! tuples the MapConcatenate Map step produces (`serial::mapcat_map`, the
//! same machinery `backend/sharded.rs` runs across *spatial* time shards)
//! and updates only the tuples a commit can actually change:
//!
//! - the **new** partition (the arriving segment) is always computed;
//! - partitions whose **forward halo** (`tau_{p+1} + span_max`) reached
//!   beyond the previous window end are recomputed — their machines could
//!   not yet see the events that just arrived (their `b` completion may
//!   now exist);
//! - when the window slides, partitions whose **back halo**
//!   (`tau_p - span_max`) reached into the retired prefix are recomputed
//!   against the shrunk window, and the first partition is recomputed
//!   unconditionally (its lower boundary `tau_0` moves to
//!   `t_min - 1` of the new first segment).
//!
//! Every other cached tuple is provably identical to what a batch Map
//! over the current window would produce, because a machine's tuple is a
//! function of exactly the events in `(start, tau_{p+1} + span_max]` and
//! neither endpoint's contents changed. Counts come from
//! [`mapconcat::concatenate_fold`] over the tuple chain; a flagged miss
//! (the chain failed to re-anchor) falls back to the serial reference
//! over the materialized window — so counts are exact at every commit,
//! which makes the incremental frequent set *identical* to a cold batch
//! re-mine (`tests/stream_incremental.rs` pins this at every commit).
//!
//! Candidate generation is gated on frontier movement: the candidate
//! lattice lives in an [`EpisodeArena`] (block `L-1` = level L's full
//! candidate set as flat SoA rows), and each block is keyed on the exact
//! frontier rows that generated it. As long as no episode crosses theta
//! the level-wise generation is skipped entirely
//! (`CommitStats::candidate_regens == 0`) and a commit costs only the
//! tuple updates above — work proportional to the arriving segment (plus
//! halo), not the window. When a frontier *does* move at level L, the
//! arena is truncated and rebuilt from L down: row refs into a rebuilt
//! block are meaningless, so deeper cached levels cannot survive the
//! regeneration (the cascade re-derives them, producing identical rows
//! whenever the deeper frontiers end up unchanged).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;

use crate::coordinator::mapconcat;
use crate::episodes::arena::{EpisodeArena, LevelBlock};
use crate::episodes::{CountedEpisode, Episode, Interval};
use crate::error::MineError;
use crate::events::{EventStream, EventType, Tick};
use crate::mining::serial;
use crate::obs::Trace;
use crate::session::{MineOptions, DEFAULT_CANDIDATE_BLOCK};

use super::diff::{CommitStats, CommitUpdate, FrequentDiff};

/// Configuration for an [`IncrementalMiner`] — the `MineOptions`
/// parameters plus the sliding-window length and the occurrence-list
/// bound.
#[derive(Clone, Debug)]
pub struct IncrementalConfig {
    /// support threshold theta (must be > 0)
    pub theta: u64,
    /// the inter-event constraint set I (must be non-empty)
    pub intervals: Vec<Interval>,
    /// stop after this episode size (default 8)
    pub max_level: usize,
    /// per-level candidate guardrail (default 2,000,000)
    pub max_candidates_per_level: usize,
    /// sliding window length in segments; 0 = unbounded (never retire)
    pub window_segments: usize,
    /// bounded occurrence-list K (`usize::MAX` = unbounded, the serial
    /// reference; a finite K reproduces the GPU kernel semantics of
    /// `serial::count_a1_bounded`)
    pub k: usize,
}

impl IncrementalConfig {
    pub fn new(theta: u64, intervals: Vec<Interval>) -> IncrementalConfig {
        IncrementalConfig {
            theta,
            intervals,
            max_level: 8,
            max_candidates_per_level: 2_000_000,
            window_segments: 0,
            k: usize::MAX,
        }
    }

    pub fn max_level(mut self, max_level: usize) -> IncrementalConfig {
        self.max_level = max_level;
        self
    }

    pub fn max_candidates_per_level(mut self, cap: usize) -> IncrementalConfig {
        self.max_candidates_per_level = cap;
        self
    }

    /// Slide over the most recent `n` segments (0 = grow forever).
    pub fn window_segments(mut self, n: usize) -> IncrementalConfig {
        self.window_segments = n;
        self
    }

    /// Bound occurrence lists to the K most recent entries.
    pub fn bounded_k(mut self, k: usize) -> IncrementalConfig {
        self.k = k;
        self
    }

    fn options(&self) -> MineOptions {
        MineOptions {
            theta: self.theta,
            intervals: self.intervals.clone(),
            max_level: self.max_level,
            max_candidates_per_level: self.max_candidates_per_level,
            candidate_block: DEFAULT_CANDIDATE_BLOCK,
        }
    }

    pub fn validate(&self) -> Result<(), MineError> {
        self.options().validate()?;
        if self.k == 0 {
            return Err(MineError::invalid("IncrementalConfig::k must be >= 1"));
        }
        Ok(())
    }
}

/// One arriving segment held in the window.
struct SegEntry {
    stream: EventStream,
    hist: Vec<u64>,
}

/// Cached automaton state for one tracked episode (size >= 2): one tuple
/// column per window partition, parallel to the segment deque, plus the
/// folded count as of the last commit.
struct Tracked {
    tuples: VecDeque<Vec<(Tick, u64, Tick)>>,
    count: u64,
}

/// The incremental sliding-window mining engine. Feed arriving segments
/// with [`IncrementalMiner::push_segment`]; each push commits and returns
/// a [`CommitUpdate`] whose frequent set equals a batch re-mine of the
/// current window.
pub struct IncrementalMiner {
    cfg: IncrementalConfig,
    n_types: usize,
    segs: VecDeque<SegEntry>,
    /// partition boundaries, `segs.len() + 1` entries once non-empty:
    /// `taus[0] = segs[0].t_min - 1`, `taus[i] = segs[i-1].t_max`
    taus: Vec<Tick>,
    /// per-type window counts (level-1 support, pure histogram deltas)
    counts1: Vec<u64>,
    tracked: HashMap<Episode, Tracked>,
    /// the candidate lattice: block 0 is the full alphabet as singles
    /// (row == type id), block `L-1` is level L's full candidate set as
    /// flat SoA rows
    arena: EpisodeArena,
    /// cache keys for levels >= 2 (index `level - 2`): the exact frontier
    /// — surviving row refs into the block below — that generated block
    /// `level - 1`. The block is reused verbatim while its frontier is
    /// unchanged; this is the theta-crossing gate.
    cached_frontiers: Vec<Vec<u32>>,
    frequent: Arc<Vec<CountedEpisode>>,
    commit_seq: u64,
}

impl IncrementalMiner {
    pub fn new(n_types: usize, cfg: IncrementalConfig) -> Result<IncrementalMiner, MineError> {
        if n_types == 0 {
            return Err(MineError::invalid("IncrementalMiner alphabet must have n_types >= 1"));
        }
        cfg.validate()?;
        let mut arena = EpisodeArena::new(&cfg.intervals);
        arena.push_singles(0..n_types as EventType);
        Ok(IncrementalMiner {
            cfg,
            n_types,
            segs: VecDeque::new(),
            taus: vec![],
            counts1: vec![0; n_types],
            tracked: HashMap::new(),
            arena,
            cached_frontiers: vec![],
            frequent: Arc::new(vec![]),
            commit_seq: 0,
        })
    }

    /// The frequent set as of the last commit.
    pub fn frequent(&self) -> &Arc<Vec<CountedEpisode>> {
        &self.frequent
    }

    /// Commits so far (== segments pushed).
    pub fn commits(&self) -> u64 {
        self.commit_seq
    }

    /// Window boundaries `(start, end]`, or `None` before the first push.
    pub fn window_bounds(&self) -> Option<(Tick, Tick)> {
        match self.taus.as_slice() {
            [] => None,
            taus => Some((taus[0], *taus.last().unwrap())),
        }
    }

    /// Materialize the current window as one sorted stream — what a batch
    /// re-mine of "the same data" means (the equivalence tests compare
    /// against a cold `Session::mine` over exactly this stream).
    pub fn window_stream(&self) -> EventStream {
        materialize(&self.segs, self.n_types)
    }

    /// Fold one arriving segment into the window and commit. The segment
    /// must be time-sorted, in-alphabet, non-empty, and start at or after
    /// the previous segment's last tick — the same contiguity the ingest
    /// log guarantees for sealed segments.
    pub fn push_segment(&mut self, seg: EventStream) -> Result<CommitUpdate, MineError> {
        self.push_segment_traced(seg, &Trace::off())
    }

    /// [`push_segment`](IncrementalMiner::push_segment) with span
    /// recording: a live `trace` gets one `commit` root span with the
    /// commit's phases (structural window update, tracked-tuple refresh,
    /// level-wise cascade, diff/publish) as children.
    pub fn push_segment_traced(
        &mut self,
        seg: EventStream,
        trace: &Trace,
    ) -> Result<CommitUpdate, MineError> {
        self.validate_segment(&seg)?;
        let root = trace.span_fmt(|| format!("commit {}", self.commit_seq + 1));
        let mut stats = CommitStats { events_added: seg.len(), ..CommitStats::default() };

        // -- structural update: append, then retire expired prefix segments
        let structural_span = root.child("structural");
        let old_end = self.taus.last().copied();
        let hist = seg.type_counts();
        for (ty, c) in hist.iter().enumerate() {
            self.counts1[ty] += c;
        }
        if self.segs.is_empty() {
            self.taus.push(seg.t_begin() - 1);
        }
        self.taus.push(seg.t_end());
        self.segs.push_back(SegEntry { stream: seg, hist });

        let mut segments_retired = 0usize;
        while self.cfg.window_segments > 0 && self.segs.len() > self.cfg.window_segments {
            let old = self.segs.pop_front().expect("window cannot be empty here");
            for (ty, c) in old.hist.iter().enumerate() {
                self.counts1[ty] -= c;
            }
            stats.events_retired += old.stream.len();
            segments_retired += 1;
            self.taus.remove(0);
        }
        if segments_retired > 0 {
            // the window's lower boundary is always t_min - 1 of its first
            // segment: a shared boundary tick between the retired and the
            // surviving segment must stay *inside* the window
            self.taus[0] = self.segs.front().unwrap().stream.t_begin() - 1;
        }
        stats.segments_retired = segments_retired;
        drop(structural_span);

        // -- refresh the cached tuples of every tracked episode
        let tuples_span = root.child("tuples");
        let window_len: usize = self.segs.iter().map(|s| s.stream.len()).sum();
        let mut window_cache: Option<EventStream> = None;
        let partitions = self.taus.len() - 1;
        for (ep, state) in self.tracked.iter_mut() {
            for _ in 0..segments_retired {
                state.tuples.pop_front();
            }
            state.tuples.push_back(vec![]); // the new partition's slot
            debug_assert_eq!(state.tuples.len(), partitions);
            let sumh = ep.span_max();
            for p in 0..partitions {
                let forward_reaches_new_data =
                    old_end.map_or(true, |end| self.taus[p + 1] + sumh >= end);
                let back_reaches_retired_data = segments_retired > 0
                    && (p == 0 || self.taus[p] - sumh <= self.taus[0]);
                if forward_reaches_new_data || back_reaches_retired_data {
                    state.tuples[p] = map_partition(
                        &self.segs, &self.taus, self.n_types, ep, p, self.cfg.k, &mut stats,
                    );
                }
            }
            state.count = fold_or_recount(
                ep,
                state,
                &self.segs,
                self.n_types,
                self.cfg.k,
                &mut window_cache,
                &mut stats,
            );
        }

        drop(tuples_span);

        // -- level-wise cascade, candidate generation gated on frontier
        //    movement (mirrors session::mine_with_backend exactly: break
        //    on empty candidates/frontier, explosion guardrail intact)
        let cascade_span = root.child("cascade");
        let mut frequent: Vec<CountedEpisode> = vec![];
        let mut frontier_refs: Vec<u32> = vec![];
        let mut active: HashSet<Episode> = HashSet::new();
        let mut levels_reached = 0usize;
        let mut scratch = Episode { types: vec![], intervals: vec![] };
        for level in 1..=self.cfg.max_level {
            if level >= 2 {
                let idx = level - 2;
                if self.cached_frontiers.get(idx) != Some(&frontier_refs) {
                    stats.candidate_regens += 1;
                    // the frontier moved: this block and every deeper one
                    // were generated from stale rows, and row refs into a
                    // rebuilt block are meaningless, so the cache cannot
                    // survive below the regeneration point — truncate and
                    // rebuild from here down (the cascade re-derives the
                    // deeper blocks, identically whenever their frontiers
                    // end up unchanged)
                    self.arena.truncate_blocks(level - 1);
                    self.cached_frontiers.truncate(idx);
                    // cap enforced before generation: the bucket pre-pass
                    // knows the exact output size, so fail fast before a
                    // single row is materialized
                    let total = self.arena.next_level_count(&frontier_refs);
                    if total > self.cfg.max_candidates_per_level {
                        return Err(MineError::CandidateExplosion {
                            level,
                            candidates: total,
                            cap: self.cfg.max_candidates_per_level,
                        });
                    }
                    let mut block = LevelBlock::default();
                    self.arena.generate_next(&frontier_refs, total.max(1), |chunk| {
                        block.extend_from_chunk(chunk);
                        Ok(())
                    })?;
                    self.arena.push_block(block);
                    self.cached_frontiers.push(frontier_refs.clone());
                }
            }
            let n_cands = self.arena.block_len(level - 1);
            if n_cands == 0 {
                break;
            }
            if n_cands > self.cfg.max_candidates_per_level {
                return Err(MineError::CandidateExplosion {
                    level,
                    candidates: n_cands,
                    cap: self.cfg.max_candidates_per_level,
                });
            }
            levels_reached = level;

            let mut counts: Vec<u64> = Vec::with_capacity(n_cands);
            if level == 1 {
                // singles rows are the alphabet in order (row == type id):
                // level-1 support is the counts1 histogram, never tracked
                for &ty in &self.arena.block(0).last_type {
                    counts.push(self.counts1[ty as usize]);
                }
            } else {
                for row in 0..n_cands {
                    self.arena.materialize_into(level - 1, row, &mut scratch);
                    active.insert(scratch.clone());
                    if !self.tracked.contains_key(&scratch) {
                        // a brand-new candidate: build its automaton state
                        // across the whole window once; subsequent commits
                        // update it incrementally
                        let mut tuples = VecDeque::with_capacity(partitions);
                        for p in 0..partitions {
                            tuples.push_back(map_partition(
                                &self.segs,
                                &self.taus,
                                self.n_types,
                                &scratch,
                                p,
                                self.cfg.k,
                                &mut stats,
                            ));
                        }
                        let mut state = Tracked { tuples, count: 0 };
                        state.count = fold_or_recount(
                            &scratch,
                            &mut state,
                            &self.segs,
                            self.n_types,
                            self.cfg.k,
                            &mut window_cache,
                            &mut stats,
                        );
                        self.tracked.insert(scratch.clone(), state);
                    }
                    counts.push(self.tracked[&scratch].count);
                }
            }

            frontier_refs = (0..n_cands as u32)
                .filter(|&row| counts[row as usize] >= self.cfg.theta)
                .collect();
            for &row in &frontier_refs {
                frequent.push(CountedEpisode {
                    episode: self.arena.episode(level - 1, row as usize),
                    count: counts[row as usize],
                });
            }
            if frontier_refs.is_empty() {
                break;
            }
        }
        // drop blocks and cache keys for levels the cascade no longer
        // reaches, and evict episodes that are no longer candidates
        // anywhere (bounded memory)
        self.arena.truncate_blocks(levels_reached.max(1));
        self.cached_frontiers.truncate(levels_reached.saturating_sub(1));
        self.tracked.retain(|ep, _| active.contains(ep));
        stats.tracked_episodes = self.tracked.len();
        drop(cascade_span);

        // -- commit: diff against the previous frequent set and publish
        let _publish_span = root.child("publish");
        let frequent = Arc::new(frequent);
        let diff = FrequentDiff::between(&self.frequent, &frequent);
        self.frequent = Arc::clone(&frequent);
        self.commit_seq += 1;
        Ok(CommitUpdate {
            seq: self.commit_seq,
            window_start: self.taus[0],
            window_end: *self.taus.last().unwrap(),
            window_segments: self.segs.len(),
            window_events: window_len,
            frequent,
            diff,
            stats,
        })
    }

    fn validate_segment(&self, seg: &EventStream) -> Result<(), MineError> {
        if seg.n_types != self.n_types {
            return Err(MineError::invalid(format!(
                "segment alphabet has {} types but the miner was built for {}",
                seg.n_types, self.n_types
            )));
        }
        if seg.is_empty() {
            return Err(MineError::invalid(
                "cannot push an empty segment (sealed log segments are never empty)",
            ));
        }
        if let Some(&ty) =
            seg.types.iter().find(|&&ty| ty < 0 || ty as usize >= self.n_types)
        {
            return Err(MineError::OutOfAlphabet { type_id: ty, n_types: self.n_types });
        }
        if !seg.times.windows(2).all(|w| w[0] <= w[1]) {
            return Err(MineError::invalid(
                "segment must be time-sorted (build it with EventStream::from_pairs)",
            ));
        }
        if let Some(&end) = self.taus.last() {
            if seg.t_begin() < end {
                return Err(MineError::invalid(format!(
                    "segment starts at {} but the window already covers through {} — \
                     segments must arrive in time order (the ingest log guarantees this)",
                    seg.t_begin(),
                    end
                )));
            }
        }
        Ok(())
    }
}

/// Concatenate the window's events inside `(t_from, t_to]` — the halo
/// sub-stream a partition's boundary machines scan. Segments are
/// time-ordered and non-overlapping (shared boundary ticks excepted), so
/// per-segment binary-searched windows concatenate sorted.
fn window_slice(
    segs: &VecDeque<SegEntry>,
    n_types: usize,
    t_from: Tick,
    t_to: Tick,
) -> EventStream {
    let mut out = EventStream::new(n_types);
    for seg in segs {
        if seg.stream.t_end() <= t_from {
            continue;
        }
        if seg.stream.t_begin() > t_to {
            break;
        }
        let w = seg.stream.window(t_from, t_to);
        out.types.extend_from_slice(&w.types);
        out.times.extend_from_slice(&w.times);
    }
    out
}

fn materialize(segs: &VecDeque<SegEntry>, n_types: usize) -> EventStream {
    let mut out = EventStream::new(n_types);
    for seg in segs {
        out.types.extend_from_slice(&seg.stream.types);
        out.times.extend_from_slice(&seg.stream.times);
    }
    out
}

/// Run the boundary-machine Map for one `(episode, partition)` pair over
/// the halo sub-stream — `backend/sharded.rs`'s per-shard idiom, scanning
/// O(partition + 2·halo) events regardless of window size.
fn map_partition(
    segs: &VecDeque<SegEntry>,
    taus: &[Tick],
    n_types: usize,
    ep: &Episode,
    p: usize,
    k: usize,
    stats: &mut CommitStats,
) -> Vec<(Tick, u64, Tick)> {
    let sumh = ep.span_max();
    let (lo, hi) = (taus[p], taus[p + 1]);
    let sub = window_slice(segs, n_types, lo - sumh, hi + sumh);
    stats.partitions_recomputed += 1;
    stats.events_rescanned += sub.len();
    serial::mapcat_map(ep, &sub, &[lo, hi], k).swap_remove(0)
}

/// Chain the cached tuple columns with the Concatenate fold; on a flagged
/// miss, restore exactness via the serial reference over the materialized
/// window (built at most once per commit, shared across episodes).
fn fold_or_recount(
    ep: &Episode,
    state: &mut Tracked,
    segs: &VecDeque<SegEntry>,
    n_types: usize,
    k: usize,
    window_cache: &mut Option<EventStream>,
    stats: &mut CommitStats,
) -> u64 {
    let (total, misses) = mapconcat::concatenate_fold(state.tuples.make_contiguous());
    if misses == 0 {
        return total;
    }
    stats.concat_misses += misses;
    stats.serial_recounts += 1;
    let window = window_cache.get_or_insert_with(|| materialize(segs, n_types));
    if k == usize::MAX {
        serial::count_a1(ep, window)
    } else {
        serial::count_a1_bounded(ep, window, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(theta: u64) -> IncrementalConfig {
        IncrementalConfig::new(theta, vec![Interval::new(0, 6)]).max_level(3)
    }

    fn seg(pairs: Vec<(i32, Tick)>) -> EventStream {
        EventStream::from_pairs(pairs, 3)
    }

    #[test]
    fn rejects_bad_segments() {
        let mut m = IncrementalMiner::new(3, cfg(1)).unwrap();
        assert!(m.push_segment(EventStream::new(3)).is_err(), "empty");
        assert!(m.push_segment(EventStream::new(2)).is_err(), "alphabet size");
        let mut bad = EventStream::new(3);
        bad.types = vec![0, 9];
        bad.times = vec![1, 2];
        assert!(matches!(
            m.push_segment(bad),
            Err(MineError::OutOfAlphabet { type_id: 9, .. })
        ));
        m.push_segment(seg(vec![(0, 10), (1, 12)])).unwrap();
        // time going backwards across segments is rejected
        assert!(m.push_segment(seg(vec![(0, 5)])).is_err());
    }

    #[test]
    fn config_validation() {
        assert!(IncrementalMiner::new(0, cfg(1)).is_err());
        assert!(IncrementalMiner::new(3, cfg(0)).is_err());
        assert!(IncrementalMiner::new(3, cfg(1).bounded_k(0)).is_err());
        assert!(IncrementalMiner::new(3, cfg(1).bounded_k(4)).is_ok());
    }

    #[test]
    fn window_slides_and_counts1_track_histograms() {
        let mut m = IncrementalMiner::new(3, cfg(2).window_segments(2)).unwrap();
        m.push_segment(seg(vec![(0, 1), (1, 3)])).unwrap();
        m.push_segment(seg(vec![(0, 11), (2, 13)])).unwrap();
        let u = m.push_segment(seg(vec![(1, 21), (2, 23)])).unwrap();
        assert_eq!(u.window_segments, 2);
        assert_eq!(u.stats.segments_retired, 1);
        assert_eq!(u.stats.events_retired, 2);
        // the retired segment's (0,1),(1,3) are gone from level-1 counts
        assert_eq!(m.counts1, vec![1, 1, 2]);
        assert_eq!(m.window_bounds(), Some((10, 23)));
        assert_eq!(m.window_stream().times, vec![11, 13, 21, 23]);
    }

    #[test]
    fn candidate_generation_is_gated_on_frontier_movement() {
        // a steady periodic pattern: after warmup the frontier stops
        // moving, and commits must stop regenerating candidates
        let mut m = IncrementalMiner::new(3, cfg(2).window_segments(3)).unwrap();
        let mut regens_late = 0;
        for i in 0..8 {
            let base = 100 * i;
            let u = m
                .push_segment(seg(vec![
                    (0, base + 1),
                    (1, base + 3),
                    (0, base + 10),
                    (1, base + 12),
                    (2, base + 50),
                ]))
                .unwrap();
            if i >= 4 {
                regens_late += u.stats.candidate_regens;
                assert!(u.diff.is_empty(), "steady state must not move: {:?}", u.diff);
            }
        }
        assert_eq!(regens_late, 0, "steady frontier must reuse cached candidates");
    }

    #[test]
    fn explosion_guardrail_matches_batch() {
        let cfg = IncrementalConfig::new(1, vec![Interval::new(0, 6)])
            .max_level(3)
            .max_candidates_per_level(2);
        let mut m = IncrementalMiner::new(3, cfg).unwrap();
        let err = m.push_segment(seg(vec![(0, 1), (1, 2), (2, 3)])).err().unwrap();
        assert!(matches!(
            err,
            MineError::CandidateExplosion { level: 1, candidates: 3, cap: 2 }
        ));
    }

    #[test]
    fn randomized_counts_match_serial_reference() {
        // the full equivalence property lives in tests/stream_incremental.rs;
        // this in-crate smoke pins the count path (fold + miss recount)
        // against count_a1 over the materialized window at every commit
        for seed in 0..5u64 {
            let mut rng = Rng::new(seed);
            let mut m = IncrementalMiner::new(3, cfg(2).window_segments(3)).unwrap();
            let mut t = 0;
            for _ in 0..6 {
                let mut pairs = vec![];
                for _ in 0..40 {
                    t += rng.range_i32(0, 4);
                    pairs.push((rng.range_i32(0, 2), t));
                }
                let update = m.push_segment(seg(pairs)).unwrap();
                let window = m.window_stream();
                for c in update.frequent.iter() {
                    assert_eq!(
                        c.count,
                        serial::count_a1(&c.episode, &window),
                        "seed {seed} episode {}",
                        c.episode.display()
                    );
                }
            }
        }
    }
}
