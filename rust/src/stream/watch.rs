//! Live mining off the ingest log: [`LogWatcher`] ties a
//! [`TailReader`](crate::ingest::TailReader) to an [`IncrementalMiner`].
//!
//! The closed loop the paper pitches: the acquisition side seals spike
//! segments into a [`SpikeLog`] (the single writer), and a watcher — in
//! the same process or any other — polls the manifest for newly sealed
//! segments and folds each one into the sliding window, committing one
//! [`CommitUpdate`] per segment. `epminer watch` drives this from the
//! CLI; `serve::MineService::publish` fans the updates out to
//! subscribers.

use std::path::Path;

use crate::error::MineError;
use crate::ingest::{SpikeLog, TailReader};

use super::diff::CommitUpdate;
use super::incremental::{IncrementalConfig, IncrementalMiner};

/// A tailing incremental miner over a [`SpikeLog`] directory.
pub struct LogWatcher {
    tail: TailReader,
    miner: IncrementalMiner,
}

impl LogWatcher {
    /// Open the log at `dir` and mine from the start of the recording:
    /// the first [`LogWatcher::poll`] replays every already-sealed
    /// segment through the incremental engine (so the window state is
    /// identical to having watched from the beginning), then subsequent
    /// polls surface only new seals.
    pub fn new(dir: &Path, cfg: IncrementalConfig) -> Result<LogWatcher, MineError> {
        let log = SpikeLog::open(dir)?;
        let miner = IncrementalMiner::new(log.n_types(), cfg)?;
        Ok(LogWatcher { tail: log.tail(), miner })
    }

    /// Watch only segments sealed after this call (skip history).
    pub fn from_end(dir: &Path, cfg: IncrementalConfig) -> Result<LogWatcher, MineError> {
        let log = SpikeLog::open(dir)?;
        let miner = IncrementalMiner::new(log.n_types(), cfg)?;
        Ok(LogWatcher { tail: log.tail_from_end(), miner })
    }

    /// Poll for newly sealed segments and commit each into the window.
    /// Returns one [`CommitUpdate`] per segment, in seal order (empty
    /// when caught up).
    pub fn poll(&mut self) -> Result<Vec<CommitUpdate>, MineError> {
        let mut updates = vec![];
        for (_meta, seg) in self.tail.poll()? {
            updates.push(self.miner.push_segment(seg)?);
        }
        Ok(updates)
    }

    pub fn miner(&self) -> &IncrementalMiner {
        &self.miner
    }

    pub fn log(&self) -> &SpikeLog {
        self.tail.log()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::episodes::Interval;
    use crate::events::EventStream;
    use crate::ingest::RollPolicy;

    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("epgs_watch_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn watcher_replays_history_then_tails_new_seals() {
        let dir = scratch("watcher_tails");
        let log = SpikeLog::create(&dir, 3).unwrap();
        let mut ing = log
            .ingestor(RollPolicy { max_events: 4, max_width_ticks: 0 })
            .unwrap();
        ing.append_stream(&EventStream::from_pairs(
            vec![(0, 1), (1, 3), (0, 11), (1, 13), (0, 21), (1, 23), (2, 30), (2, 31)],
            3,
        ))
        .unwrap();
        ing.seal().unwrap();
        let log = ing.finish().unwrap();

        let cfg = IncrementalConfig::new(2, vec![Interval::new(0, 6)]).max_level(2);
        let mut watcher = LogWatcher::new(log.dir(), cfg.clone()).unwrap();
        let history = watcher.poll().unwrap();
        assert_eq!(history.len(), log.segments().len());
        assert!(watcher.poll().unwrap().is_empty(), "caught up");

        // seal more while the watcher holds its own handle
        let mut ing = log
            .ingestor(RollPolicy { max_events: 4, max_width_ticks: 0 })
            .unwrap();
        ing.append_stream(&EventStream::from_pairs(
            vec![(0, 41), (1, 43), (0, 51), (1, 53)],
            3,
        ))
        .unwrap();
        ing.seal().unwrap();
        ing.finish().unwrap();

        let fresh = watcher.poll().unwrap();
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].seq, history.last().unwrap().seq + 1);

        // a from_end watcher skips history entirely
        let mut late = LogWatcher::from_end(&dir, cfg).unwrap();
        assert!(late.poll().unwrap().is_empty());
    }
}
