//! Hand-rolled utility substrates (the offline crate set has no rand /
//! clap / criterion / proptest — see DESIGN.md §3).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
