//! Minimal JSON tree (the offline crate set has no serde): parse and
//! render, enough for the bench result schema (`crate::bench::schema`),
//! committed perf baselines, and any other machine-readable surface that
//! needs to be read back, not just printed.
//!
//! Rendering floats uses Rust's shortest round-trip formatting, so a
//! `render` → `parse` cycle reproduces the same `f64` bit pattern —
//! the property the bench schema round-trip test pins down.

use crate::error::MineError;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered (JSON objects are rendered in the order given).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing non-whitespace is an
    /// error).
    pub fn parse(text: &str) -> Result<Json, MineError> {
        let mut p = Parser { text, bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Compact single-line rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Two-space-indented rendering, for committed baseline files.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&render_number(*x)),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that names the missing key in the error.
    pub fn req(&self, key: &str) -> Result<&Json, MineError> {
        self.get(key)
            .ok_or_else(|| MineError::invalid(format!("JSON object missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// `Some(x)` renders as the value, `None` as `null` — the optional-field
/// convention the bench schema uses.
pub fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

/// `Some(s)` renders as a string, `None` as `null`.
pub fn opt_str(s: Option<&str>) -> Json {
    match s {
        Some(s) => Json::Str(s.to_string()),
        None => Json::Null,
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn render_number(x: f64) -> String {
    if !x.is_finite() {
        // NaN/inf are not JSON; the schema never produces them, but a
        // defensive 0 beats emitting an unparseable document.
        return "0".to_string();
    }
    if x.fract() == 0.0 && x.abs() < 9.0e15 {
        format!("{}", x as i64)
    } else {
        // shortest representation that round-trips the f64 exactly
        format!("{x}")
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    text: &'a str,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, what: &str) -> MineError {
        MineError::invalid(format!("JSON parse error at byte {}: {what}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), MineError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, MineError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn value(&mut self) -> Result<Json, MineError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, MineError> {
        self.expect(b'{')?;
        let mut fields = vec![];
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, MineError> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, MineError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: require a \uXXXX low half
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        char::from_u32(
                                            0x10000
                                                + ((hi - 0xD800) << 10)
                                                + (lo - 0xDC00),
                                        )
                                    } else {
                                        None
                                    }
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(hi)
                            };
                            match c {
                                Some(c) => out.push(c),
                                None => return Err(self.err("invalid \\u escape")),
                            }
                            // hex4 leaves pos after the last hex digit;
                            // skip the shared `pos += 1` below
                            continue;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar. `pos` only ever advances
                    // by whole scalars (the escape/ASCII arms above move
                    // one matched ASCII byte at a time), so it is always
                    // a char boundary of the input &str.
                    let c = self.text[self.pos..].chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, MineError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.peek() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return Err(self.err("expected 4 hex digits after \\u")),
            };
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, MineError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().is_null());
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn render_parse_round_trip() {
        let v = Json::Obj(vec![
            ("name".into(), Json::Str("a \"b\"\n".into())),
            ("median_ns".into(), Json::Num(123456.789)),
            ("count".into(), Json::Num(42.0)),
            ("opt".into(), Json::Null),
            ("flags".into(), Json::Arr(vec![Json::Bool(true), Json::Bool(false)])),
        ]);
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
        assert_eq!(Json::parse(&v.render_pretty()).unwrap(), v);
    }

    #[test]
    fn numbers_round_trip_exactly() {
        for x in [0.0, -0.5, 1.0 / 3.0, 1e-9, 2.5e17, 123456789.123456] {
            let rendered = render_number(x);
            let back: f64 = rendered.parse().unwrap();
            assert_eq!(back, x, "{x} rendered as {rendered}");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
        // raw UTF-8 passes through; the escaped surrogate pair decodes to
        // the same glyph (𝄞, U+1D11E)
        assert_eq!(Json::parse(r#""𝄞""#).unwrap(), Json::Str("𝄞".into()));
        assert_eq!(
            Json::parse(r#""\ud834\udd1e""#).unwrap(),
            Json::Str("𝄞".into())
        );
        // malformed pairs are errors, never silent garbage
        assert!(Json::parse(r#""\ud834""#).is_err(), "lone high surrogate");
        assert!(
            Json::parse(r#""\ud834A""#).is_err(),
            "high surrogate paired with a non-low-surrogate escape"
        );
    }

    #[test]
    fn u64_accessor_guards_range_and_fraction() {
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }
}
