//! Deterministic PRNG + samplers for the dataset generators and property
//! tests. Hand-rolled (SplitMix64 core) because the offline crate set has
//! no `rand`; SplitMix64 passes BigCrush and is the canonical seeder.

/// SplitMix64: tiny, fast, statistically solid for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free for our n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply keeps bias < 2^-64 for any n < 2^63 — fine for
        // simulation use.
        (((self.next_u64() as u128) * (n as u128)) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform i32 in [lo, hi] inclusive.
    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        self.range_i64(lo as i64, hi as i64) as i32
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given rate (events per tick); the
    /// inter-arrival time of a Poisson process.
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Fork an independent stream (for per-neuron generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let v = r.range_i32(-2, 2);
            assert!((-2..=2).contains(&v));
            lo_seen |= v == -2;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(4);
        let rate = 0.02; // 20 Hz at ms ticks
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 50.0).abs() < 2.0, "mean {mean}");
    }

    #[test]
    fn uniformity_chi_square_ish() {
        let mut r = Rng::new(5);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            buckets[r.below(10) as usize] += 1;
        }
        for &b in &buckets {
            assert!((9_000..11_000).contains(&b), "bucket {b}");
        }
    }
}
