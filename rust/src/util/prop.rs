//! Mini property-testing harness (no proptest offline): seeded generators
//! + a `forall` runner that reports the failing seed for reproduction.
//!
//! No shrinking — generators are kept small-biased instead (sizes drawn
//! log-uniformly), which in practice keeps counterexamples readable.

use super::rng::Rng;

/// Run `prop(rng)` for `cases` deterministic seeds derived from `seed`;
/// panic with the failing case's seed on the first failure.
pub fn forall<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    seed: u64,
    cases: u64,
    mut prop: F,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed (case {case}, seed {case_seed:#x}): {msg}");
        }
    }
}

/// Log-uniform size in [1, max]: biases toward small structures.
pub fn small_size(rng: &mut Rng, max: usize) -> usize {
    debug_assert!(max >= 1);
    let bits = 64 - (max as u64).leading_zeros() as u64;
    let b = rng.below(bits) + 1;
    let cap = (1u64 << b).min(max as u64);
    (rng.below(cap) + 1) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall("trivial", 1, 50, |rng| {
            let x = rng.below(100);
            if x < 100 { Ok(()) } else { Err(format!("x={x}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn forall_reports_failure() {
        forall("fails", 2, 50, |rng| {
            let x = rng.below(10);
            if x < 9 { Ok(()) } else { Err(format!("x={x}")) }
        });
    }

    #[test]
    fn small_size_in_bounds_and_biased() {
        let mut rng = Rng::new(3);
        let mut small = 0;
        for _ in 0..1000 {
            let s = small_size(&mut rng, 100);
            assert!((1..=100).contains(&s));
            if s <= 10 {
                small += 1;
            }
        }
        assert!(small > 300, "small-biased: {small}");
    }
}
