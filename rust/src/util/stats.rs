//! Summary statistics for the bench harness and metrics.

/// Summary of a sample of measurements (e.g. iteration times in ns).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Summarize a sample. Pinned semantics (registry histograms and
    /// latency windows feed arbitrary runtime data through here, so the
    /// edge cases are contracts, not accidents):
    ///
    /// - **Non-finite samples are skipped**, and `n` counts only the
    ///   finite ones — a stray NaN/∞ can never poison the percentiles
    ///   or turn the sort into a panic.
    /// - **Panics** when no finite sample remains (use [`Summary::of_opt`]
    ///   where "nothing measured yet" is a legal state).
    /// - **Tiny samples degrade linearly**: n = 1 reports the sample for
    ///   every statistic (stddev 0); n ≥ 2 linearly interpolates
    ///   percentiles over rank `pct/100 · (n−1)` (so with n = 2,
    ///   p95 = lo + 0.95·(hi−lo); with n = 3 the median is the middle
    ///   sample exactly).
    pub fn of(samples: &[f64]) -> Summary {
        Summary::of_opt(samples).expect("Summary::of needs at least one finite sample")
    }

    /// [`Summary::of`], tolerating an empty (or all-non-finite) sample
    /// (`None`) — the shape a metrics snapshot wants when nothing has
    /// been measured yet.
    pub fn of_opt(samples: &[f64]) -> Option<Summary> {
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        if sorted.is_empty() {
            return None;
        }
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite samples are totally ordered"));
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Some(Summary {
            n,
            mean,
            median: percentile_sorted(&sorted, 50.0),
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares y ~ a*x + b. Returns (a, b, sse).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    let a = if denom.abs() < 1e-12 { 0.0 } else { (n * sxy - sx * sy) / denom };
    let b = (sy - a * sx) / n;
    let sse = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a * x + b);
            e * e
        })
        .sum();
    (a, b, sse)
}

/// Least squares for the paper's Fig. 8 form y ~ a/x + b. Returns (a, b, sse).
pub fn inverse_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    let inv: Vec<f64> = xs.iter().map(|x| 1.0 / x).collect();
    linear_fit(&inv, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 1.5811388).abs() < 1e-6);
        // p99 interpolates between the top two samples: rank 3.96
        assert!((s.p99 - 4.96).abs() < 1e-9, "{}", s.p99);
    }

    #[test]
    fn of_opt_handles_empty() {
        assert!(Summary::of_opt(&[]).is_none());
        assert_eq!(Summary::of_opt(&[2.0]).unwrap().p99, 2.0);
    }

    #[test]
    fn tiny_samples_have_pinned_percentiles() {
        // n = 1: every statistic is the sample itself
        let s = Summary::of(&[7.5]);
        assert_eq!((s.n, s.mean, s.median, s.min, s.max, s.p95, s.p99), (1, 7.5, 7.5, 7.5, 7.5, 7.5, 7.5));
        assert_eq!(s.stddev, 0.0);

        // n = 2: percentiles interpolate over rank pct/100 * 1
        let s = Summary::of(&[10.0, 20.0]);
        assert_eq!(s.median, 15.0);
        assert!((s.p95 - 19.5).abs() < 1e-9, "{}", s.p95);
        assert!((s.p99 - 19.9).abs() < 1e-9, "{}", s.p99);
        assert_eq!((s.min, s.max), (10.0, 20.0));

        // n = 3: median is the middle sample exactly; p95 interpolates
        // between the top two at rank 1.9
        let s = Summary::of(&[1.0, 2.0, 4.0]);
        assert_eq!(s.median, 2.0);
        assert!((s.p95 - (2.0 * 0.1 + 4.0 * 0.9)).abs() < 1e-9, "{}", s.p95);

        // order of arrival never matters
        assert_eq!(Summary::of(&[4.0, 1.0, 2.0]), Summary::of(&[1.0, 2.0, 4.0]));
    }

    #[test]
    fn non_finite_samples_are_skipped_not_poisonous() {
        // NaN/∞ are dropped; n counts finite samples only
        let s = Summary::of(&[f64::NAN, 1.0, f64::INFINITY, 3.0, f64::NEG_INFINITY]);
        assert_eq!(s.n, 2);
        assert_eq!((s.min, s.max, s.median), (1.0, 3.0, 2.0));
        assert!(s.mean.is_finite() && s.p99.is_finite());

        // nothing finite left: of_opt is None, of panics
        assert!(Summary::of_opt(&[f64::NAN, f64::INFINITY]).is_none());
        let panicked =
            std::panic::catch_unwind(|| Summary::of(&[f64::NAN])).is_err();
        assert!(panicked, "Summary::of must panic when no finite sample remains");
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x - 1.0).collect();
        let (a, b, sse) = linear_fit(&xs, &ys);
        assert!((a - 2.5).abs() < 1e-9 && (b + 1.0).abs() < 1e-9 && sse < 1e-9);
    }

    #[test]
    fn inverse_fit_exact() {
        // y = 400/x + 30 — the shape of the paper's crossover fit.
        let xs = [3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 400.0 / x + 30.0).collect();
        let (a, b, sse) = inverse_fit(&xs, &ys);
        assert!((a - 400.0).abs() < 1e-6 && (b - 30.0).abs() < 1e-6 && sse < 1e-9);
    }

    #[test]
    fn inverse_beats_linear_on_paper_table1() {
        // Table 1: crossover points by level.
        let xs = [3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let ys = [415.0, 190.0, 200.0, 100.0, 100.0, 60.0];
        let (_, _, sse_inv) = inverse_fit(&xs, &ys);
        let (_, _, sse_lin) = linear_fit(&xs, &ys);
        assert!(sse_inv < sse_lin, "paper's a/N+b fit must win: {sse_inv} vs {sse_lin}");
    }
}
