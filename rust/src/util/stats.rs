//! Summary statistics for the bench harness and metrics.

/// Summary of a sample of measurements (e.g. iteration times in ns).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            median: percentile_sorted(&sorted, 50.0),
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }

    /// [`Summary::of`], tolerating an empty sample (`None`) — the shape a
    /// metrics snapshot wants when nothing has been measured yet.
    pub fn of_opt(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            None
        } else {
            Some(Summary::of(samples))
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares y ~ a*x + b. Returns (a, b, sse).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    let a = if denom.abs() < 1e-12 { 0.0 } else { (n * sxy - sx * sy) / denom };
    let b = (sy - a * sx) / n;
    let sse = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a * x + b);
            e * e
        })
        .sum();
    (a, b, sse)
}

/// Least squares for the paper's Fig. 8 form y ~ a/x + b. Returns (a, b, sse).
pub fn inverse_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    let inv: Vec<f64> = xs.iter().map(|x| 1.0 / x).collect();
    linear_fit(&inv, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 1.5811388).abs() < 1e-6);
        // p99 interpolates between the top two samples: rank 3.96
        assert!((s.p99 - 4.96).abs() < 1e-9, "{}", s.p99);
    }

    #[test]
    fn of_opt_handles_empty() {
        assert!(Summary::of_opt(&[]).is_none());
        assert_eq!(Summary::of_opt(&[2.0]).unwrap().p99, 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x - 1.0).collect();
        let (a, b, sse) = linear_fit(&xs, &ys);
        assert!((a - 2.5).abs() < 1e-9 && (b + 1.0).abs() < 1e-9 && sse < 1e-9);
    }

    #[test]
    fn inverse_fit_exact() {
        // y = 400/x + 30 — the shape of the paper's crossover fit.
        let xs = [3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 400.0 / x + 30.0).collect();
        let (a, b, sse) = inverse_fit(&xs, &ys);
        assert!((a - 400.0).abs() < 1e-6 && (b - 30.0).abs() < 1e-6 && sse < 1e-9);
    }

    #[test]
    fn inverse_beats_linear_on_paper_table1() {
        // Table 1: crossover points by level.
        let xs = [3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let ys = [415.0, 190.0, 200.0, 100.0, 100.0, 60.0];
        let (_, _, sse_inv) = inverse_fit(&xs, &ys);
        let (_, _, sse_lin) = linear_fit(&xs, &ys);
        assert!(sse_inv < sse_lin, "paper's a/N+b fit must win: {sse_inv} vs {sse_lin}");
    }
}
