//! Criterion-style micro/macro-benchmark harness (the offline crate set has
//! no criterion). Used by all `[[bench]] harness = false` targets.
//!
//! Provides warmup, timed iterations, outlier-robust summaries, and a
//! paper-table printer so every bench target can emit the rows/series the
//! corresponding paper table or figure reports.

use std::time::Instant;

use super::stats::Summary;

/// Configuration for one measurement.
#[derive(Clone, Debug)]
pub struct BenchCfg {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop early once this much wall time (ns) has been spent measuring.
    pub budget_ns: u128,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg { warmup_iters: 1, min_iters: 3, max_iters: 30, budget_ns: 2_000_000_000 }
    }
}

/// One benchmark measurement: iteration wall times + a scalar the workload
/// returned on the last iteration (used to verify work wasn't optimized
/// away and to report counts).
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    pub last_result: u64,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean / 1e6
    }
}

/// Run `f` under the config; `f` returns a u64 sink value.
pub fn bench<F: FnMut() -> u64>(name: &str, cfg: &BenchCfg, mut f: F) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(cfg.max_iters);
    let mut last = 0u64;
    let started = Instant::now();
    for i in 0..cfg.max_iters {
        let t0 = Instant::now();
        last = std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos() as f64);
        if i + 1 >= cfg.min_iters && started.elapsed().as_nanos() > cfg.budget_ns {
            break;
        }
    }
    Measurement { name: name.to_string(), summary: Summary::of(&times), last_result: last }
}

/// Pretty-print a table of rows, e.g. the series a paper figure plots.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
            .collect();
        println!("{}", hdr.join("  "));
        println!("{}", "-".repeat(hdr.join("  ").len()));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("{}", line.join("  "));
        }
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.1}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}us", ns / 1e3)
    } else {
        format!("{:.0}ns", ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_sinks() {
        let cfg = BenchCfg { warmup_iters: 1, min_iters: 2, max_iters: 4, budget_ns: u128::MAX };
        let m = bench("t", &cfg, || (0..1000u64).sum::<u64>());
        assert_eq!(m.last_result, 499_500);
        assert!(m.summary.n >= 2);
    }

    #[test]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.5us");
        assert_eq!(fmt_ns(2_500_000.0), "2.5ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.00s");
    }
}
