//! Minimal CLI argument parser (the offline crate set has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    pub fn get_i32(&self, name: &str, default: i32) -> i32 {
        self.get(name).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|v| v.parse().expect("float option")).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["mine", "--theta", "300", "--dataset=sym26", "--verbose"]);
        assert_eq!(a.positional, vec!["mine"]);
        assert_eq!(a.get("theta"), Some("300"));
        assert_eq!(a.get("dataset"), Some("sym26"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "5", "--rate=2.5"]);
        assert_eq!(a.get_usize("n", 1), 5);
        assert_eq!(a.get_f64("rate", 0.0), 2.5);
        assert_eq!(a.get_usize("missing", 9), 9);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--fast", "run"]);
        // "--fast run": `run` is consumed as fast's value per the grammar;
        // use `--fast` last or `--fast=1`. Document by asserting behavior.
        assert_eq!(a.get("fast"), Some("run"));
    }
}
