//! Minimal CLI argument parser (the offline crate set has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Typed getters return a usage error naming the offending flag and value
//! (a malformed `--theta banana` is a user mistake, not a panic).

use std::collections::HashMap;

use crate::error::MineError;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse `--name`'s value, or return `default` when absent. A value
    /// that fails to parse is a usage error naming the flag and the value.
    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
        expected: &str,
    ) -> Result<T, MineError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                MineError::invalid(format!("bad --{name} value {v:?} (expected {expected})"))
            }),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, MineError> {
        self.get_parsed(name, default, "an unsigned integer")
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, MineError> {
        self.get_parsed(name, default, "an unsigned integer")
    }

    pub fn get_i32(&self, name: &str, default: i32) -> Result<i32, MineError> {
        self.get_parsed(name, default, "an integer")
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, MineError> {
        self.get_parsed(name, default, "a number")
    }

    /// Every provided option and flag name, for callers that reject or
    /// warn on arguments they do not understand (a silently ignored
    /// `--events 1000000` measures a different workload than the one the
    /// user asked for).
    pub fn given(&self) -> impl Iterator<Item = &str> {
        self.options
            .keys()
            .map(|s| s.as_str())
            .chain(self.flags.iter().map(|s| s.as_str()))
    }

    /// The canonical reduced-workload flag: `--smoke`. The first bench
    /// generation called it `--fast`; that spelling still works as a
    /// deprecated alias (with a stderr warning) so existing scripts and CI
    /// invocations keep running while they migrate.
    pub fn smoke(&self) -> bool {
        if self.flag("fast") {
            eprintln!("warning: --fast is deprecated, use --smoke");
            return true;
        }
        self.flag("smoke")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["mine", "--theta", "300", "--dataset=sym26", "--verbose"]);
        assert_eq!(a.positional, vec!["mine"]);
        assert_eq!(a.get("theta"), Some("300"));
        assert_eq!(a.get("dataset"), Some("sym26"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "5", "--rate=2.5"]);
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 9).unwrap(), 9);
    }

    #[test]
    fn malformed_value_is_usage_error_not_panic() {
        let a = parse(&["--theta", "banana", "--rate=fast"]);
        let err = a.get_u64("theta", 1).err().unwrap();
        let msg = err.to_string();
        assert!(msg.contains("--theta") && msg.contains("banana"), "{msg}");
        let msg = a.get_f64("rate", 0.0).err().unwrap().to_string();
        assert!(msg.contains("--rate") && msg.contains("fast"), "{msg}");
        // negative values are malformed for unsigned getters
        let a = parse(&["--n=-3"]);
        assert!(a.get_usize("n", 1).is_err());
        assert_eq!(a.get_i32("n", 1).unwrap(), -3);
    }

    #[test]
    fn given_lists_every_option_and_flag() {
        let a = parse(&["--theta", "300", "--dataset=sym26", "--verbose"]);
        let mut names: Vec<&str> = a.given().collect();
        names.sort_unstable();
        assert_eq!(names, vec!["dataset", "theta", "verbose"]);
    }

    #[test]
    fn smoke_accepts_deprecated_fast_alias() {
        assert!(parse(&["--smoke"]).smoke());
        assert!(parse(&["--fast"]).smoke());
        assert!(!parse(&["--thorough"]).smoke());
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--fast", "run"]);
        // "--fast run": `run` is consumed as fast's value per the grammar;
        // use `--fast` last or `--fast=1`. Document by asserting behavior.
        assert_eq!(a.get("fast"), Some("run"));
    }
}
