//! Versioned, machine-readable bench result schema.
//!
//! Every suite run serializes to one `BENCH_<suite>.json` document:
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "suite": "axis_scaling",
//!   "created_unix": 1753776000,
//!   "env": { "commit": "...", "host": "...", "os": "linux-x86_64",
//!            "threads": 8, "profile": "release", "runtime": "unavailable",
//!            "smoke": true },
//!   "scenarios": [ { "name": "threads1/episode_axis", "iters": 3,
//!                    "median_ns": 1.2e7, ... } ],
//!   "skipped":   [ { "name": "*", "reason": "runtime unavailable" } ]
//! }
//! ```
//!
//! The same schema is committed under `benches/baselines/` and compared by
//! [`crate::bench::check`]; baselines may additionally carry a
//! per-scenario `tolerance`.

use crate::error::MineError;
use crate::util::json::{opt_num, opt_str, Json};

/// Bump when the JSON layout changes incompatibly; `from_json` refuses
/// other versions rather than misreading them.
pub const SCHEMA_VERSION: u64 = 1;

/// One suite run: environment capture plus every measured scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct SuiteResult {
    pub schema_version: u64,
    pub suite: String,
    /// unix seconds at the end of the run
    pub created_unix: u64,
    pub env: EnvInfo,
    pub scenarios: Vec<ScenarioResult>,
    /// Scenarios this environment could not run (e.g. accelerator suites
    /// without a PJRT runtime). `--check` treats a baseline scenario that
    /// is skipped here as not-comparable instead of missing. The name
    /// `"*"` skips a whole suite.
    pub skipped: Vec<SkippedScenario>,
}

/// Where and how a suite ran — the context a wall-time number is
/// meaningless without.
#[derive(Clone, Debug, PartialEq)]
pub struct EnvInfo {
    /// `git rev-parse --short HEAD` (or `GITHUB_SHA`), "unknown" offline
    pub commit: String,
    pub host: String,
    /// `std::env::consts::{OS, ARCH}`
    pub os: String,
    /// available hardware parallelism
    pub threads: usize,
    /// "release" or "debug" (from `cfg!(debug_assertions)`)
    pub profile: String,
    /// "pjrt" when the accelerator runtime opens, "unavailable" otherwise
    pub runtime: String,
    pub smoke: bool,
}

impl EnvInfo {
    /// Best-effort capture of the current environment.
    pub fn capture(smoke: bool) -> EnvInfo {
        let commit = std::env::var("GITHUB_SHA")
            .ok()
            .map(|s| s.chars().take(12).collect::<String>())
            .or_else(git_head)
            .unwrap_or_else(|| "unknown".to_string());
        let host = std::env::var("HOSTNAME")
            .ok()
            .filter(|h| !h.is_empty())
            .or_else(hostname_cmd)
            .unwrap_or_else(|| "unknown".to_string());
        // probing the runtime means loading the artifact manifest and
        // standing up a PJRT client; cache the answer process-wide so a
        // --suite all run does not repeat it per suite
        static RUNTIME_AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        let available =
            *RUNTIME_AVAILABLE.get_or_init(|| crate::runtime::Runtime::open_default().is_ok());
        let runtime = if available { "pjrt" } else { "unavailable" }.to_string();
        EnvInfo {
            commit,
            host,
            os: format!("{}-{}", std::env::consts::OS, std::env::consts::ARCH),
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            profile: if cfg!(debug_assertions) { "debug" } else { "release" }.to_string(),
            runtime,
            smoke,
        }
    }
}

fn git_head() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let s = String::from_utf8(out.stdout).ok()?.trim().to_string();
    if s.is_empty() {
        None
    } else {
        Some(s)
    }
}

fn hostname_cmd() -> Option<String> {
    let out = std::process::Command::new("hostname").output().ok()?;
    let s = String::from_utf8(out.stdout).ok()?.trim().to_string();
    if s.is_empty() {
        None
    } else {
        Some(s)
    }
}

/// One measured scenario: robust wall-time summary plus throughput in the
/// units the workload defines (events scanned per second, and an optional
/// item rate — episodes, requests, segments — named by `item_unit`).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioResult {
    pub name: String,
    /// measured iterations behind the summary
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// stream events processed per second (median-based), when the
    /// workload has a meaningful event count
    pub events_per_s: Option<f64>,
    /// item throughput (median-based); `item_unit` names the item
    pub items_per_s: Option<f64>,
    pub item_unit: Option<String>,
    /// last iteration's sink value (verifies work wasn't optimized away)
    pub sink: u64,
    /// Baseline files only: relative tolerance `--check` applies to this
    /// scenario (e.g. 1.0 = fail when the median exceeds 2x baseline).
    /// Absent in fresh run output; `--check` falls back to its default.
    pub tolerance: Option<f64>,
}

/// A scenario the current environment declined to run, with the reason.
#[derive(Clone, Debug, PartialEq)]
pub struct SkippedScenario {
    pub name: String,
    pub reason: String,
}

impl SuiteResult {
    pub fn to_json_value(&self) -> Json {
        Json::Obj(vec![
            ("schema_version".into(), Json::Num(self.schema_version as f64)),
            ("suite".into(), Json::Str(self.suite.clone())),
            ("created_unix".into(), Json::Num(self.created_unix as f64)),
            (
                "env".into(),
                Json::Obj(vec![
                    ("commit".into(), Json::Str(self.env.commit.clone())),
                    ("host".into(), Json::Str(self.env.host.clone())),
                    ("os".into(), Json::Str(self.env.os.clone())),
                    ("threads".into(), Json::Num(self.env.threads as f64)),
                    ("profile".into(), Json::Str(self.env.profile.clone())),
                    ("runtime".into(), Json::Str(self.env.runtime.clone())),
                    ("smoke".into(), Json::Bool(self.env.smoke)),
                ]),
            ),
            (
                "scenarios".into(),
                Json::Arr(self.scenarios.iter().map(scenario_to_json).collect()),
            ),
            (
                "skipped".into(),
                Json::Arr(
                    self.skipped
                        .iter()
                        .map(|s| {
                            Json::Obj(vec![
                                ("name".into(), Json::Str(s.name.clone())),
                                ("reason".into(), Json::Str(s.reason.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Pretty-rendered document, the `BENCH_<suite>.json` file format.
    pub fn to_json(&self) -> String {
        self.to_json_value().render_pretty()
    }

    /// Parse a `BENCH_<suite>.json` / baseline document. Refuses unknown
    /// schema versions.
    pub fn from_json(text: &str) -> Result<SuiteResult, MineError> {
        let v = Json::parse(text)?;
        let version = v
            .req("schema_version")?
            .as_u64()
            .ok_or_else(|| MineError::invalid("schema_version must be an integer"))?;
        if version != SCHEMA_VERSION {
            return Err(MineError::invalid(format!(
                "unsupported bench schema version {version} (this build reads \
                 {SCHEMA_VERSION})"
            )));
        }
        let env_v = v.req("env")?;
        let env = EnvInfo {
            commit: req_str(env_v, "commit")?,
            host: req_str(env_v, "host")?,
            os: req_str(env_v, "os")?,
            threads: req_u64(env_v, "threads")? as usize,
            profile: req_str(env_v, "profile")?,
            runtime: req_str(env_v, "runtime")?,
            smoke: env_v
                .req("smoke")?
                .as_bool()
                .ok_or_else(|| MineError::invalid("env.smoke must be a boolean"))?,
        };
        let scenarios = v
            .req("scenarios")?
            .as_arr()
            .ok_or_else(|| MineError::invalid("scenarios must be an array"))?
            .iter()
            .map(scenario_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let skipped = match v.get("skipped") {
            None => vec![],
            Some(arr) => arr
                .as_arr()
                .ok_or_else(|| MineError::invalid("skipped must be an array"))?
                .iter()
                .map(|s| {
                    Ok(SkippedScenario {
                        name: req_str(s, "name")?,
                        reason: req_str(s, "reason")?,
                    })
                })
                .collect::<Result<Vec<_>, MineError>>()?,
        };
        Ok(SuiteResult {
            schema_version: version,
            suite: req_str(&v, "suite")?,
            created_unix: req_u64(&v, "created_unix")?,
            env,
            scenarios,
            skipped,
        })
    }

    pub fn scenario(&self, name: &str) -> Option<&ScenarioResult> {
        self.scenarios.iter().find(|s| s.name == name)
    }

    /// Is `name` covered by this run's skip list? Skip entries match
    /// exactly, or by prefix when they end in `*` (`"*"` skips the whole
    /// suite, `"accel_*"` a family of scenarios).
    pub fn is_skipped(&self, name: &str) -> bool {
        self.skipped.iter().any(|s| match s.name.strip_suffix('*') {
            Some(prefix) => name.starts_with(prefix),
            None => s.name == name,
        })
    }
}

fn scenario_to_json(s: &ScenarioResult) -> Json {
    let mut fields = vec![
        ("name".into(), Json::Str(s.name.clone())),
        ("iters".into(), Json::Num(s.iters as f64)),
        ("median_ns".into(), Json::Num(s.median_ns)),
        ("mean_ns".into(), Json::Num(s.mean_ns)),
        ("p95_ns".into(), Json::Num(s.p95_ns)),
        ("min_ns".into(), Json::Num(s.min_ns)),
        ("max_ns".into(), Json::Num(s.max_ns)),
        ("events_per_s".into(), opt_num(s.events_per_s)),
        ("items_per_s".into(), opt_num(s.items_per_s)),
        ("item_unit".into(), opt_str(s.item_unit.as_deref())),
        ("sink".into(), Json::Num(s.sink as f64)),
    ];
    if let Some(tol) = s.tolerance {
        fields.push(("tolerance".into(), Json::Num(tol)));
    }
    Json::Obj(fields)
}

fn scenario_from_json(v: &Json) -> Result<ScenarioResult, MineError> {
    Ok(ScenarioResult {
        name: req_str(v, "name")?,
        iters: req_u64(v, "iters")? as usize,
        median_ns: req_f64(v, "median_ns")?,
        mean_ns: req_f64(v, "mean_ns")?,
        p95_ns: req_f64(v, "p95_ns")?,
        min_ns: req_f64(v, "min_ns")?,
        max_ns: req_f64(v, "max_ns")?,
        events_per_s: opt_f64(v, "events_per_s"),
        items_per_s: opt_f64(v, "items_per_s"),
        item_unit: v.get("item_unit").and_then(|x| x.as_str()).map(|s| s.to_string()),
        sink: req_u64(v, "sink")?,
        tolerance: opt_f64(v, "tolerance"),
    })
}

fn req_str(v: &Json, key: &str) -> Result<String, MineError> {
    v.req(key)?
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| MineError::invalid(format!("{key} must be a string")))
}

fn req_f64(v: &Json, key: &str) -> Result<f64, MineError> {
    v.req(key)?
        .as_f64()
        .ok_or_else(|| MineError::invalid(format!("{key} must be a number")))
}

fn req_u64(v: &Json, key: &str) -> Result<u64, MineError> {
    v.req(key)?
        .as_u64()
        .ok_or_else(|| MineError::invalid(format!("{key} must be a non-negative integer")))
}

fn opt_f64(v: &Json, key: &str) -> Option<f64> {
    v.get(key).and_then(|x| x.as_f64())
}

/// A fully-populated result for schema/check unit tests.
#[cfg(test)]
pub(crate) fn sample_suite() -> SuiteResult {
    SuiteResult {
        schema_version: SCHEMA_VERSION,
        suite: "axis_scaling".into(),
        created_unix: 1_753_776_000,
        env: EnvInfo {
            commit: "abc123def456".into(),
            host: "ci-runner".into(),
            os: "linux-x86_64".into(),
            threads: 8,
            profile: "release".into(),
            runtime: "unavailable".into(),
            smoke: true,
        },
        scenarios: vec![
            ScenarioResult {
                name: "threads1/episode_axis".into(),
                iters: 5,
                median_ns: 1.25e7,
                mean_ns: 1.3e7,
                p95_ns: 1.5e7,
                min_ns: 1.2e7,
                max_ns: 1.6e7,
                events_per_s: Some(2.4e6),
                items_per_s: Some(320.0),
                item_unit: Some("episodes".into()),
                sink: 42,
                tolerance: None,
            },
            ScenarioResult {
                name: "threads4/stream_axis".into(),
                iters: 3,
                median_ns: 4.0e6,
                mean_ns: 4.1e6,
                p95_ns: 4.4e6,
                min_ns: 3.9e6,
                max_ns: 4.5e6,
                events_per_s: None,
                items_per_s: None,
                item_unit: None,
                sink: 0,
                tolerance: Some(1.5),
            },
        ],
        skipped: vec![SkippedScenario {
            name: "threads8/stream_axis".into(),
            reason: "not enough cores".into(),
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SuiteResult {
        sample_suite()
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let r = sample();
        let text = r.to_json();
        let back = SuiteResult::from_json(&text).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn rejects_wrong_schema_version() {
        let mut r = sample();
        r.schema_version = SCHEMA_VERSION + 1;
        let err = SuiteResult::from_json(&r.to_json()).err().unwrap();
        assert!(err.to_string().contains("schema version"), "{err}");
    }

    #[test]
    fn rejects_missing_required_fields() {
        let text = r#"{"schema_version": 1, "suite": "x"}"#;
        assert!(SuiteResult::from_json(text).is_err());
    }

    #[test]
    fn skip_list_supports_wildcard() {
        let mut r = sample();
        assert!(r.is_skipped("threads8/stream_axis"));
        assert!(!r.is_skipped("threads1/episode_axis"));
        r.skipped = vec![SkippedScenario { name: "*".into(), reason: "no runtime".into() }];
        assert!(r.is_skipped("anything/at_all"));
        r.skipped =
            vec![SkippedScenario { name: "accel_*".into(), reason: "no runtime".into() }];
        assert!(r.is_skipped("accel_n3_s8/ptpe"));
        assert!(!r.is_skipped("cpu_n3_s8/episode_axis"));
    }

    #[test]
    fn env_capture_is_well_formed() {
        let env = EnvInfo::capture(true);
        assert!(env.smoke);
        assert!(!env.os.is_empty());
        assert!(env.threads >= 1);
        assert!(env.profile == "debug" || env.profile == "release");
    }
}
