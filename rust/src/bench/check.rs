//! Baseline comparison: the regression gate behind `epminer bench
//! --check` and CI's perf-smoke job.
//!
//! Wall-time benches are noisy, so the gate is deliberately coarse: a
//! scenario regresses only when its median exceeds the baseline median by
//! more than a *relative tolerance* (per-scenario `tolerance` in the
//! baseline file, else [`CheckConfig::default_tolerance`]). Improvements
//! past the same band are reported, never failed — refresh the baseline
//! to bank them. A baseline scenario the current run no longer produces
//! is a failure (a silently vanished measurement is how regressions hide),
//! unless the run explicitly lists it as skipped.

use super::schema::SuiteResult;

/// Knobs for one comparison.
#[derive(Clone, Debug)]
pub struct CheckConfig {
    /// Relative tolerance when the baseline scenario carries none:
    /// `1.0` fails a scenario whose median exceeds 2x baseline.
    pub default_tolerance: f64,
}

impl Default for CheckConfig {
    fn default() -> CheckConfig {
        CheckConfig { default_tolerance: 1.0 }
    }
}

/// Outcome of comparing one scenario against its baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// current median > baseline * (1 + tolerance) — fails the gate
    Regression,
    /// current median < baseline / (1 + tolerance) — reported, passes
    Improvement,
    WithinNoise,
    /// in the baseline, absent from the current run — fails the gate
    MissingScenario,
    /// in the baseline, listed in the current run's skip list — passes
    SkippedScenario,
    /// in the current run, absent from the baseline — reported, passes
    NewScenario,
}

impl Verdict {
    pub fn fails(self) -> bool {
        matches!(self, Verdict::Regression | Verdict::MissingScenario)
    }

    pub fn label(self) -> &'static str {
        match self {
            Verdict::Regression => "REGRESSION",
            Verdict::Improvement => "improvement",
            Verdict::WithinNoise => "ok",
            Verdict::MissingScenario => "MISSING",
            Verdict::SkippedScenario => "skipped",
            Verdict::NewScenario => "new",
        }
    }
}

/// One comparison row.
#[derive(Clone, Debug)]
pub struct CheckEntry {
    pub name: String,
    pub verdict: Verdict,
    /// current median / baseline median (None when not comparable)
    pub ratio: Option<f64>,
    /// the tolerance applied
    pub tolerance: f64,
}

/// The full comparison for one suite.
#[derive(Clone, Debug)]
pub struct CheckReport {
    pub suite: String,
    pub entries: Vec<CheckEntry>,
    /// set when the runs are not comparable at all (profile mismatch);
    /// a non-empty value fails the gate with this explanation
    pub incomparable: Option<String>,
}

impl CheckReport {
    pub fn passed(&self) -> bool {
        self.incomparable.is_none() && !self.entries.iter().any(|e| e.verdict.fails())
    }

    pub fn regressions(&self) -> usize {
        self.entries.iter().filter(|e| e.verdict.fails()).count()
    }

    /// Human-readable report, one line per non-quiet entry plus a summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(why) = &self.incomparable {
            out.push_str(&format!("check {}: NOT COMPARABLE — {why}\n", self.suite));
            return out;
        }
        for e in &self.entries {
            // within-noise rows are the common case; keep the report short
            if e.verdict == Verdict::WithinNoise {
                continue;
            }
            match e.ratio {
                Some(r) => out.push_str(&format!(
                    "  {:<12} {}  ({:.2}x baseline, tolerance {:.0}%)\n",
                    e.verdict.label(),
                    e.name,
                    r,
                    e.tolerance * 100.0
                )),
                None => out.push_str(&format!("  {:<12} {}\n", e.verdict.label(), e.name)),
            }
        }
        let fails = self.regressions();
        let ok = self.entries.iter().filter(|e| !e.verdict.fails()).count();
        out.push_str(&format!(
            "check {}: {} ({} compared/noted, {} failing)\n",
            self.suite,
            if fails == 0 { "PASS" } else { "FAIL" },
            ok,
            fails
        ));
        out
    }
}

/// Compare a fresh run against a committed baseline.
pub fn check_suite(
    current: &SuiteResult,
    baseline: &SuiteResult,
    cfg: &CheckConfig,
) -> CheckReport {
    let mut report =
        CheckReport { suite: current.suite.clone(), entries: vec![], incomparable: None };
    if current.suite != baseline.suite {
        report.incomparable = Some(format!(
            "baseline is for suite {:?}, current run is {:?}",
            baseline.suite, current.suite
        ));
        return report;
    }
    // Comparing a --smoke run against a full baseline (or debug against
    // release) gates on noise, not regressions — refuse loudly.
    if current.env.smoke != baseline.env.smoke {
        report.incomparable = Some(format!(
            "baseline was recorded with smoke={}, current run has smoke={} — \
             rerun with the matching profile or refresh the baseline",
            baseline.env.smoke, current.env.smoke
        ));
        return report;
    }
    if current.env.profile != baseline.env.profile {
        report.incomparable = Some(format!(
            "baseline was built with the {} profile, current run with {}",
            baseline.env.profile, current.env.profile
        ));
        return report;
    }

    for base in &baseline.scenarios {
        let tolerance = base.tolerance.unwrap_or(cfg.default_tolerance).max(0.0);
        let entry = match current.scenario(&base.name) {
            None => CheckEntry {
                name: base.name.clone(),
                verdict: if current.is_skipped(&base.name) {
                    Verdict::SkippedScenario
                } else {
                    Verdict::MissingScenario
                },
                ratio: None,
                tolerance,
            },
            Some(cur) => {
                let ratio = if base.median_ns > 0.0 {
                    cur.median_ns / base.median_ns
                } else {
                    1.0
                };
                let verdict = if ratio > 1.0 + tolerance {
                    Verdict::Regression
                } else if ratio < 1.0 / (1.0 + tolerance) {
                    Verdict::Improvement
                } else {
                    Verdict::WithinNoise
                };
                CheckEntry { name: base.name.clone(), verdict, ratio: Some(ratio), tolerance }
            }
        };
        report.entries.push(entry);
    }
    for cur in &current.scenarios {
        if baseline.scenario(&cur.name).is_none() {
            report.entries.push(CheckEntry {
                name: cur.name.clone(),
                verdict: Verdict::NewScenario,
                ratio: None,
                tolerance: cfg.default_tolerance,
            });
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::schema::sample_suite;

    fn cfg() -> CheckConfig {
        CheckConfig::default()
    }

    fn verdict_of(report: &CheckReport, name: &str) -> Verdict {
        report.entries.iter().find(|e| e.name == name).map(|e| e.verdict).unwrap()
    }

    #[test]
    fn identical_runs_pass_within_noise() {
        let r = sample_suite();
        let rep = check_suite(&r, &r, &cfg());
        assert!(rep.passed(), "{}", rep.render());
        assert!(rep.entries.iter().all(|e| {
            e.verdict == Verdict::WithinNoise || e.verdict == Verdict::SkippedScenario
        }));
    }

    #[test]
    fn artificially_tightened_baseline_fails() {
        let current = sample_suite();
        let mut baseline = sample_suite();
        // tighten: pretend the baseline was 10x faster than reality
        for s in &mut baseline.scenarios {
            s.median_ns /= 10.0;
            s.tolerance = Some(1.0);
        }
        let rep = check_suite(&current, &baseline, &cfg());
        assert!(!rep.passed(), "{}", rep.render());
        assert_eq!(verdict_of(&rep, "threads1/episode_axis"), Verdict::Regression);
        assert!(rep.regressions() >= 1);
        assert!(rep.render().contains("REGRESSION"));
    }

    #[test]
    fn improvement_is_reported_not_failed() {
        let mut current = sample_suite();
        for s in &mut current.scenarios {
            s.median_ns /= 10.0;
        }
        let rep = check_suite(&current, &sample_suite(), &cfg());
        assert!(rep.passed(), "{}", rep.render());
        assert_eq!(verdict_of(&rep, "threads1/episode_axis"), Verdict::Improvement);
    }

    #[test]
    fn per_scenario_tolerance_overrides_default() {
        let mut current = sample_suite();
        let baseline = sample_suite();
        // threads4/stream_axis carries tolerance 1.5 in the sample: a 2.2x
        // median is within its band but past the 1.0 default
        for s in &mut current.scenarios {
            s.median_ns *= 2.2;
        }
        let rep = check_suite(&current, &baseline, &cfg());
        assert_eq!(verdict_of(&rep, "threads1/episode_axis"), Verdict::Regression);
        assert_eq!(verdict_of(&rep, "threads4/stream_axis"), Verdict::WithinNoise);
    }

    #[test]
    fn missing_scenario_fails_unless_skipped() {
        let mut current = sample_suite();
        current.scenarios.remove(0); // drop threads1/episode_axis
        let rep = check_suite(&current, &sample_suite(), &cfg());
        assert_eq!(verdict_of(&rep, "threads1/episode_axis"), Verdict::MissingScenario);
        assert!(!rep.passed());

        // ...but an explicit skip (e.g. runtime unavailable) passes
        current
            .skipped
            .push(crate::bench::schema::SkippedScenario {
                name: "threads1/episode_axis".into(),
                reason: "runtime unavailable".into(),
            });
        let rep = check_suite(&current, &sample_suite(), &cfg());
        assert_eq!(verdict_of(&rep, "threads1/episode_axis"), Verdict::SkippedScenario);
        assert!(rep.passed(), "{}", rep.render());
    }

    #[test]
    fn new_scenarios_are_noted_and_pass() {
        let mut current = sample_suite();
        let mut extra = current.scenarios[0].clone();
        extra.name = "threads16/stream_axis".into();
        current.scenarios.push(extra);
        let rep = check_suite(&current, &sample_suite(), &cfg());
        assert!(rep.passed());
        assert_eq!(verdict_of(&rep, "threads16/stream_axis"), Verdict::NewScenario);
    }

    #[test]
    fn profile_and_smoke_mismatches_refuse_to_compare() {
        let current = sample_suite();
        let mut baseline = sample_suite();
        baseline.env.smoke = false;
        let rep = check_suite(&current, &baseline, &cfg());
        assert!(!rep.passed());
        assert!(rep.render().contains("NOT COMPARABLE"));

        let mut baseline = sample_suite();
        baseline.env.profile = "debug".into();
        assert!(!check_suite(&current, &baseline, &cfg()).passed());

        let mut baseline = sample_suite();
        baseline.suite = "other".into();
        assert!(!check_suite(&current, &baseline, &cfg()).passed());
    }
}
