//! Table 1 + Fig. 8 reproduction: strategy crossover points by episode
//! size, and the f(N) = a/N + b vs a*N + b fit comparison.
//!
//! Three series:
//!
//! 1. **CPU-measured (always runs)** — episode-axis workers vs the
//!    stream-axis sharded backend on growing batch sizes S; the crossover
//!    is the S where the episode axis first wins. This is the dispatch
//!    decision `HybridBackend::cpu_sharded` makes, measured.
//! 2. **Accelerator-measured (runtime only)** — PTPE vs MapConcatenate,
//!    the paper's own crossover; skipped (declared) without a runtime.
//! 3. **GTX280 analytical model** — the paper's Eq. 1 utilization
//!    threshold per level; instant, printed with the fits.
//!
//! All series are fitted with a/N + b and a*N + b (Fig. 8's comparison).

use crate::backend::cpu::CpuParallelBackend;
use crate::backend::sharded::ShardedBackend;
use crate::backend::{self, CountBackend};
use crate::coordinator::Strategy;
use crate::datasets::sym26::{generate, Sym26Config};
use crate::episodes::Interval;
use crate::error::MineError;
use crate::gpu_model::crossover::{fit_comparison, CrossoverModel, PAPER_TABLE1};
use crate::gpu_model::occupancy::{a1_resources, GTX280};
use crate::util::rng::Rng;

use super::super::harness::{SuiteCtx, Work};
use super::{head_window, open_runtime, random_episodes};

/// Threads for the CPU series: fixed so scenario identity (and baseline
/// comparability) does not depend on the host's core count.
const CPU_THREADS: usize = 4;

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let rt = open_runtime();
    let cfg = Sym26Config::default();
    // the crossover regime is probed on a partition-sized stream — the
    // workload the segment-parallel construction targets
    let full = generate(&cfg, 7);
    let stream = head_window(&full, 20_000);
    let iv = Interval::new(5, 15);
    let mut rng = Rng::new(0x7AB1E1);

    let sizes: &[usize] = if ctx.smoke { &[3, 5] } else { &[3, 4, 5, 6, 7, 8] };
    let probes: &[usize] = if ctx.smoke { &[1, 8, 64] } else { &[1, 2, 4, 8, 16, 32, 64] };

    // --- series 1: CPU episode-axis vs stream-axis (always) ---
    let mut cpu_measured: Vec<(usize, f64)> = vec![];
    for &n in sizes {
        let mut crossover: Option<f64> = None;
        let mut prev_s: Option<usize> = None;
        for &s in probes {
            let eps = random_episodes(&mut rng, n, s, stream.n_types as i32, iv);
            let work = Work::counting(stream.len() as u64, s as u64);
            let mut ep_axis = CpuParallelBackend::new(CPU_THREADS);
            ctx.measure(&format!("cpu_n{n}_s{s}/episode_axis"), work, || {
                ep_axis.count(&eps, &stream).unwrap().counts.iter().sum()
            });
            let mut st_axis = ShardedBackend::new(CPU_THREADS);
            ctx.measure(&format!("cpu_n{n}_s{s}/stream_axis"), work, || {
                st_axis.count(&eps, &stream).unwrap().counts.iter().sum()
            });
            let ep_ns = ctx.median_ns(&format!("cpu_n{n}_s{s}/episode_axis")).unwrap();
            let st_ns = ctx.median_ns(&format!("cpu_n{n}_s{s}/stream_axis")).unwrap();
            if crossover.is_none() && ep_ns <= st_ns {
                crossover = Some(match prev_s {
                    Some(p) => (p + s) as f64 / 2.0,
                    None => 0.5,
                });
            }
            prev_s = Some(s);
        }
        let c = crossover.unwrap_or(*probes.last().unwrap() as f64 * 2.0);
        cpu_measured.push((n, c));
        ctx.note(format!("cpu crossover at size {n}: S = {c:.1}"));
    }

    // --- series 2: accelerator PTPE vs MapConcatenate (runtime only) ---
    let mut accel_measured: Vec<(usize, f64)> = vec![];
    match &rt {
        None => {
            ctx.skip("accel_*", "accelerator runtime unavailable");
            ctx.note("accelerator crossover series skipped: no PJRT runtime");
        }
        Some(rt) => {
            for &n in sizes {
                let mut crossover: Option<f64> = None;
                let mut prev_s: Option<usize> = None;
                for &s in probes {
                    let eps = random_episodes(&mut rng, n, s, stream.n_types as i32, iv);
                    let work = Work::counting(stream.len() as u64, s as u64);
                    let mut ptpe = backend::for_strategy(
                        Strategy::PtpeA1,
                        Some(rt.clone()),
                        CPU_THREADS,
                    )?;
                    ctx.measure(&format!("accel_n{n}_s{s}/ptpe"), work, || {
                        ptpe.count(&eps, &stream).unwrap().counts.iter().sum()
                    });
                    let mut mc = backend::for_strategy(
                        Strategy::MapConcat,
                        Some(rt.clone()),
                        CPU_THREADS,
                    )?;
                    ctx.measure(&format!("accel_n{n}_s{s}/mapconcat"), work, || {
                        mc.count(&eps, &stream).unwrap().counts.iter().sum()
                    });
                    let pt = ctx.median_ns(&format!("accel_n{n}_s{s}/ptpe")).unwrap();
                    let mcn = ctx.median_ns(&format!("accel_n{n}_s{s}/mapconcat")).unwrap();
                    if crossover.is_none() && pt <= mcn {
                        crossover = Some(match prev_s {
                            Some(p) => (p + s) as f64 / 2.0,
                            None => 0.5,
                        });
                    }
                    prev_s = Some(s);
                }
                let c = crossover.unwrap_or(*probes.last().unwrap() as f64 * 2.0);
                accel_measured.push((n, c));
                ctx.note(format!("accel crossover at size {n}: S = {c:.1}"));
            }
        }
    }

    // --- series 3: GTX280 analytical model + Fig. 8 fits ---
    let k_slots = match &rt {
        Some(rt) => rt.manifest().k_slots,
        None => 8,
    };
    let mut model_pts: Vec<(usize, f64)> = vec![];
    for &(n, paper_c) in PAPER_TABLE1 {
        let r = a1_resources(n, k_slots);
        let s_star = GTX280.full_utilization_threshold(&r);
        model_pts.push((n, s_star as f64));
        ctx.note(format!(
            "GTX280 model size {n}: S* = {s_star} (paper crossover {paper_c:.0})"
        ));
    }

    let mut series: Vec<(&str, &[(usize, f64)])> = vec![
        ("cpu measured (this substrate)", &cpu_measured),
        ("GTX280 model S*", &model_pts),
        ("paper Table 1", PAPER_TABLE1),
    ];
    if !accel_measured.is_empty() {
        series.push(("accel measured (this substrate)", &accel_measured));
    }
    for (name, pts) in series {
        let (sse_inv, sse_lin) = fit_comparison(pts);
        ctx.note(format!(
            "Fig 8 fit, {name}: SSE a/N+b = {sse_inv:.1}, a*N+b = {sse_lin:.1} -> {} wins",
            if sse_inv <= sse_lin { "a/N+b" } else { "a*N+b" }
        ));
    }
    let model = CrossoverModel::fit(&cpu_measured);
    ctx.note(format!(
        "fitted cpu dispatch model: crossover(N) = {:.1}/N + {:.1}",
        model.a, model.b
    ));
    Ok(())
}
