//! Service throughput under multi-client load — the tentpole metric for
//! the `serve/` layer.
//!
//! `hot/serial_remine` measures the pre-service world (a serial loop
//! re-mining every repeated query from scratch); `hot/service` replays
//! the same hot-repeat pattern through `MineService` (coalescing + result
//! cache). The repeat-query throughput ratio must clear 5x — that floor
//! is this suite's acceptance criterion and fails the run when missed.
//! `mixed/service` runs the full scenario mix for the realistic-traffic
//! picture.

use std::time::Instant;

use crate::error::MineError;
use crate::serve::loadgen::{self, LoadGenConfig, MixWeights, Workload};
use crate::serve::{mine_direct, MineService, ServiceConfig};

use super::super::harness::{SuiteCtx, Work};

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let lg = if ctx.smoke { LoadGenConfig::smoke() } else { LoadGenConfig::default() };
    let sc = ServiceConfig { workers: 4, ..ServiceConfig::default() };
    let workload = Workload::build(&lg)?;

    // Phase 1: serial re-mine baseline over the hot repeats (enough
    // repeats for a stable rate; the point is cost-per-request).
    let serial_requests: usize = if ctx.smoke { 12 } else { 20 };
    let t0 = Instant::now();
    for i in 0..serial_requests {
        let q = &workload.hot[i % workload.hot.len()];
        mine_direct(q, sc.strategy, sc.cpu_threads)?;
    }
    let serial_ns = t0.elapsed().as_nanos() as f64;
    ctx.record(
        "hot/serial_remine",
        Work::items(serial_requests as u64, "requests"),
        serial_ns,
        serial_requests as u64,
    );
    let serial_qps = serial_requests as f64 / (serial_ns / 1e9);

    // Phase 2: the same hot-repeat pattern through the service.
    let hot_lg = LoadGenConfig {
        mix: MixWeights { hot_repeat: 1, theta_sweep: 0, distinct: 0, sliding_window: 0 },
        ..lg.clone()
    };
    let service = MineService::start(sc.clone())?;
    let hot_report = loadgen::run(&service, &workload, &hot_lg);
    let hot_metrics = service.shutdown();
    ctx.record(
        "hot/service",
        Work::items(hot_report.completed, "requests"),
        hot_report.wall.as_nanos() as f64,
        hot_report.completed,
    );
    let speedup = hot_report.qps / serial_qps;
    ctx.note(format!(
        "repeat-query speedup: {speedup:.1}x (cache hit rate {:.1}%, acceptance floor 5x)",
        hot_metrics.cache.hit_rate() * 100.0
    ));
    if let Some(lat) = &hot_report.latency_ns {
        ctx.note(format!(
            "hot client latency: p50 {:.3}ms p95 {:.3}ms p99 {:.3}ms",
            lat.median / 1e6,
            lat.p95 / 1e6,
            lat.p99 / 1e6
        ));
    }
    if hot_report.errors > 0 {
        return Err(MineError::internal(format!(
            "{} hot-path requests errored under load",
            hot_report.errors
        )));
    }
    if speedup < 5.0 {
        return Err(MineError::internal(format!(
            "service repeat-query throughput must beat serial re-mine by >= 5x, \
             got {speedup:.1}x"
        )));
    }

    // Phase 3: the full mixed scenario set.
    let service = MineService::start(sc)?;
    let report = loadgen::run(&service, &workload, &lg);
    let metrics = service.shutdown();
    ctx.record(
        "mixed/service",
        Work::items(report.completed, "requests"),
        report.wall.as_nanos() as f64,
        report.completed,
    );
    ctx.note(format!(
        "mixed mix ({} clients x {} requests): {:.1} qps, {} completed / {} rejected / \
         {} errors; {}",
        lg.clients,
        lg.requests_per_client,
        report.qps,
        report.completed,
        report.rejected,
        report.errors,
        metrics.report()
    ));
    Ok(())
}
