//! Arena-backed candidate generation vs the legacy quadratic join — the
//! tentpole metrics for the huge-alphabet candidate engine.
//!
//! Two claims under test. First, the bucketed arena join generates a
//! level-3 candidate set (K³ candidates from a full K² level-2 lattice)
//! strictly faster than the retained O(F²) [`candidates::join`] scan of
//! the same frequent set — the asymptotic win the engine exists for.
//! Second, the block-streamed mining loop digests the `huge-alphabet`
//! dataset (512 types, Zipf-skewed) end to end with a small
//! `candidate_block`, exercising remap + arena + streamed counting on the
//! workload shape the paper's 10³–10⁴-electrode regime implies. Both the
//! generation scenarios cross-check content equality against the legacy
//! join before any timing is trusted; a mismatch or a lost speedup fails
//! the suite rather than recording a number.

use crate::coordinator::Strategy;
use crate::datasets::huge::{self, HugeConfig};
use crate::episodes::arena::{EpisodeArena, LevelBlock, ROW_BYTES};
use crate::episodes::{candidates, Episode};
use crate::error::MineError;
use crate::events::EventType;
use crate::Session;

use super::super::harness::{SuiteCtx, Work};

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    // Frontier width for the generation scenarios: a full K-type level-2
    // lattice joins into exactly K³ level-3 candidates, so K picks the
    // output scale (64k smoke / 262k full) without touching the shape.
    let k: usize = if ctx.smoke { 40 } else { 64 };
    let cfg = if ctx.smoke {
        HugeConfig::smoke()
    } else {
        HugeConfig::default()
    };
    let i_set = cfg.interval_set();

    // Arena with the full level-2 lattice installed: singles 0..K, then
    // the K² cross as one block (identity frontier at each step).
    let mut arena = EpisodeArena::new(&i_set);
    arena.push_singles(0..k as EventType);
    let singles: Vec<u32> = (0..k as u32).collect();
    let mut level2 = LevelBlock::default();
    arena.generate_next(&singles, 65_536, |chunk| {
        level2.extend_from_chunk(chunk);
        Ok(())
    })?;
    arena.push_block(level2);
    let frontier: Vec<u32> = (0..arena.block_len(1) as u32).collect();
    let expected = arena.next_level_count(&frontier);

    // The legacy path's input: the same K² frequent set as heap-allocated
    // episodes, built exactly as the pre-arena miner did.
    let legacy_input = candidates::level2(&candidates::level1(k), &i_set);

    // Exactness gate: the arena's level-3 output must equal the legacy
    // join's, candidate for candidate, in the same order — the timing
    // below compares two routes to one answer or it compares nothing.
    let legacy_out = candidates::join(&legacy_input);
    if legacy_out.len() != expected {
        return Err(MineError::internal(format!(
            "arena predicts {expected} level-3 candidates, legacy join made {}",
            legacy_out.len()
        )));
    }
    let mut row = 0usize;
    let mut scratch = Episode { types: vec![], intervals: vec![] };
    arena.generate_next(&frontier, 65_536, |chunk| {
        for i in 0..chunk.len() {
            arena.materialize_chunk_row(chunk, i, &mut scratch);
            if scratch != legacy_out[row] {
                return Err(MineError::internal(format!(
                    "arena candidate {row} is {} but legacy join made {}",
                    scratch.display(),
                    legacy_out[row].display()
                )));
            }
            row += 1;
        }
        Ok(())
    })?;
    drop(legacy_out);

    // The engine under test: bucketed suffix-prefix join over the arena,
    // emitting flat SoA rows in bounded chunks — O(F + output).
    ctx.measure("gen/arena_bucketed", Work::items(expected as u64, "candidates"), || {
        let mut out = LevelBlock::default();
        arena
            .generate_next(&frontier, 65_536, |chunk| {
                out.extend_from_chunk(chunk);
                Ok(())
            })
            .expect("arena generation");
        out.len() as u64
    });

    // The reference point: the retained O(F²) all-pairs scan over the
    // same frequent set, materializing Vec-backed episodes.
    ctx.measure("join/legacy_quadratic", Work::items(expected as u64, "candidates"), || {
        candidates::join(&legacy_input).len() as u64
    });

    let arena_ns = ctx.median_ns("gen/arena_bucketed").unwrap_or(f64::MAX);
    let legacy_ns = ctx.median_ns("join/legacy_quadratic").unwrap_or(0.0);
    if arena_ns >= legacy_ns {
        return Err(MineError::internal(format!(
            "bucketed arena join lost to the quadratic scan: {:.2}ms vs {:.2}ms \
             over {expected} candidates",
            arena_ns / 1e6,
            legacy_ns / 1e6
        )));
    }
    // a heap-backed 3-node candidate: two Vec headers plus 3 types + 2 gaps
    let legacy_bytes = std::mem::size_of::<Episode>()
        + 3 * std::mem::size_of::<EventType>()
        + 2 * std::mem::size_of::<crate::episodes::Interval>();
    ctx.note(format!(
        "K={k}: {expected} level-3 candidates, arena {:.2}ms vs legacy {:.2}ms \
         ({:.1}x), {ROW_BYTES} B/candidate vs ~{legacy_bytes} B heap-backed",
        arena_ns / 1e6,
        legacy_ns / 1e6,
        legacy_ns / arena_ns.max(1.0),
    ));

    // End to end on the huge-alphabet dataset: level-1 counting picks the
    // theta that keeps the densest ~48 types frequent, then the
    // block-streamed loop (deliberately small candidate_block, so a
    // level-2 lattice of ~2.3k candidates streams in several blocks)
    // remaps, generates, and counts through to level 2.
    let stream = huge::generate(&cfg, 0xA1F);
    let mut counts = stream.type_counts();
    counts.sort_unstable_by_key(|&c| std::cmp::Reverse(c));
    let theta = counts[47.min(counts.len() - 1)].max(1);
    let frequent_types = counts.iter().filter(|&&c| c >= theta).count() as u64;
    let events = stream.len() as u64;
    ctx.measure(
        "mine/block_streamed",
        Work::counting(events, frequent_types * frequent_types),
        || {
            let mut session = Session::builder()
                .stream(stream.clone())
                .theta(theta)
                .intervals(i_set.clone())
                .strategy(Strategy::CpuSerial)
                .one_pass()
                .max_level(2)
                .candidate_block(1024)
                .build()
                .expect("huge-alphabet session");
            session.mine().expect("huge-alphabet mine").frequent.len() as u64
        },
    );
    ctx.note(format!(
        "huge-alphabet: {events} events over {} types, theta {theta} keeps \
         {frequent_types} types frequent",
        stream.n_types
    ));

    Ok(())
}
