//! Episode-axis vs stream-axis CPU scaling — the tentpole metric for the
//! sharded backend.
//!
//! The workload is the regime that motivates stream sharding: *few*
//! surviving candidates over a *long* stream, exactly what late mining
//! levels look like. Episode-axis workers can use at most `episodes`
//! threads there; stream-axis shards keep every core busy regardless of
//! the candidate count — the inversion `HybridBackend::cpu_sharded`
//! dispatches on.

use crate::backend::cpu::CpuParallelBackend;
use crate::backend::sharded::ShardedBackend;
use crate::backend::CountBackend;
use crate::episodes::{Episode, Interval};
use crate::error::MineError;

use super::super::harness::{SuiteCtx, Work};
use super::synth_stream;

const N_EPISODES: usize = 4;

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let n_events = if ctx.smoke { 30_000 } else { 200_000 };
    let threads: &[usize] = if ctx.smoke { &[1, 4] } else { &[1, 2, 4, 8] };

    let stream = synth_stream(0x5A4D, n_events, 8);
    let iv = Interval::new(0, 6);
    let eps: Vec<Episode> = (0..N_EPISODES as i32)
        .map(|i| Episode::new(vec![i % 8, (i + 1) % 8, (i + 2) % 8], vec![iv; 2]))
        .collect();
    let work = Work::counting(n_events as u64, N_EPISODES as u64);

    let mut baselines = (0.0f64, 0.0f64);
    for &th in threads {
        let mut ep_axis = CpuParallelBackend::new(th);
        let ep_sink = ctx
            .measure(&format!("threads{th}/episode_axis"), work, || {
                ep_axis.count(&eps, &stream).unwrap().counts.iter().sum()
            })
            .sink;
        let mut st_axis = ShardedBackend::new(th);
        let st_sink = ctx
            .measure(&format!("threads{th}/stream_axis"), work, || {
                st_axis.count(&eps, &stream).unwrap().counts.iter().sum()
            })
            .sink;
        if ep_sink != st_sink {
            return Err(MineError::internal(format!(
                "episode-axis and stream-axis engines disagree at {th} threads: \
                 {ep_sink} vs {st_sink}"
            )));
        }
        let ep_ns = ctx.median_ns(&format!("threads{th}/episode_axis")).unwrap();
        let st_ns = ctx.median_ns(&format!("threads{th}/stream_axis")).unwrap();
        if th == threads[0] {
            baselines = (ep_ns, st_ns);
        }
        ctx.note(format!(
            "{th} threads: episode-axis {:.2}x self-speedup, stream-axis {:.2}x, \
             stream/episode {:.2}x",
            baselines.0 / ep_ns,
            baselines.1 / st_ns,
            ep_ns / st_ns
        ));
    }
    ctx.note(format!(
        "episode-axis self-speedup saturates at min(threads, {N_EPISODES} episodes); \
         stream-axis keeps scaling with threads"
    ));
    Ok(())
}
