//! Fig. 9 reproduction: one-pass vs two-pass (A2+A1) counting.
//!
//! (a) per-episode-size breakdown on the day-35 culture;
//! (b) overall speedup across culture datasets and support thresholds.
//!
//! Paper shape: two-pass wins wherever the relaxed A2 pass culls a large
//! fraction of candidates (99.9% culled at size 4 => 3.6x there).
//!
//! Two-pass is backend *composition* ([`TwoPassBackend`] over any exact
//! engine), so the suite runs everywhere: over accelerated Hybrid when
//! the runtime opens, over episode-axis CPU workers otherwise — the
//! culling economics are algorithmic, not substrate-specific.

use crate::backend::two_pass::TwoPassBackend;
use crate::backend::CountBackend;
use crate::datasets::culture::{generate, CultureConfig};
use crate::episodes::Episode;
use crate::error::MineError;

use super::super::harness::{SuiteCtx, Work};
use super::{best_exact_engine, default_threads, head_window, level_candidate_sets, open_runtime};

/// Smoke mode probes the same code paths on the first 20 s of the
/// recording; thresholds shrink with the window so the lattice keeps the
/// same shape (frequent sets at several sizes).
const SMOKE_WINDOW_TICKS: i32 = 20_000;

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let rt = open_runtime();
    let threads = default_threads();
    ctx.note(format!(
        "exact engine: {}",
        if rt.is_some() { "accelerated hybrid" } else { "cpu-parallel" }
    ));

    // --- 9(a): per-size breakdown on day 35 ---
    let cfg35 = CultureConfig::day(35);
    let full35 = generate(&cfg35, 11);
    let (stream35, theta35, max_level) = if ctx.smoke {
        (head_window(&full35, SMOKE_WINDOW_TICKS), 24, 4)
    } else {
        (full35, 140, 6)
    };
    let intervals = cfg35.interval_set();
    let mut probe = best_exact_engine(&rt, threads)?;
    let per_level =
        level_candidate_sets(probe.as_mut(), &stream35, &intervals, theta35, max_level)?;
    for (li, cands) in per_level.iter().enumerate() {
        let n = li + 1;
        if n < 2 {
            continue;
        }
        if cands.is_empty() {
            // declare, never silently drop: --check treats an undeclared
            // missing scenario as a failed gate
            ctx.skip(&format!("d35_size{n}/*"), "no candidates at this level");
            continue;
        }
        let work = Work::counting(stream35.len() as u64, cands.len() as u64);
        let mut one = best_exact_engine(&rt, threads)?;
        ctx.measure(&format!("d35_size{n}/one_pass"), work, || {
            one.count(cands, &stream35).unwrap().counts.iter().sum()
        });
        let mut two = TwoPassBackend::new(best_exact_engine(&rt, threads)?, theta35);
        let culled = std::cell::Cell::new(0u64);
        ctx.measure(&format!("d35_size{n}/two_pass"), work, || {
            let (out, _) = two.run(cands, &stream35).unwrap();
            culled.set(out.culled);
            out.counts.iter().sum()
        });
        let one_ns = ctx.median_ns(&format!("d35_size{n}/one_pass")).unwrap();
        let two_ns = ctx.median_ns(&format!("d35_size{n}/two_pass")).unwrap();
        ctx.note(format!(
            "size {n}: {}/{} culled by A2 ({:.1}%), two-pass speedup {:.2}x",
            culled.get(),
            cands.len(),
            100.0 * culled.get() as f64 / cands.len() as f64,
            one_ns / two_ns
        ));
    }

    // --- 9(b): overall speedup across datasets and thresholds ---
    let days: &[(u32, &[u64])] = if ctx.smoke {
        &[(35, &[24, 50])]
    } else {
        &[(33, &[40, 90]), (34, &[85, 180]), (35, &[140, 300])]
    };
    for &(day, thetas) in days {
        let cfg = CultureConfig::day(day);
        let full = generate(&cfg, 11);
        let stream =
            if ctx.smoke { head_window(&full, SMOKE_WINDOW_TICKS) } else { full };
        let intervals = cfg.interval_set();
        for &th in thetas {
            let mut probe = best_exact_engine(&rt, threads)?;
            let per_level = level_candidate_sets(probe.as_mut(), &stream, &intervals, th, 5)?;
            let all: Vec<Episode> = per_level.into_iter().skip(1).flatten().collect();
            if all.is_empty() {
                ctx.skip(&format!("d{day}_t{th}/*"), "no candidates above level 1");
                continue;
            }
            let work = Work::counting(stream.len() as u64, all.len() as u64);
            let mut one = best_exact_engine(&rt, threads)?;
            ctx.measure(&format!("d{day}_t{th}/one_pass"), work, || {
                one.count(&all, &stream).unwrap().counts.iter().sum()
            });
            let mut two = TwoPassBackend::new(best_exact_engine(&rt, threads)?, th);
            ctx.measure(&format!("d{day}_t{th}/two_pass"), work, || {
                two.run(&all, &stream).unwrap().0.counts.iter().sum()
            });
            let one_ns = ctx.median_ns(&format!("d{day}_t{th}/one_pass")).unwrap();
            let two_ns = ctx.median_ns(&format!("d{day}_t{th}/two_pass")).unwrap();
            ctx.note(format!(
                "2-1-{day} theta {th}: {} episodes, two-pass {:.2}x",
                all.len(),
                one_ns / two_ns
            ));
        }
    }
    Ok(())
}
