//! Fig. 11 reproduction: accelerated two-pass counting vs the paper's
//! optimized multithreaded CPU baseline, across support thresholds on the
//! 2-1-35 analog.
//!
//! The baseline is always `CpuParallelBackend` at 4 threads (the paper's
//! quad-core). The contender is two-pass (A2+A1) over the best engine the
//! environment offers: accelerated Hybrid with a PJRT runtime, the
//! stream-sharded CPU backend otherwise — batched/vectorized or
//! stream-parallel counting beating the scalar episode-axis loop, with
//! the gap growing as candidate counts rise (lower thresholds).

use std::rc::Rc;

use crate::backend::cpu::CpuParallelBackend;
use crate::backend::sharded::ShardedBackend;
use crate::backend::two_pass::TwoPassBackend;
use crate::backend::{self, CountBackend};
use crate::coordinator::Strategy;
use crate::datasets::culture::{generate, CultureConfig};
use crate::episodes::Episode;
use crate::error::MineError;
use crate::runtime::Runtime;

use super::super::harness::{SuiteCtx, Work};
use super::{best_exact_engine, default_threads, head_window, level_candidate_sets, open_runtime};

fn contender(
    rt: &Option<Rc<Runtime>>,
    threads: usize,
    theta: u64,
) -> Result<TwoPassBackend, MineError> {
    let inner: Box<dyn CountBackend> = match rt {
        Some(rt) => backend::for_strategy(Strategy::Hybrid, Some(rt.clone()), threads)?,
        None => Box::new(ShardedBackend::new(threads)),
    };
    Ok(TwoPassBackend::new(inner, theta))
}

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let rt = open_runtime();
    let threads = default_threads();
    ctx.note(format!(
        "contender: two-pass over {}",
        if rt.is_some() { "accelerated hybrid" } else { "cpu-sharded (stream-axis)" }
    ));

    let cfg = CultureConfig::day(35);
    let full = generate(&cfg, 11);
    let (stream, thetas): (_, &[u64]) = if ctx.smoke {
        (head_window(&full, 20_000), &[24])
    } else {
        (full, &[140, 200, 320])
    };
    let intervals = cfg.interval_set();

    for &th in thetas {
        // the candidate population the counting phase sees at this theta
        let mut probe = best_exact_engine(&rt, threads)?;
        let per_level = level_candidate_sets(probe.as_mut(), &stream, &intervals, th, 5)?;
        let all: Vec<Episode> = per_level.into_iter().skip(1).flatten().collect();
        if all.is_empty() {
            // declare, never silently drop: --check treats an undeclared
            // missing scenario as a failed gate
            ctx.skip(&format!("theta{th}/*"), "no candidates above level 1");
            continue;
        }
        let work = Work::counting(stream.len() as u64, all.len() as u64);
        let mut cpu = CpuParallelBackend::new(4); // the paper's quad-core baseline
        ctx.measure(&format!("theta{th}/cpu_baseline_4t"), work, || {
            cpu.count(&all, &stream).unwrap().counts.iter().sum()
        });
        let mut best = contender(&rt, threads, th)?;
        ctx.measure(&format!("theta{th}/two_pass_best"), work, || {
            best.run(&all, &stream).unwrap().0.counts.iter().sum()
        });
        let base = ctx.median_ns(&format!("theta{th}/cpu_baseline_4t")).unwrap();
        let acc = ctx.median_ns(&format!("theta{th}/two_pass_best")).unwrap();
        ctx.note(format!(
            "theta {th}: {} episodes, two-pass contender {:.2}x vs cpu-4t",
            all.len(),
            base / acc
        ));
    }
    Ok(())
}
