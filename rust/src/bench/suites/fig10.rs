//! Fig. 10 reproduction: A1 vs A2 profiler counters on the 2-1-33 analog.
//!
//! No CUDA Visual Profiler exists on this substrate; the counters come
//! from the instrumented SIMT-warp simulation (`mining::telemetry`) and
//! the analytical GTX280 occupancy model. The measured scenarios time the
//! simulation itself (it is the Fig. 10 hot path); the counters ride
//! along in the sink and the notes, and the occupancy table prints after.
//!
//! Pure CPU — this suite runs in every environment.

use crate::datasets::culture::{generate, CultureConfig};
use crate::episodes::{candidates, Episode, Interval};
use crate::error::MineError;
use crate::gpu_model::occupancy::{a1_resources, a2_resources, GTX280};
use crate::mining::telemetry::{profile_a1, profile_a2};
use crate::util::benchkit::Table;
use crate::util::rng::Rng;

use super::super::harness::{SuiteCtx, Work};
use super::head_window;

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let cfg = CultureConfig::day(33);
    let full = generate(&cfg, 11);
    let stream = if ctx.smoke { head_window(&full, 20_000) } else { full };
    let k = 8;
    let iv = Interval::new(cfg.d_low, cfg.d_high);
    let mut rng = Rng::new(0xF16);

    let sizes: &[usize] = if ctx.smoke { &[2, 3] } else { &[2, 3, 4, 5] };
    let count = if ctx.smoke { 64 } else { 256 };
    for &n in sizes {
        // representative candidate batch at this size: the level-2 cross
        // product, or random type sequences mid-lattice
        let eps: Vec<Episode> = if n == 2 {
            candidates::level2(&candidates::level1(stream.n_types), &[iv])
                .into_iter()
                .take(count)
                .collect()
        } else {
            (0..count)
                .map(|_| {
                    let types: Vec<i32> =
                        (0..n).map(|_| rng.range_i32(0, stream.n_types as i32 - 1)).collect();
                    Episode::new(types, vec![iv; n - 1])
                })
                .collect()
        };
        let work = Work::counting(stream.len() as u64, eps.len() as u64);
        ctx.measure(&format!("n{n}/a1_profile"), work, || {
            let c = profile_a1(&eps, &stream, k);
            c.local_loads + c.local_stores + c.divergent_branches
        });
        ctx.measure(&format!("n{n}/a2_profile"), work, || {
            let c = profile_a2(&eps, &stream);
            c.local_loads + c.local_stores + c.divergent_branches
        });
        let c1 = profile_a1(&eps, &stream, k);
        let c2 = profile_a2(&eps, &stream);
        ctx.note(format!(
            "n={n}: A1 local ld/st {}/{}, divergent {}; A2 local ld/st {}/{}, divergent {}",
            c1.local_loads,
            c1.local_stores,
            c1.divergent_branches,
            c2.local_loads,
            c2.local_stores,
            c2.divergent_branches
        ));
        if c2.local_loads + c2.local_stores != 0 {
            return Err(MineError::internal(
                "A2 must be register-resident (zero local traffic) — telemetry model broke",
            ));
        }
    }

    // occupancy table (the paper's §6.1.2 thread-budget arithmetic)
    let mut occ = Table::new(
        "GTX280 occupancy model: max threads/block and full-utilization threshold",
        &["size", "A1 shared B/thr", "A1 T_B", "A1 S*", "A2 shared B/thr", "A2 T_B", "A2 S*"],
    );
    for n in 1..=8 {
        let r1 = a1_resources(n, k);
        let r2 = a2_resources(n);
        occ.row(vec![
            n.to_string(),
            r1.shared_bytes_per_thread.to_string(),
            GTX280.max_threads(&r1).to_string(),
            GTX280.full_utilization_threshold(&r1).to_string(),
            r2.shared_bytes_per_thread.to_string(),
            GTX280.max_threads(&r2).to_string(),
            GTX280.full_utilization_threshold(&r2).to_string(),
        ]);
    }
    occ.print();
    ctx.note(
        "shape check (paper Fig 10): A2 local traffic == 0 everywhere; \
         A1 local traffic and divergence grow with episode size",
    );
    Ok(())
}
