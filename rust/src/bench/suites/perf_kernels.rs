//! Isolated kernel-execution throughput for the counting artifacts,
//! separated from one-time compilation: per (algo, N), artifact compile
//! time (recorded once) and per-call wall time over one full batch x one
//! full chunk, in episode-events/s — the L1 metric the perf pass
//! optimizes.
//!
//! Entirely about the PJRT executables, so the suite is skipped
//! (declared) when the runtime is unavailable.

use std::time::Instant;

use crate::episodes::Interval;
use crate::error::MineError;
use crate::events::EventStream;
use crate::runtime::exec;
use crate::util::rng::Rng;

use super::super::harness::{SuiteCtx, Work};
use super::{open_runtime, random_episodes};

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let rt = match open_runtime() {
        Some(rt) => rt,
        None => {
            ctx.skip(
                "*",
                "accelerator runtime unavailable (kernel suite measures PJRT \
                 executables)",
            );
            ctx.note("skipped: no PJRT runtime in this environment");
            return Ok(());
        }
    };
    let mf = *rt.manifest();
    let mut rng = Rng::new(0x9E4F);

    // exactly one full chunk of events and one full batch of episodes
    let mut pairs = vec![];
    let mut t = 0;
    for _ in 0..mf.c_chunk {
        t += rng.range_i32(0, 3);
        pairs.push((rng.range_i32(0, 25), t));
    }
    let stream = EventStream::from_pairs(pairs, 26);
    let iv = Interval::new(5, 15);

    let sizes: &[usize] = if ctx.smoke { &[3] } else { &[2, 3, 4, 5, 8] };
    for &n in sizes {
        let eps = random_episodes(&mut rng, n, mf.m_episodes, 26, iv);
        for algo in ["a2", "a1"] {
            let artifact = format!("{algo}_n{n}");
            let t0 = Instant::now();
            rt.executable(&artifact)?; // compile once, cached afterwards
            let compile_ns = t0.elapsed().as_nanos() as f64;
            ctx.record(&format!("{artifact}/compile"), Work::none(), compile_ns, 0);

            let work =
                Work::counting(mf.c_chunk as u64, mf.m_episodes as u64);
            let rt_ref = &rt;
            let eps_ref = &eps;
            let stream_ref = &stream;
            ctx.measure(&format!("{artifact}/run"), work, move || {
                let counts = if algo == "a1" {
                    exec::count_a1(rt_ref, eps_ref, stream_ref).unwrap()
                } else {
                    exec::count_a2(rt_ref, eps_ref, stream_ref).unwrap()
                };
                counts.iter().sum()
            });
            let med = ctx.median_ns(&format!("{artifact}/run")).unwrap();
            let ep_events = (mf.m_episodes * mf.c_chunk) as f64;
            ctx.note(format!(
                "{artifact}: {:.1}M episode-events/s ({:.2} us/event-batch)",
                ep_events / med * 1e9 / 1e6,
                med / 1e3 / mf.c_chunk as f64
            ));
        }
    }
    Ok(())
}
