//! The registered suite bodies — one module per bench target, all thin
//! over [`crate::bench::harness::SuiteCtx`].
//!
//! Shared conventions:
//!
//! - scenario names are `group/variant` (e.g. `size3/ptpe`,
//!   `threads4/stream_axis`); they are the identity baselines key on, so
//!   they must be deterministic for a given (smoke, runtime) environment.
//! - `--smoke` shrinks the workload (windowed streams, fewer sweep
//!   points), never the meaning: a smoke scenario measures the same code
//!   path as its full-mode sibling.
//! - suites that need the PJRT runtime probe it once and declare what
//!   they cannot run via [`SuiteCtx::skip`] rather than erroring, so
//!   `--suite all` is green on CPU-only environments and `--check` can
//!   tell "declared skip" from "lost measurement".

pub mod ablation;
pub mod axis_scaling;
pub mod candidate_scaling;
pub mod cluster_scatter;
pub mod connectivity;
pub mod fig10;
pub mod fig11;
pub mod fig7;
pub mod fig9;
pub mod ingest_replay;
pub mod perf_kernels;
pub mod serve_load;
pub mod stream_incremental;
pub mod table1;

use std::rc::Rc;

use crate::backend::{self, CountBackend};
use crate::coordinator::{Metrics, Strategy};
use crate::episodes::{candidates, Episode, Interval};
use crate::error::MineError;
use crate::events::EventStream;
use crate::runtime::Runtime;
use crate::session::{mine_with_backend, MineOptions};
use crate::util::rng::Rng;

thread_local! {
    // One runtime standup (artifact manifest + PJRT client + executable
    // cache) shared by every suite a `--suite all` run executes on this
    // thread, instead of one per suite.
    static RUNTIME: Option<Rc<Runtime>> = Runtime::open_default().ok().map(Rc::new);
}

/// The shared accelerator runtime handle, if this environment has one.
pub(crate) fn open_runtime() -> Option<Rc<Runtime>> {
    RUNTIME.with(|rt| rt.clone())
}

pub(crate) fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
}

/// The best exact engine this environment offers: accelerated Hybrid when
/// the runtime opens, episode-axis CPU workers otherwise.
pub(crate) fn best_exact_engine(
    rt: &Option<Rc<Runtime>>,
    threads: usize,
) -> Result<Box<dyn CountBackend>, MineError> {
    match rt {
        Some(rt) => backend::for_strategy(Strategy::Hybrid, Some(rt.clone()), threads),
        None => backend::for_strategy(Strategy::CpuParallel, None, threads),
    }
}

/// Mine the stream, then rebuild each level's candidate set exactly as
/// the level-wise miner generated it (level-1 alphabet, joins over the
/// mined frequent sets) — the candidate populations the counting suites
/// measure over.
pub(crate) fn level_candidate_sets(
    engine: &mut dyn CountBackend,
    stream: &EventStream,
    intervals: &[Interval],
    theta: u64,
    max_level: usize,
) -> Result<Vec<Vec<Episode>>, MineError> {
    let opts = MineOptions {
        theta,
        intervals: intervals.to_vec(),
        max_level,
        max_candidates_per_level: 2_000_000,
        candidate_block: crate::session::DEFAULT_CANDIDATE_BLOCK,
    };
    let mut metrics = Metrics::default();
    let result = mine_with_backend(engine, stream, &opts, &mut metrics)?;
    let mut per_level = vec![];
    let mut frontier: Vec<Episode> = vec![];
    for level in 1..=max_level {
        let cands = if level == 1 {
            candidates::level1(stream.n_types)
        } else {
            candidates::next_level(&frontier, intervals)
        };
        if cands.is_empty() {
            break;
        }
        frontier = result
            .frequent
            .iter()
            .filter(|c| c.episode.n() == level)
            .map(|c| c.episode.clone())
            .collect();
        per_level.push(cands);
    }
    Ok(per_level)
}

/// Random episodes of size `n` over an alphabet, all links constrained by
/// `iv` — the synthetic candidate batches the kernel/crossover suites use.
pub(crate) fn random_episodes(
    rng: &mut Rng,
    n: usize,
    count: usize,
    n_types: i32,
    iv: Interval,
) -> Vec<Episode> {
    (0..count)
        .map(|_| {
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, n_types - 1)).collect();
            Episode::new(types, vec![iv; n - 1])
        })
        .collect()
}

/// A dense synthetic stream: `events` events over `n_types` types with
/// 1–3 tick gaps (the axis-scaling / ingest workload shape).
pub(crate) fn synth_stream(seed: u64, events: usize, n_types: usize) -> EventStream {
    let mut rng = Rng::new(seed);
    let mut pairs = Vec::with_capacity(events);
    let mut t = 0;
    for _ in 0..events {
        t += rng.range_i32(1, 3);
        pairs.push((rng.range_i32(0, n_types as i32 - 1), t));
    }
    EventStream::from_pairs(pairs, n_types)
}

/// Window the first `ticks` of a stream (the smoke-mode shrink).
pub(crate) fn head_window(stream: &EventStream, ticks: i32) -> EventStream {
    stream.window(stream.t_begin() - 1, stream.t_begin() + ticks)
}
