//! The connectivity pipeline's cost shape: the `1 + n_surrogates` mine
//! fan-out (serial reference loop vs the batched executor) and the
//! scoring/reconstruction tail.
//!
//! Before anything is timed, the batched pipeline's ranked output is
//! checked identical to the serial loop's — the executor's whole claim
//! is that parallelism is invisible in the result, and a fast divergent
//! answer is not a benchmark. `fanout/serial_loop` re-mines every stream
//! one at a time on one engine (the pre-batch baseline); `fanout/batched`
//! spreads the same jobs across four thread-local engines.
//! `score/pipeline` prices the statistics alone: p-values, excess counts
//! and the significance-ranked circuit over already-mined results.

use crate::analysis::batch::{self, BatchConfig};
use crate::analysis::connectivity::{infer_connectivity, Circuit, ConnectivityConfig};
use crate::analysis::significance;
use crate::analysis::surrogate;
use crate::coordinator::Strategy;
use crate::datasets::{self, sym26::Sym26Config};
use crate::error::MineError;
use crate::events::EventStream;
use crate::obs::Trace;
use crate::session::{MineOptions, DEFAULT_CANDIDATE_BLOCK};

use super::super::harness::{SuiteCtx, Work};

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    // the planted sym26 variant the connectivity tests pin: quiet
    // background, every chain link firing, so significance is unambiguous
    let cfg = Sym26Config {
        duration_ms: if ctx.smoke { 6_000 } else { 20_000 },
        basal_hz: 5.0,
        trigger_hz: 3.0,
        link_prob: 1.0,
        ..Sym26Config::default()
    };
    let stream = datasets::sym26::generate(&cfg, 0xC0);
    let n_surrogates = if ctx.smoke { 4 } else { 9 };
    let theta = if ctx.smoke { 8 } else { 20 };
    let jitter = cfg.d_high;
    let seed = 0x5EED;
    let opts = MineOptions {
        theta,
        intervals: cfg.interval_set(),
        max_level: 3,
        max_candidates_per_level: 2_000_000,
        candidate_block: DEFAULT_CANDIDATE_BLOCK,
    };
    let conn = |parallelism: usize| ConnectivityConfig {
        n_surrogates,
        jitter,
        seed,
        batch: BatchConfig {
            strategy: Strategy::CpuParallel,
            two_pass: true,
            cpu_threads: 1,
            parallelism,
            profile: false,
        },
    };

    // Exactness gate: batched fan-out must reproduce the serial loop's
    // ranked graph byte for byte before its timings mean anything.
    let serial = infer_connectivity(&stream, &opts, &conn(1), &Trace::off())?;
    let batched = infer_connectivity(&stream, &opts, &conn(4), &Trace::off())?;
    if serial.report != batched.report || serial.circuit != batched.circuit {
        return Err(MineError::internal(format!(
            "batched connectivity diverged from the serial loop: \
             {} vs {} scored episodes, {} vs {} edges",
            serial.report.scores.len(),
            batched.report.scores.len(),
            serial.circuit.edges.len(),
            batched.circuit.edges.len()
        )));
    }
    let truth = datasets::ground_truth("sym26").expect("sym26 embeds chains");
    let floor = serial.report.p_floor();
    let s = serial.circuit.significant(floor + 1e-9).score(&truth.chains);
    ctx.note(format!(
        "exactness gate: batched == serial ({} scored episodes, {} edges); \
         p-floor recall {:.2} precision {:.2} over {} true edges",
        serial.report.scores.len(),
        serial.circuit.edges.len(),
        s.recall(),
        s.precision(),
        s.actual
    ));

    let mines = (1 + n_surrogates) as u64;
    let work = Work::items(mines, "mines").with_events(mines * stream.len() as u64);
    ctx.measure("fanout/serial_loop", work, || {
        infer_connectivity(&stream, &opts, &conn(1), &Trace::off())
            .expect("serial pipeline")
            .circuit
            .edges
            .len() as u64
    });
    ctx.measure("fanout/batched", work, || {
        infer_connectivity(&stream, &opts, &conn(4), &Trace::off())
            .expect("batched pipeline")
            .circuit
            .edges
            .len() as u64
    });
    let s1 = ctx.median_ns("fanout/serial_loop").unwrap_or(f64::MAX);
    let s4 = ctx.median_ns("fanout/batched").unwrap_or(f64::MAX);
    ctx.note(format!(
        "fan-out: batched {:.1}ms vs serial loop {:.1}ms ({:.2}x) over {mines} mines",
        s4 / 1e6,
        s1 / 1e6,
        s1 / s4
    ));

    // the statistics tail alone, over pre-mined results
    let surr_streams = surrogate::surrogates(&stream, n_surrogates, jitter, seed)?;
    let mut jobs: Vec<&EventStream> = vec![&stream];
    jobs.extend(surr_streams.iter());
    let mut mined = batch::mine_batch(&jobs, &opts, &conn(4).batch, &Trace::off())?;
    let base = mined.remove(0);
    let scored = serial.report.scores.len() as u64;
    ctx.measure("score/pipeline", Work::items(scored, "episodes"), || {
        let report = significance::score_against_surrogates(&base, &mined);
        Circuit::reconstruct(&report).edges.len() as u64
    });

    Ok(())
}
