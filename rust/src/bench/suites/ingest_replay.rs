//! Durable-log ingest throughput and range-query replay — the tentpole
//! metrics for the `ingest/` layer.
//!
//! `ingest/*` measures events/s into the segmented spike log, both direct
//! (`append_stream`) and through the chip-on-chip partition producer (the
//! acquisition path). `replay/*` measures what segment footers buy at
//! query time: mining a narrow window via a cold full-log read versus a
//! footer-pruned range query. The two paths must return identical results
//! and pruning must actually skip segments — violations fail the suite.

use std::path::PathBuf;
use std::time::Instant;

use crate::coordinator::streaming::{spawn_producer_with, ProducerConfig};
use crate::coordinator::Strategy;
use crate::episodes::Interval;
use crate::error::MineError;
use crate::events::EventStream;
use crate::ingest::{RollPolicy, SpikeLog};
use crate::Session;

use super::super::harness::{SuiteCtx, Work};
use super::synth_stream;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bench_ingest_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn mine_counts(stream: EventStream, theta: u64) -> Result<usize, MineError> {
    let mut session = Session::builder()
        .stream(stream)
        .theta(theta)
        .interval(Interval::new(0, 4))
        .strategy(Strategy::CpuParallel)
        .max_level(3)
        .build()?;
    Ok(session.mine()?.frequent.len())
}

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let events = if ctx.smoke { 40_000 } else { 400_000 };
    let n_types = 12;
    let policy = RollPolicy { max_events: 4_096, max_width_ticks: 1_000_000_000 };
    let stream = synth_stream(0x1065, events, n_types);

    // Phase 1a: direct ingest throughput.
    let dir_direct = scratch("direct");
    let t0 = Instant::now();
    let mut ingestor = SpikeLog::create(&dir_direct, n_types)?.ingestor(policy)?;
    ingestor.append_stream(&stream)?;
    let log = ingestor.finish()?;
    let direct_ns = t0.elapsed().as_nanos() as f64;
    let n_segments = log.segments().len();
    ctx.record(
        "ingest/append_stream",
        Work::items(n_segments as u64, "segments").with_events(stream.len() as u64),
        direct_ns,
        stream.len() as u64,
    );
    drop(log);

    // Phase 1b: ingest through the partition producer (accelerated
    // replay; the pacing is the producer's, the disk work is ours).
    let dir_stream = scratch("streamed");
    let width = (stream.span() / 64).max(1);
    let rx = spawn_producer_with(
        stream.clone(),
        width,
        ProducerConfig { speedup: 1e9, ..Default::default() },
    )?;
    let t0 = Instant::now();
    let mut ingestor = SpikeLog::create(&dir_stream, n_types)?.ingestor(policy)?;
    let streamed = ingestor.ingest_partitions(rx)?;
    let log = ingestor.finish()?;
    let streamed_ns = t0.elapsed().as_nanos() as f64;
    if streamed != stream.len() {
        return Err(MineError::internal(format!(
            "producer-fed ingest must be lossless: {streamed} of {} events",
            stream.len()
        )));
    }
    ctx.record(
        "ingest/partition_producer",
        Work::items(log.segments().len() as u64, "segments")
            .with_events(streamed as u64),
        streamed_ns,
        streamed as u64,
    );
    ctx.note(format!(
        "{} events into {} segments; direct {:.0} events/s, via producer {:.0} events/s",
        stream.len(),
        n_segments,
        stream.len() as f64 / (direct_ns / 1e9),
        streamed as f64 / (streamed_ns / 1e9)
    ));

    // Phase 2: cold full-read mining vs footer-pruned range mining over a
    // narrow window (~1/16 of the recording).
    let span = stream.span();
    let from = stream.t_begin() + span / 2;
    let to = from + span / 16;
    let theta = if ctx.smoke { 8 } else { 40 };

    let t0 = Instant::now();
    let (full, cold_stats) = log.read_all()?;
    let cold_window = full.window(from, to);
    let cold_frequent = mine_counts(cold_window.clone(), theta)?;
    let cold_ns = t0.elapsed().as_nanos() as f64;
    ctx.record(
        "replay/cold_full_read",
        Work::items(cold_stats.segments_read as u64, "segments")
            .with_events(cold_stats.events_scanned as u64),
        cold_ns,
        cold_frequent as u64,
    );

    let t0 = Instant::now();
    let (pruned_window, pruned_stats) = log.read_range(from, to)?;
    let pruned_frequent = mine_counts(pruned_window.clone(), theta)?;
    let pruned_ns = t0.elapsed().as_nanos() as f64;
    ctx.record(
        "replay/footer_pruned",
        Work::items(pruned_stats.segments_read as u64, "segments")
            .with_events(pruned_stats.events_scanned as u64),
        pruned_ns,
        pruned_frequent as u64,
    );

    if pruned_window != cold_window {
        return Err(MineError::internal("pruned range read must equal the cold slice"));
    }
    if pruned_frequent != cold_frequent {
        return Err(MineError::internal("range mining must not depend on the read path"));
    }
    if pruned_stats.pruned_by_time == 0 {
        return Err(MineError::internal(format!(
            "footer pruning must skip segments outside ({from}, {to}]"
        )));
    }
    ctx.note(format!(
        "pruned replay: {:.1}x less I/O, {:.1}x wall speedup vs cold full read \
         ({} of {} segments read)",
        cold_stats.events_scanned as f64 / pruned_stats.events_scanned.max(1) as f64,
        cold_ns / pruned_ns.max(1.0),
        pruned_stats.segments_read,
        pruned_stats.segments_total
    ));

    std::fs::remove_dir_all(&dir_direct).ok();
    std::fs::remove_dir_all(&dir_stream).ok();
    Ok(())
}
