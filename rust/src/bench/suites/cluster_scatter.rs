//! Distributed scatter-gather mining over a segmented log — the tentpole
//! metrics for the `cluster/` layer.
//!
//! `mine/single_process` is the one-machine baseline (`Session::mine`
//! over the whole recording). `scatter/nodes1` and `scatter/nodes4` run
//! the same query through `ScatterMiner` over a `LocalCluster` (threads
//! as nodes, full wire codec, no sockets): nodes1 prices the protocol
//! overhead, nodes4 the parallel win. Before anything is timed, the
//! distributed result is checked byte-identical to the single-process
//! mine — a divergence fails the suite, because a fast wrong answer is
//! not a benchmark. The acceptance gate: 4-node scatter must beat
//! single-node scatter on a multi-segment log. `saturation/curve` drives
//! concurrent closed-loop clients through the coordinator for the
//! latency-under-saturation picture.

use std::path::PathBuf;
use std::time::Instant;

use crate::cluster::{LocalCluster, ScatterConfig, ScatterMiner};
use crate::coordinator::miner::MineResult;
use crate::coordinator::Strategy;
use crate::episodes::Interval;
use crate::error::MineError;
use crate::ingest::{RollPolicy, SpikeLog};
use crate::serve::loadgen::cluster_curve;
use crate::serve::ServiceConfig;
use crate::session::{MineOptions, DEFAULT_CANDIDATE_BLOCK};
use crate::Session;

use super::super::harness::{SuiteCtx, Work};
use super::synth_stream;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bench_cluster_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The identity two mines are compared on: episodes with counts, in
/// order, plus per-level tallies (timing fields excluded).
fn shape(r: &MineResult) -> (Vec<(String, u64)>, Vec<(usize, usize, usize, u64)>) {
    (
        r.frequent.iter().map(|c| (c.episode.display(), c.count)).collect(),
        r.levels
            .iter()
            .map(|l| (l.level, l.candidates, l.frequent, l.culled_by_a2))
            .collect(),
    )
}

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let events = if ctx.smoke { 30_000 } else { 200_000 };
    let n_types = 10usize;
    let theta = (events / n_types / 4) as u64;
    let interval = Interval::new(0, 4);
    let stream = synth_stream(0xC1A57E2, events, n_types);

    let dir = scratch("log");
    let mut ingestor = SpikeLog::create(&dir, n_types)?
        .ingestor(RollPolicy { max_events: events / 16, max_width_ticks: 1_000_000_000 })?;
    ingestor.append_stream(&stream)?;
    let log = ingestor.finish()?;
    let n_segments = log.segments().len();
    if n_segments < 8 {
        return Err(MineError::internal(format!(
            "cluster fixture must span >= 8 segments, got {n_segments}"
        )));
    }
    let opts = MineOptions {
        theta,
        intervals: vec![interval],
        max_level: 3,
        max_candidates_per_level: 2_000_000,
        candidate_block: DEFAULT_CANDIDATE_BLOCK,
    };
    let node_service = || {
        let d = ServiceConfig::default();
        ServiceConfig { workers: 1, strategy: Strategy::CpuSerial, ..d }
    };

    // the one-machine ground truth, reused as the exactness reference
    let mut single = Session::builder()
        .stream(stream)
        .theta(theta)
        .interval(interval)
        .strategy(Strategy::CpuSerial)
        .max_level(3)
        .max_candidates_per_level(2_000_000)
        .build()?;
    let want = single.mine()?;

    let cluster1 = LocalCluster::start(&dir, 1, node_service())?;
    let miner1 = ScatterMiner::connect(&dir, cluster1.links(), ScatterConfig::default())?;
    let cluster4 = LocalCluster::start(&dir, 4, node_service())?;
    let miner4 = ScatterMiner::connect(&dir, cluster4.links(), ScatterConfig::default())?;

    // Exactness gate: the distributed answer must be byte-identical
    // before any of its timings mean anything.
    let got = miner4.mine_all(&opts, false, "bench")?;
    if shape(&got) != shape(&want) {
        return Err(MineError::internal(format!(
            "distributed mine diverged from single-process: {} vs {} frequent episodes",
            got.frequent.len(),
            want.frequent.len()
        )));
    }
    ctx.note(format!(
        "exactness gate: {} frequent episodes over {n_segments} segments, \
         4-node scatter == single-process",
        want.frequent.len()
    ));

    let ev = events as u64;
    ctx.measure("mine/single_process", Work::events(ev), || {
        single.mine().expect("single-process mine").frequent.len() as u64
    });
    ctx.measure("scatter/nodes1", Work::events(ev), || {
        miner1.mine_all(&opts, false, "bench").expect("1-node scatter").frequent.len() as u64
    });
    ctx.measure("scatter/nodes4", Work::events(ev), || {
        miner4.mine_all(&opts, false, "bench").expect("4-node scatter").frequent.len() as u64
    });

    let n1 = ctx.median_ns("scatter/nodes1").unwrap_or(f64::MAX);
    let n4 = ctx.median_ns("scatter/nodes4").unwrap_or(f64::MAX);
    ctx.note(format!(
        "scatter scaling: 4 nodes {:.1}ms vs 1 node {:.1}ms ({:.2}x)",
        n4 / 1e6,
        n1 / 1e6,
        n1 / n4
    ));
    if n4 >= n1 {
        return Err(MineError::internal(format!(
            "4-node scatter must beat single-node on a {n_segments}-segment log: \
             {:.1}ms vs {:.1}ms",
            n4 / 1e6,
            n1 / 1e6
        )));
    }

    // Latency under saturation: closed-loop tenants against the 4-node
    // coordinator; admission sheds instead of queueing unboundedly.
    let steps: Vec<usize> = if ctx.smoke { vec![2] } else { vec![2, 4, 8] };
    let t0 = Instant::now();
    let points = cluster_curve(&miner4, &opts, false, &steps, 1);
    let wall = t0.elapsed().as_nanos() as f64;
    let completed: u64 = points.iter().map(|p| p.completed).sum();
    ctx.record("saturation/curve", Work::items(completed, "mines"), wall, completed);
    for p in &points {
        ctx.note(p.report());
    }
    ctx.note(miner4.metrics().report());

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
