//! Ablations over the design choices the substrate makes:
//!
//! 1. **Bounded list depth K** — cost and exactness of bounded A1 vs the
//!    unbounded reference, on a dense adversarial stream and on Sym26
//!    (the sink carries the divergence count; the notes the fraction).
//! 2. **Concatenate fold vs log-tree** — merge cost of the two stitch
//!    implementations at growing segment counts.
//! 3. **Hybrid dispatch rules** — paper Eq. 2 crossover form vs the
//!    substrate cost model, scored by how often each picks the truly
//!    faster accelerator strategy (runtime only; skipped otherwise).

use crate::backend::{self, CountBackend};
use crate::coordinator::mapconcat::{concatenate_fold, concatenate_tree};
use crate::coordinator::Strategy;
use crate::datasets::sym26::{generate, Sym26Config};
use crate::episodes::{Episode, Interval};
use crate::error::MineError;
use crate::events::EventStream;
use crate::gpu_model::crossover::{CostModel, CrossoverModel};
use crate::mining::serial;
use crate::util::rng::Rng;

use super::super::harness::{SuiteCtx, Work};
use super::{head_window, open_runtime, random_episodes};

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let mut rng = Rng::new(0xAB1A);
    let cfg = Sym26Config::default();
    let sym = generate(&cfg, 7);

    // --- 1. K ablation: bounded-list cost and exactness ---
    // dense random stream: the worst case for truncation
    let mut pairs = vec![];
    let mut t = 0;
    for _ in 0..6_000 {
        t += rng.range_i32(0, 2);
        pairs.push((rng.range_i32(0, 3), t));
    }
    let dense = EventStream::from_pairs(pairs, 4);

    let trials = if ctx.smoke { 20 } else { 120 };
    // the randomized episode population is fixed up front so every K (and
    // the unbounded reference) counts the same episodes
    let dense_eps: Vec<Episode> = (0..trials)
        .map(|_| {
            let n = rng.range_i32(2, 4) as usize;
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 3)).collect();
            let ivs: Vec<Interval> = (0..n - 1)
                .map(|_| {
                    let lo = rng.range_i32(0, 3);
                    Interval::new(lo, lo + rng.range_i32(1, 10))
                })
                .collect();
            Episode::new(types, ivs)
        })
        .collect();
    let sym_eps: Vec<Episode> = (0..trials)
        .map(|_| {
            let n = rng.range_i32(2, 4) as usize;
            random_episodes(&mut rng, n, 1, 26, Interval::new(5, 15)).remove(0)
        })
        .collect();
    let dense_exact: Vec<u64> =
        dense_eps.iter().map(|ep| serial::count_a1(ep, &dense)).collect();
    let sym_exact: Vec<u64> = sym_eps.iter().map(|ep| serial::count_a1(ep, &sym)).collect();

    let ks: &[usize] = if ctx.smoke { &[1, 4, 16] } else { &[1, 2, 4, 8, 16] };
    for &k in ks {
        let dense_work =
            Work::counting((dense.len() * trials) as u64, trials as u64);
        ctx.measure(&format!("k{k}/bounded_dense"), dense_work, || {
            let mut divergent = 0u64;
            for (ep, &exact) in dense_eps.iter().zip(&dense_exact) {
                if serial::count_a1_bounded(ep, &dense, k) != exact {
                    divergent += 1;
                }
            }
            divergent
        });
        let sym_work = Work::counting((sym.len() * trials) as u64, trials as u64);
        ctx.measure(&format!("k{k}/bounded_sym26"), sym_work, || {
            let mut divergent = 0u64;
            for (ep, &exact) in sym_eps.iter().zip(&sym_exact) {
                if serial::count_a1_bounded(ep, &sym, k) != exact {
                    divergent += 1;
                }
            }
            divergent
        });
        let dd = ctx.results().iter().find(|r| r.name == format!("k{k}/bounded_dense"));
        let ds = ctx.results().iter().find(|r| r.name == format!("k{k}/bounded_sym26"));
        let (dd, ds) = (dd.map(|r| r.sink).unwrap_or(0), ds.map(|r| r.sink).unwrap_or(0));
        ctx.note(format!(
            "K={k}: divergent {:.1}% (dense), {:.1}% (Sym26); state {} B/lane at N=5",
            100.0 * dd as f64 / trials as f64,
            100.0 * ds as f64 / trials as f64,
            4 * 5 * k
        ));
    }

    // --- 2. Concatenate fold vs log-tree merge cost ---
    let ep = Episode::new(vec![0, 1, 2], vec![Interval::new(5, 15); 2]);
    let ps: &[usize] = if ctx.smoke { &[64, 512] } else { &[8, 64, 512, 4096] };
    for &p in ps {
        let taus: Vec<i32> = {
            let t0 = sym.t_begin() as i64 - 1;
            let span = sym.t_end() as i64 - t0;
            (0..p as i64)
                .map(|i| (t0 + span * i / p as i64) as i32)
                .chain([sym.t_end()])
                .collect()
        };
        let tuples = serial::mapcat_map(&ep, &sym, &taus, 8);
        let work = Work::items(p as u64, "segments");
        ctx.measure(&format!("merge_p{p}/fold"), work, || concatenate_fold(&tuples).0);
        ctx.measure(&format!("merge_p{p}/tree"), work, || concatenate_tree(&tuples).0);
        let fold = ctx.results().iter().find(|r| r.name == format!("merge_p{p}/fold"));
        let tree = ctx.results().iter().find(|r| r.name == format!("merge_p{p}/tree"));
        let (fs, ts) = (fold.map(|r| r.sink), tree.map(|r| r.sink));
        if fs != ts {
            return Err(MineError::internal(format!(
                "fold and tree merges disagree at P={p}: {fs:?} vs {ts:?}"
            )));
        }
    }

    // --- 3. dispatch-rule ablation (accelerator strategies) ---
    let rt = match open_runtime() {
        Some(rt) => rt,
        None => {
            ctx.skip("dispatch_*", "accelerator runtime unavailable");
            ctx.note("dispatch-rule ablation skipped: no PJRT runtime");
            return Ok(());
        }
    };
    let window = head_window(&sym, 20_000);
    let mf = *rt.manifest();
    let cost = CostModel::substrate_default(mf.m_episodes, mf.c_chunk);
    let paper = CrossoverModel::paper_default();
    let substrate = CrossoverModel::substrate_default();
    let probe_s: &[usize] = if ctx.smoke { &[2, 64] } else { &[1, 4, 16, 64, 256] };
    let probe_n: &[usize] = if ctx.smoke { &[3, 6] } else { &[3, 4, 6, 8] };
    let mut scores = [0usize; 3];
    let mut total = 0usize;
    for &n in probe_n {
        for &s in probe_s {
            let eps = random_episodes(&mut rng, n, s, 26, Interval::new(5, 15));
            let work = Work::counting(window.len() as u64, s as u64);
            let mut ptpe =
                backend::for_strategy(Strategy::PtpeA1, Some(rt.clone()), 4)?;
            ctx.measure(&format!("dispatch_s{s}_n{n}/ptpe"), work, || {
                ptpe.count(&eps, &window).unwrap().counts.iter().sum()
            });
            let mut mc =
                backend::for_strategy(Strategy::MapConcat, Some(rt.clone()), 4)?;
            ctx.measure(&format!("dispatch_s{s}_n{n}/mapconcat"), work, || {
                mc.count(&eps, &window).unwrap().counts.iter().sum()
            });
            let pt = ctx.median_ns(&format!("dispatch_s{s}_n{n}/ptpe")).unwrap();
            let mcn = ctx.median_ns(&format!("dispatch_s{s}_n{n}/mapconcat")).unwrap();
            let truth = pt <= mcn;
            let picks = [
                paper.choose_ptpe(s, n),
                substrate.choose_ptpe(s, n),
                cost.choose_ptpe(s, n, window.len()),
            ];
            for (i, &pick) in picks.iter().enumerate() {
                if pick == truth {
                    scores[i] += 1;
                }
            }
            total += 1;
        }
    }
    ctx.note(format!(
        "dispatch accuracy: paper {}/{total}, substrate-crossover {}/{total}, \
         cost-model {}/{total}",
        scores[0], scores[1], scores[2]
    ));
    Ok(())
}
