//! Incremental sliding-window mining vs batch re-mine — the tentpole
//! metrics for the `stream/` layer.
//!
//! The claim under test: once a window is warm, an [`IncrementalMiner`]
//! commit costs work proportional to the *arriving* segment (halo-dirty
//! partitions only), while a cold re-mine of the same window scales with
//! the *window*. So `w{N}/incremental_update` should stay near-flat as N
//! grows and `w{N}/batch_remine` should grow with N — the asymptotic win
//! the live-mining path (`epminer watch`, serve/ subscriptions) is built
//! on. Every measured window also cross-checks the incremental frequent
//! set against a cold one-pass serial mine of the exact window stream;
//! divergence fails the suite, so the speedup is never bought with
//! approximation.
//!
//! [`IncrementalMiner`]: crate::stream::IncrementalMiner

use crate::coordinator::Strategy;
use crate::episodes::Interval;
use crate::error::MineError;
use crate::events::Tick;
use crate::stream::{IncrementalConfig, IncrementalMiner};
use crate::Session;

use super::super::harness::{SuiteCtx, Work};
use super::synth_stream;

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let n_types = 10;
    let max_level = 3;
    let theta = if ctx.smoke { 4 } else { 12 };
    let windows: &[usize] = if ctx.smoke { &[4, 8] } else { &[8, 16, 32] };
    let seg_width: Tick = if ctx.smoke { 400 } else { 1_000 };
    let iv = Interval::new(0, 6);

    // Enough segments to warm the widest window and feed every measured
    // update iteration (warmup + max_iters, per window). synth_stream's
    // 1-3 tick gaps average ~2 ticks/event, so `need * seg_width` events
    // span ~2x the required ticks — a comfortable margin.
    let feed = ctx.cfg.warmup_iters + ctx.cfg.max_iters;
    let need = windows.iter().max().unwrap() + feed + 1;
    let stream = synth_stream(0x57E4, need * seg_width as usize, n_types);
    let segs = stream.partitions(seg_width);
    if segs.len() < need {
        return Err(MineError::internal(format!(
            "workload too short: {} segments of {need} needed",
            segs.len()
        )));
    }

    for &w in windows {
        let cfg = IncrementalConfig::new(theta, vec![iv])
            .max_level(max_level)
            .window_segments(w);
        let mut miner = IncrementalMiner::new(n_types, cfg)?;
        let mut next = 0usize;
        for _ in 0..w {
            miner.push_segment(segs[next].clone())?;
            next += 1;
        }
        let seg_events = segs[next].len() as u64;

        // Slide the warm window by one segment per iteration: retire the
        // expired prefix, fold in the arriving suffix, re-cascade only
        // where the frequency frontier moved.
        ctx.measure(&format!("w{w}/incremental_update"), Work::events(seg_events), || {
            let seg = segs[next].clone();
            next += 1;
            let update = miner.push_segment(seg).expect("incremental commit");
            update.frequent.len() as u64
        });

        // The comparison point: a cold one-pass serial mine of the very
        // window the miner now holds (one-pass CpuSerial is the exact
        // reference the incremental counting path generalizes).
        let window = miner.window_stream();
        let window_events = window.len() as u64;
        ctx.measure(&format!("w{w}/batch_remine"), Work::events(window_events), || {
            let mut session = Session::builder()
                .stream(window.clone())
                .theta(theta)
                .interval(iv)
                .strategy(Strategy::CpuSerial)
                .one_pass()
                .max_level(max_level)
                .build()
                .expect("batch session");
            session.mine().expect("batch mine").frequent.len() as u64
        });

        // Exactness gate: the incremental frequent set must equal the
        // batch re-mine of the same window, episode for episode, count
        // for count, in the same level-wise candidate order.
        let mut session = Session::builder()
            .stream(window.clone())
            .theta(theta)
            .interval(iv)
            .strategy(Strategy::CpuSerial)
            .one_pass()
            .max_level(max_level)
            .build()?;
        let batch = session.mine()?;
        if batch.frequent != **miner.frequent() {
            return Err(MineError::internal(format!(
                "w{w}: incremental frequent set diverged from batch re-mine \
                 ({} vs {} episodes)",
                miner.frequent().len(),
                batch.frequent.len()
            )));
        }

        let inc = ctx.median_ns(&format!("w{w}/incremental_update")).unwrap_or(0.0);
        let batch_ns = ctx.median_ns(&format!("w{w}/batch_remine")).unwrap_or(0.0);
        ctx.note(format!(
            "w{w}: window {} events, update {:.2}ms vs re-mine {:.2}ms \
             ({:.1}x), results identical",
            window_events,
            inc / 1e6,
            batch_ns / 1e6,
            batch_ns / inc.max(1.0),
        ));
    }

    Ok(())
}
