//! Fig. 7 reproduction: PTPE vs MapConcatenate vs Hybrid on Sym26.
//!
//! (a) execution time per episode size at one support threshold;
//! (b) Hybrid speedup over both pure strategies across support
//!     thresholds. Paper shape: neither pure strategy wins everywhere —
//!     PTPE wins at sizes with many candidates, MapConcatenate when few
//!     episodes leave lanes idle, and Hybrid tracks the winner.
//!
//! All three strategies count on the accelerator, so the whole suite is
//! skipped (declared, not silent) when the PJRT runtime is unavailable.

use crate::backend;
use crate::backend::CountBackend;
use crate::coordinator::Strategy;
use crate::datasets::sym26::{generate, Sym26Config};
use crate::episodes::Episode;
use crate::error::MineError;

use super::super::harness::{SuiteCtx, Work};
use super::{best_exact_engine, default_threads, level_candidate_sets, open_runtime};

const STRATEGIES: &[(&str, Strategy)] = &[
    ("ptpe", Strategy::PtpeA1),
    ("mapconcat", Strategy::MapConcat),
    ("hybrid", Strategy::Hybrid),
];

/// Candidate sets are sampled down to one PTPE batch: MapConcatenate over
/// a 17k-episode level costs ~2*S*C kernel loop steps on this substrate;
/// its disadvantage at large S is unambiguous at the cap.
const CAP: usize = 512;

pub fn run(ctx: &mut SuiteCtx) -> Result<(), MineError> {
    let rt = match open_runtime() {
        Some(rt) => Some(rt),
        None => {
            ctx.skip(
                "*",
                "accelerator runtime unavailable (PTPE/MapConcatenate/Hybrid \
                 all count on the accelerator)",
            );
            ctx.note("skipped: no PJRT runtime in this environment");
            return Ok(());
        }
    };
    let threads = default_threads();
    let cfg = Sym26Config::default();
    let full = generate(&cfg, 7);
    // smoke shrinks the workload like every other suite: a 20 s window
    // (theta scaled with it) and a shallower lattice
    let (stream, theta, max_level) = if ctx.smoke {
        (super::head_window(&full, 20_000), 20, 5)
    } else {
        (full, 60, 8)
    };
    let intervals = cfg.interval_set();

    // --- 7(a): execution time by episode size ---
    let mut probe = best_exact_engine(&rt, threads)?;
    let per_level =
        level_candidate_sets(probe.as_mut(), &stream, &intervals, theta, max_level)?;
    for (li, cands) in per_level.iter().enumerate() {
        let n = li + 1;
        if n < 2 {
            continue;
        }
        if cands.is_empty() {
            ctx.skip(&format!("size{n}/*"), "no candidates at this level");
            continue;
        }
        let cands: Vec<Episode> = cands.iter().take(CAP).cloned().collect();
        let work = Work::counting(stream.len() as u64, cands.len() as u64);
        for &(label, strat) in STRATEGIES {
            let mut be = backend::for_strategy(strat, rt.clone(), threads)?;
            ctx.measure(&format!("size{n}/{label}"), work, || {
                be.count(&cands, &stream).unwrap().counts.iter().sum()
            });
        }
        let winner = STRATEGIES
            .iter()
            .min_by(|a, b| {
                let ta = ctx.median_ns(&format!("size{n}/{}", a.0)).unwrap();
                let tb = ctx.median_ns(&format!("size{n}/{}", b.0)).unwrap();
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap()
            .0;
        ctx.note(format!("size {n}: fastest strategy is {winner}"));
    }

    // --- 7(b): Hybrid speedup across support thresholds ---
    let thetas: &[u64] = if ctx.smoke { &[15, 30] } else { &[40, 60, 120] };
    for &th in thetas {
        let mut probe = best_exact_engine(&rt, threads)?;
        let per_level = level_candidate_sets(probe.as_mut(), &stream, &intervals, th, 5)?;
        let all: Vec<Episode> = per_level
            .into_iter()
            .skip(1) // counting work is levels >= 2
            .flat_map(|lvl| lvl.into_iter().take(CAP))
            .collect();
        if all.is_empty() {
            ctx.skip(&format!("theta{th}/*"), "no candidates above level 1");
            continue;
        }
        let work = Work::counting(stream.len() as u64, all.len() as u64);
        for &(label, strat) in STRATEGIES {
            let mut be = backend::for_strategy(strat, rt.clone(), threads)?;
            ctx.measure(&format!("theta{th}/{label}"), work, || {
                be.count(&all, &stream).unwrap().counts.iter().sum()
            });
        }
        let ptpe = ctx.median_ns(&format!("theta{th}/ptpe")).unwrap();
        let mc = ctx.median_ns(&format!("theta{th}/mapconcat")).unwrap();
        let hy = ctx.median_ns(&format!("theta{th}/hybrid")).unwrap();
        ctx.note(format!(
            "theta {th}: hybrid {:.2}x vs PTPE, {:.2}x vs MapConcatenate",
            ptpe / hy,
            mc / hy
        ));
    }
    Ok(())
}
