//! The shared command-line driver behind `epminer bench` and every
//! `benches/<suite>.rs` binary (which are thin registrants: one line of
//! `main` delegating here).
//!
//! Flags:
//!
//! - `--suite <name|a,b|all>` — which suites to run (binaries pin one)
//! - `--smoke` — the reduced CI workload (`--fast` is a deprecated alias)
//! - `--json-out <dir>` — write `BENCH_<suite>.json` per suite
//! - `--check <baseline.json|dir>` — compare against committed baselines;
//!   a directory is expected to hold `<suite>.json` files
//! - `--tolerance <rel>` — default relative tolerance for `--check`
//!   (per-scenario `tolerance` in the baseline wins); with
//!   `--write-baseline`, the tolerance stamped into every scenario
//! - `--write-baseline <dir>` — write each suite's result as a baseline
//!   (`<dir>/<suite>.json`, per-scenario `tolerance` included) — the
//!   refresh path for `benches/baselines/`: run the suites on the
//!   reference machine, write over the committed files, review the diff
//!
//! Exit status: 0 all suites ran and all checks passed; 1 a suite failed
//! or a check regressed; 2 usage error.

use std::path::Path;

use crate::error::MineError;
use crate::util::benchkit::{fmt_ns, Table};
use crate::util::cli::Args;

use super::check::{check_suite, CheckConfig};
use super::schema::SuiteResult;
use super::{find, run_suite, SuiteDef, SUITES};

/// Entry point for `epminer bench`. Returns whether everything passed.
pub fn run_from_args(args: &Args) -> Result<bool, MineError> {
    let selection = args.get_or("suite", "all").to_string();
    run_selection(&selection, args)
}

/// Entry point for a `benches/<suite>.rs` binary: run exactly that suite
/// with the shared flags, then exit with the shared status convention.
pub fn bench_binary_main(suite: &str) -> ! {
    let args = Args::from_env();
    match run_selection(suite, &args) {
        Ok(true) => std::process::exit(0),
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

/// Flags the harness understands. `bench` rides along because `cargo
/// bench` appends `--bench` to the binaries it launches.
const KNOWN_FLAGS: &[&str] =
    &["suite", "smoke", "fast", "json-out", "check", "tolerance", "write-baseline", "bench"];

fn run_selection(selection: &str, args: &Args) -> Result<bool, MineError> {
    for name in args.given() {
        if !KNOWN_FLAGS.contains(&name) {
            // the first bench generation had per-binary tuning flags
            // (--events, --threads, --sizes, ...); ignoring one silently
            // would measure a different workload than the one asked for
            eprintln!(
                "warning: --{name} is not a bench-harness flag and was ignored \
                 (known: {})",
                KNOWN_FLAGS.join(", ")
            );
        }
    }
    let smoke = args.smoke();
    let json_out = args.get("json-out");
    let check = args.get("check");
    let write_baseline = args.get("write-baseline");
    let check_cfg = CheckConfig {
        default_tolerance: args.get_f64("tolerance", CheckConfig::default().default_tolerance)?,
    };

    let defs: Vec<&'static SuiteDef> = if selection == "all" {
        SUITES.iter().collect()
    } else {
        selection
            .split(',')
            .map(|name| {
                find(name.trim()).ok_or_else(|| {
                    MineError::invalid(format!(
                        "unknown suite {name:?} (valid: all, {})",
                        SUITES.iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
                    ))
                })
            })
            .collect::<Result<_, _>>()?
    };

    let mut all_ok = true;
    for def in defs {
        println!(
            "\n== suite {} — {}{} ==",
            def.name,
            def.description,
            if smoke { " [smoke]" } else { "" }
        );
        let result = match run_suite(def, smoke) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("suite {} FAILED: {e}", def.name);
                all_ok = false;
                continue;
            }
        };
        print_result(&result);
        if let Some(dir) = json_out {
            let path = Path::new(dir).join(format!("BENCH_{}.json", def.name));
            std::fs::write(&path, result.to_json())
                .map_err(|e| MineError::io(format!("writing {}", path.display()), e))?;
            println!("wrote {}", path.display());
        }
        if let Some(dir) = write_baseline {
            let path = write_baseline_file(dir, &result, check_cfg.default_tolerance)?;
            println!("wrote baseline {path}");
        }
        if let Some(base_path) = check {
            match load_baseline(base_path, def.name)? {
                None => println!(
                    "no baseline for {} under {base_path} — check skipped",
                    def.name
                ),
                Some(baseline) => {
                    let report = check_suite(&result, &baseline, &check_cfg);
                    print!("{}", report.render());
                    if !report.passed() {
                        all_ok = false;
                    }
                }
            }
        }
    }
    Ok(all_ok)
}

/// Write one suite's result as a baseline file: `<dir>/<suite>.json` with
/// `tolerance` stamped into every scenario (the value `--tolerance` set,
/// else the check default), so a refreshed baseline gates at the band the
/// refresher chose rather than whatever each future checker passes.
fn write_baseline_file(
    dir: &str,
    result: &SuiteResult,
    tolerance: f64,
) -> Result<String, MineError> {
    std::fs::create_dir_all(dir)
        .map_err(|e| MineError::io(format!("creating baseline dir {dir}"), e))?;
    let mut baseline = result.clone();
    for s in &mut baseline.scenarios {
        s.tolerance = Some(tolerance);
    }
    let path = Path::new(dir).join(format!("{}.json", result.suite));
    std::fs::write(&path, baseline.to_json())
        .map_err(|e| MineError::io(format!("writing {}", path.display()), e))?;
    Ok(path.display().to_string())
}

/// Resolve the baseline for one suite: a direct file, or
/// `<dir>/<suite>.json` when `path` is a directory. `Ok(None)` only when
/// an *existing* baselines directory has no file for this suite (new
/// suites land before their baselines); a `--check` path that exists as
/// neither file nor directory is a usage error — a typo must not
/// silently disable the regression gate.
fn load_baseline(path: &str, suite: &str) -> Result<Option<SuiteResult>, MineError> {
    let p = Path::new(path);
    if !p.exists() {
        return Err(MineError::invalid(format!(
            "--check path {path:?} does not exist (expected a baseline file or a \
             directory of <suite>.json baselines)"
        )));
    }
    let file = if p.is_dir() { p.join(format!("{suite}.json")) } else { p.to_path_buf() };
    if !file.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&file)
        .map_err(|e| MineError::io(format!("reading baseline {}", file.display()), e))?;
    let baseline = SuiteResult::from_json(&text).map_err(|e| {
        MineError::invalid(format!("baseline {}: {e}", file.display()))
    })?;
    Ok(Some(baseline))
}

fn print_result(result: &SuiteResult) {
    let mut table = Table::new(
        &format!(
            "{} ({} scenario{}, commit {}, {} profile, runtime {})",
            result.suite,
            result.scenarios.len(),
            if result.scenarios.len() == 1 { "" } else { "s" },
            result.env.commit,
            result.env.profile,
            result.env.runtime
        ),
        &["scenario", "iters", "median", "p95", "throughput"],
    );
    for s in &result.scenarios {
        let throughput = match (s.events_per_s, s.items_per_s, s.item_unit.as_deref()) {
            (Some(ev), Some(it), Some(unit)) => {
                format!("{} events/s, {} {unit}/s", si(ev), si(it))
            }
            (Some(ev), _, _) => format!("{} events/s", si(ev)),
            (None, Some(it), Some(unit)) => format!("{} {unit}/s", si(it)),
            _ => "-".to_string(),
        };
        table.row(vec![
            s.name.clone(),
            s.iters.to_string(),
            fmt_ns(s.median_ns),
            fmt_ns(s.p95_ns),
            throughput,
        ]);
    }
    table.print();
    for s in &result.skipped {
        println!("  skipped {}: {}", s.name, s.reason);
    }
}

/// Compact SI-ish magnitude formatting for throughput cells.
fn si(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_scales() {
        assert_eq!(si(12.34), "12.3");
        assert_eq!(si(1_500.0), "1.5k");
        assert_eq!(si(2_500_000.0), "2.50M");
        assert_eq!(si(3.1e9), "3.10G");
    }

    #[test]
    fn load_baseline_absent_is_none() {
        let dir = std::env::temp_dir().join(format!("bench_cli_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = load_baseline(dir.to_str().unwrap(), "no_such_suite").unwrap();
        assert!(out.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_baseline_nonexistent_check_path_is_an_error() {
        // a typoed --check path must fail loudly, not skip the gate
        let err = load_baseline("/no/such/baselines-dir", "axis_scaling").err().unwrap();
        assert!(err.to_string().contains("does not exist"), "{err}");
    }

    #[test]
    fn write_baseline_stamps_tolerance_into_every_scenario() {
        let dir = std::env::temp_dir().join(format!("bench_wb_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let result = crate::bench::schema::sample_suite();
        let path = write_baseline_file(dir.to_str().unwrap(), &result, 2.5).unwrap();
        assert!(path.ends_with("axis_scaling.json"), "{path}");
        let back = SuiteResult::from_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.suite, result.suite);
        assert!(back.scenarios.iter().all(|s| s.tolerance == Some(2.5)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_suite_is_usage_error() {
        let args = Args::parse(["--suite".to_string(), "warp".to_string()]);
        let err = run_from_args(&args).err().unwrap();
        assert!(err.to_string().contains("warp"), "{err}");
        assert!(err.to_string().contains("axis_scaling"), "{err}");
    }
}
