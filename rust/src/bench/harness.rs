//! The shared measurement loop every suite runs through.
//!
//! A suite body receives a [`SuiteCtx`] and calls [`SuiteCtx::measure`]
//! (repeated timed iterations via [`crate::util::benchkit::bench`]:
//! warmup, median/p95, early stop on a wall-time budget) or
//! [`SuiteCtx::record`] (single-shot phases like a full ingest run, where
//! repetition is built into the workload). Either way the scenario lands
//! in the same versioned schema, so `BENCH_<suite>.json` looks identical
//! whether the number came from a micro- or a macro-measurement.

use std::time::Instant;

use crate::util::benchkit::{bench, BenchCfg};
use crate::util::stats::Summary;

use super::schema::{ScenarioResult, SkippedScenario};

/// Per-iteration workload size, for throughput derivation. `events` is
/// stream events scanned; `items`/`item_unit` is the scenario's natural
/// unit (episodes counted, requests served, segments merged).
#[derive(Clone, Copy, Debug, Default)]
pub struct Work {
    pub events: u64,
    pub items: u64,
    pub item_unit: Option<&'static str>,
}

impl Work {
    /// No meaningful throughput (pure-latency scenario).
    pub fn none() -> Work {
        Work::default()
    }

    /// `events` stream events per iteration.
    pub fn events(events: u64) -> Work {
        Work { events, items: 0, item_unit: None }
    }

    /// The counting shape: a batch of `episodes` over `events` events.
    pub fn counting(events: u64, episodes: u64) -> Work {
        Work { events, items: episodes, item_unit: Some("episodes") }
    }

    /// `items` of some named unit per iteration (requests, segments, ...).
    pub fn items(items: u64, unit: &'static str) -> Work {
        Work { events: 0, items, item_unit: Some(unit) }
    }

    /// Add an event count to an item-shaped workload.
    pub fn with_events(mut self, events: u64) -> Work {
        self.events = events;
        self
    }
}

/// Accumulates one suite run: config, measured scenarios, skips.
pub struct SuiteCtx {
    pub smoke: bool,
    /// the default measurement config (suites may pass their own to
    /// [`SuiteCtx::measure_with`] for scenarios with unusual costs)
    pub cfg: BenchCfg,
    results: Vec<ScenarioResult>,
    skipped: Vec<SkippedScenario>,
}

impl SuiteCtx {
    pub fn new(smoke: bool) -> SuiteCtx {
        let cfg = if smoke {
            // CI profile: enough repeats for a median, bounded wall time
            BenchCfg { warmup_iters: 1, min_iters: 2, max_iters: 5, budget_ns: 1_000_000_000 }
        } else {
            BenchCfg { warmup_iters: 1, min_iters: 3, max_iters: 15, budget_ns: 4_000_000_000 }
        };
        SuiteCtx { smoke, cfg, results: vec![], skipped: vec![] }
    }

    /// Run `f` under the shared measurement loop and record the scenario.
    /// Returns the recorded result (copy out what you need; the borrow
    /// ends at the call site).
    pub fn measure<F: FnMut() -> u64>(&mut self, name: &str, work: Work, f: F) -> &ScenarioResult {
        let cfg = self.cfg.clone();
        self.measure_with(name, work, &cfg, f)
    }

    /// [`SuiteCtx::measure`] with an explicit measurement config.
    pub fn measure_with<F: FnMut() -> u64>(
        &mut self,
        name: &str,
        work: Work,
        cfg: &BenchCfg,
        f: F,
    ) -> &ScenarioResult {
        let m = bench(name, cfg, f);
        self.push(from_summary(name, work, &m.summary, m.last_result))
    }

    /// Record a scenario measured once, externally (`elapsed` covers the
    /// whole workload described by `work`).
    pub fn record(
        &mut self,
        name: &str,
        work: Work,
        elapsed_ns: f64,
        sink: u64,
    ) -> &ScenarioResult {
        let summary = Summary::of(&[elapsed_ns.max(1.0)]);
        self.push(from_summary(name, work, &summary, sink))
    }

    /// Time `f` once and record it (convenience over [`SuiteCtx::record`]).
    pub fn record_run<T>(
        &mut self,
        name: &str,
        work: Work,
        sink: u64,
        f: impl FnOnce() -> T,
    ) -> T {
        let t0 = Instant::now();
        let out = f();
        let elapsed = t0.elapsed().as_nanos() as f64;
        self.record(name, work, elapsed, sink);
        out
    }

    /// Mark a scenario (or, with name `"*"`, the whole suite) as not
    /// runnable in this environment.
    pub fn skip(&mut self, name: &str, reason: impl Into<String>) {
        self.skipped.push(SkippedScenario { name: name.to_string(), reason: reason.into() });
    }

    /// Narrate a suite-level observation (printed, not serialized).
    pub fn note(&mut self, msg: impl AsRef<str>) {
        println!("  note: {}", msg.as_ref());
    }

    /// The median of an already-recorded scenario (suites derive speedup
    /// ratios and crossover points from these).
    pub fn median_ns(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|r| r.name == name).map(|r| r.median_ns)
    }

    pub fn results(&self) -> &[ScenarioResult] {
        &self.results
    }

    pub fn skipped(&self) -> &[SkippedScenario] {
        &self.skipped
    }

    pub(crate) fn into_parts(self) -> (Vec<ScenarioResult>, Vec<SkippedScenario>) {
        (self.results, self.skipped)
    }

    fn push(&mut self, r: ScenarioResult) -> &ScenarioResult {
        assert!(
            self.results.iter().all(|p| p.name != r.name),
            "duplicate scenario name {:?} — scenario names are the baseline identity",
            r.name
        );
        self.results.push(r);
        self.results.last().unwrap()
    }
}

fn from_summary(name: &str, work: Work, summary: &Summary, sink: u64) -> ScenarioResult {
    let per_second = |count: u64| {
        if count > 0 && summary.median > 0.0 {
            Some(count as f64 * 1e9 / summary.median)
        } else {
            None
        }
    };
    ScenarioResult {
        name: name.to_string(),
        iters: summary.n,
        median_ns: summary.median,
        mean_ns: summary.mean,
        p95_ns: summary.p95,
        min_ns: summary.min,
        max_ns: summary.max,
        events_per_s: per_second(work.events),
        items_per_s: per_second(work.items),
        item_unit: if work.items > 0 { work.item_unit.map(|s| s.to_string()) } else { None },
        sink,
        tolerance: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_records_throughput_and_sink() {
        let mut ctx = SuiteCtx::new(true);
        ctx.measure("sum", Work::counting(1000, 4), || (0..1000u64).sum::<u64>());
        let r = &ctx.results()[0];
        assert_eq!(r.name, "sum");
        assert_eq!(r.sink, 499_500);
        assert!(r.iters >= 2);
        assert!(r.median_ns > 0.0);
        let ev = r.events_per_s.unwrap();
        assert!((ev - 1000.0 * 1e9 / r.median_ns).abs() < 1e-6);
        assert_eq!(r.item_unit.as_deref(), Some("episodes"));
    }

    #[test]
    fn record_is_single_shot() {
        let mut ctx = SuiteCtx::new(true);
        ctx.record("ingest", Work::events(50_000), 2.0e9, 50_000);
        let r = &ctx.results()[0];
        assert_eq!(r.iters, 1);
        assert_eq!(r.median_ns, 2.0e9);
        assert!((r.events_per_s.unwrap() - 25_000.0).abs() < 1e-6);
        assert!(r.items_per_s.is_none());
    }

    #[test]
    #[should_panic(expected = "duplicate scenario name")]
    fn duplicate_names_panic() {
        let mut ctx = SuiteCtx::new(true);
        ctx.record("x", Work::none(), 1.0, 0);
        ctx.record("x", Work::none(), 1.0, 0);
    }

    #[test]
    fn median_lookup_and_skip_list() {
        let mut ctx = SuiteCtx::new(true);
        ctx.record("a", Work::none(), 5.0, 0);
        ctx.skip("b", "no runtime");
        assert_eq!(ctx.median_ns("a"), Some(5.0));
        assert_eq!(ctx.median_ns("b"), None);
        assert_eq!(ctx.skipped()[0].name, "b");
    }
}
