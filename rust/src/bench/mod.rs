//! The unified perf harness: a scenario registry over every benchmark in
//! the repo, a shared measurement loop, a versioned machine-readable
//! result schema, and baseline regression gating.
//!
//! The paper's headline claim is throughput, and its companion work is an
//! exercise in disciplined measurement across algorithm variants — so the
//! repro treats measurement as a subsystem, not an afterthought. Every
//! bench target registers here as a *suite*:
//!
//! - [`SUITES`] — the registry; `benches/<name>.rs` binaries and the
//!   `epminer bench` subcommand both resolve suites from it.
//! - [`harness::SuiteCtx`] — the shared measurement loop (warmup +
//!   repeats, median/p95 wall time, throughput in events/s and an
//!   item rate) plus single-shot recording for macro phases.
//! - [`schema::SuiteResult`] — the versioned JSON document written to
//!   `BENCH_<suite>.json` (environment capture: commit, host, threads,
//!   build profile, runtime availability).
//! - [`check`] — noise-tolerant comparison against committed baselines
//!   (`benches/baselines/<suite>.json`): fail on regression, report on
//!   improvement. CI's perf-smoke job runs `epminer bench --suite all
//!   --smoke --json-out . --check benches/baselines`.
//!
//! Suites that need the accelerator runtime degrade explicitly: scenarios
//! they cannot run land in the result's `skipped` list (so `--check`
//! knows a missing scenario was declared, not lost).

pub mod check;
pub mod cli;
pub mod harness;
pub mod schema;
pub mod suites;

use crate::error::MineError;

pub use check::{check_suite, CheckConfig, CheckReport, Verdict};
pub use harness::{SuiteCtx, Work};
pub use schema::{EnvInfo, ScenarioResult, SuiteResult, SCHEMA_VERSION};

/// One registered suite: a name (also the `BENCH_<name>.json` identity),
/// a one-line description, and the suite body.
pub struct SuiteDef {
    pub name: &'static str,
    pub description: &'static str,
    pub run: fn(&mut SuiteCtx) -> Result<(), MineError>,
}

/// Every registered suite, in the order `--suite all` runs them.
pub const SUITES: &[SuiteDef] = &[
    SuiteDef {
        name: "fig7_algorithms",
        description: "PTPE vs MapConcatenate vs Hybrid on Sym26 (paper Fig. 7)",
        run: suites::fig7::run,
    },
    SuiteDef {
        name: "fig9_twopass",
        description: "one-pass vs two-pass A2+A1 elimination (paper Fig. 9)",
        run: suites::fig9::run,
    },
    SuiteDef {
        name: "fig10_profiler",
        description: "A1 vs A2 SIMT profiler counters + occupancy (paper Fig. 10)",
        run: suites::fig10::run,
    },
    SuiteDef {
        name: "fig11_gpu_cpu",
        description: "two-pass counting vs the 4-thread CPU baseline (paper Fig. 11)",
        run: suites::fig11::run,
    },
    SuiteDef {
        name: "table1_crossover",
        description: "strategy crossover points by episode size (paper Table 1 / Fig. 8)",
        run: suites::table1::run,
    },
    SuiteDef {
        name: "perf_kernels",
        description: "isolated kernel-execution throughput per counting artifact",
        run: suites::perf_kernels::run,
    },
    SuiteDef {
        name: "ablation_k_slots",
        description: "bounded-K exactness, fold-vs-tree merge, dispatch rules",
        run: suites::ablation::run,
    },
    SuiteDef {
        name: "axis_scaling",
        description: "episode-axis vs stream-axis CPU scaling (sharded backend)",
        run: suites::axis_scaling::run,
    },
    SuiteDef {
        name: "serve_load",
        description: "multi-tenant service throughput under closed-loop load",
        run: suites::serve_load::run,
    },
    SuiteDef {
        name: "ingest_replay",
        description: "durable-log ingest throughput and footer-pruned replay",
        run: suites::ingest_replay::run,
    },
    SuiteDef {
        name: "stream_incremental",
        description: "incremental sliding-window commits vs batch re-mine (stream/)",
        run: suites::stream_incremental::run,
    },
    SuiteDef {
        name: "candidate_scaling",
        description: "arena bucketed generation vs legacy quadratic join (huge alphabets)",
        run: suites::candidate_scaling::run,
    },
    SuiteDef {
        name: "cluster_scatter",
        description: "scatter-gather distributed mining vs single-process (cluster/)",
        run: suites::cluster_scatter::run,
    },
    SuiteDef {
        name: "connectivity",
        description: "surrogate fan-out (serial loop vs batched executor) + significance scoring",
        run: suites::connectivity::run,
    },
];

/// Look a suite up by name.
pub fn find(name: &str) -> Option<&'static SuiteDef> {
    SUITES.iter().find(|s| s.name == name)
}

/// Run one suite to a schema document. A panicking scenario is contained
/// here (mapped to [`MineError::Internal`]) so one broken suite cannot
/// take down a `--suite all` run.
pub fn run_suite(def: &SuiteDef, smoke: bool) -> Result<SuiteResult, MineError> {
    let mut ctx = SuiteCtx::new(smoke);
    let outcome =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (def.run)(&mut ctx)));
    match outcome {
        Ok(Ok(())) => {}
        Ok(Err(e)) => return Err(e),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("non-string panic payload");
            return Err(MineError::internal(format!("suite {} panicked: {msg}", def.name)));
        }
    }
    let (scenarios, skipped) = ctx.into_parts();
    let created_unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    Ok(SuiteResult {
        schema_version: SCHEMA_VERSION,
        suite: def.name.to_string(),
        created_unix,
        env: EnvInfo::capture(smoke),
        scenarios,
        skipped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_complete() {
        let names: Vec<&str> = SUITES.iter().map(|s| s.name).collect();
        for (i, n) in names.iter().enumerate() {
            assert!(!names[i + 1..].contains(n), "duplicate suite {n}");
            assert!(find(n).is_some());
        }
        assert_eq!(SUITES.len(), 14, "every bench target registers exactly once");
        assert!(find("nonexistent").is_none());
    }

    #[test]
    fn panicking_suite_is_contained() {
        let def = SuiteDef {
            name: "boom",
            description: "test",
            run: |_| panic!("scenario exploded"),
        };
        let err = run_suite(&def, true).err().unwrap();
        assert!(err.to_string().contains("scenario exploded"), "{err}");
    }
}
