//! Developing cortical-culture simulator: the stand-in for the Wagenaar
//! et al. recordings (paper datasets 2-1-33, 2-1-34, 2-1-35 — culture 2-1
//! on days-in-vitro 33/34/35).
//!
//! What the paper's experiments actually exercise in those recordings:
//! event volume (hundreds of thousands of spikes), strong temporal
//! clumping into network bursts (which drives A1 list occupancy, A2
//! culling rates, and branch divergence), and day-over-day maturation
//! (burst rate/size and circuit strength grow with age — §6.5 "mining
//! evolving cultures"). The simulator reproduces those three properties:
//!
//! - tonic background firing per channel,
//! - network bursts: Poisson-timed population events in which a random
//!   subset of channels fires densely for ~100 ms,
//! - synfire chains embedded *within* bursts whose participation
//!   probability rises with culture age.

use crate::events::{EventStream, Tick};
use crate::episodes::{Episode, Interval};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct CultureConfig {
    pub n_channels: usize,
    pub duration_ms: Tick,
    /// days in vitro — the maturation knob (paper days: 33, 34, 35)
    pub div_age: u32,
    pub tonic_hz: f64,
    /// network bursts per second
    pub burst_hz: f64,
    /// burst envelope width (ms)
    pub burst_width_ms: Tick,
    /// fraction of channels recruited per burst
    pub burst_participation: f64,
    /// per-channel firing rate inside a burst (Hz)
    pub burst_rate_hz: f64,
    /// embedded synfire chains (channel sequences) + per-link probability
    pub chains: Vec<Vec<i32>>,
    pub chain_prob: f64,
    /// chain trigger rate (Hz) — circuits fire tonically, not only in
    /// bursts, and more reliably than chance coincidences inside bursts
    pub chain_hz: f64,
    pub d_low: Tick,
    pub d_high: Tick,
}

impl CultureConfig {
    /// Configuration for culture 2-1 at the given day in vitro; the knobs
    /// scale with (day - 33) the way burst statistics mature in Wagenaar's
    /// data (denser, more structured bursts late in development).
    pub fn day(div_age: u32) -> CultureConfig {
        let m = (div_age.saturating_sub(33)) as f64; // 0, 1, 2
        CultureConfig {
            n_channels: 64,
            duration_ms: 120_000,
            div_age,
            tonic_hz: 2.0 + 0.5 * m,
            burst_hz: 0.25 + 0.1 * m,
            burst_width_ms: 100,
            burst_participation: 0.4 + 0.1 * m,
            burst_rate_hz: 120.0,
            chains: vec![
                vec![3, 17, 29, 41],
                vec![8, 22, 50],
                vec![12, 33, 47, 55, 60],
            ],
            chain_prob: 0.75 + 0.08 * m,
            chain_hz: 1.0 + 0.4 * m,
            d_low: 2,
            d_high: 10,
        }
    }

    pub fn embedded_episodes(&self) -> Vec<Episode> {
        let iv = Interval::new(self.d_low, self.d_high);
        self.chains
            .iter()
            .map(|c| Episode::new(c.clone(), vec![iv; c.len() - 1]))
            .collect()
    }

    pub fn interval_set(&self) -> Vec<Interval> {
        vec![Interval::new(self.d_low, self.d_high)]
    }
}

/// Generate a culture recording.
pub fn generate(cfg: &CultureConfig, seed: u64) -> EventStream {
    let mut rng = Rng::new(seed ^ (cfg.div_age as u64) << 32);
    let mut pairs: Vec<(i32, Tick)> = vec![];

    // tonic background
    let tonic_per_ms = cfg.tonic_hz / 1000.0;
    for ch in 0..cfg.n_channels as i32 {
        let mut r = rng.fork(ch as u64 + 1);
        let mut t = 0f64;
        loop {
            t += r.exponential(tonic_per_ms);
            if t >= cfg.duration_ms as f64 {
                break;
            }
            pairs.push((ch, t as Tick));
        }
    }

    // network bursts
    let mut rb = rng.fork(7_001);
    let burst_per_ms = cfg.burst_hz / 1000.0;
    let in_burst_per_ms = cfg.burst_rate_hz / 1000.0;
    let mut bt = 0f64;
    loop {
        bt += rb.exponential(burst_per_ms);
        if bt >= cfg.duration_ms as f64 {
            break;
        }
        let burst_start = bt as Tick;
        // recruit channels
        for ch in 0..cfg.n_channels as i32 {
            if !rb.chance(cfg.burst_participation) {
                continue;
            }
            let mut t = burst_start as f64 + rb.f64() * 20.0; // staggered onset
            let burst_end = (burst_start + cfg.burst_width_ms) as f64;
            loop {
                t += rb.exponential(in_burst_per_ms);
                if t >= burst_end || t >= cfg.duration_ms as f64 {
                    break;
                }
                pairs.push((ch, t as Tick));
            }
        }
        // synfire chains also ride on bursts
        for chain in &cfg.chains {
            if !rb.chance(cfg.chain_prob) {
                continue;
            }
            let mut ct = burst_start + rb.range_i32(0, 10);
            pairs.push((chain[0], ct));
            for &next in &chain[1..] {
                if !rb.chance(cfg.chain_prob) {
                    break;
                }
                ct += rb.range_i32(cfg.d_low + 1, cfg.d_high);
                if ct >= cfg.duration_ms {
                    break;
                }
                pairs.push((next, ct));
            }
        }
    }

    // tonic synfire-chain triggers: the maturing circuits fire throughout
    // the recording, which is what makes them stand out against chance
    // in-burst coincidences at mining thresholds
    for (ci, chain) in cfg.chains.iter().enumerate() {
        let mut rc = rng.fork(9_000 + ci as u64);
        let per_ms = cfg.chain_hz / 1000.0;
        let mut t = 0f64;
        loop {
            t += rc.exponential(per_ms);
            if t >= cfg.duration_ms as f64 {
                break;
            }
            let mut ct = t as Tick;
            pairs.push((chain[0], ct));
            for &next in &chain[1..] {
                if !rc.chance(cfg.chain_prob) {
                    break;
                }
                ct += rc.range_i32(cfg.d_low + 1, cfg.d_high);
                if ct >= cfg.duration_ms {
                    break;
                }
                pairs.push((next, ct));
            }
        }
    }

    EventStream::from_pairs(pairs, cfg.n_channels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::serial;

    #[test]
    fn volume_scales_with_age() {
        let d33 = generate(&CultureConfig::day(33), 1);
        let d35 = generate(&CultureConfig::day(35), 1);
        assert!(d33.len() > 10_000, "{}", d33.len());
        assert!(d35.len() > d33.len(), "{} !> {}", d35.len(), d33.len());
    }

    #[test]
    fn bursts_create_clumping() {
        let cfg = CultureConfig::day(34);
        let s = generate(&cfg, 2);
        // clumping: the max events in any 200ms window far exceeds the mean
        let mut max_w = 0usize;
        let mut t0 = s.t_begin();
        while t0 < s.t_end() {
            max_w = max_w.max(s.window(t0, t0 + 200).len());
            t0 += 200;
        }
        let mean_w = s.len() as f64 / (s.span() as f64 / 200.0);
        assert!(max_w as f64 > 4.0 * mean_w, "max {max_w} mean {mean_w}");
    }

    #[test]
    fn chain_counts_grow_with_age() {
        let c33 = CultureConfig::day(33);
        let c35 = CultureConfig::day(35);
        let s33 = generate(&c33, 3);
        let s35 = generate(&c35, 3);
        let ep33 = &c33.embedded_episodes()[0];
        let ep35 = &c35.embedded_episodes()[0];
        let n33 = serial::count_a1(ep33, &s33);
        let n35 = serial::count_a1(ep35, &s35);
        assert!(n35 > n33, "day35 {n35} !> day33 {n33}");
    }

    #[test]
    fn deterministic_per_seed_and_day() {
        let a = generate(&CultureConfig::day(34), 5);
        let b = generate(&CultureConfig::day(34), 5);
        assert_eq!(a, b);
    }
}
