//! Sym26: the paper's synthetic spike-train model (§6.1.1).
//!
//! 26 neurons (event types A..Z), each an independent Poisson process at a
//! 20 Hz basal rate, observed for 60 s at 1 ms ticks. Two causal chains
//! are embedded — a short one and a long one: whenever a chain is
//! triggered (its own Poisson process), each successive neuron fires after
//! a delay drawn uniformly from the chain's `(d_low, d_high]` ms window
//! with high probability, producing the syn-fire episodes the miner must
//! recover against the basal "junk" background.

use crate::events::{EventStream, Tick};
use crate::episodes::{Episode, Interval};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Sym26Config {
    pub n_neurons: usize,
    pub duration_ms: Tick,
    pub basal_hz: f64,
    /// chain trigger rate (Hz) — how often each embedded cascade starts
    pub trigger_hz: f64,
    /// per-link firing probability
    pub link_prob: f64,
    /// inter-event delay window (d_low, d_high] in ms
    pub d_low: Tick,
    pub d_high: Tick,
    /// the two embedded chains (neuron id sequences)
    pub short_chain: Vec<i32>,
    pub long_chain: Vec<i32>,
}

impl Default for Sym26Config {
    fn default() -> Self {
        Sym26Config {
            n_neurons: 26,
            duration_ms: 60_000,
            basal_hz: 20.0,
            trigger_hz: 2.0,
            link_prob: 0.9,
            d_low: 5,
            d_high: 15,
            // neurons 0..3 form the short chain, 10..17 the long one
            short_chain: vec![0, 1, 2],
            long_chain: vec![10, 11, 12, 13, 14, 15, 16, 17],
        }
    }
}

impl Sym26Config {
    /// The episodes the generator embeds, with the matching constraint —
    /// the ground truth the mining examples verify against.
    pub fn embedded_episodes(&self) -> Vec<Episode> {
        let iv = Interval::new(self.d_low, self.d_high);
        vec![
            Episode::new(self.short_chain.clone(), vec![iv; self.short_chain.len() - 1]),
            Episode::new(self.long_chain.clone(), vec![iv; self.long_chain.len() - 1]),
        ]
    }

    /// The constraint set `I` a miner should use on this data.
    pub fn interval_set(&self) -> Vec<Interval> {
        vec![Interval::new(self.d_low, self.d_high)]
    }
}

/// Generate a Sym26 stream.
pub fn generate(cfg: &Sym26Config, seed: u64) -> EventStream {
    let mut rng = Rng::new(seed);
    let mut pairs: Vec<(i32, Tick)> = vec![];

    // basal Poisson background per neuron (the "junk" events)
    let rate_per_ms = cfg.basal_hz / 1000.0;
    for neuron in 0..cfg.n_neurons as i32 {
        let mut r = rng.fork(neuron as u64 + 1);
        let mut t = 0f64;
        loop {
            t += r.exponential(rate_per_ms);
            if t >= cfg.duration_ms as f64 {
                break;
            }
            pairs.push((neuron, t as Tick));
        }
    }

    // embedded cascades
    for (ci, chain) in [&cfg.short_chain, &cfg.long_chain].iter().enumerate() {
        let mut r = rng.fork(1000 + ci as u64);
        let trig_per_ms = cfg.trigger_hz / 1000.0;
        let mut t = 0f64;
        loop {
            t += r.exponential(trig_per_ms);
            if t >= cfg.duration_ms as f64 {
                break;
            }
            let mut ct = t as Tick;
            pairs.push((chain[0], ct));
            for &next in &chain[1..] {
                if !r.chance(cfg.link_prob) {
                    break;
                }
                // delay uniform in (d_low, d_high]
                ct += r.range_i32(cfg.d_low + 1, cfg.d_high);
                if ct >= cfg.duration_ms {
                    break;
                }
                pairs.push((next, ct));
            }
        }
    }

    EventStream::from_pairs(pairs, cfg.n_neurons)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::serial;

    #[test]
    fn volume_matches_paper_scale() {
        let s = generate(&Sym26Config::default(), 1);
        // 26 neurons * 20 Hz * 60 s = 31.2k basal + cascades ≈ 32-45k
        assert!(s.len() > 25_000 && s.len() < 60_000, "len {}", s.len());
        assert!(s.check_sorted());
        assert_eq!(s.n_types, 26);
    }

    #[test]
    fn embedded_chains_are_minable() {
        let cfg = Sym26Config::default();
        let s = generate(&cfg, 2);
        // the short chain should occur roughly trigger_hz * 60s * p^2 times
        let ep = &cfg.embedded_episodes()[0];
        let count = serial::count_a1(ep, &s);
        let expect = cfg.trigger_hz * 60.0 * cfg.link_prob * cfg.link_prob;
        assert!(
            (count as f64) > 0.6 * expect,
            "count {count} vs expected ~{expect}"
        );
    }

    #[test]
    fn non_embedded_chains_are_rare() {
        let cfg = Sym26Config::default();
        let s = generate(&cfg, 3);
        // a random 3-chain over non-chain neurons at the same constraint
        let iv = Interval::new(cfg.d_low, cfg.d_high);
        let bogus = Episode::new(vec![20, 21, 22], vec![iv, iv]);
        let planted = serial::count_a1(&cfg.embedded_episodes()[0], &s);
        let noise = serial::count_a1(&bogus, &s);
        assert!(planted > 2 * noise, "planted {planted} noise {noise}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&Sym26Config::default(), 9);
        let b = generate(&Sym26Config::default(), 9);
        assert_eq!(a, b);
        let c = generate(&Sym26Config::default(), 10);
        assert_ne!(a, c);
    }
}
