//! Dataset substrate: generators standing in for the paper's data sources.
//!
//! - `sym26`: the paper's mathematical model (§6.1.1) — 26 neurons firing
//!   as inhomogeneous Poisson processes at a 20 Hz basal rate with two
//!   embedded causal chains (one short, one long), 60 s ≈ 50 k events.
//! - `culture`: a simulator of developing cortical cultures standing in
//!   for the Wagenaar et al. recordings (datasets 2-1-33/34/35): network
//!   bursts whose rate and size grow with culture age, plus synfire
//!   chains that strengthen day over day. See DESIGN.md §5 for why this
//!   substitution preserves what the experiments exercise.
//! - `huge`: a 512-type Zipf-skewed background with embedded causal
//!   chains — the huge-alphabet workload the arena-backed candidate
//!   engine and frequency-sorted alphabet remap are built for.
//!
//! The [`REGISTRY`] is the single source of truth for dataset names and
//! their default physiological delay bands — the CLI, the `Session`
//! builder and the examples all resolve defaults through it instead of
//! string-matching dataset names locally.
//!
//! Beyond generator names, [`resolve`] accepts two path-based schemes so
//! every mining surface (CLI subcommands, `Session::dataset`, the serve
//! load generator) can run off disk:
//!
//! - `file:<path>` — a binary stream written by `events::io` (`epminer
//!   gen --format bin`),
//! - `log:<dir>` — a sealed [`crate::ingest::SpikeLog`] recording.

pub mod culture;
pub mod huge;
pub mod sym26;

use std::path::Path;

use crate::episodes::Interval;
use crate::error::MineError;
use crate::events::{io, EventStream, Tick};

/// A registered dataset: its canonical name and mining defaults.
#[derive(Clone, Copy, Debug)]
pub struct DatasetInfo {
    pub name: &'static str,
    /// dataset-appropriate default inter-event constraint `(t_low, t_high]`
    /// in ticks — the physiological delay band the generator embeds its
    /// chains with (kept in sync with `Sym26Config` / `CultureConfig`).
    pub default_interval: (Tick, Tick),
    pub description: &'static str,
}

impl DatasetInfo {
    pub fn default_interval(&self) -> Interval {
        Interval::new(self.default_interval.0, self.default_interval.1)
    }
}

/// Every dataset the CLI, examples and benches can name.
pub const REGISTRY: &[DatasetInfo] = &[
    DatasetInfo {
        name: "sym26",
        default_interval: (5, 15),
        description: "paper §6.1.1 synthetic model: 26 Poisson neurons + 2 causal chains",
    },
    DatasetInfo {
        name: "2-1-33",
        default_interval: (2, 10),
        description: "developing-culture analog, day-in-vitro 33",
    },
    DatasetInfo {
        name: "2-1-34",
        default_interval: (2, 10),
        description: "developing-culture analog, day-in-vitro 34",
    },
    DatasetInfo {
        name: "2-1-35",
        default_interval: (2, 10),
        description: "developing-culture analog, day-in-vitro 35",
    },
    DatasetInfo {
        name: "huge-alphabet",
        default_interval: (2, 10),
        description: "512-type Zipf-skewed background + embedded chains (arena/remap workload)",
    },
];

/// Registry entry for a dataset name.
pub fn info(name: &str) -> Option<&'static DatasetInfo> {
    REGISTRY.iter().find(|d| d.name == name)
}

/// All registered dataset names, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|d| d.name).collect()
}

/// The `file:<path>` scheme prefix: a binary stream on disk.
pub const FILE_SCHEME: &str = "file:";
/// The `log:<dir>` scheme prefix: a sealed ingest log.
pub const LOG_SCHEME: &str = "log:";

/// Is this a `file:`/`log:` spec rather than a registry name?
pub fn is_path_scheme(spec: &str) -> bool {
    spec.starts_with(FILE_SCHEME) || spec.starts_with(LOG_SCHEME)
}

/// Everything a dataset argument accepts, for error listings: the
/// registry names plus the path-based scheme shapes.
pub fn names_and_schemes() -> Vec<&'static str> {
    let mut v = names();
    v.push("file:<path.bin>");
    v.push("log:<segment-dir>");
    v
}

/// The dataset's default inter-event constraint, if the name is known.
/// Path-based specs (`file:`/`log:`) carry no registry metadata, so they
/// fall back to the generic physiological band `(2, 10]` rather than
/// refusing to mine — `--low`/`--high` (or `.intervals(..)`) override it
/// as usual.
pub fn default_interval(name: &str) -> Option<Interval> {
    if is_path_scheme(name) {
        return Some(Interval::new(2, 10));
    }
    info(name).map(|d| d.default_interval())
}

/// Named dataset selector used by the CLI, examples and benches.
pub fn by_name(name: &str, seed: u64) -> Option<(EventStream, &'static str)> {
    match name {
        "sym26" => Some((sym26::generate(&sym26::Sym26Config::default(), seed), "sym26")),
        "2-1-33" => Some((culture::generate(&culture::CultureConfig::day(33), seed), "2-1-33")),
        "2-1-34" => Some((culture::generate(&culture::CultureConfig::day(34), seed), "2-1-34")),
        "2-1-35" => Some((culture::generate(&culture::CultureConfig::day(35), seed), "2-1-35")),
        "huge-alphabet" => {
            Some((huge::generate(&huge::HugeConfig::default(), seed), "huge-alphabet"))
        }
        _ => None,
    }
}

/// The causal structure a generator embeds: the chains the connectivity
/// pipeline should recover. Typed so precision/recall in `analysis/` is
/// registry-driven instead of hardcoded to one dataset; recordings
/// (`file:`/`log:` specs) have no generator and so no ground truth.
#[derive(Clone, Debug, PartialEq)]
pub struct GroundTruth {
    /// registry name of the generator
    pub dataset: &'static str,
    /// the embedded episodes, with the generator's delay band
    pub chains: Vec<crate::episodes::Episode>,
}

impl GroundTruth {
    /// The true directed edge set: every adjacent pair of every chain,
    /// deduplicated, in first-seen order.
    pub fn edges(&self) -> Vec<(crate::events::EventType, crate::events::EventType)> {
        let mut out = vec![];
        for ch in &self.chains {
            for w in ch.types.windows(2) {
                if !out.contains(&(w[0], w[1])) {
                    out.push((w[0], w[1]));
                }
            }
        }
        out
    }
}

/// Ground truth for a registered generator name, if it embeds any.
pub fn ground_truth(name: &str) -> Option<GroundTruth> {
    let chains = match name {
        "sym26" => sym26::Sym26Config::default().embedded_episodes(),
        "2-1-33" => culture::CultureConfig::day(33).embedded_episodes(),
        "2-1-34" => culture::CultureConfig::day(34).embedded_episodes(),
        "2-1-35" => culture::CultureConfig::day(35).embedded_episodes(),
        "huge-alphabet" => huge::HugeConfig::default().embedded_episodes(),
        _ => return None,
    };
    let dataset = info(name)?.name;
    Some(GroundTruth { dataset, chains })
}

/// Resolve any dataset spec — a registry name, `file:<path>` (the
/// `events::io` binary format), or `log:<dir>` (a sealed ingest log) —
/// into a stream plus its display tag. The single entry point behind
/// `Session::dataset`, the CLI subcommands, and the serve load
/// generator, so every mining surface can run off disk. `seed` only
/// matters for generator names; recordings are what they are.
pub fn resolve(spec: &str, seed: u64) -> Result<(EventStream, String), MineError> {
    if let Some(path) = spec.strip_prefix(FILE_SCHEME) {
        let stream = io::load_binary(Path::new(path))?;
        Ok((stream, spec.to_string()))
    } else if let Some(dir) = spec.strip_prefix(LOG_SCHEME) {
        let log = crate::ingest::SpikeLog::open(Path::new(dir))?;
        let (stream, _) = log.read_all()?;
        Ok((stream, spec.to_string()))
    } else {
        match by_name(spec, seed) {
            Some((stream, tag)) => Ok((stream, tag.to_string())),
            None => Err(MineError::UnknownDataset {
                given: spec.to_string(),
                valid: names_and_schemes(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_generatable_dataset() {
        for d in REGISTRY {
            assert!(by_name(d.name, 1).is_some(), "{} not generatable", d.name);
        }
    }

    #[test]
    fn path_schemes_fall_back_and_are_listed() {
        // file-backed streams carry no registry metadata: a sensible
        // default band, not a refusal (or worse, a panic)
        assert_eq!(default_interval("file:/tmp/x.bin"), Some(Interval::new(2, 10)));
        assert_eq!(default_interval("log:/tmp/recording"), Some(Interval::new(2, 10)));
        assert!(is_path_scheme("log:anywhere") && !is_path_scheme("sym26"));
        match resolve("warp-field", 1) {
            Err(MineError::UnknownDataset { given, valid }) => {
                assert_eq!(given, "warp-field");
                assert!(valid.contains(&"sym26"));
                assert!(valid.contains(&"file:<path.bin>"));
                assert!(valid.contains(&"log:<segment-dir>"));
            }
            _ => panic!("unknown spec must list names and schemes"),
        }
    }

    #[test]
    fn every_generator_exposes_ground_truth() {
        for d in REGISTRY {
            let gt = ground_truth(d.name).expect("registered generators embed chains");
            assert_eq!(gt.dataset, d.name);
            assert!(!gt.chains.is_empty());
            assert!(!gt.edges().is_empty());
            // chains carry the generator's own delay band
            let band = d.default_interval();
            for ch in &gt.chains {
                assert!(ch.intervals.iter().all(|iv| *iv == band));
            }
        }
        assert_eq!(ground_truth("file:/tmp/x.bin"), None);
        assert_eq!(ground_truth("nope"), None);
    }

    #[test]
    fn ground_truth_edges_dedup_adjacent_pairs() {
        let gt = ground_truth("sym26").unwrap();
        let edges = gt.edges();
        let mut uniq = edges.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), edges.len());
    }

    #[test]
    fn default_intervals_match_generator_configs() {
        let s = sym26::Sym26Config::default();
        assert_eq!(default_interval("sym26"), Some(Interval::new(s.d_low, s.d_high)));
        let c = culture::CultureConfig::day(35);
        assert_eq!(default_interval("2-1-35"), Some(Interval::new(c.d_low, c.d_high)));
        let h = huge::HugeConfig::default();
        assert_eq!(default_interval("huge-alphabet"), Some(Interval::new(h.d_low, h.d_high)));
        assert_eq!(default_interval("unknown"), None);
    }
}
