//! Dataset substrate: generators standing in for the paper's data sources.
//!
//! - `sym26`: the paper's mathematical model (§6.1.1) — 26 neurons firing
//!   as inhomogeneous Poisson processes at a 20 Hz basal rate with two
//!   embedded causal chains (one short, one long), 60 s ≈ 50 k events.
//! - `culture`: a simulator of developing cortical cultures standing in
//!   for the Wagenaar et al. recordings (datasets 2-1-33/34/35): network
//!   bursts whose rate and size grow with culture age, plus synfire
//!   chains that strengthen day over day. See DESIGN.md §5 for why this
//!   substitution preserves what the experiments exercise.
//!
//! The [`REGISTRY`] is the single source of truth for dataset names and
//! their default physiological delay bands — the CLI, the `Session`
//! builder and the examples all resolve defaults through it instead of
//! string-matching dataset names locally.

pub mod culture;
pub mod sym26;

use crate::episodes::Interval;
use crate::events::{EventStream, Tick};

/// A registered dataset: its canonical name and mining defaults.
#[derive(Clone, Copy, Debug)]
pub struct DatasetInfo {
    pub name: &'static str,
    /// dataset-appropriate default inter-event constraint `(t_low, t_high]`
    /// in ticks — the physiological delay band the generator embeds its
    /// chains with (kept in sync with `Sym26Config` / `CultureConfig`).
    pub default_interval: (Tick, Tick),
    pub description: &'static str,
}

impl DatasetInfo {
    pub fn default_interval(&self) -> Interval {
        Interval::new(self.default_interval.0, self.default_interval.1)
    }
}

/// Every dataset the CLI, examples and benches can name.
pub const REGISTRY: &[DatasetInfo] = &[
    DatasetInfo {
        name: "sym26",
        default_interval: (5, 15),
        description: "paper §6.1.1 synthetic model: 26 Poisson neurons + 2 causal chains",
    },
    DatasetInfo {
        name: "2-1-33",
        default_interval: (2, 10),
        description: "developing-culture analog, day-in-vitro 33",
    },
    DatasetInfo {
        name: "2-1-34",
        default_interval: (2, 10),
        description: "developing-culture analog, day-in-vitro 34",
    },
    DatasetInfo {
        name: "2-1-35",
        default_interval: (2, 10),
        description: "developing-culture analog, day-in-vitro 35",
    },
];

/// Registry entry for a dataset name.
pub fn info(name: &str) -> Option<&'static DatasetInfo> {
    REGISTRY.iter().find(|d| d.name == name)
}

/// All registered dataset names, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|d| d.name).collect()
}

/// The dataset's default inter-event constraint, if the name is known.
pub fn default_interval(name: &str) -> Option<Interval> {
    info(name).map(|d| d.default_interval())
}

/// Named dataset selector used by the CLI, examples and benches.
pub fn by_name(name: &str, seed: u64) -> Option<(EventStream, &'static str)> {
    match name {
        "sym26" => Some((sym26::generate(&sym26::Sym26Config::default(), seed), "sym26")),
        "2-1-33" => Some((culture::generate(&culture::CultureConfig::day(33), seed), "2-1-33")),
        "2-1-34" => Some((culture::generate(&culture::CultureConfig::day(34), seed), "2-1-34")),
        "2-1-35" => Some((culture::generate(&culture::CultureConfig::day(35), seed), "2-1-35")),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_generatable_dataset() {
        for d in REGISTRY {
            assert!(by_name(d.name, 1).is_some(), "{} not generatable", d.name);
        }
    }

    #[test]
    fn default_intervals_match_generator_configs() {
        let s = sym26::Sym26Config::default();
        assert_eq!(default_interval("sym26"), Some(Interval::new(s.d_low, s.d_high)));
        let c = culture::CultureConfig::day(35);
        assert_eq!(default_interval("2-1-35"), Some(Interval::new(c.d_low, c.d_high)));
        assert_eq!(default_interval("unknown"), None);
    }
}
