//! Dataset substrate: generators standing in for the paper's data sources.
//!
//! - `sym26`: the paper's mathematical model (§6.1.1) — 26 neurons firing
//!   as inhomogeneous Poisson processes at a 20 Hz basal rate with two
//!   embedded causal chains (one short, one long), 60 s ≈ 50 k events.
//! - `culture`: a simulator of developing cortical cultures standing in
//!   for the Wagenaar et al. recordings (datasets 2-1-33/34/35): network
//!   bursts whose rate and size grow with culture age, plus synfire
//!   chains that strengthen day over day. See DESIGN.md §5 for why this
//!   substitution preserves what the experiments exercise.

pub mod sym26;
pub mod culture;

use crate::events::EventStream;

/// Named dataset selector used by the CLI, examples and benches.
pub fn by_name(name: &str, seed: u64) -> Option<(EventStream, &'static str)> {
    match name {
        "sym26" => Some((sym26::generate(&sym26::Sym26Config::default(), seed), "sym26")),
        "2-1-33" => Some((culture::generate(&culture::CultureConfig::day(33), seed), "2-1-33")),
        "2-1-34" => Some((culture::generate(&culture::CultureConfig::day(34), seed), "2-1-34")),
        "2-1-35" => Some((culture::generate(&culture::CultureConfig::day(35), seed), "2-1-35")),
        _ => None,
    }
}
