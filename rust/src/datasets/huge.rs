//! Huge-alphabet synthetic: the arena/remap workload.
//!
//! The paper's arrays have 26–64 electrodes, but the arena-backed
//! candidate engine exists for the 10³–10⁴-type regime where level-2 is
//! millions of candidates. This generator stands in for such a recording:
//! `n_types` (default 512) event types firing as a long-tailed
//! background — squaring a uniform draw gives a Zipf-ish rate profile, so
//! a handful of types carry most of the mass while the tail is sparse
//! (exactly the shape the frequency-sorted [`AlphabetRemap`] exploits) —
//! plus a few embedded causal chains over mid-frequency types that a
//! miner with the right theta recovers as frequent episodes.
//!
//! [`AlphabetRemap`]: crate::episodes::arena::AlphabetRemap

use crate::episodes::{Episode, Interval};
use crate::events::{EventStream, Tick};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct HugeConfig {
    pub n_types: usize,
    /// background events to generate (cascade events ride on top)
    pub events: usize,
    /// number of embedded causal chains
    pub chains: usize,
    /// nodes per embedded chain
    pub chain_len: usize,
    /// one cascade is injected every this many background events,
    /// round-robin across the chains
    pub inject_every: usize,
    /// inter-event delay window (d_low, d_high] in ticks
    pub d_low: Tick,
    pub d_high: Tick,
}

impl Default for HugeConfig {
    fn default() -> Self {
        HugeConfig {
            n_types: 512,
            events: 200_000,
            chains: 4,
            chain_len: 4,
            inject_every: 50,
            d_low: 2,
            d_high: 10,
        }
    }
}

impl HugeConfig {
    /// The CI-sized profile: same alphabet and chain structure, a tenth
    /// of the events — small enough for the perf-smoke job, same code
    /// paths as the full workload.
    pub fn smoke() -> Self {
        HugeConfig { events: 20_000, ..HugeConfig::default() }
    }

    /// The embedded chains as node sequences: disjoint runs of
    /// mid-frequency types (ids from `n_types / 8` up), so the planted
    /// structure is neither drowned by the densest background types nor
    /// starved in the sparse tail.
    pub fn embedded_chains(&self) -> Vec<Vec<i32>> {
        let base = (self.n_types / 8) as i32;
        (0..self.chains)
            .map(|c| {
                let start = base + (c * self.chain_len) as i32;
                (start..start + self.chain_len as i32).collect()
            })
            .collect()
    }

    /// The episodes the generator embeds, with the matching constraint.
    pub fn embedded_episodes(&self) -> Vec<Episode> {
        let iv = Interval::new(self.d_low, self.d_high);
        self.embedded_chains()
            .into_iter()
            .map(|chain| {
                let links = chain.len() - 1;
                Episode::new(chain, vec![iv; links])
            })
            .collect()
    }

    /// The constraint set `I` a miner should use on this data.
    pub fn interval_set(&self) -> Vec<Interval> {
        vec![Interval::new(self.d_low, self.d_high)]
    }
}

/// Generate a huge-alphabet stream.
pub fn generate(cfg: &HugeConfig, seed: u64) -> EventStream {
    assert!(
        cfg.n_types / 8 + cfg.chains * cfg.chain_len <= cfg.n_types,
        "embedded chains must fit inside the alphabet"
    );
    let mut rng = Rng::new(seed);
    let chains = cfg.embedded_chains();
    let mut pairs: Vec<(i32, Tick)> = Vec::with_capacity(cfg.events);
    let mut t: Tick = 0;
    let mut next_chain = 0usize;
    for i in 0..cfg.events {
        t += rng.range_i32(1, 3);
        // squaring the uniform skews mass toward low ids: the long-tailed
        // per-type rate profile of a real dense array
        let u = rng.f64();
        let ty = ((u * u * cfg.n_types as f64) as i32).min(cfg.n_types as i32 - 1);
        pairs.push((ty, t));
        if cfg.chains > 0 && cfg.inject_every > 0 && i % cfg.inject_every == 0 {
            let chain = &chains[next_chain % chains.len()];
            next_chain += 1;
            let mut ct = t;
            pairs.push((chain[0], ct));
            for &node in &chain[1..] {
                // delay uniform in (d_low, d_high]
                ct += rng.range_i32(cfg.d_low + 1, cfg.d_high);
                pairs.push((node, ct));
            }
        }
    }
    EventStream::from_pairs(pairs, cfg.n_types)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::serial;

    #[test]
    fn volume_and_alphabet() {
        let cfg = HugeConfig::default();
        let s = generate(&cfg, 1);
        assert_eq!(s.n_types, 512);
        assert!(s.check_sorted());
        // background + ~events/inject_every cascades of chain_len nodes
        let planted = cfg.events / cfg.inject_every * cfg.chain_len;
        assert!(s.len() >= cfg.events && s.len() <= cfg.events + planted + cfg.chain_len);
        let smoke = generate(&HugeConfig::smoke(), 1);
        assert!(smoke.len() < s.len() / 5, "smoke profile must be CI-sized");
    }

    #[test]
    fn background_is_long_tailed() {
        let s = generate(&HugeConfig::default(), 2);
        let counts = s.type_counts();
        // the u² draw concentrates mass at low ids: the densest type must
        // dwarf a deep-tail type (this is what the alphabet remap sorts by)
        assert!(
            counts[0] > 5 * counts[400].max(1),
            "type 0 fired {} vs type 400 {}",
            counts[0],
            counts[400]
        );
    }

    #[test]
    fn embedded_chains_are_minable() {
        let cfg = HugeConfig::default();
        let s = generate(&cfg, 3);
        let per_chain = cfg.events / cfg.inject_every / cfg.chains;
        for ep in cfg.embedded_episodes() {
            let count = serial::count_a1(&ep, &s);
            assert!(
                count as usize > per_chain / 2,
                "{} occurred {count}, planted ~{per_chain}",
                ep.display()
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&HugeConfig::default(), 9);
        let b = generate(&HugeConfig::default(), 9);
        assert_eq!(a, b);
        let c = generate(&HugeConfig::default(), 10);
        assert_ne!(a, c);
    }
}
