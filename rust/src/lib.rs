//! episodes-gpu: a three-layer (Rust + JAX + Pallas, AOT via PJRT)
//! reproduction of *"Towards Chip-on-Chip Neuroscience: Fast Mining of
//! Frequent Episodes Using Graphics Processors"* (Cao et al., 2009).
//!
//! # Entry points
//!
//! The library's front door is the [`Session`] facade over the pluggable
//! [`CountBackend`] counting engines — the abstraction that carries the
//! paper's CPU/GPU division of labor (candidate generation on the host,
//! counting on whatever substrate the backend wraps):
//!
//! ```no_run
//! use episodes_gpu::Session;
//!
//! let mut session = Session::builder()
//!     .dataset("sym26")      // or .stream(my_event_stream)
//!     .theta(60)             // support threshold
//!     .max_level(8)
//!     .build()?;             // accelerated Hybrid if PJRT opens, CPU otherwise
//! let result = session.mine()?;
//! println!("{} frequent episodes ({})", result.frequent.len(), session.backend_name());
//! # Ok::<(), episodes_gpu::MineError>(())
//! ```
//!
//! Engines compose rather than enumerate: two-pass A2+A1 elimination is
//! [`backend::two_pass::TwoPassBackend`] wrapping any exact engine, and
//! Hybrid dispatch is [`backend::accel::HybridBackend`] wrapping any two
//! (e.g. `HybridBackend::cpu_sharded` pairing episode-axis workers with
//! stream-axis time shards, no accelerator involved).
//! Custom engines (multi-GPU, sharded pools, mocks for tests) implement
//! [`CountBackend`] and plug into [`SessionBuilder::backend`] — no PJRT
//! runtime required. Every public library function returns
//! [`MineError`], a typed, actionable error enum.
//!
//! # Layers
//!
//! - [`events`] / [`datasets`] — spike-train data model, generators, and
//!   the dataset registry (names + default delay bands).
//! - [`episodes`] — serial episodes with inter-event constraints,
//!   level-wise candidate generation, and the arena-backed candidate
//!   engine: a flat SoA episode lattice ([`episodes::arena::EpisodeArena`],
//!   14 B/candidate with parent + suffix links), bucketed O(F + output)
//!   suffix-prefix joins, and the frequency-sorted alphabet remap that
//!   keeps huge-alphabet pruning cache-friendly (every report is
//!   inverted back to original type ids).
//! - [`mining`] — CPU reference algorithms (Algorithm 1, Algorithm 3, the
//!   paper's multithreaded baseline, profiler telemetry).
//! - [`gpu_model`] — analytical GTX280 model (occupancy, crossover fits,
//!   Fig. 10 counters).
//! - [`runtime`] — PJRT loading/execution of the AOT-compiled Pallas
//!   counting kernels (`artifacts/*.hlo.txt`). Absence is a runtime
//!   condition ([`MineError::RuntimeUnavailable`]), never a build break.
//! - [`backend`] — the counting engines: CPU serial/parallel
//!   (episode-axis), stream-sharded CPU (stream-axis time shards, strategy
//!   `cpu-sharded`), PTPE, MapConcatenate, Hybrid composition, two-pass
//!   elimination.
//! - [`session`] — the [`Session`] facade, its builder, and the
//!   block-streamed level-wise mining driver (generate-count-prune in
//!   bounded candidate blocks, [`SessionBuilder::candidate_block`]).
//! - [`ingest`] — the durable spike log: checksummed columnar segments
//!   sealed by an [`ingest::Ingestor`] (fed directly from the streaming
//!   partition producer), a crash-recovering [`ingest::SpikeLog`]
//!   manifest (read-only open; torn tails quarantined at writer
//!   attach), and
//!   footer-pruned time-range / electrode-projection queries that replay
//!   recorded history into `Session` or the serving layer (`epminer
//!   ingest`, `epminer log-mine`, the `file:`/`log:` dataset schemes).
//! - [`stream`] — incremental sliding-window mining: an
//!   [`stream::IncrementalMiner`] that carries per-partition automaton
//!   state across arriving segments (recomputing only halo-dirty
//!   partitions, re-generating candidates only when an episode crosses
//!   theta), commit diffs of the frequent set, and a
//!   [`stream::LogWatcher`] that tails a live [`ingest::SpikeLog`]
//!   (`epminer watch`). Every commit is provably identical to a cold
//!   batch mine of the current window.
//! - [`serve`] — the multi-tenant mining service: one typed
//!   [`serve::Request`] surface (plain mines, live subscriptions, and
//!   connectivity inference) over a worker pool with request coalescing,
//!   a sharded LRU result cache keyed by exact stream fingerprint,
//!   bounded admission ([`MineError::Busy`]), service metrics,
//!   live-update subscriptions pushing frequent-set diffs to waiters,
//!   and a closed-loop load generator (`epminer serve-bench`,
//!   `benches/serve_load.rs`).
//! - [`cluster`] — scatter-gather distributed mining over log segments:
//!   a coordinator ([`cluster::ScatterMiner`], `epminer scatter`) that
//!   runs the exact level-wise driver locally and distributes only the
//!   counting across [`cluster::ClusterNode`] workers (`epminer node`)
//!   over a length-prefixed JSON wire protocol, merging with the
//!   MapConcatenate fold + flagged-miss recount so results are
//!   byte-identical to a single-process mine — with deadlines, retry +
//!   re-plan onto survivors, hedged duplicates, tenant-aware admission,
//!   and per-node latency metrics. [`cluster::LocalCluster`] runs the
//!   whole tier in-process for tests and benches.
//! - [`obs`] — unified observability: per-query [`obs::Trace`] spans
//!   (minted at serve admission or the CLI, propagated across the
//!   cluster wire as an optional envelope field, merged into one span
//!   tree covering remote counting work), the single [`obs::Registry`]
//!   of typed counters/gauges/histograms every tier publishes into
//!   (Prometheus text + JSON via `epminer stats` and the cluster `Stats`
//!   RPC), and the [`obs::MineProfile`] mining-phase profiler
//!   (`SessionBuilder::profile` / `--profile`). Disabled tracing is
//!   zero-allocation — the default hot path is unaffected.
//! - [`analysis`] — the statistically-grounded connectivity pipeline on
//!   top of mining: seeded spike-time jitter surrogates
//!   ([`analysis::surrogate`]), the batched multi-mine executor fanning
//!   `1 + n` streams across thread-local engines
//!   ([`analysis::batch::mine_batch`]), per-episode empirical p-values
//!   and excess counts against the surrogate null
//!   ([`analysis::significance`]), and significance-ranked circuit
//!   reconstruction scored against generator ground truth
//!   ([`analysis::connectivity`], `epminer connectivity`, the serve
//!   layer's connectivity query).
//! - [`coordinator`] — strategy name menu, run metrics, the streaming
//!   partition producer, and the level/mine report types (the pre-0.2
//!   `Coordinator` shims were removed in 0.3).
//! - [`bench`] — the unified perf harness: a suite registry every bench
//!   target registers into, a shared measurement loop, the versioned
//!   `BENCH_<suite>.json` result schema with environment capture, and
//!   noise-tolerant baseline checking (`epminer bench --suite all --smoke
//!   --check benches/baselines` is CI's perf regression gate).
//! - [`util`] — RNG, stats, CLI, JSON, bench and property-test harnesses.

pub mod analysis;
pub mod backend;
pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod datasets;
pub mod episodes;
pub mod error;
pub mod events;
pub mod gpu_model;
pub mod ingest;
pub mod mining;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod stream;
pub mod util;

pub use backend::{CountBackend, CountReport};
pub use coordinator::Strategy;
pub use error::MineError;
pub use serve::{MineService, ServiceConfig};
pub use session::{Session, SessionBuilder};
