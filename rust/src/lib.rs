//! episodes-gpu: a three-layer (Rust + JAX + Pallas, AOT via PJRT)
//! reproduction of *"Towards Chip-on-Chip Neuroscience: Fast Mining of
//! Frequent Episodes Using Graphics Processors"* (Cao et al., 2009).
//!
//! - [`events`] / [`datasets`] — spike-train data model and generators.
//! - [`episodes`] — serial episodes with inter-event constraints and
//!   level-wise candidate generation.
//! - [`mining`] — CPU reference algorithms (Algorithm 1, Algorithm 3, the
//!   paper's multithreaded baseline, profiler telemetry).
//! - [`gpu_model`] — analytical GTX280 model (occupancy, crossover fits,
//!   Fig. 10 counters).
//! - [`runtime`] — PJRT loading/execution of the AOT-compiled Pallas
//!   counting kernels (`artifacts/*.hlo.txt`).
//! - [`coordinator`] — the paper's system contribution: PTPE /
//!   MapConcatenate / Hybrid dispatch, the two-pass A2+A1 elimination
//!   pipeline, the level-wise miner, and the streaming ("chip-on-chip")
//!   driver.
//! - [`util`] — RNG, stats, CLI, bench and property-test harnesses.

pub mod analysis;
pub mod coordinator;
pub mod datasets;
pub mod episodes;
pub mod events;
pub mod gpu_model;
pub mod mining;
pub mod runtime;
pub mod util;
