//! Event-stream substrate: the spike-train data model (paper Def. 2.1).
//!
//! An event stream is a time-ordered sequence of (event type, tick) pairs.
//! Event types are small non-negative integers (one per neuron/channel);
//! times are integer ticks (1 tick = 1 ms in the datasets). Structure-of-
//! arrays layout so chunks can be handed to the PJRT executables without
//! reshuffling.

pub mod io;

/// Event type id. Real types are >= 0; negative values are kernel padding
/// sentinels (see `runtime::manifest`).
pub type EventType = i32;
/// Time in integer ticks (ms).
pub type Tick = i32;

/// A time-sorted event stream (paper Definition 2.1).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EventStream {
    pub types: Vec<EventType>,
    pub times: Vec<Tick>,
    /// Size of the event-type alphabet (neuron count).
    pub n_types: usize,
}

impl EventStream {
    pub fn new(n_types: usize) -> EventStream {
        EventStream { types: vec![], times: vec![], n_types }
    }

    /// Build from pairs, sorting by time (stable: simultaneous events keep
    /// insertion order, which the counting semantics observe).
    pub fn from_pairs(mut pairs: Vec<(EventType, Tick)>, n_types: usize) -> EventStream {
        pairs.sort_by_key(|&(_, t)| t);
        let mut s = EventStream::new(n_types);
        for (e, t) in pairs {
            s.types.push(e);
            s.times.push(t);
        }
        debug_assert!(s.check_sorted());
        s
    }

    pub fn len(&self) -> usize {
        self.types.len()
    }

    pub fn is_empty(&self) -> bool {
        self.types.is_empty()
    }

    pub fn push(&mut self, e: EventType, t: Tick) {
        debug_assert!(self.times.last().map(|&lt| lt <= t).unwrap_or(true));
        self.types.push(e);
        self.times.push(t);
    }

    /// First event time, or 0 for an empty stream.
    pub fn t_begin(&self) -> Tick {
        self.times.first().copied().unwrap_or(0)
    }

    /// Last event time, or 0 for an empty stream.
    pub fn t_end(&self) -> Tick {
        self.times.last().copied().unwrap_or(0)
    }

    /// Duration in ticks.
    pub fn span(&self) -> Tick {
        self.t_end() - self.t_begin()
    }

    pub fn check_sorted(&self) -> bool {
        self.times.windows(2).all(|w| w[0] <= w[1])
            && self.types.iter().all(|&e| e >= 0 && (e as usize) < self.n_types)
    }

    pub fn iter(&self) -> impl Iterator<Item = (EventType, Tick)> + '_ {
        self.types.iter().copied().zip(self.times.iter().copied())
    }

    /// Events with time in `(t_from, t_to]` as a sub-stream (index range is
    /// resolved by binary search — the stream is sorted).
    pub fn window(&self, t_from: Tick, t_to: Tick) -> EventStream {
        let lo = self.times.partition_point(|&t| t <= t_from);
        let hi = self.times.partition_point(|&t| t <= t_to);
        EventStream {
            types: self.types[lo..hi].to_vec(),
            times: self.times[lo..hi].to_vec(),
            n_types: self.n_types,
        }
    }

    /// Index of the first event with time > t.
    pub fn first_after(&self, t: Tick) -> usize {
        self.times.partition_point(|&x| x <= t)
    }

    /// Split into fixed-duration partitions (the chip-on-chip streaming
    /// unit): each partition covers `(start + i*width, start + (i+1)*width]`.
    pub fn partitions(&self, width: Tick) -> Vec<EventStream> {
        self.partitions_with_starts(width).into_iter().map(|(_, p)| p).collect()
    }

    /// [`EventStream::partitions`], with each partition tagged with its
    /// window start: partition `i` covers `(start, start + width]`. The
    /// single source of partition boundaries — the streaming producer uses
    /// the starts to stamp each partition's actually-covered recording
    /// span (the tail usually ends before a full width).
    pub fn partitions_with_starts(&self, width: Tick) -> Vec<(Tick, EventStream)> {
        assert!(width > 0);
        if self.is_empty() {
            return vec![];
        }
        let mut out = vec![];
        let mut t0 = self.t_begin() - 1;
        while t0 < self.t_end() {
            out.push((t0, self.window(t0, t0 + width)));
            t0 += width;
        }
        out
    }

    /// Per-type event counts (the level-1 mining pass).
    pub fn type_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.n_types];
        for &e in &self.types {
            counts[e as usize] += 1;
        }
        counts
    }

    /// Mean event rate in events per 1000 ticks (Hz at ms ticks).
    pub fn mean_rate_hz(&self) -> f64 {
        if self.span() == 0 {
            return 0.0;
        }
        self.len() as f64 / (self.span() as f64 / 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EventStream {
        EventStream::from_pairs(
            vec![(0, 5), (1, 2), (2, 9), (0, 2), (1, 7)],
            3,
        )
    }

    #[test]
    fn from_pairs_sorts_stably() {
        let s = sample();
        assert_eq!(s.times, vec![2, 2, 5, 7, 9]);
        // stable: (1,2) inserted before (0,2) stays first
        assert_eq!(s.types, vec![1, 0, 0, 1, 2]);
        assert!(s.check_sorted());
    }

    #[test]
    fn window_is_half_open_on_left() {
        let s = sample();
        let w = s.window(2, 7);
        assert_eq!(w.times, vec![5, 7]);
    }

    #[test]
    fn partitions_cover_everything() {
        let s = sample();
        let parts = s.partitions(3);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, s.len());
        // partition boundaries respect (lo, hi]
        assert_eq!(parts[0].times, vec![2, 2]);
    }

    #[test]
    fn partitions_with_starts_tag_window_starts() {
        let s = sample(); // times 2..=9, so t0 = 1
        let parts = s.partitions_with_starts(3);
        let starts: Vec<Tick> = parts.iter().map(|&(t0, _)| t0).collect();
        assert_eq!(starts, vec![1, 4, 7]);
        for (t0, p) in &parts {
            assert!(p.times.iter().all(|&t| *t0 < t && t <= t0 + 3));
        }
    }

    #[test]
    fn type_counts_and_rate() {
        let s = sample();
        assert_eq!(s.type_counts(), vec![2, 2, 1]);
        assert!(s.mean_rate_hz() > 0.0);
    }

    #[test]
    fn first_after_binary_search() {
        let s = sample();
        assert_eq!(s.first_after(1), 0);
        assert_eq!(s.first_after(2), 2);
        assert_eq!(s.first_after(9), 5);
    }
}
