//! Event-stream I/O: a simple binary format and CSV interchange.
//!
//! Binary layout (little-endian): magic `EPGS`, u32 version, u32 n_types,
//! u64 n_events, then n_events × (i32 type, i32 time).

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::EventStream;

const MAGIC: &[u8; 4] = b"EPGS";
const VERSION: u32 = 1;

pub fn write_binary(stream: &EventStream, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(stream.n_types as u32).to_le_bytes())?;
    w.write_all(&(stream.len() as u64).to_le_bytes())?;
    for (e, t) in stream.iter() {
        w.write_all(&e.to_le_bytes())?;
        w.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}

pub fn read_binary(path: &Path) -> io::Result<EventStream> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad version"));
    }
    let n_types = read_u32(&mut r)? as usize;
    let n_events = read_u64(&mut r)? as usize;
    let mut s = EventStream::new(n_types);
    s.types.reserve(n_events);
    s.times.reserve(n_events);
    for _ in 0..n_events {
        s.types.push(read_i32(&mut r)?);
        s.times.push(read_i32(&mut r)?);
    }
    if !s.check_sorted() {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "unsorted stream"));
    }
    Ok(s)
}

/// CSV: header `type,time`, one event per line.
pub fn write_csv(stream: &EventStream, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "type,time")?;
    for (e, t) in stream.iter() {
        writeln!(w, "{e},{t}")?;
    }
    Ok(())
}

pub fn read_csv(path: &Path, n_types: usize) -> io::Result<EventStream> {
    let r = BufReader::new(File::open(path)?);
    let mut pairs = vec![];
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        if i == 0 && line.starts_with("type") {
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.splitn(2, ',');
        let bad = || io::Error::new(io::ErrorKind::InvalidData, format!("line {}", i + 1));
        let e: i32 = parts.next().ok_or_else(bad)?.trim().parse().map_err(|_| bad())?;
        let t: i32 = parts.next().ok_or_else(bad)?.trim().parse().map_err(|_| bad())?;
        pairs.push((e, t));
    }
    Ok(EventStream::from_pairs(pairs, n_types))
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_i32<R: Read>(r: &mut R) -> io::Result<i32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(i32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EventStream {
        EventStream::from_pairs(vec![(0, 1), (1, 3), (2, 3), (0, 9)], 3)
    }

    #[test]
    fn binary_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("epgs_test_roundtrip.bin");
        let s = sample();
        write_binary(&s, &path).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(s, r);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("epgs_test_roundtrip.csv");
        let s = sample();
        write_csv(&s, &path).unwrap();
        let r = read_csv(&path, 3).unwrap();
        assert_eq!(s, r);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir();
        let path = dir.join("epgs_test_bad_magic.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
