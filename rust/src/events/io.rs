//! Event-stream I/O: a simple binary format and CSV interchange.
//!
//! Binary layout (little-endian): magic `EPGS`, u32 version, u32 n_types,
//! u64 n_events, then n_events × (i32 type, i32 time).
//!
//! The `read_*`/`write_*` functions speak `std::io::Error` (they are the
//! low-level codec); the `load_*`/`save_*` wrappers return the library's
//! typed [`MineError::Io`] carrying the path and operation, and are what
//! the CLI and the dataset registry's `file:` scheme call. Neither path
//! ever produces a stream the miners would have to re-validate: an event
//! type outside `0..n_types` is rejected by both; unsorted *times* are
//! rejected by the binary reader (the format is defined as time-sorted,
//! so disorder means corruption) but re-sorted by the CSV reader (CSV is
//! hand-editable interchange, and `EventStream::from_pairs` sorting
//! stably is the friendlier contract there).

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::EventStream;
use crate::error::MineError;

const MAGIC: &[u8; 4] = b"EPGS";
const VERSION: u32 = 1;
/// magic + version + n_types + n_events
const HEADER_LEN: u64 = 20;

pub fn write_binary(stream: &EventStream, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(stream.n_types as u32).to_le_bytes())?;
    w.write_all(&(stream.len() as u64).to_le_bytes())?;
    for (e, t) in stream.iter() {
        w.write_all(&e.to_le_bytes())?;
        w.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}

pub fn read_binary(path: &Path) -> io::Result<EventStream> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(invalid("bad magic"));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(invalid("bad version"));
    }
    let n_types = read_u32(&mut r)? as usize;
    if n_types == 0 {
        return Err(invalid("n_types must be > 0"));
    }
    let n_events = read_u64(&mut r)?;
    // Validate the advertised count against the actual file size *before*
    // any `reserve`: a corrupt header must produce an error, not an
    // unbounded allocation (and a short file must fail here, not midway
    // through a partial read).
    let body = file_len.saturating_sub(HEADER_LEN);
    if n_events.checked_mul(8) != Some(body) {
        return Err(invalid(format!(
            "header advertises {n_events} events but the file has {body} body bytes"
        )));
    }
    let n_events = n_events as usize;
    let mut s = EventStream::new(n_types);
    s.types.reserve(n_events);
    s.times.reserve(n_events);
    for _ in 0..n_events {
        let e = read_i32(&mut r)?;
        if e < 0 || e as usize >= n_types {
            return Err(invalid(format!("event type {e} outside alphabet 0..{n_types}")));
        }
        s.types.push(e);
        s.times.push(read_i32(&mut r)?);
    }
    if !s.check_sorted() {
        return Err(invalid("unsorted stream"));
    }
    Ok(s)
}

/// CSV: header `type,time`, one event per line.
pub fn write_csv(stream: &EventStream, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "type,time")?;
    for (e, t) in stream.iter() {
        writeln!(w, "{e},{t}")?;
    }
    Ok(())
}

pub fn read_csv(path: &Path, n_types: usize) -> io::Result<EventStream> {
    if n_types == 0 {
        return Err(invalid("n_types must be > 0"));
    }
    let r = BufReader::new(File::open(path)?);
    let mut pairs = vec![];
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        if i == 0 && line.starts_with("type") {
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.splitn(2, ',');
        let bad = || invalid(format!("line {}", i + 1));
        let e: i32 = parts.next().ok_or_else(bad)?.trim().parse().map_err(|_| bad())?;
        let t: i32 = parts.next().ok_or_else(bad)?.trim().parse().map_err(|_| bad())?;
        if e < 0 || e as usize >= n_types {
            return Err(invalid(format!(
                "line {}: event type {e} outside alphabet 0..{n_types}",
                i + 1
            )));
        }
        pairs.push((e, t));
    }
    Ok(EventStream::from_pairs(pairs, n_types))
}

/// [`read_binary`] behind the library's typed error surface: failures
/// name the path and operation ([`MineError::Io`]).
pub fn load_binary(path: &Path) -> Result<EventStream, MineError> {
    read_binary(path)
        .map_err(|e| MineError::io(format!("reading binary stream {}", path.display()), e))
}

/// [`write_binary`], typed.
pub fn save_binary(stream: &EventStream, path: &Path) -> Result<(), MineError> {
    write_binary(stream, path)
        .map_err(|e| MineError::io(format!("writing binary stream {}", path.display()), e))
}

/// [`read_csv`], typed.
pub fn load_csv(path: &Path, n_types: usize) -> Result<EventStream, MineError> {
    read_csv(path, n_types)
        .map_err(|e| MineError::io(format!("reading CSV stream {}", path.display()), e))
}

/// [`write_csv`], typed.
pub fn save_csv(stream: &EventStream, path: &Path) -> Result<(), MineError> {
    write_csv(stream, path)
        .map_err(|e| MineError::io(format!("writing CSV stream {}", path.display()), e))
}

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_i32<R: Read>(r: &mut R) -> io::Result<i32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(i32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, small_size};
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("epgs_io_{}_{name}", std::process::id()))
    }

    fn sample() -> EventStream {
        EventStream::from_pairs(vec![(0, 1), (1, 3), (2, 3), (0, 9)], 3)
    }

    /// Random valid stream: small alphabet, non-decreasing times.
    fn random_stream(rng: &mut Rng) -> EventStream {
        let n_types = small_size(rng, 8);
        let n_events = rng.below(200) as usize; // empty streams included
        let mut s = EventStream::new(n_types);
        let mut t = rng.range_i32(-50, 50);
        for _ in 0..n_events {
            t += rng.range_i32(0, 4);
            s.push(rng.range_i32(0, n_types as i32 - 1), t);
        }
        s
    }

    #[test]
    fn binary_roundtrip() {
        let path = tmp("roundtrip.bin");
        let s = sample();
        write_binary(&s, &path).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(s, r);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_roundtrip() {
        let path = tmp("roundtrip.csv");
        let s = sample();
        write_csv(&s, &path).unwrap();
        let r = read_csv(&path, 3).unwrap();
        assert_eq!(s, r);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn randomized_roundtrips_are_lossless() {
        let bin = tmp("prop.bin");
        let csv = tmp("prop.csv");
        forall("io roundtrip", 0xD15C, 60, |rng| {
            let s = random_stream(rng);
            write_binary(&s, &bin).map_err(|e| e.to_string())?;
            let back = read_binary(&bin).map_err(|e| e.to_string())?;
            if back != s {
                return Err(format!("binary roundtrip lost data ({} events)", s.len()));
            }
            write_csv(&s, &csv).map_err(|e| e.to_string())?;
            let back = read_csv(&csv, s.n_types).map_err(|e| e.to_string())?;
            if back != s {
                return Err(format!("csv roundtrip lost data ({} events)", s.len()));
            }
            Ok(())
        });
        std::fs::remove_file(bin).ok();
        std::fs::remove_file(csv).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad_magic.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_version_rejected() {
        let path = tmp("bad_version.bin");
        write_binary(&sample(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 9; // version lives at offset 4
        std::fs::write(&path, &bytes).unwrap();
        let msg = read_binary(&path).unwrap_err().to_string();
        assert!(msg.contains("version"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_body_rejected_without_allocation() {
        let path = tmp("truncated.bin");
        write_binary(&sample(), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let msg = read_binary(&path).unwrap_err().to_string();
        assert!(msg.contains("body bytes"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn oversized_n_events_rejected_before_reserve() {
        // a 4-event body whose header claims u64::MAX events: must be a
        // clean error, not a multi-exabyte reserve
        let path = tmp("oversized.bin");
        write_binary(&sample(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[12..20].copy_from_slice(&u64::MAX.to_le_bytes()); // n_events at offset 12
        std::fs::write(&path, &bytes).unwrap();
        let msg = read_binary(&path).unwrap_err().to_string();
        assert!(msg.contains("advertises"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn zero_n_types_rejected() {
        let path = tmp("zero_types.bin");
        write_binary(&sample(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&0u32.to_le_bytes()); // n_types at offset 8
        std::fs::write(&path, &bytes).unwrap();
        let msg = read_binary(&path).unwrap_err().to_string();
        assert!(msg.contains("n_types"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn unsorted_payload_rejected() {
        // events live at offset 20, 8 bytes each, time at +4: swap the
        // first two events' times to break ordering
        let path = tmp("unsorted.bin");
        write_binary(&sample(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[24..28].copy_from_slice(&9i32.to_le_bytes());
        bytes[32..36].copy_from_slice(&1i32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let msg = read_binary(&path).unwrap_err().to_string();
        assert!(msg.contains("unsorted"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn out_of_alphabet_type_rejected() {
        let path = tmp("bad_type.bin");
        write_binary(&sample(), &path).unwrap(); // alphabet 0..3
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20..24].copy_from_slice(&7i32.to_le_bytes()); // first event's type
        std::fs::write(&path, &bytes).unwrap();
        let msg = read_binary(&path).unwrap_err().to_string();
        assert!(msg.contains("alphabet"), "{msg}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_rejects_out_of_alphabet_and_garbage() {
        let path = tmp("bad.csv");
        std::fs::write(&path, "type,time\n0,1\n9,2\n").unwrap();
        let msg = read_csv(&path, 3).unwrap_err().to_string();
        assert!(msg.contains("alphabet"), "{msg}");
        std::fs::write(&path, "type,time\n0,banana\n").unwrap();
        assert!(read_csv(&path, 3).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_resorts_but_binary_rejects_disorder() {
        // CSV is hand-editable interchange: out-of-order lines are
        // stably re-sorted, not rejected (the binary format, by
        // contrast, treats disorder as corruption — see
        // `unsorted_payload_rejected`)
        let path = tmp("disorder.csv");
        std::fs::write(&path, "type,time\n0,9\n1,3\n").unwrap();
        let s = read_csv(&path, 3).unwrap();
        assert_eq!(s.times, vec![3, 9]);
        assert_eq!(s.types, vec![1, 0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn typed_wrappers_name_the_path() {
        let missing = tmp("does_not_exist.bin");
        let err = load_binary(&missing).unwrap_err();
        match &err {
            MineError::Io { what, source } => {
                assert!(what.contains("does_not_exist"), "{what}");
                assert_eq!(source.kind(), io::ErrorKind::NotFound);
            }
            other => panic!("wrong variant: {other}"),
        }

        let path = tmp("typed.bin");
        save_binary(&sample(), &path).unwrap();
        assert_eq!(load_binary(&path).unwrap(), sample());
        std::fs::remove_file(path).ok();
    }
}
