//! Analytical GTX280 model — the substitute for the paper's hardware
//! (DESIGN.md §5 substitution 1 and 3).
//!
//! Three roles:
//! 1. **Occupancy / thread-block sizing** (§6.1.2): given an algorithm's
//!    per-thread shared-memory and register footprint, how many threads
//!    fit a multiprocessor — reproduces the paper's "only 32 threads/block
//!    at N=6 for A1" arithmetic and the resource asymmetry driving the
//!    two-pass approach.
//! 2. **Hybrid dispatch** (Eq. 2): `S > MP * B_MP * T_B * f(N)` with
//!    `f(N) = a/N + b` fitted to measured crossover points (Fig. 8).
//! 3. **Profiler counters** (Fig. 10): pairs with `mining::telemetry`.

pub mod occupancy;
pub mod crossover;
