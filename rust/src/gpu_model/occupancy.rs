//! GTX280 occupancy arithmetic (paper §4, §5.3, §6.1.2).

/// GTX280 machine description (paper Fig. 1 right, §6.1.2).
#[derive(Clone, Copy, Debug)]
pub struct Gpu {
    pub multiprocessors: u32,
    pub cores_per_mp: u32,
    pub warp_size: u32,
    pub shared_mem_per_mp: u32,
    pub registers_per_mp: u32,
    pub max_threads_per_block: u32,
}

pub const GTX280: Gpu = Gpu {
    multiprocessors: 30,
    cores_per_mp: 8,
    warp_size: 32,
    shared_mem_per_mp: 16 * 1024,
    registers_per_mp: 16 * 1024,
    max_threads_per_block: 512,
};

/// Per-thread resource footprint of a counting kernel.
#[derive(Clone, Copy, Debug)]
pub struct KernelResources {
    pub shared_bytes_per_thread: u32,
    pub registers_per_thread: u32,
    pub local_bytes_per_thread: u32,
}

/// A1's footprint at episode size N: per-level bounded lists of K int32
/// timestamps + list cursors in shared memory (the paper reports 220 B at
/// N=5, K≈8: 4*5*8=160 B of lists + bookkeeping), 17 registers, 80 B of
/// local-memory spill.
pub fn a1_resources(n: usize, k: usize) -> KernelResources {
    KernelResources {
        shared_bytes_per_thread: (4 * n * k + 12 * n) as u32,
        registers_per_thread: 17,
        local_bytes_per_thread: 80,
    }
}

/// A2's footprint: one int32 timestamp per level in registers, no local
/// memory (paper §6.3: 13 registers, no local loads/stores).
pub fn a2_resources(n: usize) -> KernelResources {
    KernelResources {
        shared_bytes_per_thread: (4 * n) as u32,
        registers_per_thread: 13,
        local_bytes_per_thread: 0,
    }
}

impl Gpu {
    /// Maximum threads per block under the shared-memory budget — the
    /// paper's runtime parameter T (§6.1.2), rounded down to a warp
    /// multiple (min one warp).
    pub fn max_threads(&self, r: &KernelResources) -> u32 {
        let by_shared = if r.shared_bytes_per_thread == 0 {
            self.max_threads_per_block
        } else {
            self.shared_mem_per_mp / r.shared_bytes_per_thread
        };
        let by_regs = if r.registers_per_thread == 0 {
            self.max_threads_per_block
        } else {
            self.registers_per_mp / r.registers_per_thread
        };
        let t = by_shared.min(by_regs).min(self.max_threads_per_block);
        (t / self.warp_size).max(1) * self.warp_size
    }

    /// Blocks per multiprocessor for a block of `t_block` threads (B_MP in
    /// Eq. 1) — bounded by shared memory.
    pub fn blocks_per_mp(&self, r: &KernelResources, t_block: u32) -> u32 {
        let shared_per_block = r.shared_bytes_per_thread * t_block;
        if shared_per_block == 0 {
            return 8;
        }
        (self.shared_mem_per_mp / shared_per_block).clamp(1, 8)
    }

    /// Paper Eq. 1 threshold: episodes needed to fully utilize the GPU.
    pub fn full_utilization_threshold(&self, r: &KernelResources) -> u64 {
        let t = self.max_threads(r);
        let b = self.blocks_per_mp(r, t);
        self.multiprocessors as u64 * b as u64 * t as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a1_threads_shrink_with_n_paper_6_1_2() {
        // paper: N=1 allows 128 threads; by N=6 only 32 threads/block fit.
        let t1 = GTX280.max_threads(&a1_resources(1, 8));
        let t6 = GTX280.max_threads(&a1_resources(6, 8));
        assert!(t1 >= 128, "t1 {t1}");
        assert!(t6 <= 64, "t6 {t6}");
        assert!(t6 >= 32);
    }

    #[test]
    fn a1_at_n5_matches_paper_footprint_scale() {
        // §5.3: "episode size 5 -> 220 bytes of shared memory"
        let r = a1_resources(5, 8);
        assert!((200..=260).contains(&r.shared_bytes_per_thread), "{r:?}");
    }

    #[test]
    fn a2_allows_many_more_threads_than_a1() {
        for n in 2..=8 {
            let ta1 = GTX280.max_threads(&a1_resources(n, 8));
            let ta2 = GTX280.max_threads(&a2_resources(n));
            assert!(ta2 >= 2 * ta1, "n={n}: a2 {ta2} vs a1 {ta1}");
        }
    }

    #[test]
    fn utilization_threshold_positive_and_monotone() {
        let th3 = GTX280.full_utilization_threshold(&a1_resources(3, 8));
        let th7 = GTX280.full_utilization_threshold(&a1_resources(7, 8));
        assert!(th3 > 0 && th7 > 0);
        assert!(th3 >= th7, "more state => fewer resident threads");
    }

    #[test]
    fn warp_rounding() {
        let r = KernelResources {
            shared_bytes_per_thread: 300,
            registers_per_thread: 16,
            local_bytes_per_thread: 0,
        };
        let t = GTX280.max_threads(&r);
        assert_eq!(t % 32, 0);
    }
}
