//! Crossover model for Hybrid dispatch (paper §5.2.3, Table 1, Fig. 8).
//!
//! The hybrid algorithm (A1) runs PTPE when the episode count S exceeds a
//! level-dependent crossover, else MapConcatenate:
//!
//!   S > MP * B_MP * T_B * f(N),   f(N) = a/N + b          (Eq. 2)
//!
//! The paper fits f to experimentally measured crossover points and finds
//! `a/N + b` a better fit than `a*N + b` (Fig. 8). We do the same against
//! crossovers measured on *this* substrate (`benches/table1_crossover.rs`)
//! and ship the fitted constants as the default dispatch model.

use crate::util::stats::{inverse_fit, linear_fit};

/// The paper's experimentally determined crossover points (Table 1):
/// number of episodes below which MapConcatenate wins, per level.
pub const PAPER_TABLE1: &[(usize, f64)] =
    &[(3, 415.0), (4, 190.0), (5, 200.0), (6, 100.0), (7, 100.0), (8, 60.0)];

/// Fitted crossover model `crossover(N) = a/N + b`, clamped at 0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrossoverModel {
    pub a: f64,
    pub b: f64,
}

impl CrossoverModel {
    /// Fit to measured (level, crossover) points with the paper's winning
    /// `a/N + b` form.
    pub fn fit(points: &[(usize, f64)]) -> CrossoverModel {
        let xs: Vec<f64> = points.iter().map(|&(n, _)| n as f64).collect();
        let ys: Vec<f64> = points.iter().map(|&(_, c)| c).collect();
        let (a, b, _) = inverse_fit(&xs, &ys);
        CrossoverModel { a, b }
    }

    /// Default model: fitted to the paper's Table 1.
    pub fn paper_default() -> CrossoverModel {
        Self::fit(PAPER_TABLE1)
    }

    /// Dispatch model fitted to crossovers measured on *this* substrate
    /// (CPU-PJRT interpret mode; `benches/table1_crossover.rs`). The
    /// serialized Pallas grid removes MapConcatenate's parallel-hardware
    /// advantage, so crossovers are far smaller than the paper's GTX280
    /// numbers — same a/N + b shape, different constants. This is what the
    /// coordinator uses by default; see EXPERIMENTS.md §Perf.
    pub fn substrate_default() -> CrossoverModel {
        CrossoverModel { a: 165.3, b: -23.1 }
    }

    /// Predicted crossover (episode count) at level n.
    pub fn crossover(&self, n: usize) -> f64 {
        (self.a / n as f64 + self.b).max(0.0)
    }

    /// Hybrid dispatch decision (Alg. 2): true = run PTPE, false = run
    /// MapConcatenate.
    pub fn choose_ptpe(&self, n_episodes: usize, n: usize) -> bool {
        // Levels 1-2 have no MapConcatenate advantage (Table 1 note:
        // crossovers only exist for levels >= 3; tiny-N state machines are
        // cheap enough that PTPE always wins unless there are almost no
        // episodes).
        if n < 3 {
            return n_episodes as f64 > 1.0;
        }
        n_episodes as f64 > self.crossover(n)
    }
}

/// Cost-based dispatch for this substrate — the Eq. 2 analog when the
/// hardware is CPU-PJRT rather than a GTX280.
///
/// The paper's dispatch rule only needs S and N because on a real GPU the
/// stream length divides out (both algorithms scan everything, in
/// parallel). On the serialized interpret-mode substrate the economics
/// change: PTPE's cost is quantized by full batches/chunks while
/// MapConcatenate's scales linearly with S and scans ~2x the stream
/// (boundary machines re-read the previous segment). The per-event
/// coefficients below are calibrated from `benches/perf_kernels.rs` and
/// `benches/table1_crossover.rs` on this build (EXPERIMENTS.md §Perf L3).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// episode lanes per PTPE batch (manifest m_episodes)
    pub m_episodes: usize,
    /// events per PTPE chunk (manifest c_chunk)
    pub c_chunk: usize,
    /// PTPE us per event per batch at level n: `a1_us_base + a1_us_per_n * n`
    pub a1_us_base: f64,
    pub a1_us_per_n: f64,
    /// MapConcatenate us per scanned event per episode at level n
    pub mc_us_base: f64,
    pub mc_us_per_n: f64,
}

impl CostModel {
    pub fn substrate_default(m_episodes: usize, c_chunk: usize) -> CostModel {
        // Calibrated against single-call timings in benches/table1_crossover
        // (S=1..16 probes, n=3/5/7): PTPE ~23.5/39.8/56 us per event-batch
        // at n=3/5/7 (includes per-call literal/padding overhead), MapConcat
        // ~15/34.8 us per scanned event per episode at n=3/5.
        CostModel {
            m_episodes,
            c_chunk,
            a1_us_base: -0.5,
            a1_us_per_n: 8.0,
            mc_us_base: -15.0,
            mc_us_per_n: 10.0,
        }
    }

    pub fn ptpe_us(&self, s: usize, n: usize, events: usize) -> f64 {
        let batches = s.div_ceil(self.m_episodes).max(1);
        let chunked = events.div_ceil(self.c_chunk).max(1) * self.c_chunk;
        batches as f64 * chunked as f64 * (self.a1_us_base + self.a1_us_per_n * n as f64).max(1.0)
    }

    pub fn mapcat_us(&self, s: usize, n: usize, events: usize) -> f64 {
        // boundary machines scan their own + the previous segment: ~2x
        s as f64
            * 2.0
            * events as f64
            * (self.mc_us_base + self.mc_us_per_n * n as f64).max(1.0)
    }

    /// true = PTPE, false = MapConcatenate.
    pub fn choose_ptpe(&self, s: usize, n: usize, events: usize) -> bool {
        self.ptpe_us(s, n, events) <= self.mapcat_us(s, n, events)
    }
}

/// Goodness-of-fit comparison for Fig. 8: SSE of `a/N+b` vs `a*N+b`.
pub fn fit_comparison(points: &[(usize, f64)]) -> (f64, f64) {
    let xs: Vec<f64> = points.iter().map(|&(n, _)| n as f64).collect();
    let ys: Vec<f64> = points.iter().map(|&(_, c)| c).collect();
    let (_, _, sse_inv) = inverse_fit(&xs, &ys);
    let (_, _, sse_lin) = linear_fit(&xs, &ys);
    (sse_inv, sse_lin)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fit_reproduces_fig8_preference() {
        let (sse_inv, sse_lin) = fit_comparison(PAPER_TABLE1);
        assert!(sse_inv < sse_lin, "a/N+b must fit Table 1 better (Fig. 8)");
    }

    #[test]
    fn crossover_decreases_with_level() {
        let m = CrossoverModel::paper_default();
        assert!(m.crossover(3) > m.crossover(8));
    }

    #[test]
    fn dispatch_matches_table1_direction() {
        let m = CrossoverModel::paper_default();
        // Well above the crossover: PTPE. Well below: MapConcatenate.
        assert!(m.choose_ptpe(10_000, 4));
        assert!(!m.choose_ptpe(10, 6));
    }

    #[test]
    fn small_levels_default_to_ptpe() {
        let m = CrossoverModel::paper_default();
        assert!(m.choose_ptpe(100, 2));
        assert!(m.choose_ptpe(100, 1));
    }

    #[test]
    fn cost_model_prefers_mapcat_only_at_tiny_batches() {
        let m = CostModel::substrate_default(512, 8192);
        // one episode on a short stream: MapConcatenate's partial scan wins
        assert!(!m.choose_ptpe(1, 3, 4000));
        // a full batch: PTPE amortizes the chunk scan across 512 lanes
        assert!(m.choose_ptpe(512, 3, 4000));
        // long streams penalize MapConcatenate linearly
        assert!(m.choose_ptpe(4, 3, 200_000));
    }

    #[test]
    fn cost_model_ptpe_cost_quantized_by_batches() {
        let m = CostModel::substrate_default(512, 8192);
        // same cost anywhere inside one batch...
        assert_eq!(m.ptpe_us(1, 4, 8000), m.ptpe_us(512, 4, 8000));
        // ...doubles at the batch boundary
        assert!(m.ptpe_us(513, 4, 8000) > 1.9 * m.ptpe_us(512, 4, 8000));
    }

    #[test]
    fn cost_model_mapcat_scales_linearly_in_s() {
        let m = CostModel::substrate_default(512, 8192);
        let one = m.mapcat_us(1, 5, 10_000);
        let ten = m.mapcat_us(10, 5, 10_000);
        assert!((ten / one - 10.0).abs() < 1e-9);
    }

    #[test]
    fn fit_roundtrip_on_synthetic_points() {
        let pts: Vec<(usize, f64)> =
            (3..=8).map(|n| (n, 600.0 / n as f64 + 25.0)).collect();
        let m = CrossoverModel::fit(&pts);
        assert!((m.a - 600.0).abs() < 1e-6 && (m.b - 25.0).abs() < 1e-6);
    }
}
