//! Typed error surface for the library crate.
//!
//! Every public library entry point returns `Result<_, MineError>`; binaries
//! and benches may wrap it however they like at the edge. Variants are
//! designed to be *actionable*: they carry the numbers and valid choices a
//! caller needs to correct the problem, not just a message.

use std::fmt;

/// Library-wide error type.
#[derive(Debug)]
pub enum MineError {
    /// A backend was asked to count an episode size it has no path for.
    /// (The shipped backends fall back to CPU counting instead of raising
    /// this; it surfaces only from direct low-level `runtime::exec` use.)
    UnsupportedEpisodeSize { backend: String, n: usize },
    /// An episode references an event type outside the stream's alphabet
    /// `0..n_types`. Counting it is a contract violation (the per-type
    /// frequency table and watcher indexes are alphabet-sized), so it is
    /// a typed error rather than a panic or a silent 0.
    OutOfAlphabet { type_id: i32, n_types: usize },
    /// A mining level generated more candidates than the configured cap —
    /// the fail-fast guardrail against a too-low theta on bursty data.
    CandidateExplosion { level: usize, candidates: usize, cap: usize },
    /// The mining service's admission queue is full. A bounded queue must
    /// reject (so clients can back off) rather than buffer unboundedly;
    /// `queue_depth` is the depth observed at rejection time.
    Busy { queue_depth: usize, capacity: usize },
    /// The PJRT runtime (artifacts + client) could not be opened. CPU
    /// backends remain fully functional without it.
    RuntimeUnavailable { reason: String },
    /// A `Session` was configured inconsistently (missing stream, zero
    /// theta, bad max_level, ...).
    InvalidConfig { what: String },
    /// An unrecognized strategy name; `valid` lists every accepted name.
    UnknownStrategy { given: String, valid: &'static [&'static str] },
    /// An unrecognized dataset name; `valid` lists the registry.
    UnknownDataset { given: String, valid: Vec<&'static str> },
    /// An I/O failure, with what was being attempted.
    Io { what: String, source: std::io::Error },
    /// Durable data on disk failed validation (bad magic, torn write,
    /// checksum mismatch, manifest/segment disagreement). Distinct from
    /// [`MineError::Io`]: the bytes were readable, they just cannot be
    /// trusted — and corrupt recordings must surface, never be silently
    /// mined.
    Corrupt { path: String, detail: String },
    /// The accelerator path failed mid-execution (compile/execute/readback).
    Accelerator { what: String },
    /// An internal contract violation (a bug, not a user error).
    Internal { what: String },
}

impl MineError {
    pub fn invalid(what: impl Into<String>) -> MineError {
        MineError::InvalidConfig { what: what.into() }
    }

    pub fn runtime_unavailable(reason: impl Into<String>) -> MineError {
        MineError::RuntimeUnavailable { reason: reason.into() }
    }

    pub fn accel(what: impl Into<String>) -> MineError {
        MineError::Accelerator { what: what.into() }
    }

    pub fn internal(what: impl Into<String>) -> MineError {
        MineError::Internal { what: what.into() }
    }

    pub fn io(what: impl Into<String>, source: std::io::Error) -> MineError {
        MineError::Io { what: what.into(), source }
    }

    pub fn corrupt(path: impl Into<String>, detail: impl Into<String>) -> MineError {
        MineError::Corrupt { path: path.into(), detail: detail.into() }
    }
}

impl fmt::Display for MineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MineError::UnsupportedEpisodeSize { backend, n } => {
                write!(f, "backend {backend} has no counting path for episode size {n}")
            }
            MineError::OutOfAlphabet { type_id, n_types } => write!(
                f,
                "episode event type {type_id} is outside the stream alphabet \
                 0..{n_types} — was the stream built with the right n_types?"
            ),
            MineError::CandidateExplosion { level, candidates, cap } => write!(
                f,
                "level {level} generated {candidates} candidates (> {cap} cap) — raise \
                 theta or max_candidates_per_level"
            ),
            MineError::Busy { queue_depth, capacity } => write!(
                f,
                "service busy: admission queue at capacity ({queue_depth}/{capacity}) — \
                 back off and retry, or raise ServiceConfig::queue_capacity"
            ),
            MineError::RuntimeUnavailable { reason } => {
                write!(f, "PJRT runtime unavailable: {reason}")
            }
            MineError::InvalidConfig { what } => write!(f, "invalid configuration: {what}"),
            MineError::UnknownStrategy { given, valid } => {
                write!(f, "unknown strategy {given:?}; valid strategies: {}", valid.join(", "))
            }
            MineError::UnknownDataset { given, valid } => {
                write!(f, "unknown dataset {given:?}; valid datasets: {}", valid.join(", "))
            }
            MineError::Io { what, source } => write!(f, "{what}: {source}"),
            MineError::Corrupt { path, detail } => write!(
                f,
                "corrupt on-disk data at {path}: {detail} — the recording is \
                 quarantined from mining; restore it from a replica or re-ingest"
            ),
            MineError::Accelerator { what } => write!(f, "accelerator error: {what}"),
            MineError::Internal { what } => write!(f, "internal error: {what}"),
        }
    }
}

/// Manual because `std::io::Error` is not `Clone`: the duplicate keeps the
/// kind and message. Needed by the serving layer, where one execution's
/// outcome fans out to every coalesced waiter.
impl Clone for MineError {
    fn clone(&self) -> MineError {
        match self {
            MineError::UnsupportedEpisodeSize { backend, n } => {
                MineError::UnsupportedEpisodeSize { backend: backend.clone(), n: *n }
            }
            MineError::OutOfAlphabet { type_id, n_types } => {
                MineError::OutOfAlphabet { type_id: *type_id, n_types: *n_types }
            }
            MineError::CandidateExplosion { level, candidates, cap } => {
                MineError::CandidateExplosion {
                    level: *level,
                    candidates: *candidates,
                    cap: *cap,
                }
            }
            MineError::Busy { queue_depth, capacity } => {
                MineError::Busy { queue_depth: *queue_depth, capacity: *capacity }
            }
            MineError::RuntimeUnavailable { reason } => {
                MineError::RuntimeUnavailable { reason: reason.clone() }
            }
            MineError::InvalidConfig { what } => {
                MineError::InvalidConfig { what: what.clone() }
            }
            MineError::UnknownStrategy { given, valid } => {
                MineError::UnknownStrategy { given: given.clone(), valid }
            }
            MineError::UnknownDataset { given, valid } => {
                MineError::UnknownDataset { given: given.clone(), valid: valid.clone() }
            }
            MineError::Io { what, source } => MineError::Io {
                what: what.clone(),
                source: std::io::Error::new(source.kind(), source.to_string()),
            },
            MineError::Corrupt { path, detail } => {
                MineError::Corrupt { path: path.clone(), detail: detail.clone() }
            }
            MineError::Accelerator { what } => {
                MineError::Accelerator { what: what.clone() }
            }
            MineError::Internal { what } => MineError::Internal { what: what.clone() },
        }
    }
}

impl std::error::Error for MineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MineError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<xla::Error> for MineError {
    fn from(e: xla::Error) -> MineError {
        MineError::Accelerator { what: e.to_string() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_actionable() {
        let e = MineError::CandidateExplosion { level: 3, candidates: 10, cap: 5 };
        let s = e.to_string();
        assert!(s.contains("level 3") && s.contains("theta"), "{s}");

        let e = MineError::UnknownStrategy { given: "warp".into(), valid: &["hybrid", "cpu"] };
        assert!(e.to_string().contains("hybrid"));
    }

    #[test]
    fn clone_preserves_variant_and_io_kind() {
        let e = MineError::Busy { queue_depth: 8, capacity: 8 };
        assert!(matches!(e.clone(), MineError::Busy { queue_depth: 8, capacity: 8 }));

        let e = MineError::io(
            "reading x",
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        match e.clone() {
            MineError::Io { what, source } => {
                assert_eq!(what, "reading x");
                assert_eq!(source.kind(), std::io::ErrorKind::NotFound);
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn io_source_is_chained() {
        use std::error::Error as _;
        let e = MineError::io(
            "reading x",
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        assert!(e.source().is_some());
    }
}
