//! Sharded LRU result cache keyed by [`QueryKey`], verified by content.
//!
//! Results are cached under the exact stream fingerprint, so a hit can
//! never be stale — there is no invalidation problem because a mutated or
//! extended stream hashes to a different key. The fingerprint is only the
//! *routing* identity, though: each entry keeps its [`WorkItem`] and every
//! lookup re-verifies exact semantic equality ([`WorkItem::equivalent`]),
//! so a fingerprint collision (FNV-style mixing is invertible, and tenants
//! are untrusted) degrades to a miss/overwrite instead of serving one
//! tenant another tenant's counts. Since 0.3 the cache stores typed
//! [`WorkOutput`]s, so every [`Request`](super::Request) arm that
//! produces a result shares one cache (connectivity answers are cached
//! alongside plain mines; the kind discriminator in
//! `ConnectivityQuery::key` keeps their key spaces disjoint). Sharding
//! (by fingerprint low bits) keeps lock contention off the submit hot
//! path; eviction is LRU per shard via a last-used stamp and a scan,
//! which is O(shard capacity) only on insertion into a full shard — fine
//! at the few-hundred entry capacities a result cache wants (each entry
//! is a full result, not a counter).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::pool::{WorkItem, WorkOutput};
use super::query::QueryKey;

/// Hit/miss/eviction counters plus current occupancy, as one snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
}

impl CacheStats {
    /// hits / (hits + misses); 0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Shard {
    /// monotonic per-shard use counter stamping recency
    clock: u64,
    entries: HashMap<QueryKey, Entry>,
}

struct Entry {
    last_used: u64,
    /// the work item this result answers, for collision verification
    /// (streams are `Arc`-shared, so this is cheap for repeat-heavy
    /// workloads)
    item: WorkItem,
    result: WorkOutput,
}

/// A sharded LRU cache of mining results. `capacity == 0` disables
/// caching (every lookup misses, inserts are dropped).
pub struct ResultCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ResultCache {
    /// `capacity` total entries spread over `shards` (rounded up to a
    /// power of two so the fingerprint's low bits select a shard).
    pub fn new(capacity: usize, shards: usize) -> ResultCache {
        let n_shards = shards.max(1).next_power_of_two();
        let per_shard_capacity = if capacity == 0 { 0 } else { capacity.div_ceil(n_shards) };
        ResultCache {
            shards: (0..n_shards)
                .map(|_| Mutex::new(Shard { clock: 0, entries: HashMap::new() }))
                .collect(),
            per_shard_capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &QueryKey) -> &Mutex<Shard> {
        &self.shards[key.fingerprint() as usize & (self.shards.len() - 1)]
    }

    fn lookup(&self, key: &QueryKey, item: &WorkItem) -> Option<WorkOutput> {
        if self.per_shard_capacity == 0 {
            return None;
        }
        let mut shard = self.shard(key).lock().unwrap();
        shard.clock += 1;
        let now = shard.clock;
        match shard.entries.get_mut(key) {
            Some(entry) if entry.item.equivalent(item) => {
                entry.last_used = now;
                Some(entry.result.clone())
            }
            _ => None,
        }
    }

    /// Look up `item`'s result, counting a hit or miss. A same-key entry
    /// whose contents are not [`WorkItem::equivalent`] is a miss.
    pub fn get(&self, key: &QueryKey, item: &WorkItem) -> Option<WorkOutput> {
        let found = self.lookup(key, item);
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// [`ResultCache::get`] without touching the hit/miss counters (still
    /// freshens recency). The submit path uses this to re-check the cache
    /// under the in-flight lock — a job can complete (cache insert, then
    /// in-flight removal) between a counted miss and that lock, and the
    /// re-check closes the window without double-counting the lookup.
    pub fn peek(&self, key: &QueryKey, item: &WorkItem) -> Option<WorkOutput> {
        self.lookup(key, item)
    }

    /// Insert (or replace) the result for `item`. A same-key entry for a
    /// non-equivalent item is overwritten — the collision degrades to
    /// thrash between the colliding tenants, never to a wrong answer.
    pub fn insert(&self, key: QueryKey, item: WorkItem, result: WorkOutput) {
        if self.per_shard_capacity == 0 {
            return;
        }
        let mut shard = self.shard(&key).lock().unwrap();
        shard.clock += 1;
        let now = shard.clock;
        if shard.entries.len() >= self.per_shard_capacity && !shard.entries.contains_key(&key)
        {
            let victim =
                shard.entries.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k);
            if let Some(victim) = victim {
                shard.entries.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.entries.insert(key, Entry { last_used: now, item, result });
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::miner::MineResult;
    use crate::episodes::Interval;
    use crate::events::EventStream;
    use crate::serve::query::Query;
    use std::sync::Arc;

    fn item(theta: u64) -> WorkItem {
        let stream = Arc::new(EventStream::from_pairs(vec![(0, 1), (1, 5)], 2));
        WorkItem::Mine(Query::new(stream, theta, vec![Interval::new(0, 4)]))
    }

    fn result() -> WorkOutput {
        WorkOutput::Mine(Arc::new(MineResult::default()))
    }

    fn put(cache: &ResultCache, q: &WorkItem) {
        cache.insert(q.key(), q.clone(), result());
    }

    #[test]
    fn get_after_insert_hits() {
        let cache = ResultCache::new(8, 2);
        let q = item(3);
        assert!(cache.get(&q.key(), &q).is_none());
        put(&cache, &q);
        assert!(cache.get(&q.key(), &q).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_the_least_recently_used() {
        // single shard, capacity 2: freshen q1, insert q3 → q2 evicted
        let cache = ResultCache::new(2, 1);
        let (q1, q2, q3) = (item(1), item(2), item(3));
        put(&cache, &q1);
        put(&cache, &q2);
        assert!(cache.get(&q1.key(), &q1).is_some());
        put(&cache, &q3);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get(&q1.key(), &q1).is_some(), "freshened entry survives");
        assert!(cache.get(&q2.key(), &q2).is_none(), "LRU entry evicted");
        assert!(cache.get(&q3.key(), &q3).is_some());
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let cache = ResultCache::new(0, 4);
        let q = item(1);
        put(&cache, &q);
        assert!(cache.get(&q.key(), &q).is_none());
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn reinsert_updates_in_place_without_eviction() {
        let cache = ResultCache::new(1, 1);
        let q = item(1);
        put(&cache, &q);
        put(&cache, &q);
        assert_eq!(cache.stats().evictions, 0);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn same_key_different_query_is_a_miss_not_an_alias() {
        // Simulate a fingerprint collision by looking up a *different*
        // query under q1's key: content verification must refuse the hit.
        let cache = ResultCache::new(8, 1);
        let (q1, q2) = (item(1), item(2));
        put(&cache, &q1);
        assert!(cache.get(&q1.key(), &q2).is_none(), "colliding lookup must miss");
        assert!(cache.get(&q1.key(), &q1).is_some());
    }

    #[test]
    fn kinds_never_cross_alias() {
        // a connectivity item under a mine entry's key (or vice versa)
        // must miss even though both wrap the same query
        let cache = ResultCache::new(8, 1);
        let WorkItem::Mine(q) = item(1) else { unreachable!() };
        let mine = WorkItem::Mine(q.clone());
        let conn = WorkItem::Connectivity(crate::serve::query::ConnectivityQuery::new(
            q, 5, 5, 1,
        ));
        put(&cache, &mine);
        assert!(cache.get(&mine.key(), &conn).is_none());
        assert!(cache.get(&conn.key(), &conn).is_none());
    }
}
