//! The serving layer: a multi-tenant mining service over the counting
//! engines.
//!
//! The paper's chip-on-chip vision (§1, §6.5) is ultimately a *service* —
//! one chip produces spike trains, the other answers mining queries fast
//! enough to keep up — and the analyses built on this miner (theta sweeps,
//! window scans, connectivity inference) fire many closely related
//! queries per dataset. This module turns the single-caller `Session`
//! world into that service:
//!
//! - [`query::Request`] — the one typed request surface: every way of
//!   asking the service for work (a plain mine, a live subscription, a
//!   surrogate-tested connectivity inference) is an arm of one enum,
//!   admitted through shared validation and dispatched at the single
//!   [`MineService::request`] point. A [`query::ConnectivityQuery`] is
//!   admission-counted as **one** tenant job (one queue slot, one cache
//!   entry) even though the worker that claims it fans out into
//!   `1 + n_surrogates` internal mines through the batched executor
//!   ([`crate::analysis::batch`]); the fan-out never re-enters the
//!   service's own queue, so connectivity requests cannot deadlock the
//!   pool however small it is.
//! - [`pool::MineService`] — a pool of worker threads, each constructing
//!   its counting engine thread-locally (sessions hold `Rc<Runtime>` and
//!   do not cross threads; engines do not need to — workers build them in
//!   place and run the shared `mine_with_backend` driver).
//! - [`query::QueryKey`] — a canonical fingerprint over the exact stream
//!   contents and every mining parameter; the identity for both request
//!   coalescing (identical in-flight queries share one execution) and the
//!   [`cache::ResultCache`] (sharded LRU with hit/miss/eviction
//!   counters). Keyed on exact content, a cached result can never be
//!   stale.
//! - admission control — a bounded job queue that rejects with the typed
//!   [`MineError::Busy`] instead of buffering unboundedly.
//! - live subscriptions — [`MineService::subscribe`] registers a
//!   [`query::SubscribeQuery`] (tenant + topic + buffer) and
//!   [`MineService::publish`] pushes each incremental-mining
//!   [`CommitUpdate`](crate::stream::CommitUpdate) to every matching
//!   [`pool::Subscription`] as a frequent-set diff. Per-tenant
//!   subscription caps extend the bounded-admission story to long-lived
//!   feeds; full mailboxes drop oldest (every update carries the full
//!   set, so consumers resynchronize from the latest). With
//!   [`pool::WatchLogConfig`] the service publishes to itself: a
//!   watcher thread tails a `log:` directory and pushes every commit,
//!   no external publisher required.
//! - [`metrics::ServiceMetrics`] — throughput, queue depth, p50/p95/p99
//!   latency, cache hit rate, per-worker utilization. The counters live
//!   in a unified [`obs::Registry`](crate::obs::Registry) (see
//!   [`MineService::registry`]) so `epminer stats` renders the service,
//!   cluster, and coordinator in one snapshot.
//! - observability — [`ServiceConfig::tracing`] mints a per-query
//!   [`TraceId`](crate::obs::TraceId) at admission and records a span
//!   tree per query; [`ServiceConfig::profile`] attaches an
//!   [`obs::MineProfile`](crate::obs::MineProfile) phase breakdown to
//!   every result (cache hits annotated `cache_outcome="cache"`); and
//!   [`ServiceConfig::slow_query_threshold`] dumps the span tree of any
//!   over-budget query into the bounded slow-query log
//!   ([`MineService::slow_queries`]).
//! - [`loadgen`] — a closed-loop load generator over a scenario mix (hot
//!   repeats, theta sweeps, distinct datasets, sliding stream windows fed
//!   by the partition producer), driving `epminer serve-bench` and
//!   `benches/serve_load.rs`.
//!
//! [`MineError::Busy`]: crate::error::MineError::Busy

pub mod cache;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod query;

pub use cache::{CacheStats, ResultCache};
pub use metrics::ServiceMetrics;
pub use pool::{
    mine_direct, Admitted, ConnectivityTicket, MineService, ServiceConfig, SlowQuery,
    Subscription, Ticket, WatchLogConfig, WorkItem, WorkOutput, SLOW_QUERY_LOG,
};
pub use query::{ConnectivityQuery, Query, QueryKey, Request, SubscribeQuery};
