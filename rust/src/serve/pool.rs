//! The multi-tenant mining service: a worker pool over the counting
//! engines, with request coalescing, a result cache, and bounded
//! admission.
//!
//! Every way of asking the service for work is one arm of the typed
//! [`Request`] enum, admitted through the same validation and routed at
//! a single dispatch point, [`MineService::request`]. The convenience
//! wrappers ([`MineService::submit`], [`MineService::subscribe`],
//! [`MineService::submit_connectivity`]) are thin shims over it. Layout
//! of one mining request's life (connectivity requests follow the same
//! path — one queue slot, one cache entry — but the worker that claims
//! one fans out into `1 + n_surrogates` internal mines through
//! [`analysis::batch`](crate::analysis::batch)):
//!
//! ```text
//! request(req) ── key() ── cache? ──hit──> Ticket::Ready
//!                    │
//!                    ├── in-flight? ──yes──> Ticket joins that job (coalesced)
//!                    │
//!                    └── queue full? ──yes──> MineError::Busy (admission control)
//!                                  └──no───> job queued ──> worker thread:
//!                                            build engine (thread-local),
//!                                            mine_with_backend, cache insert,
//!                                            wake every coalesced waiter
//! ```
//!
//! Workers construct engines, not sessions: [`crate::Session`] holds an
//! `Rc<Runtime>` and is deliberately not `Send`, so each worker thread
//! opens its own runtime handle (when the strategy is accelerated) and
//! builds a fresh engine per job via [`crate::session::engine_for`],
//! running the shared [`mine_with_backend`] driver directly (the traced
//! variant, so per-query spans and phase profiles ride along). CPU engine
//! construction is a few allocations; the per-job build is what lets
//! theta-specific two-pass wrappers differ between jobs.
//!
//! [`mine_with_backend`]: crate::session::mine_with_backend

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::analysis::batch::BatchConfig;
use crate::analysis::connectivity::{infer_connectivity, ConnectivityConfig, ConnectivityResult};
use crate::coordinator::miner::MineResult;
use crate::coordinator::{Metrics, Strategy};
use crate::error::MineError;
use crate::obs::{Counter, Histogram, MineProfile, Registry, Trace};
use crate::runtime::Runtime;
use crate::session::{engine_for, mine_with_backend_obs};

use crate::stream::{CommitUpdate, IncrementalConfig, LogWatcher};

use super::cache::ResultCache;
use super::metrics::ServiceMetrics;
use super::query::{ConnectivityQuery, Query, QueryKey, Request, SubscribeQuery};

/// Pool/cache/admission knobs for [`MineService::start`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// worker threads (each executes one query at a time)
    pub workers: usize,
    /// bounded job queue: submissions beyond this depth are rejected with
    /// [`MineError::Busy`] instead of buffering unboundedly
    pub queue_capacity: usize,
    /// total result-cache entries (0 disables caching)
    pub cache_capacity: usize,
    /// cache shard count (rounded up to a power of two)
    pub cache_shards: usize,
    /// the engine every worker builds per job
    pub strategy: Strategy,
    /// threads *inside* each worker's engine. Default 1: the pool's
    /// parallelism is across queries; nested engine threads oversubscribe
    /// unless the workload is a few huge queries.
    pub cpu_threads: usize,
    /// fan-out threads *inside* the one worker that claims a
    /// [`ConnectivityQuery`]: the batched executor spreads the
    /// `1 + n_surrogates` internal mines over this many engines while the
    /// request itself holds a single queue slot (admission counts it as
    /// one tenant job). Default: available parallelism — a connectivity
    /// request is a burst workload, unlike the steady per-query engines
    /// `cpu_threads` guards.
    pub connectivity_parallelism: usize,
    /// how many recent execution latencies the metrics window keeps
    pub latency_window: usize,
    /// live-update subscriptions one tenant may hold at once; the next
    /// [`MineService::subscribe`] beyond this is rejected with
    /// [`MineError::Busy`] (the subscription analogue of the bounded job
    /// queue)
    pub max_subscriptions_per_tenant: usize,
    /// tail a [`SpikeLog`](crate::ingest::SpikeLog) directory and publish
    /// each incremental commit to this service's subscribers — see
    /// [`WatchLogConfig`]. `None` (the default): updates arrive only when
    /// an external caller drives [`MineService::publish`].
    pub watch_log: Option<WatchLogConfig>,
    /// mint a [`TraceId`](crate::obs::TraceId) at admission and record a
    /// span tree for every query (default off — disabled tracing is
    /// zero-allocation on the mining hot path)
    pub tracing: bool,
    /// attach an [`obs::MineProfile`](crate::obs::MineProfile) to every
    /// result (default off); cache hits are annotated
    /// `cache_outcome="cache"`
    pub profile: bool,
    /// dump the span tree of any query whose submit-to-completion latency
    /// exceeds this into the bounded slow-query log
    /// ([`MineService::slow_queries`]); setting it implies per-query
    /// tracing even when `tracing` is off
    pub slow_query_threshold: Option<Duration>,
}

/// Bounded slow-query log depth: newest [`SlowQuery`] records evict the
/// oldest beyond this.
pub const SLOW_QUERY_LOG: usize = 64;

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
            queue_capacity: 64,
            cache_capacity: 256,
            cache_shards: 8,
            strategy: Strategy::CpuParallel,
            cpu_threads: 1,
            connectivity_parallelism: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            latency_window: 4096,
            max_subscriptions_per_tenant: 4,
            watch_log: None,
            tracing: false,
            profile: false,
            slow_query_threshold: None,
        }
    }
}

/// One slow-query log entry: the query's trace id, how long it took,
/// and its rendered span tree (text flamegraph) at completion.
#[derive(Clone, Debug)]
pub struct SlowQuery {
    /// hex trace id ("" when tracing was off for this query)
    pub trace_id: String,
    /// submit-to-completion latency
    pub latency: Duration,
    /// [`Trace::render_tree`] output at completion
    pub tree: String,
}

/// Make the service its own publisher: a [`LogWatcher`] thread tails a
/// [`SpikeLog`](crate::ingest::SpikeLog) directory and pushes every
/// [`CommitUpdate`] it commits to subscribers of the configured topic.
/// With this set, a tenant that [`subscribe`](MineService::subscribe)s
/// against a `log:` dataset receives live updates without any external
/// process driving [`MineService::publish`]. The watcher replays
/// already-sealed history on its first poll (window state identical to
/// having watched from the start) and is joined at shutdown.
#[derive(Clone, Debug)]
pub struct WatchLogConfig {
    /// the log directory to tail
    pub dir: PathBuf,
    /// incremental-mining parameters (theta, intervals, window, K)
    pub config: IncrementalConfig,
    /// manifest poll cadence; shutdown interrupts a sleeping poller, so
    /// a long cadence does not delay teardown
    pub poll_interval: Duration,
    /// publish topic; `None` means `log:<dir>`, matching the `log:`
    /// dataset spec the CLI uses for the same directory
    pub topic: Option<String>,
}

impl WatchLogConfig {
    /// Watch `dir` at a 200ms cadence, publishing to `log:<dir>`.
    pub fn new(dir: impl Into<PathBuf>, config: IncrementalConfig) -> WatchLogConfig {
        WatchLogConfig {
            dir: dir.into(),
            config,
            poll_interval: Duration::from_millis(200),
            topic: None,
        }
    }

    /// The topic updates are published to (`log:<dir>` unless overridden).
    pub fn resolved_topic(&self) -> String {
        match &self.topic {
            Some(t) => t.clone(),
            None => format!("log:{}", self.dir.display()),
        }
    }
}

/// One unit of queued work: the executable payload behind every
/// [`Request`] arm that takes a queue slot. The cache and in-flight map
/// store these, so a fingerprint collision between kinds still fails the
/// [`WorkItem::equivalent`] check (cross-kind is never equivalent) and
/// degrades to a miss, exactly like a same-kind collision.
#[derive(Clone, Debug)]
pub enum WorkItem {
    Mine(Query),
    Connectivity(ConnectivityQuery),
}

impl WorkItem {
    /// The kind-discriminated cache/coalescing identity.
    pub fn key(&self) -> QueryKey {
        match self {
            WorkItem::Mine(q) => q.key(),
            WorkItem::Connectivity(c) => c.key(),
        }
    }

    /// Exact semantic equality; items of different kinds are never
    /// equivalent.
    pub fn equivalent(&self, other: &WorkItem) -> bool {
        match (self, other) {
            (WorkItem::Mine(a), WorkItem::Mine(b)) => a.equivalent(b),
            (WorkItem::Connectivity(a), WorkItem::Connectivity(b)) => a.equivalent(b),
            _ => false,
        }
    }
}

/// What one execution produced, matching its [`WorkItem`]'s kind. Cheap
/// to clone (the payload is `Arc`-shared), so cache entries and coalesced
/// waiters all hand out the same allocation.
#[derive(Clone, Debug)]
pub enum WorkOutput {
    Mine(Arc<MineResult>),
    Connectivity(Arc<ConnectivityResult>),
}

/// One execution's outcome: the shared output, or an error each waiter
/// receives a duplicate of.
type JobOutcome = Result<WorkOutput, MineError>;

/// One admitted execution; coalesced waiters share it through the `Arc`.
struct Job {
    key: QueryKey,
    item: WorkItem,
    submitted: Instant,
    /// per-query span recorder, minted at admission; [`Trace::off`] when
    /// the service runs without tracing
    trace: Trace,
    /// tickets that coalesced onto this job after it was admitted; feeds
    /// the [`ServiceMetrics::coalesced_waiting`] gauge, which counts
    /// waiters separately from queued jobs (a waiter holds no queue slot)
    waiters: AtomicU64,
    slot: Mutex<Option<JobOutcome>>,
    done: Condvar,
}

impl Job {
    fn resolve(&self, outcome: JobOutcome) {
        let mut slot = self.slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some(outcome);
        }
        drop(slot);
        self.done.notify_all();
    }
}

/// A claim on a plain mine's result. `Ready` tickets were answered from
/// the cache at submit time; `Pending` tickets resolve when the (possibly
/// shared) execution completes.
pub struct Ticket(TicketState);

/// A claim on a connectivity request's result — the same admission state
/// machine as [`Ticket`], typed to what the request produces.
pub struct ConnectivityTicket(TicketState);

enum TicketState {
    Ready(WorkOutput),
    Pending(Arc<Job>),
}

/// Block until the (possibly coalesced) execution resolves; `Ready`
/// states return immediately. Both ticket types funnel through here.
fn wait_outcome(state: TicketState) -> JobOutcome {
    match state {
        TicketState::Ready(output) => Ok(output),
        TicketState::Pending(job) => {
            let mut slot = job.slot.lock().unwrap();
            while slot.is_none() {
                slot = job.done.wait(slot).unwrap();
            }
            slot.as_ref().unwrap().clone()
        }
    }
}

impl Ticket {
    /// Block until the result is available. Coalesced waiters each get
    /// the same `Arc`'d result (or a duplicate of the same error).
    pub fn wait(self) -> Result<Arc<MineResult>, MineError> {
        match wait_outcome(self.0)? {
            WorkOutput::Mine(result) => Ok(result),
            // unreachable by construction: admission only pairs a mine
            // item with a mine output — typed here instead of panicking
            WorkOutput::Connectivity(_) => {
                Err(MineError::internal("mine ticket resolved with a connectivity result"))
            }
        }
    }

    /// Was this ticket answered from the cache at submit time?
    pub fn from_cache(&self) -> bool {
        matches!(self.0, TicketState::Ready(_))
    }
}

impl ConnectivityTicket {
    /// Block until the inference pipeline (real mine + surrogate fan-out
    /// + scoring) completes; coalesced waiters share the same `Arc`.
    pub fn wait(self) -> Result<Arc<ConnectivityResult>, MineError> {
        match wait_outcome(self.0)? {
            WorkOutput::Connectivity(result) => Ok(result),
            WorkOutput::Mine(_) => Err(MineError::internal(
                "connectivity ticket resolved with a plain mine result",
            )),
        }
    }

    /// Was this ticket answered from the cache at submit time?
    pub fn from_cache(&self) -> bool {
        matches!(self.0, TicketState::Ready(_))
    }
}

/// What [`MineService::request`] hands back: one arm per [`Request`]
/// arm. The typed wrappers (`submit`, `submit_connectivity`,
/// `subscribe`) unwrap the matching arm for callers that know their
/// request kind statically.
pub enum Admitted {
    Mine(Ticket),
    Subscription(Subscription),
    Connectivity(ConnectivityTicket),
}

struct QueueState {
    jobs: VecDeque<Arc<Job>>,
    /// test/ops hook: a paused pool admits and coalesces but does not
    /// execute until [`MineService::resume`]
    paused: bool,
}

/// One subscriber's mailbox. Publishers push under the mutex and notify;
/// the subscriber drains via [`Subscription::try_recv`] /
/// [`Subscription::recv_timeout`]. A full mailbox drops its *oldest*
/// update — a slow consumer loses history (each update carries the full
/// frequent set, so the latest is always sufficient to resynchronize) and
/// never blocks the publisher or other subscribers.
struct SubShared {
    queue: Mutex<VecDeque<Arc<CommitUpdate>>>,
    cv: Condvar,
    closed: AtomicBool,
    buffer: usize,
}

struct SubEntry {
    tenant: String,
    topic: String,
    shared: Arc<SubShared>,
}

#[derive(Default)]
struct HubState {
    subs: HashMap<u64, SubEntry>,
    next_id: u64,
}

struct Shared {
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    queue_capacity: usize,
    inflight: Mutex<HashMap<QueryKey, Arc<Job>>>,
    cache: ResultCache,
    strategy: Strategy,
    cpu_threads: usize,
    connectivity_parallelism: usize,
    shutdown: AtomicBool,
    started: Instant,
    /// the unified metrics namespace; the fields below are live handles
    /// into it (the atomic a handle wraps IS the registry's number — a
    /// snapshot needs no copy step)
    registry: Registry,
    submitted: Counter,
    completed: Counter,
    failed: Counter,
    rejected: Counter,
    coalesced: Counter,
    latencies_ns: Histogram,
    busy_ns: Vec<Counter>,
    hub: Mutex<HubState>,
    max_subs_per_tenant: usize,
    subs_rejected: Counter,
    updates_published: Counter,
    updates_dropped: Counter,
    trace_queries: bool,
    profile: bool,
    slow_query_threshold: Option<Duration>,
    slow: Mutex<VecDeque<SlowQuery>>,
}

impl Shared {
    /// Cache hits hand back the cached `Arc` untouched unless profiling
    /// is on, in which case a clone is annotated `cache_outcome="cache"`
    /// so the tenant can tell a 2µs cache answer from a fresh execution.
    /// Connectivity hits annotate the base (real-stream) mine's profile.
    fn annotate_cache_hit(&self, hit: WorkOutput) -> WorkOutput {
        if !self.profile {
            return hit;
        }
        match hit {
            WorkOutput::Mine(r) => {
                let mut r = (*r).clone();
                mark_profile_cached(&mut r);
                WorkOutput::Mine(Arc::new(r))
            }
            WorkOutput::Connectivity(c) => {
                let mut c = (*c).clone();
                mark_profile_cached(&mut c.base);
                WorkOutput::Connectivity(Arc::new(c))
            }
        }
    }

    /// A fresh per-query trace when tracing (or the slow-query log)
    /// wants one; the zero-cost disabled trace otherwise.
    fn new_trace(&self) -> Trace {
        if self.trace_queries || self.slow_query_threshold.is_some() {
            Trace::started()
        } else {
            Trace::off()
        }
    }
}

/// Stamp `cache_outcome="cache"` onto a result's profile (creating an
/// otherwise-empty profile when the cached run was executed unprofiled).
fn mark_profile_cached(r: &mut MineResult) {
    match &mut r.profile {
        Some(p) => p.cache_outcome = Some("cache".to_string()),
        None => {
            r.profile = Some(MineProfile {
                cache_outcome: Some("cache".to_string()),
                ..MineProfile::default()
            })
        }
    }
}

/// The service: start it, submit [`Query`]s from any thread, shut it down
/// to drain. See the module docs for the data path.
pub struct MineService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// the [`WatchLogConfig`] tailer, when configured; unparked and
    /// joined at shutdown
    watcher: Option<JoinHandle<()>>,
}

impl MineService {
    pub fn start(cfg: ServiceConfig) -> Result<MineService, MineError> {
        MineService::start_inner(cfg, false)
    }

    /// Start with the worker pool paused: submissions are admitted,
    /// coalesced, and queued, but nothing executes until
    /// [`MineService::resume`]. This makes queue-shape behavior
    /// (coalescing, admission rejection, drain) deterministic for tests
    /// and lets an operator warm the cache before opening the floodgates.
    pub fn start_paused(cfg: ServiceConfig) -> Result<MineService, MineError> {
        MineService::start_inner(cfg, true)
    }

    fn start_inner(cfg: ServiceConfig, paused: bool) -> Result<MineService, MineError> {
        if cfg.workers == 0 {
            return Err(MineError::invalid("ServiceConfig::workers must be >= 1"));
        }
        if cfg.queue_capacity == 0 {
            return Err(MineError::invalid("ServiceConfig::queue_capacity must be >= 1"));
        }
        if cfg.strategy.needs_runtime() {
            // Fail fast at start instead of failing every query later:
            // workers open their own handles, but if the runtime cannot
            // open here it will not open there either.
            drop(Runtime::open_default()?);
        }
        if let Some(wl) = &cfg.watch_log {
            // Same fail-fast contract for the log tailer: if the log will
            // not open (or the incremental config is invalid) here, it
            // will not open in the watcher thread either. The thread
            // builds its own watcher — `LogWatcher` is not `Send`-bound.
            drop(LogWatcher::new(&wl.dir, wl.config.clone())?);
            if wl.poll_interval.is_zero() {
                return Err(MineError::invalid(
                    "WatchLogConfig::poll_interval must be non-zero",
                ));
            }
        }
        let registry = Registry::new();
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), paused }),
            queue_cv: Condvar::new(),
            queue_capacity: cfg.queue_capacity,
            inflight: Mutex::new(HashMap::new()),
            cache: ResultCache::new(cfg.cache_capacity, cfg.cache_shards),
            strategy: cfg.strategy,
            cpu_threads: cfg.cpu_threads.max(1),
            connectivity_parallelism: cfg.connectivity_parallelism.max(1),
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
            submitted: registry.counter("serve.submitted"),
            completed: registry.counter("serve.completed"),
            failed: registry.counter("serve.failed"),
            rejected: registry.counter("serve.rejected"),
            coalesced: registry.counter("serve.coalesced"),
            latencies_ns: registry
                .histogram_windowed("serve.latency_ns", cfg.latency_window.max(1)),
            busy_ns: (0..cfg.workers)
                .map(|wi| registry.counter(&format!("serve.worker.{wi}.busy_ns")))
                .collect(),
            hub: Mutex::new(HubState::default()),
            max_subs_per_tenant: cfg.max_subscriptions_per_tenant.max(1),
            subs_rejected: registry.counter("serve.subscriptions_rejected"),
            updates_published: registry.counter("serve.updates_published"),
            updates_dropped: registry.counter("serve.updates_dropped"),
            trace_queries: cfg.tracing,
            profile: cfg.profile,
            slow_query_threshold: cfg.slow_query_threshold,
            slow: Mutex::new(VecDeque::new()),
            registry,
        });
        let mut workers = Vec::with_capacity(cfg.workers);
        for wi in 0..cfg.workers {
            let worker_shared = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("mine-worker-{wi}"))
                .spawn(move || worker_loop(wi, worker_shared));
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    // Tear the partial pool down rather than leaking the
                    // already-spawned workers (and the Shared they pin)
                    // parked on the condvar forever.
                    {
                        let _queue = shared.queue.lock().unwrap();
                        shared.shutdown.store(true, Ordering::SeqCst);
                    }
                    shared.queue_cv.notify_all();
                    for handle in workers {
                        let _ = handle.join();
                    }
                    return Err(MineError::io("spawning service worker", e));
                }
            }
        }
        let mut service = MineService { shared, workers, watcher: None };
        if let Some(wl) = cfg.watch_log {
            let topic = wl.resolved_topic();
            let watch_shared = Arc::clone(&service.shared);
            let spawned = std::thread::Builder::new()
                .name("mine-watcher".to_string())
                .spawn(move || watcher_loop(&watch_shared, &wl, &topic));
            match spawned {
                Ok(handle) => service.watcher = Some(handle),
                Err(e) => {
                    // shutdown_inner tears the already-running pool down
                    service.shutdown_inner();
                    return Err(MineError::io("spawning log watcher", e));
                }
            }
        }
        Ok(service)
    }

    /// The single dispatch point for every request kind: shared
    /// validation ([`Request::validate`]), then the arm-appropriate
    /// admission — queue-slot admission for the mining arms (cache,
    /// coalescing, bounded queue), the per-tenant subscription cap for
    /// [`Request::Subscribe`]. New query types are new arms here, not
    /// parallel code paths.
    pub fn request(&self, req: Request) -> Result<Admitted, MineError> {
        req.validate()?;
        match req {
            Request::Mine(q) => Ok(Admitted::Mine(Ticket(self.admit(WorkItem::Mine(q))?))),
            Request::Subscribe(s) => Ok(Admitted::Subscription(self.subscribe_inner(s)?)),
            Request::Connectivity(c) => Ok(Admitted::Connectivity(ConnectivityTicket(
                self.admit(WorkItem::Connectivity(c))?,
            ))),
        }
    }

    /// Admit a query. Returns a [`Ticket`] (possibly already resolved
    /// from the cache, possibly joined onto an identical in-flight
    /// execution), or [`MineError::Busy`] when the job queue is full.
    pub fn submit(&self, query: Query) -> Result<Ticket, MineError> {
        match self.request(Request::Mine(query))? {
            Admitted::Mine(ticket) => Ok(ticket),
            _ => Err(MineError::internal("mine request admitted as a different kind")),
        }
    }

    /// Admit a connectivity-inference request. One queue slot and one
    /// cache entry even though execution fans out into `1 + n_surrogates`
    /// internal mines; identical in-flight requests coalesce onto one
    /// pipeline run.
    pub fn submit_connectivity(
        &self,
        query: ConnectivityQuery,
    ) -> Result<ConnectivityTicket, MineError> {
        match self.request(Request::Connectivity(query))? {
            Admitted::Connectivity(ticket) => Ok(ticket),
            _ => Err(MineError::internal("connectivity request admitted as a different kind")),
        }
    }

    /// Queue-slot admission shared by every executable request kind:
    /// cache lookup, verified coalescing onto an in-flight twin, bounded
    /// queue with [`MineError::Busy`]. Validation already happened in
    /// [`MineService::request`].
    fn admit(&self, item: WorkItem) -> Result<TicketState, MineError> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(MineError::invalid("service is shut down"));
        }
        self.shared.submitted.inc();
        let key = item.key();
        if let Some(hit) = self.shared.cache.get(&key, &item) {
            return Ok(TicketState::Ready(self.shared.annotate_cache_hit(hit)));
        }
        let mut inflight = self.shared.inflight.lock().unwrap();
        // Coalesce only onto a *verified-equivalent* in-flight twin: the
        // fingerprint routes, content equality decides (a crafted
        // collision must never hand this tenant another tenant's result).
        // On a collision mismatch the item runs standalone — queued but
        // never registered in the in-flight map, which stays owned by the
        // earlier job.
        let mut register = true;
        if let Some(job) = inflight.get(&key) {
            if job.item.equivalent(&item) {
                self.shared.coalesced.inc();
                job.waiters.fetch_add(1, Ordering::Relaxed);
                return Ok(TicketState::Pending(Arc::clone(job)));
            }
            register = false;
        }
        // A job completes by inserting into the cache *then* leaving the
        // in-flight map, so "not in flight" under this lock means any
        // just-finished twin is already visible in the cache — re-check
        // (uncounted) before paying for a fresh execution.
        if let Some(hit) = self.shared.cache.peek(&key, &item) {
            return Ok(TicketState::Ready(self.shared.annotate_cache_hit(hit)));
        }
        let job = Arc::new(Job {
            key,
            item,
            submitted: Instant::now(),
            trace: self.shared.new_trace(),
            waiters: AtomicU64::new(0),
            slot: Mutex::new(None),
            done: Condvar::new(),
        });
        {
            let mut queue = self.shared.queue.lock().unwrap();
            if queue.jobs.len() >= self.shared.queue_capacity {
                self.shared.rejected.inc();
                return Err(MineError::Busy {
                    queue_depth: queue.jobs.len(),
                    capacity: self.shared.queue_capacity,
                });
            }
            queue.jobs.push_back(Arc::clone(&job));
        }
        if register {
            inflight.insert(key, Arc::clone(&job));
        }
        drop(inflight);
        self.shared.queue_cv.notify_one();
        Ok(TicketState::Pending(job))
    }

    /// Join a live-update topic. The returned [`Subscription`] receives
    /// every [`CommitUpdate`] subsequently [`publish`](MineService::publish)ed
    /// to that topic (as frequent-set diffs — entered / left /
    /// count-changed — plus the full set for resynchronization). A tenant
    /// already holding [`ServiceConfig::max_subscriptions_per_tenant`]
    /// live subscriptions is rejected with [`MineError::Busy`], mirroring
    /// the bounded job queue: `queue_depth` reports the tenant's active
    /// subscriptions, `capacity` the cap.
    pub fn subscribe(&self, query: SubscribeQuery) -> Result<Subscription, MineError> {
        match self.request(Request::Subscribe(query))? {
            Admitted::Subscription(sub) => Ok(sub),
            _ => Err(MineError::internal("subscribe request admitted as a different kind")),
        }
    }

    /// The subscription arm of [`MineService::request`] (validation
    /// already ran there).
    fn subscribe_inner(&self, query: SubscribeQuery) -> Result<Subscription, MineError> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(MineError::invalid("service is shut down"));
        }
        let mut hub = self.shared.hub.lock().unwrap();
        let active = hub.subs.values().filter(|s| s.tenant == query.tenant).count();
        if active >= self.shared.max_subs_per_tenant {
            self.shared.subs_rejected.inc();
            return Err(MineError::Busy {
                queue_depth: active,
                capacity: self.shared.max_subs_per_tenant,
            });
        }
        let sub = Arc::new(SubShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            buffer: query.buffer,
        });
        let id = hub.next_id;
        hub.next_id += 1;
        hub.subs.insert(
            id,
            SubEntry { tenant: query.tenant, topic: query.topic, shared: Arc::clone(&sub) },
        );
        drop(hub);
        Ok(Subscription { id, sub, service: Arc::clone(&self.shared) })
    }

    /// Push one incremental-mining commit to every subscriber of `topic`
    /// (typically called by whatever drives a
    /// [`stream::LogWatcher`](crate::stream::LogWatcher) or
    /// [`stream::IncrementalMiner`](crate::stream::IncrementalMiner)).
    /// Subscribers share one `Arc` of the update. Full mailboxes drop
    /// their oldest entry rather than blocking. Returns how many
    /// subscribers were handed the update.
    pub fn publish(&self, topic: &str, update: CommitUpdate) -> usize {
        publish_update(&self.shared, topic, update)
    }

    /// Open a paused pool (no-op when already running).
    pub fn resume(&self) {
        self.shared.queue.lock().unwrap().paused = false;
        self.shared.queue_cv.notify_all();
    }

    /// Point-in-time health snapshot. The counters read the same live
    /// registry handles the hot path bumps; derived gauges (queue depth,
    /// waiters, cache occupancy) are refreshed into the registry here so
    /// an `epminer stats` snapshot carries them too.
    pub fn metrics(&self) -> ServiceMetrics {
        let cache = self.shared.cache.stats();
        let queue_depth = self.shared.queue.lock().unwrap().jobs.len();
        // gauge, not counter: waiters on jobs that already resolved
        // left the in-flight map with their job
        let coalesced_waiting: usize = self
            .shared
            .inflight
            .lock()
            .unwrap()
            .values()
            .map(|job| job.waiters.load(Ordering::Relaxed) as usize)
            .sum();
        let subscriptions_active = self.shared.hub.lock().unwrap().subs.len();
        let reg = &self.shared.registry;
        reg.gauge("serve.queue_depth").set(queue_depth as i64);
        reg.gauge("serve.coalesced_waiting").set(coalesced_waiting as i64);
        reg.gauge("serve.subscriptions_active").set(subscriptions_active as i64);
        reg.gauge("serve.cache.entries").set(cache.entries as i64);
        reg.gauge("serve.cache.hits").set(cache.hits as i64);
        reg.gauge("serve.cache.misses").set(cache.misses as i64);
        reg.gauge("serve.cache.evictions").set(cache.evictions as i64);
        ServiceMetrics {
            submitted: self.shared.submitted.get(),
            completed: self.shared.completed.get(),
            failed: self.shared.failed.get(),
            rejected: self.shared.rejected.get(),
            coalesced: self.shared.coalesced.get(),
            coalesced_waiting,
            cache,
            queue_depth,
            uptime: self.shared.started.elapsed(),
            latency_ns: self.shared.latencies_ns.summary(),
            worker_busy: self
                .shared
                .busy_ns
                .iter()
                .map(|b| std::time::Duration::from_nanos(b.get()))
                .collect(),
            subscriptions_active,
            subscriptions_rejected: self.shared.subs_rejected.get(),
            updates_published: self.shared.updates_published.get(),
            updates_dropped: self.shared.updates_dropped.get(),
        }
    }

    /// The unified metrics registry this service publishes into. Clone
    /// it to register additional subsystems (the cluster node does) or
    /// to render `epminer stats`.
    pub fn registry(&self) -> Registry {
        self.shared.registry.clone()
    }

    /// The slow-query log, oldest first: every query whose latency
    /// exceeded [`ServiceConfig::slow_query_threshold`], with its span
    /// tree. Bounded at [`SLOW_QUERY_LOG`] records.
    pub fn slow_queries(&self) -> Vec<SlowQuery> {
        self.shared.slow.lock().unwrap().iter().cloned().collect()
    }

    /// Graceful shutdown: stop admitting, let workers drain every queued
    /// job (paused pools drain too), join them, and return the final
    /// metrics snapshot.
    pub fn shutdown(mut self) -> ServiceMetrics {
        self.shutdown_inner();
        self.metrics()
    }

    fn shutdown_inner(&mut self) {
        {
            // The store must happen under the queue mutex: a worker that
            // just checked the flag (false) while holding the lock is
            // guaranteed to reach `wait` before this store can proceed,
            // so the notify below cannot be lost between its check and
            // its sleep.
            let _queue = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.queue_cv.notify_all();
        if let Some(handle) = self.watcher.take() {
            // wake a sleeping poller; the unpark token is buffered, so a
            // watcher mid-poll still returns immediately from its next
            // park_timeout and sees the shutdown flag
            handle.thread().unpark();
            let _ = handle.join();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
        // A submit racing the shutdown flag can enqueue after the workers
        // drained; fail those tickets rather than leaving waiters hung.
        let leftovers: Vec<Arc<Job>> =
            self.shared.queue.lock().unwrap().jobs.drain(..).collect();
        for job in leftovers {
            self.shared.inflight.lock().unwrap().remove(&job.key);
            job.resolve(Err(MineError::invalid("service shut down before the query ran")));
        }
        // Close every live subscription so blocked receivers return
        // instead of waiting out their timeouts on a dead service.
        let mut hub = self.shared.hub.lock().unwrap();
        for entry in hub.subs.values() {
            entry.shared.closed.store(true, Ordering::SeqCst);
            entry.shared.cv.notify_all();
        }
        hub.subs.clear();
    }
}

/// A live claim on a topic's update feed, handed out by
/// [`MineService::subscribe`]. Dropping it unregisters the subscription
/// (freeing the tenant's slot); service shutdown closes it remotely.
pub struct Subscription {
    id: u64,
    sub: Arc<SubShared>,
    service: Arc<Shared>,
}

impl Subscription {
    /// The next buffered update, without blocking.
    pub fn try_recv(&self) -> Option<Arc<CommitUpdate>> {
        self.sub.queue.lock().unwrap().pop_front()
    }

    /// Block until an update arrives, the subscription closes, or the
    /// timeout elapses. Returns `None` on close/timeout — check
    /// [`Subscription::is_closed`] to tell the two apart.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Arc<CommitUpdate>> {
        let deadline = Instant::now() + timeout;
        let mut queue = self.sub.queue.lock().unwrap();
        loop {
            if let Some(update) = queue.pop_front() {
                return Some(update);
            }
            if self.sub.closed.load(Ordering::SeqCst) {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (q, timed_out) =
                self.sub.cv.wait_timeout(queue, deadline - now).unwrap();
            queue = q;
            if timed_out.timed_out() && queue.is_empty() {
                return None;
            }
        }
    }

    /// Updates currently buffered and undelivered.
    pub fn backlog(&self) -> usize {
        self.sub.queue.lock().unwrap().len()
    }

    /// True once the service shut down (buffered updates may still be
    /// drained with [`Subscription::try_recv`]).
    pub fn is_closed(&self) -> bool {
        self.sub.closed.load(Ordering::SeqCst)
    }
}

impl Drop for Subscription {
    fn drop(&mut self) {
        self.service.hub.lock().unwrap().subs.remove(&self.id);
    }
}

impl Drop for MineService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// The body of [`MineService::publish`], callable from the watcher
/// thread (which holds the `Arc<Shared>`, not the service handle).
fn publish_update(shared: &Shared, topic: &str, update: CommitUpdate) -> usize {
    let update = Arc::new(update);
    let hub = shared.hub.lock().unwrap();
    let mut delivered = 0;
    for entry in hub.subs.values().filter(|s| s.topic == topic) {
        let mut queue = entry.shared.queue.lock().unwrap();
        while queue.len() >= entry.shared.buffer {
            queue.pop_front();
            shared.updates_dropped.inc();
        }
        queue.push_back(Arc::clone(&update));
        drop(queue);
        entry.shared.cv.notify_all();
        delivered += 1;
    }
    drop(hub);
    shared.updates_published.inc();
    delivered
}

/// The [`WatchLogConfig`] thread: poll the log, publish every commit,
/// sleep (interruptibly) until the next cadence tick or shutdown.
fn watcher_loop(shared: &Shared, wl: &WatchLogConfig, topic: &str) {
    // start_inner probed this construction; a failure now (log deleted
    // in the window between probe and spawn) ends the feed, which is
    // also what a later poll error does.
    let Ok(mut watcher) = LogWatcher::new(&wl.dir, wl.config.clone()) else {
        return;
    };
    while !shared.shutdown.load(Ordering::SeqCst) {
        match watcher.poll() {
            Ok(updates) => {
                for update in updates {
                    publish_update(shared, topic, update);
                }
            }
            // the log regressed or corrupted under us: stop publishing
            // rather than spinning on the same error; subscribers keep
            // their buffered history
            Err(_) => return,
        }
        std::thread::park_timeout(wl.poll_interval);
    }
}

fn worker_loop(wi: usize, shared: Arc<Shared>) {
    // Thread-local runtime handle for accelerated strategies: `Rc` never
    // crosses the thread boundary, each worker owns its own.
    let (rt, rt_err): (Option<Rc<Runtime>>, Option<MineError>) =
        if shared.strategy.needs_runtime() {
            match Runtime::open_default() {
                Ok(rt) => (Some(Rc::new(rt)), None),
                Err(e) => (None, Some(e)),
            }
        } else {
            (None, None)
        };

    loop {
        let job = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                let draining = shared.shutdown.load(Ordering::SeqCst);
                if !queue.paused || draining {
                    if let Some(job) = queue.jobs.pop_front() {
                        break job;
                    }
                    if draining {
                        return;
                    }
                }
                queue = shared.queue_cv.wait(queue).unwrap();
            }
        };

        let t0 = Instant::now();
        let outcome = match &rt_err {
            Some(e) => Err(e.clone()),
            // Contain panics: an unwinding worker would die with the job
            // unresolved and its in-flight entry stuck, hanging the
            // submitter and every future identical query. A panic becomes
            // a typed error on this job; the worker lives on.
            None => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                execute_item(&job.item, &shared, rt.clone(), &job.trace)
            }))
            .unwrap_or_else(|_| {
                Err(MineError::internal("worker panicked while executing the query"))
            }),
        };
        shared.busy_ns[wi].add(t0.elapsed().as_nanos() as u64);

        let outcome = match outcome {
            Ok(output) => {
                shared.cache.insert(job.key, job.item.clone(), output.clone());
                shared.completed.inc();
                Ok(output)
            }
            Err(e) => {
                shared.failed.inc();
                Err(e)
            }
        };
        let elapsed = job.submitted.elapsed();
        shared.latencies_ns.observe(elapsed.as_nanos() as f64);
        if shared.slow_query_threshold.is_some_and(|th| elapsed >= th) && job.trace.is_on() {
            let mut slow = shared.slow.lock().unwrap();
            while slow.len() >= SLOW_QUERY_LOG {
                slow.pop_front();
            }
            slow.push_back(SlowQuery {
                trace_id: job.trace.id().map(|i| i.to_hex()).unwrap_or_default(),
                latency: elapsed,
                tree: job.trace.render_tree(),
            });
        }
        // Leave the in-flight map only after the cache insert above, so a
        // submit that finds the key absent here can trust the cache
        // re-check (see `MineService::submit`). A standalone job from a
        // collision mismatch was never registered — only evict the entry
        // if it is actually this job, or a colliding twin's registration
        // would be torn down mid-flight.
        {
            let mut inflight = shared.inflight.lock().unwrap();
            if inflight.get(&job.key).is_some_and(|current| Arc::ptr_eq(current, &job)) {
                inflight.remove(&job.key);
            }
        }
        job.resolve(outcome);
    }
}

/// Run one query to completion on a freshly built engine — also the
/// serial "re-mine every request" baseline the service's repeat-query
/// speedup is measured against (`benches/serve_load.rs`).
pub fn mine_direct(
    query: &Query,
    strategy: Strategy,
    cpu_threads: usize,
) -> Result<MineResult, MineError> {
    execute(query, strategy, None, cpu_threads, &Trace::off(), false)
}

/// Execute one claimed [`WorkItem`] on this worker thread. Plain mines
/// run on the worker's thread-local engine state; a connectivity item
/// hands its `1 + n_surrogates` fan-out to the batched executor, whose
/// workers are scoped threads that build their own engines (so the
/// worker's `rt` handle stays thread-local and unused for that arm).
fn execute_item(
    item: &WorkItem,
    shared: &Shared,
    rt: Option<Rc<Runtime>>,
    trace: &Trace,
) -> Result<WorkOutput, MineError> {
    match item {
        WorkItem::Mine(query) => {
            execute(query, shared.strategy, rt, shared.cpu_threads, trace, shared.profile)
                .map(|r| WorkOutput::Mine(Arc::new(r)))
        }
        WorkItem::Connectivity(c) => {
            let cfg = ConnectivityConfig {
                n_surrogates: c.n_surrogates,
                jitter: c.jitter,
                seed: c.seed,
                batch: BatchConfig {
                    strategy: shared.strategy,
                    two_pass: c.mine.two_pass,
                    cpu_threads: shared.cpu_threads,
                    parallelism: shared.connectivity_parallelism,
                    profile: shared.profile,
                },
            };
            infer_connectivity(&c.mine.stream, &c.mine.options(), &cfg, trace)
                .map(|r| WorkOutput::Connectivity(Arc::new(r)))
        }
    }
}

fn execute(
    query: &Query,
    strategy: Strategy,
    rt: Option<Rc<Runtime>>,
    cpu_threads: usize,
    trace: &Trace,
    profile: bool,
) -> Result<MineResult, MineError> {
    let mut engine = engine_for(strategy, rt, query.two_pass, query.theta, cpu_threads)?;
    let mut metrics = Metrics::default();
    mine_with_backend_obs(&mut *engine, &query.stream, &query.options(), &mut metrics, trace, profile)
}
