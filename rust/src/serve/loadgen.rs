//! Closed-loop load generator for [`MineService`]: M client threads, each
//! submitting and waiting (closed loop — a client has at most one request
//! outstanding), drawing from a weighted scenario mix:
//!
//! - **hot repeats** — a small set of queries hit over and over (the
//!   connectivity-inference pattern: many analyses over one recording);
//!   after the first execution these are cache hits.
//! - **theta sweeps** — the same stream at stepped support thresholds
//!   (the parameter-scan pattern); every theta is a distinct key, but
//!   clients step in lockstep so coalescing and caching both help.
//! - **distinct datasets** — unique streams, guaranteed cache misses
//!   (and, past cache capacity, evictions).
//! - **sliding stream windows** — partitions of the base stream produced
//!   by the existing chip-on-chip partition producer
//!   ([`spawn_producer_with`]), the streaming re-mine pattern.
//!
//! The [`Workload`] (query universe) is built once and deterministically
//! from the config seed, so the same scenario set can be replayed against
//! the service, the serial baseline, or a direct `Session` — that replay
//! is how the service-equivalence test and the `serve_load` bench are
//! built.
//!
//! [`cluster_curve`] is the distributed-tier sibling: stepped client
//! counts against a [`ScatterMiner`](crate::cluster::ScatterMiner)
//! coordinator, reporting latency under saturation (and how much load the
//! coordinator's tenant-aware admission shed) per step.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::streaming::{spawn_producer_with, ProducerConfig};
use crate::episodes::Interval;
use crate::error::MineError;
use crate::events::EventStream;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::metrics::ServiceMetrics;
use super::pool::MineService;
use super::query::{Query, SubscribeQuery};

/// Topic the loadgen's live publisher pushes incremental commits to.
pub const LIVE_TOPIC: &str = "loadgen/live";

/// Relative draw weights for the scenario mix (0 disables a scenario).
#[derive(Clone, Copy, Debug)]
pub struct MixWeights {
    pub hot_repeat: u32,
    pub theta_sweep: u32,
    pub distinct: u32,
    pub sliding_window: u32,
}

impl Default for MixWeights {
    fn default() -> MixWeights {
        MixWeights { hot_repeat: 60, theta_sweep: 20, distinct: 10, sliding_window: 10 }
    }
}

impl MixWeights {
    fn total(&self) -> u32 {
        self.hot_repeat + self.theta_sweep + self.distinct + self.sliding_window
    }
}

/// Load-generator shape: client count, per-client request count, the mix,
/// and the synthetic-workload sizes.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    pub clients: usize,
    pub requests_per_client: usize,
    pub mix: MixWeights,
    pub seed: u64,
    /// When set, the shared base stream (hot/sweep/sliding scenarios)
    /// comes from this dataset spec via [`crate::datasets::resolve`] —
    /// any registry name, `file:<path>`, or `log:<dir>` — so the load
    /// generator can replay recorded history instead of a synthetic
    /// stream. `base_events`/`n_types` then only shape the distinct-pool
    /// scenario.
    pub base_dataset: Option<String>,
    /// events in the synthetic base stream (when `base_dataset` is None)
    pub base_events: usize,
    pub n_types: usize,
    /// number of distinct hot queries
    pub hot_set: usize,
    /// theta sweep over `sweep_theta_lo ..= sweep_theta_hi` (stepped)
    pub sweep_theta_lo: u64,
    pub sweep_theta_hi: u64,
    /// pool of unique-stream queries (clients cycle through it), each
    /// with `distinct_events` events
    pub distinct_pool: usize,
    pub distinct_events: usize,
    /// sliding-window width in ticks
    pub window_ticks: i32,
    pub max_level: usize,
    /// live-subscription side channel: when > 0, a publisher thread
    /// drives an incremental miner over the sliding partitions and
    /// publishes each commit to [`LIVE_TOPIC`], while this many
    /// subscriber threads (one tenant each) drain the pushed updates
    /// concurrently with the query load
    pub subscribers: usize,
}

impl Default for LoadGenConfig {
    fn default() -> LoadGenConfig {
        LoadGenConfig {
            clients: 8,
            requests_per_client: 50,
            mix: MixWeights::default(),
            seed: 0x5EED,
            base_dataset: None,
            base_events: 20_000,
            n_types: 8,
            hot_set: 4,
            sweep_theta_lo: 6,
            sweep_theta_hi: 26,
            distinct_pool: 32,
            distinct_events: 2_000,
            window_ticks: 4_000,
            max_level: 4,
            subscribers: 0,
        }
    }
}

impl LoadGenConfig {
    /// The shrunk profile behind every `--smoke` flag (CI, `epminer
    /// serve-bench`, `benches/serve_load.rs`): one definition, so what CI
    /// measures is what the CLI reports.
    pub fn smoke() -> LoadGenConfig {
        LoadGenConfig {
            clients: 4,
            requests_per_client: 25,
            base_events: 6_000,
            distinct_pool: 8,
            distinct_events: 800,
            window_ticks: 1_500,
            ..LoadGenConfig::default()
        }
    }
}

/// The deterministic query universe the clients draw from.
pub struct Workload {
    pub hot: Vec<Query>,
    pub sweep: Vec<Query>,
    pub distinct: Vec<Query>,
    pub sliding: Vec<Query>,
}

fn synth_stream(rng: &mut Rng, events: usize, n_types: usize) -> EventStream {
    let mut pairs = Vec::with_capacity(events);
    let mut t = 0;
    for _ in 0..events {
        t += rng.range_i32(1, 3);
        pairs.push((rng.range_i32(0, n_types as i32 - 1), t));
    }
    EventStream::from_pairs(pairs, n_types)
}

impl Workload {
    pub fn build(cfg: &LoadGenConfig) -> Result<Workload, MineError> {
        if cfg.clients == 0 || cfg.requests_per_client == 0 {
            return Err(MineError::invalid("loadgen needs clients >= 1 and requests >= 1"));
        }
        if cfg.mix.total() == 0 {
            return Err(MineError::invalid("loadgen mix weights must not all be 0"));
        }
        if cfg.n_types < 2 || cfg.base_events == 0 {
            return Err(MineError::invalid("loadgen needs n_types >= 2 and base_events >= 1"));
        }
        let mut rng = Rng::new(cfg.seed);
        let iv = Interval::new(0, 6);
        let base = match &cfg.base_dataset {
            Some(spec) => {
                let (stream, _) = crate::datasets::resolve(spec, cfg.seed)?;
                if stream.is_empty() {
                    return Err(MineError::invalid(format!(
                        "base dataset {spec} resolved to an empty stream"
                    )));
                }
                Arc::new(stream)
            }
            None => Arc::new(synth_stream(&mut rng, cfg.base_events, cfg.n_types)),
        };

        let hot = (0..cfg.hot_set.max(1))
            .map(|i| {
                Query::new(Arc::clone(&base), 8 + 4 * i as u64, vec![iv])
                    .max_level(cfg.max_level)
            })
            .collect();

        let lo = cfg.sweep_theta_lo.max(1);
        let hi = cfg.sweep_theta_hi.max(lo);
        let sweep = (lo..=hi)
            .step_by(2)
            .map(|theta| {
                Query::new(Arc::clone(&base), theta, vec![iv]).max_level(cfg.max_level)
            })
            .collect();

        let distinct = (0..cfg.distinct_pool.max(1))
            .map(|_| {
                let stream =
                    Arc::new(synth_stream(&mut rng, cfg.distinct_events.max(1), cfg.n_types));
                Query::new(stream, 4, vec![iv]).max_level(cfg.max_level)
            })
            .collect();

        // Sliding windows come from the chip-on-chip partition producer
        // (accelerated replay: the load generator wants the partitions,
        // not the pacing).
        let rx = spawn_producer_with(
            (*base).clone(),
            cfg.window_ticks.max(1),
            ProducerConfig { speedup: 1e9, ..Default::default() },
        )?;
        let sliding: Vec<Query> = rx
            .iter()
            .filter(|p| !p.stream.is_empty())
            .map(|p| {
                Query::new(Arc::new(p.stream), 3, vec![iv]).max_level(cfg.max_level)
            })
            .collect();

        Ok(Workload { hot, sweep, distinct, sliding })
    }

    /// Every query in the universe, for scenario-set replays (the
    /// equivalence test mines each one directly and via the service).
    pub fn all(&self) -> impl Iterator<Item = &Query> {
        self.hot
            .iter()
            .chain(self.sweep.iter())
            .chain(self.distinct.iter())
            .chain(self.sliding.iter())
    }
}

/// Aggregated outcome of one load-generation run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub wall: Duration,
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    /// client-observed completed requests per second (cache hits
    /// included — this is the number the ≥5x repeat-query criterion is
    /// about)
    pub qps: f64,
    /// client-observed submit-to-result latency (ns), cache hits included
    pub latency_ns: Option<Summary>,
    /// incremental commits the live publisher pushed (0 when
    /// `cfg.subscribers == 0`)
    pub updates_published: u64,
    /// updates drained across all subscriber threads — at most
    /// `subscribers * updates_published`, less whatever the bounded
    /// per-subscription buffers dropped under load
    pub updates_received: u64,
    /// the service's own snapshot, taken as the last client finished
    pub service: ServiceMetrics,
}

impl LoadReport {
    pub fn to_json(&self) -> String {
        let (p50, p95, p99) = match &self.latency_ns {
            Some(s) => (s.median / 1e6, s.p95 / 1e6, s.p99 / 1e6),
            None => (0.0, 0.0, 0.0),
        };
        format!(
            "{{\"wall_s\":{:.3},\"completed\":{},\"rejected\":{},\"errors\":{},\
             \"qps\":{:.2},\"client_latency_ms\":{{\"p50\":{:.3},\"p95\":{:.3},\
             \"p99\":{:.3}}},\"updates_published\":{},\"updates_received\":{},\
             \"service\":{}}}",
            self.wall.as_secs_f64(),
            self.completed,
            self.rejected,
            self.errors,
            self.qps,
            p50,
            p95,
            p99,
            self.updates_published,
            self.updates_received,
            self.service.to_json(),
        )
    }
}

#[derive(Default)]
struct ClientStats {
    completed: u64,
    rejected: u64,
    errors: u64,
    latencies_ns: Vec<f64>,
}

/// Run the closed loop: `cfg.clients` threads, each issuing
/// `cfg.requests_per_client` requests drawn from the mix, against a
/// running service. With `cfg.subscribers > 0` a live publisher drives an
/// incremental miner over the sliding partitions and the subscribers drain
/// the pushed commits concurrently — so the report measures query
/// throughput with the push path active, not in isolation.
pub fn run(service: &MineService, workload: &Workload, cfg: &LoadGenConfig) -> LoadReport {
    let next_distinct = AtomicUsize::new(0);
    let next_distinct = &next_distinct;
    let live_done = AtomicBool::new(false);
    let live_done = &live_done;
    let t0 = Instant::now();
    let (stats, updates_published, updates_received) = std::thread::scope(|scope| {
        // Subscriptions are registered before the publisher starts so no
        // commit can slip by unobserved; each subscriber is its own tenant
        // (the per-tenant cap is a fairness control, not a fleet limit).
        let subs: Vec<_> = (0..cfg.subscribers)
            .map(|si| {
                let sub = service.subscribe(SubscribeQuery::new(format!("live-{si}"), LIVE_TOPIC));
                scope.spawn(move || {
                    let Ok(sub) = sub else { return 0u64 };
                    let mut got = 0u64;
                    loop {
                        if sub.recv_timeout(Duration::from_millis(25)).is_some() {
                            got += 1;
                            continue;
                        }
                        // Timed out empty: exit once the feed is over and
                        // the backlog is drained (or the service shut the
                        // subscription down under us).
                        if sub.is_closed()
                            || (live_done.load(Ordering::Acquire) && sub.backlog() == 0)
                        {
                            return got;
                        }
                    }
                })
            })
            .collect();
        let publisher = (cfg.subscribers > 0).then(|| {
            scope.spawn(move || {
                let n = publish_live(service, workload, cfg);
                live_done.store(true, Ordering::Release);
                n
            })
        });
        let handles: Vec<_> = (0..cfg.clients)
            .map(|ci| {
                scope.spawn(move || client_loop(ci, service, workload, cfg, next_distinct))
            })
            .collect();
        let stats: Vec<ClientStats> =
            handles.into_iter().map(|h| h.join().expect("load client panicked")).collect();
        let published = publisher.map_or(0, |h| h.join().expect("live publisher panicked"));
        let received: u64 =
            subs.into_iter().map(|h| h.join().expect("live subscriber panicked")).sum();
        (stats, published, received)
    });
    let wall = t0.elapsed();

    let mut latencies: Vec<f64> = vec![];
    let (mut completed, mut rejected, mut errors) = (0, 0, 0);
    for s in stats {
        completed += s.completed;
        rejected += s.rejected;
        errors += s.errors;
        latencies.extend(s.latencies_ns);
    }
    LoadReport {
        wall,
        completed,
        rejected,
        errors,
        qps: completed as f64 / wall.as_secs_f64().max(1e-9),
        latency_ns: Summary::of_opt(&latencies),
        updates_published,
        updates_received,
        service: service.metrics(),
    }
}

/// Replay the sliding partitions through an [`IncrementalMiner`] in arrival
/// order, publishing every commit to [`LIVE_TOPIC`]. Returns the commit
/// count. The sliding queries use theta 3 and the (0, 6] interval — the
/// miner mirrors them so subscribers see the frequent sets a sliding-window
/// client would compute, arriving as diffs instead of re-mines.
///
/// [`IncrementalMiner`]: crate::stream::IncrementalMiner
fn publish_live(service: &MineService, workload: &Workload, cfg: &LoadGenConfig) -> u64 {
    let Some(first) = workload.sliding.first() else { return 0 };
    let mcfg = crate::stream::IncrementalConfig::new(3, vec![Interval::new(0, 6)])
        .max_level(cfg.max_level)
        .window_segments(4);
    let mut miner = match crate::stream::IncrementalMiner::new(first.stream.n_types, mcfg) {
        Ok(m) => m,
        Err(_) => return 0,
    };
    let mut published = 0u64;
    for q in &workload.sliding {
        match miner.push_segment((*q.stream).clone()) {
            Ok(update) => {
                service.publish(LIVE_TOPIC, update);
                published += 1;
            }
            Err(_) => break,
        }
    }
    published
}

fn client_loop(
    ci: usize,
    service: &MineService,
    workload: &Workload,
    cfg: &LoadGenConfig,
    next_distinct: &AtomicUsize,
) -> ClientStats {
    let mut rng = Rng::new(cfg.seed ^ (ci as u64 + 1).wrapping_mul(0xC11E57));
    let mut stats = ClientStats::default();
    // sweeps step in lockstep-ish: staggered starts, sequential advance
    let mut sweep_i = ci;
    // Workload::build rejects an all-zero mix; the max(1) keeps a caller
    // who pairs a prebuilt workload with a zeroed config on the hot path
    // instead of panicking in Rng::below.
    let total = cfg.mix.total().max(1) as u64;
    for _ in 0..cfg.requests_per_client {
        let pick = rng.below(total) as u32;
        let query = pick_query(workload, cfg, pick, &mut rng, &mut sweep_i, next_distinct);
        let t = Instant::now();
        match service.submit(query) {
            Err(MineError::Busy { .. }) => stats.rejected += 1,
            Err(_) => stats.errors += 1,
            Ok(ticket) => match ticket.wait() {
                Ok(_) => {
                    stats.completed += 1;
                    stats.latencies_ns.push(t.elapsed().as_nanos() as f64);
                }
                Err(_) => stats.errors += 1,
            },
        }
    }
    stats
}

fn pick_query(
    workload: &Workload,
    cfg: &LoadGenConfig,
    pick: u32,
    rng: &mut Rng,
    sweep_i: &mut usize,
    next_distinct: &AtomicUsize,
) -> Query {
    let m = &cfg.mix;
    let sweep_edge = m.hot_repeat + m.theta_sweep;
    let distinct_edge = sweep_edge + m.distinct;
    if (m.hot_repeat..sweep_edge).contains(&pick) && !workload.sweep.is_empty() {
        let q = workload.sweep[*sweep_i % workload.sweep.len()].clone();
        *sweep_i += 1;
        return q;
    }
    if (sweep_edge..distinct_edge).contains(&pick) && !workload.distinct.is_empty() {
        let i = next_distinct.fetch_add(1, Ordering::Relaxed);
        return workload.distinct[i % workload.distinct.len()].clone();
    }
    if pick >= distinct_edge && !workload.sliding.is_empty() {
        return workload.sliding[rng.below(workload.sliding.len() as u64) as usize].clone();
    }
    // hot repeat, or the fallback when a drawn scenario's pool is empty
    // (hot is never empty — Workload::build guarantees >= 1)
    workload.hot[rng.below(workload.hot.len() as u64) as usize].clone()
}

/// One step of the multi-node saturation curve: `clients` concurrent
/// closed-loop tenants against one scatter coordinator.
#[derive(Clone, Debug)]
pub struct ClusterCurvePoint {
    pub clients: usize,
    /// distributed mines that returned a result
    pub completed: u64,
    /// mines the coordinator's admission shed with [`MineError::Busy`]
    /// (per-tenant quota or queue pressure) — expected load-shedding
    /// under saturation, not failure
    pub shed: u64,
    pub errors: u64,
    pub qps: f64,
    /// client-observed mine latency (ns)
    pub latency_ns: Option<Summary>,
}

impl ClusterCurvePoint {
    pub fn report(&self) -> String {
        let lat = match &self.latency_ns {
            Some(s) => format!(
                "p50={:.1}ms p95={:.1}ms p99={:.1}ms",
                s.median / 1e6,
                s.p95 / 1e6,
                s.p99 / 1e6
            ),
            None => "no completions".to_string(),
        };
        format!(
            "clients={} completed={} shed={} errors={} qps={:.1} latency[{lat}]",
            self.clients, self.completed, self.shed, self.errors, self.qps
        )
    }
}

/// Latency under saturation against a distributed coordinator: for each
/// entry in `steps`, run that many closed-loop clients, each mining the
/// whole recording `rounds` times under its own tenant (`curve-<i>`), and
/// record the step's throughput/latency/shed counts. The curve's shape is
/// the capacity story: qps should grow with clients until the node pool
/// saturates, after which admission sheds instead of queueing unboundedly.
pub fn cluster_curve(
    miner: &crate::cluster::ScatterMiner,
    opts: &crate::session::MineOptions,
    two_pass: bool,
    steps: &[usize],
    rounds: usize,
) -> Vec<ClusterCurvePoint> {
    let mut points = Vec::with_capacity(steps.len());
    for &clients in steps {
        let clients = clients.max(1);
        let t0 = Instant::now();
        let stats: Vec<ClientStats> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..clients)
                .map(|ci| {
                    scope.spawn(move || {
                        let tenant = format!("curve-{ci}");
                        let mut s = ClientStats::default();
                        for _ in 0..rounds.max(1) {
                            let t = Instant::now();
                            match miner.mine_all(opts, two_pass, &tenant) {
                                Ok(_) => {
                                    s.completed += 1;
                                    s.latencies_ns.push(t.elapsed().as_nanos() as f64);
                                }
                                Err(MineError::Busy { .. }) => s.rejected += 1,
                                Err(_) => s.errors += 1,
                            }
                        }
                        s
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("curve client panicked")).collect()
        });
        let wall = t0.elapsed();
        let mut latencies: Vec<f64> = vec![];
        let (mut completed, mut shed, mut errors) = (0, 0, 0);
        for s in stats {
            completed += s.completed;
            shed += s.rejected;
            errors += s.errors;
            latencies.extend(s.latencies_ns);
        }
        points.push(ClusterCurvePoint {
            clients,
            completed,
            shed,
            errors,
            qps: completed as f64 / wall.as_secs_f64().max(1e-9),
            latency_ns: Summary::of_opt(&latencies),
        });
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> LoadGenConfig {
        LoadGenConfig {
            clients: 2,
            requests_per_client: 4,
            base_events: 1_000,
            distinct_pool: 4,
            distinct_events: 300,
            window_ticks: 600,
            max_level: 3,
            ..LoadGenConfig::default()
        }
    }

    #[test]
    fn workload_is_deterministic_and_non_empty() {
        let cfg = tiny_cfg();
        let a = Workload::build(&cfg).unwrap();
        let b = Workload::build(&cfg).unwrap();
        assert!(!a.hot.is_empty() && !a.sweep.is_empty());
        assert!(!a.distinct.is_empty() && !a.sliding.is_empty());
        let keys =
            |w: &Workload| w.all().map(|q| q.key()).collect::<Vec<_>>();
        assert_eq!(keys(&a), keys(&b), "same seed must replay the same universe");
    }

    #[test]
    fn workload_rejects_degenerate_configs() {
        let mut cfg = tiny_cfg();
        cfg.mix = MixWeights { hot_repeat: 0, theta_sweep: 0, distinct: 0, sliding_window: 0 };
        assert!(Workload::build(&cfg).is_err());
        let mut cfg = tiny_cfg();
        cfg.clients = 0;
        assert!(Workload::build(&cfg).is_err());
    }

    #[test]
    fn sliding_windows_come_from_the_partition_producer() {
        let cfg = tiny_cfg();
        let w = Workload::build(&cfg).unwrap();
        // partitions cover disjoint spans of the base stream: total events
        // across windows equal the base stream's (lossless round-trip)
        let total: usize = w.sliding.iter().map(|q| q.stream.len()).sum();
        assert_eq!(total, cfg.base_events);
        for q in &w.sliding {
            assert!(q.stream.span() <= cfg.window_ticks);
        }
    }
}
