//! Mining queries and their canonical fingerprints.
//!
//! A [`Query`] is everything a service worker needs to reproduce a
//! `Session::mine` run: the event stream plus the mining parameters. Its
//! [`QueryKey`] is an FNV-style 64-bit fingerprint over the *exact* stream
//! contents and every semantic parameter (theta, intervals, max_level,
//! candidate cap, counting mode) — the routing identity for request
//! coalescing and the result cache. The fingerprint only *routes*: every
//! cache hit and coalesce join additionally verifies exact semantic
//! equality ([`Query::equivalent`]), so even a deliberately crafted
//! fingerprint collision costs a cache slot rather than handing one
//! tenant another tenant's [`MineResult`]. Cached answers are never
//! stale: a mutated or extended stream is a different stream and a
//! different key.
//!
//! [`MineResult`]: crate::coordinator::miner::MineResult

use std::sync::Arc;

use crate::episodes::Interval;
use crate::error::MineError;
use crate::events::EventStream;
use crate::session::MineOptions;

/// One mining request: an event stream (shared, so coalesced waiters and
/// scenario generators clone cheaply) plus the `Session`-shaped mining
/// parameters.
#[derive(Clone, Debug)]
pub struct Query {
    pub stream: Arc<EventStream>,
    /// support threshold theta (must be > 0)
    pub theta: u64,
    /// the inter-event constraint set I (must be non-empty)
    pub intervals: Vec<Interval>,
    /// stop after this episode size (default 8)
    pub max_level: usize,
    /// per-level candidate guardrail (default 2,000,000)
    pub max_candidates_per_level: usize,
    /// count two-pass (A2 elimination + exact pass, the default) or
    /// one-pass exact-only
    pub two_pass: bool,
}

impl Query {
    pub fn new(stream: Arc<EventStream>, theta: u64, intervals: Vec<Interval>) -> Query {
        Query {
            stream,
            theta,
            intervals,
            max_level: 8,
            max_candidates_per_level: 2_000_000,
            two_pass: true,
        }
    }

    pub fn max_level(mut self, max_level: usize) -> Query {
        self.max_level = max_level;
        self
    }

    pub fn one_pass(mut self) -> Query {
        self.two_pass = false;
        self
    }

    /// Admission-time validation: the shared parameter invariants
    /// (`MineOptions::validate`, the same validator `SessionBuilder::build`
    /// runs) plus the stream invariants `EventStream` itself only
    /// `debug_assert`s. Service clients are untrusted, and an
    /// out-of-alphabet event type would otherwise panic level-1 counting
    /// (`type_counts` indexes an alphabet-sized table) in release builds.
    /// The O(events) scan rides alongside the O(events) fingerprint every
    /// submission already pays.
    pub fn validate(&self) -> Result<(), MineError> {
        if let Some(&ty) = self
            .stream
            .types
            .iter()
            .find(|&&ty| ty < 0 || ty as usize >= self.stream.n_types)
        {
            return Err(MineError::OutOfAlphabet { type_id: ty, n_types: self.stream.n_types });
        }
        if !self.stream.times.windows(2).all(|w| w[0] <= w[1]) {
            return Err(MineError::invalid(
                "query stream must be time-sorted (build it with EventStream::from_pairs)",
            ));
        }
        self.options().validate()
    }

    /// Exact semantic equality — the collision-proofing check behind
    /// every cache hit and coalesce join. The 64-bit fingerprint routes
    /// lookups, but FNV-style mixing is invertible, so an adversarial
    /// tenant could craft a colliding stream; equality on the actual
    /// contents (Arc identity fast path first) makes a collision cost a
    /// cache slot, never a wrong answer.
    pub fn equivalent(&self, other: &Query) -> bool {
        self.theta == other.theta
            && self.max_level == other.max_level
            && self.max_candidates_per_level == other.max_candidates_per_level
            && self.two_pass == other.two_pass
            && self.intervals == other.intervals
            && (Arc::ptr_eq(&self.stream, &other.stream) || *self.stream == *other.stream)
    }

    pub(crate) fn options(&self) -> MineOptions {
        MineOptions {
            theta: self.theta,
            intervals: self.intervals.clone(),
            max_level: self.max_level,
            max_candidates_per_level: self.max_candidates_per_level,
            // an execution knob, not a semantic parameter: results are
            // block-size-invariant, so it stays out of Query / QueryKey
            candidate_block: crate::session::DEFAULT_CANDIDATE_BLOCK,
        }
    }

    /// Canonical cache/coalescing identity of this query.
    pub fn key(&self) -> QueryKey {
        let mut h = Mix::new();
        h.u64(self.stream.n_types as u64);
        h.u64(self.stream.len() as u64);
        for (ty, t) in self.stream.iter() {
            h.u64(((ty as u32 as u64) << 32) | (t as u32 as u64));
        }
        h.u64(self.theta);
        h.u64(self.intervals.len() as u64);
        for iv in &self.intervals {
            h.i32(iv.t_low);
            h.i32(iv.t_high);
        }
        h.u64(self.max_level as u64);
        h.u64(self.max_candidates_per_level as u64);
        h.u64(self.two_pass as u64);
        QueryKey { fingerprint: h.0, events: self.stream.len(), theta: self.theta }
    }
}

/// A served connectivity-inference request: one mine config plus the
/// null-model knobs. Admission counts it as a single tenant job — one
/// queue slot, one cache entry — even though executing it fans out into
/// `1 + n_surrogates` internal mines (see
/// [`analysis::connectivity::infer_connectivity`]).
///
/// [`analysis::connectivity::infer_connectivity`]: crate::analysis::connectivity::infer_connectivity
#[derive(Clone, Debug)]
pub struct ConnectivityQuery {
    /// the mine every stream (real and surrogate) runs under
    pub mine: Query,
    /// null-model sample size; the p-value floor is `1/(n+1)`
    pub n_surrogates: usize,
    /// surrogate jitter half-width in ticks
    pub jitter: crate::events::Tick,
    /// surrogate RNG seed — same seed, same ranked graph
    pub seed: u64,
}

impl ConnectivityQuery {
    pub fn new(mine: Query, n_surrogates: usize, jitter: crate::events::Tick, seed: u64) -> Self {
        ConnectivityQuery { mine, n_surrogates, jitter, seed }
    }

    /// Admission-time validation: the shared mine invariants plus the
    /// surrogate knobs (the same checks the pipeline itself runs).
    pub fn validate(&self) -> Result<(), MineError> {
        self.mine.validate()?;
        crate::analysis::surrogate::validate(self.n_surrogates, self.jitter)
    }

    /// Exact semantic equality (collision-proofing, as for
    /// [`Query::equivalent`]).
    pub fn equivalent(&self, other: &ConnectivityQuery) -> bool {
        self.n_surrogates == other.n_surrogates
            && self.jitter == other.jitter
            && self.seed == other.seed
            && self.mine.equivalent(&other.mine)
    }

    /// Canonical identity. Extends the mine fingerprint with a kind
    /// discriminator and the surrogate knobs, so a connectivity query
    /// can never alias the plain mine of the same stream.
    pub fn key(&self) -> QueryKey {
        let base = self.mine.key();
        let mut h = Mix::new();
        h.u64(base.fingerprint);
        h.u64(KIND_CONNECTIVITY);
        h.u64(self.n_surrogates as u64);
        h.i32(self.jitter);
        h.u64(self.seed);
        QueryKey { fingerprint: h.0, events: base.events, theta: base.theta }
    }
}

/// Kind discriminator mixed into [`ConnectivityQuery::key`] (a plain
/// [`Query::key`] never mixes one, so the key spaces are disjoint even
/// for identical parameters).
const KIND_CONNECTIVITY: u64 = 0xC09A_EC71_11F3_0001;

/// The one typed request surface of [`MineService`]: every way of asking
/// the service for work is an arm here, admitted through the same
/// validation and dispatched at a single point
/// ([`MineService::request`]). The next query type — ROADMAP item 2's
/// batched device mine — is a new arm, not a parallel code path.
///
/// [`MineService`]: super::MineService
/// [`MineService::request`]: super::MineService::request
#[derive(Clone, Debug)]
pub enum Request {
    /// one mine of one stream → [`Ticket`](super::Ticket)
    Mine(Query),
    /// join a live update feed → [`Subscription`](super::Subscription)
    Subscribe(SubscribeQuery),
    /// surrogate-tested connectivity inference →
    /// [`ConnectivityTicket`](super::ConnectivityTicket)
    Connectivity(ConnectivityQuery),
}

impl Request {
    /// Shared admission validation — `MineOptions::validate` and the
    /// stream invariants for the mining arms, tenant/topic/buffer rules
    /// for subscriptions.
    pub fn validate(&self) -> Result<(), MineError> {
        match self {
            Request::Mine(q) => q.validate(),
            Request::Subscribe(s) => s.validate(),
            Request::Connectivity(c) => c.validate(),
        }
    }
}

/// A live-update subscription request: which tenant is asking, which
/// topic of [`CommitUpdate`](crate::stream::CommitUpdate)s they want
/// pushed, and how many undelivered updates may buffer before the oldest
/// is dropped (slow consumers lose history, never block the publisher).
///
/// Topics name incremental feeds — by convention the watched log
/// directory or load-generator scenario (e.g. `"logs/array7"`). A
/// subscription matches exactly one topic.
#[derive(Clone, Debug)]
pub struct SubscribeQuery {
    /// tenant identity, counted against
    /// [`ServiceConfig::max_subscriptions_per_tenant`](super::ServiceConfig)
    pub tenant: String,
    /// the update feed to join (exact match)
    pub topic: String,
    /// per-subscription buffer of undelivered updates (oldest dropped on
    /// overflow)
    pub buffer: usize,
}

impl SubscribeQuery {
    pub fn new(tenant: impl Into<String>, topic: impl Into<String>) -> SubscribeQuery {
        SubscribeQuery { tenant: tenant.into(), topic: topic.into(), buffer: 64 }
    }

    pub fn buffer(mut self, buffer: usize) -> SubscribeQuery {
        self.buffer = buffer;
        self
    }

    pub fn validate(&self) -> Result<(), MineError> {
        if self.tenant.is_empty() {
            return Err(MineError::invalid("SubscribeQuery::tenant must be non-empty"));
        }
        if self.topic.is_empty() {
            return Err(MineError::invalid("SubscribeQuery::topic must be non-empty"));
        }
        if self.buffer == 0 {
            return Err(MineError::invalid("SubscribeQuery::buffer must be >= 1"));
        }
        Ok(())
    }
}

/// The canonical query identity: a 64-bit fingerprint plus two cheap
/// fields carried verbatim, so a fingerprint collision must also match
/// stream length and theta before two distinct queries could alias. (A
/// full-byte comparison would need the streams resident; this is the
/// standard fingerprint-cache trade, and at 64+ bits the collision odds
/// are negligible for any realistic working set.)
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QueryKey {
    fingerprint: u64,
    events: usize,
    theta: u64,
}

impl QueryKey {
    /// The raw 64-bit fingerprint (cache shard selector).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }
}

/// FNV-1a-style 64-bit mix, folding a whole u64 word per step rather than
/// a byte — same xor-multiply structure, ~8x fewer multiplies, which keeps
/// keying a 100k-event stream well under a millisecond (the key is on the
/// cache-hit hot path).
struct Mix(u64);

impl Mix {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    fn new() -> Mix {
        Mix(Self::OFFSET)
    }

    #[inline]
    fn u64(&mut self, v: u64) {
        self.0 = (self.0 ^ v).wrapping_mul(Self::PRIME);
    }

    #[inline]
    fn i32(&mut self, v: i32) {
        self.u64(v as u32 as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Query {
        let stream = Arc::new(EventStream::from_pairs(
            vec![(0, 1), (1, 4), (2, 8), (0, 20), (1, 24)],
            3,
        ));
        Query::new(stream, 5, vec![Interval::new(0, 10)])
    }

    #[test]
    fn identical_queries_share_a_key() {
        assert_eq!(base().key(), base().key());
    }

    #[test]
    fn every_semantic_field_perturbs_the_key() {
        let k = base().key();

        let mut q = base();
        q.theta = 6;
        assert_ne!(q.key(), k, "theta");

        let mut q = base();
        q.intervals = vec![Interval::new(0, 11)];
        assert_ne!(q.key(), k, "interval");

        let q = base().max_level(3);
        assert_ne!(q.key(), k, "max_level");

        let q = base().one_pass();
        assert_ne!(q.key(), k, "mode");

        let mut q = base();
        q.max_candidates_per_level = 99;
        assert_ne!(q.key(), k, "cap");

        // one tick moved in the stream is a different stream
        let stream = Arc::new(EventStream::from_pairs(
            vec![(0, 1), (1, 4), (2, 9), (0, 20), (1, 24)],
            3,
        ));
        let q = Query::new(stream, 5, vec![Interval::new(0, 10)]);
        assert_ne!(q.key(), k, "stream tick");
    }

    #[test]
    fn equivalent_is_content_equality_not_arc_identity() {
        let a = base();
        let b = base(); // different Arc, identical contents
        assert!(a.equivalent(&b));
        let mut c = base();
        c.theta = 6;
        assert!(!a.equivalent(&c));
        let d = base().one_pass();
        assert!(!a.equivalent(&d));
    }

    #[test]
    fn validate_mirrors_session_builder() {
        assert!(base().validate().is_ok());

        let mut q = base();
        q.theta = 0;
        assert!(matches!(q.validate(), Err(MineError::InvalidConfig { .. })));

        let mut q = base();
        q.intervals.clear();
        assert!(matches!(q.validate(), Err(MineError::InvalidConfig { .. })));

        let q = base().max_level(0);
        assert!(matches!(q.validate(), Err(MineError::InvalidConfig { .. })));
    }

    #[test]
    fn connectivity_key_never_aliases_the_plain_mine() {
        let c = ConnectivityQuery::new(base(), 20, 10, 7);
        assert_ne!(c.key(), base().key());
        assert_eq!(c.key(), ConnectivityQuery::new(base(), 20, 10, 7).key());
        // every surrogate knob perturbs the key
        assert_ne!(ConnectivityQuery::new(base(), 21, 10, 7).key(), c.key());
        assert_ne!(ConnectivityQuery::new(base(), 20, 11, 7).key(), c.key());
        assert_ne!(ConnectivityQuery::new(base(), 20, 10, 8).key(), c.key());
        // so does the underlying mine
        assert_ne!(ConnectivityQuery::new(base().one_pass(), 20, 10, 7).key(), c.key());
    }

    #[test]
    fn connectivity_equivalence_and_validation() {
        let c = ConnectivityQuery::new(base(), 20, 10, 7);
        assert!(c.equivalent(&ConnectivityQuery::new(base(), 20, 10, 7)));
        assert!(!c.equivalent(&ConnectivityQuery::new(base(), 20, 10, 8)));
        assert!(c.validate().is_ok());
        assert!(ConnectivityQuery::new(base(), 0, 10, 7).validate().is_err());
        assert!(ConnectivityQuery::new(base(), 20, 0, 7).validate().is_err());
        let mut bad = base();
        bad.theta = 0;
        assert!(ConnectivityQuery::new(bad, 20, 10, 7).validate().is_err());
    }

    #[test]
    fn request_validate_dispatches_per_arm() {
        assert!(Request::Mine(base()).validate().is_ok());
        assert!(Request::Subscribe(SubscribeQuery::new("t", "topic")).validate().is_ok());
        assert!(Request::Connectivity(ConnectivityQuery::new(base(), 5, 5, 1)).validate().is_ok());
        let mut q = base();
        q.theta = 0;
        assert!(Request::Mine(q.clone()).validate().is_err());
        assert!(Request::Connectivity(ConnectivityQuery::new(q, 5, 5, 1)).validate().is_err());
        assert!(Request::Subscribe(SubscribeQuery::new("", "topic")).validate().is_err());
    }

    #[test]
    fn subscribe_query_validation() {
        assert!(SubscribeQuery::new("t1", "logs/a").validate().is_ok());
        assert!(SubscribeQuery::new("", "logs/a").validate().is_err());
        assert!(SubscribeQuery::new("t1", "").validate().is_err());
        assert!(SubscribeQuery::new("t1", "logs/a").buffer(0).validate().is_err());
    }

    #[test]
    fn validate_rejects_malformed_streams() {
        // out-of-alphabet event type: EventStream only debug_asserts its
        // invariant, so admission must catch what a client hand-built
        let mut stream = EventStream::new(2);
        stream.types = vec![0, 7];
        stream.times = vec![1, 5];
        let q = Query::new(Arc::new(stream), 1, vec![Interval::new(0, 4)]);
        assert!(matches!(
            q.validate(),
            Err(MineError::OutOfAlphabet { type_id: 7, n_types: 2 })
        ));

        let mut stream = EventStream::new(2);
        stream.types = vec![0, 1];
        stream.times = vec![9, 5]; // unsorted
        let q = Query::new(Arc::new(stream), 1, vec![Interval::new(0, 4)]);
        assert!(matches!(q.validate(), Err(MineError::InvalidConfig { .. })));
    }
}
